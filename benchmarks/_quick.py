"""Quick-mode switch for the benchmark harness.

`benchmarks/run.py --quick` (the CI smoke job) sets ``NDV_BENCH_QUICK=1``;
modules shrink their shapes through `pick()` so the whole suite exercises
every code path in seconds instead of minutes. Numbers from a quick run
characterize nothing — the mode exists to catch harness rot, not to
measure.
"""
from __future__ import annotations

import os


def quick() -> bool:
    return bool(os.environ.get("NDV_BENCH_QUICK"))


def pick(full, tiny):
    """`full` normally; `tiny` under --quick."""
    return tiny if quick() else full
