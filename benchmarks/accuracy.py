"""Paper Table 1 + §10.1 accuracy claims, measured on ground-truth data.

Produces the regime x estimator error grid:
  rows:   data layout regimes (well-spread uniform/zipf, sorted,
          partitioned, clustered, low-NDV)
  cols:   ndv_dict (paper §4), ndv_minmax (paper §5), hybrid (paper §7),
          improved (beyond-paper layout-aware aggregation)

plus the coverage sweep (error vs rows-per-group/ndv), the
row-group-count sweep (information content of the min/max signal), and
the q-error-by-route grid: ground-truth q-error grouped by the route the
estimator actually chose (dict vs minmax, from per-estimate provenance) —
the offline twin of the live `ndv_audit_qerror{route=}` series.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.columnar import column_metadata_from_footer, read_footer, write_file
from repro.columnar.generator import (
    clustered_column,
    int_domain,
    partitioned_column,
    sorted_column,
    string_domain,
    uniform_column,
    zipf_column,
)
from repro.columnar.writer import WriterOptions
from benchmarks._quick import pick
from repro.core import estimate_columns

ROWS = pick(1 << 17, 1 << 13)
RG = pick(8192, 512)


def _estimate_one(vals, mode, rg=RG, name="c"):
    tmp = tempfile.mkdtemp()
    write_file(os.path.join(tmp, "f"), {name: vals},
               options=WriterOptions(row_group_size=rg))
    footer = read_footer(os.path.join(tmp, "f"))
    meta = column_metadata_from_footer(footer, name)
    return estimate_columns([meta], mode=mode)[0]


def regime_grid(seed: int = 0) -> List[dict]:
    dom_i = int_domain(5000, seed=seed + 1)
    dom_s = string_domain(2000, seed=seed + 2, dist="uniform")
    regimes = {
        "uniform_int": uniform_column(dom_i, ROWS, seed=seed + 3),
        "zipf_str": zipf_column(dom_s, ROWS, seed=seed + 4),
        "sorted_int": sorted_column(dom_i, ROWS, seed=seed + 5),
        "partitioned_int": partitioned_column(dom_i, ROWS, seed=seed + 6),
        "clustered_int": clustered_column(dom_i, ROWS, mean_run=64, seed=seed + 7),
        "low_ndv_int": uniform_column(int_domain(16, seed=seed + 8), ROWS, seed=seed + 9),
    }
    rows = []
    for regime, (vals, truth) in regimes.items():
        rec: Dict[str, object] = {"regime": regime, "true_ndv": truth}
        for mode in ("paper", "improved"):
            e = _estimate_one(vals, mode)
            rec[f"{mode}_ndv"] = round(e.ndv, 1)
            rec[f"{mode}_err"] = round(abs(e.ndv - truth) / truth, 4)
            if mode == "paper":
                rec["dict_err"] = round(abs(e.ndv_dict - truth) / truth, 4)
                rec["minmax_err"] = round(abs(e.ndv_minmax - truth) / truth, 4)
                rec["layout"] = e.layout.name
        rows.append(rec)
    return rows


def coverage_sweep(seed: int = 0) -> List[dict]:
    """Error vs rows-per-group/NDV ratio (the well-spread coverage regime)."""
    out = []
    for ratio in (1, 2, 4, 8, 16):
        ndv = RG // ratio
        dom = int_domain(ndv, seed=seed + ratio)
        vals, truth = uniform_column(dom, ROWS, seed=seed + 10 + ratio)
        rec = {"rows_per_group_over_ndv": ratio, "true_ndv": truth}
        for mode in ("paper", "improved"):
            e = _estimate_one(vals, mode)
            rec[f"{mode}_err"] = round(abs(e.ndv - truth) / truth, 4)
        out.append(rec)
    return out


def rowgroup_sweep(seed: int = 0) -> List[dict]:
    """Sorted + clustered error vs number of row groups (signal content)."""
    out = []
    dom = int_domain(pick(4000, 400), seed=seed)
    # Row-group sizes scale with ROWS: n_groups = 4, 16, 64, 256 either way.
    for rg_size in (ROWS // 4, ROWS // 16, ROWS // 64, ROWS // 256):
        n_groups = ROWS // rg_size
        svals, struth = sorted_column(dom, ROWS, seed=seed + 1)
        cvals, ctruth = clustered_column(dom, ROWS, mean_run=64, seed=seed + 2)
        rec = {"row_groups": n_groups}
        for name, vals, truth in (("sorted", svals, struth),
                                  ("clustered", cvals, ctruth)):
            for mode in ("paper", "improved"):
                e = _estimate_one(vals, mode, rg=rg_size)
                rec[f"{name}_{mode}_err"] = round(abs(e.ndv - truth) / truth, 4)
        out.append(rec)
    return out


def heavy_tail_length_bias(seed: int = 0) -> List[dict]:
    """Eq 4 limitation: heavy-tailed value lengths bias len low.

    Uniform FREQUENCIES isolate the length effect (zipf frequencies would
    confound it with the coverage-correction skew limitation)."""
    out = []
    for dist in ("uniform", "geometric"):
        dom = string_domain(1500, seed=seed + 3, dist=dist)
        vals, truth = uniform_column(dom, ROWS, seed=seed + 4)
        rec = {"length_dist": dist, "true_ndv": truth}
        for mode in ("paper", "improved"):
            e = _estimate_one(vals, mode)
            rec[f"{mode}_err"] = round(abs(e.ndv - truth) / truth, 4)
            rec[f"{mode}_len_sample"] = e.len_sample_size
        out.append(rec)
    return out


def qerror_by_route(seed: int = 0) -> List[dict]:
    """Ground-truth q-error grouped by the provenance-reported route.

    Re-runs the regime-grid datasets through the engine's explained call
    (one run yields estimates + provenance, bit-identical to the plain
    call) and buckets per-column q-error by which estimator won. Answers
    the routing question the live audit loop samples in production: when
    the router picks `dict` (or `minmax`), how wrong is it?
    """
    from repro.engine import default_engine

    dom_i = int_domain(5000, seed=seed + 1)
    dom_s = string_domain(2000, seed=seed + 2, dist="uniform")
    regimes = {
        "uniform_int": uniform_column(dom_i, ROWS, seed=seed + 3),
        "zipf_str": zipf_column(dom_s, ROWS, seed=seed + 4),
        "sorted_int": sorted_column(dom_i, ROWS, seed=seed + 5),
        "partitioned_int": partitioned_column(dom_i, ROWS, seed=seed + 6),
        "clustered_int": clustered_column(dom_i, ROWS, mean_run=64, seed=seed + 7),
        "low_ndv_int": uniform_column(int_domain(16, seed=seed + 8), ROWS, seed=seed + 9),
    }
    engine = default_engine()
    by_route: Dict[tuple, List[float]] = {}
    for regime, (vals, truth) in regimes.items():
        tmp = tempfile.mkdtemp()
        write_file(os.path.join(tmp, "f"), {"c": vals},
                   options=WriterOptions(row_group_size=RG))
        footer = read_footer(os.path.join(tmp, "f"))
        meta = column_metadata_from_footer(footer, "c")
        for mode in ("paper", "improved"):
            ests, provs = engine.estimate_columns_explained([meta], mode=mode)
            est = float(ests[0].ndv)
            q = max(est / truth, truth / est) if est > 0 else float("inf")
            by_route.setdefault((mode, provs[0].route), []).append(q)
    return [
        {
            "mode": mode, "route": route, "columns": len(qs),
            "mean_qerror": round(sum(qs) / len(qs), 4),
            "max_qerror": round(max(qs), 4),
        }
        for (mode, route), qs in sorted(by_route.items())
    ]


def run() -> List[tuple]:
    t0 = time.time()
    grid = regime_grid()
    cov = coverage_sweep()
    rgs = rowgroup_sweep()
    tails = heavy_tail_length_bias()
    routes = qerror_by_route()
    dt = (time.time() - t0) * 1e6
    rows = []
    for r in grid:
        rows.append((
            f"accuracy/{r['regime']}", dt / (len(grid) + 10),
            f"paper_err={r['paper_err']};improved_err={r['improved_err']};"
            f"dict_err={r['dict_err']};minmax_err={r['minmax_err']};layout={r['layout']}",
        ))
    for r in cov:
        rows.append((
            f"coverage/ratio_{r['rows_per_group_over_ndv']}", 0.0,
            f"paper_err={r['paper_err']};improved_err={r['improved_err']}",
        ))
    for r in rgs:
        rows.append((
            f"rowgroups/{r['row_groups']}", 0.0,
            ";".join(f"{k}={v}" for k, v in r.items() if k != "row_groups"),
        ))
    for r in tails:
        rows.append((
            f"len_bias/{r['length_dist']}", 0.0,
            f"paper_err={r['paper_err']};improved_err={r['improved_err']};"
            f"len_sample={r['paper_len_sample']}",
        ))
    for r in routes:
        rows.append((
            f"qerror_by_route/{r['mode']}_{r['route']}", 0.0,
            f"columns={r['columns']};mean_qerror={r['mean_qerror']};"
            f"max_qerror={r['max_qerror']}",
        ))
    return rows
