"""Zero-cost estimator vs data-access baselines (paper §11 positioning).

Compares accuracy AND cost (bytes read / time) of:
  metadata (paper, zero data access)  vs  HLL / CVM / sampling / exact.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import List

import numpy as np

from repro.columnar import DataReader, column_metadata_from_footer, read_footer, write_file
from repro.columnar.generator import int_domain, uniform_column, zipf_column
from repro.columnar.writer import WriterOptions
from benchmarks._quick import pick
from repro.core import estimate_columns
from repro.core.baselines import cvm_ndv, exact_ndv, hll_ndv, sampling_ndv

ROWS = pick(1 << 17, 1 << 13)


def run() -> List[tuple]:
    dom = int_domain(pick(20000, 2000), seed=1)
    vals, truth = zipf_column(dom, ROWS, s=1.1, seed=2)
    tmp = tempfile.mkdtemp()
    write_file(os.path.join(tmp, "f"), {"c": vals},
               options=WriterOptions(row_group_size=pick(8192, 512)))
    footer = read_footer(os.path.join(tmp, "f"))
    meta = column_metadata_from_footer(footer, "c")
    data_bytes = int(np.asarray(vals).nbytes)

    rows = []

    t0 = time.perf_counter()
    est = estimate_columns([meta], mode="improved")[0].ndv
    t_meta = (time.perf_counter() - t0) * 1e6
    rows.append(("baseline/metadata_improved", t_meta,
                 f"err={abs(est-truth)/truth:.4f};bytes_read=0"))

    t0 = time.perf_counter()
    est_p = estimate_columns([meta], mode="paper")[0].ndv
    rows.append(("baseline/metadata_paper", (time.perf_counter()-t0)*1e6,
                 f"err={abs(est_p-truth)/truth:.4f};bytes_read=0"))

    reader = DataReader(os.path.join(tmp, "f"))
    col = reader.non_null_values("c")

    t0 = time.perf_counter()
    h = hll_ndv(col, p=12)
    rows.append(("baseline/hll_p12", (time.perf_counter()-t0)*1e6,
                 f"err={abs(h-truth)/truth:.4f};bytes_read={data_bytes}"))

    sub = min(1 << 15, len(col))
    t0 = time.perf_counter()
    c = cvm_ndv(col[:sub], buffer_size=pick(4096, 512))  # CVM is python-slow; subset
    sub_truth = exact_ndv(col[:sub])
    rows.append(("baseline/cvm_32k_rows", (time.perf_counter()-t0)*1e6,
                 f"err={abs(c-sub_truth)/sub_truth:.4f};bytes_read={sub*8}"))

    for frac in (0.01, 0.1):
        t0 = time.perf_counter()
        s, n = sampling_ndv(col, frac=frac, method="gee")
        rows.append((f"baseline/sample_gee_{frac}", (time.perf_counter()-t0)*1e6,
                     f"err={abs(s-truth)/truth:.4f};bytes_read={n*8}"))

    t0 = time.perf_counter()
    ex = exact_ndv(col)
    rows.append(("baseline/exact", (time.perf_counter()-t0)*1e6,
                 f"err=0.0;bytes_read={data_bytes}"))
    return rows
