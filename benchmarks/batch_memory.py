"""Paper §8: batch dictionary-memory prediction vs measured batch dictionaries.

For each layout, split the column into B-byte batches, measure each batch's
actual distinct-value dictionary bytes, and compare with Eq 16's prediction
from the (metadata-only) global NDV estimate.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import List

import numpy as np

from repro.columnar import column_metadata_from_footer, read_footer, write_file
from repro.columnar.generator import int_domain, sorted_column, uniform_column, zipf_column
from repro.columnar.writer import WriterOptions
from benchmarks._quick import pick
from repro.core import estimate_columns
from repro.core.ndv.batch_memory import predict_batch_memory

ROWS = pick(1 << 17, 1 << 13)
VALUE_LEN = 8  # int64


def _measure(vals: np.ndarray, batch_bytes: int) -> float:
    rows_per_batch = batch_bytes // VALUE_LEN
    sizes = []
    for i in range(0, len(vals), rows_per_batch):
        chunk = vals[i: i + rows_per_batch]
        if len(chunk) < rows_per_batch // 2:
            continue
        sizes.append(np.unique(chunk).size * VALUE_LEN)
    return float(np.mean(sizes))


def run() -> List[tuple]:
    batch_bytes = pick(64 * 1024, 4 * 1024)
    dom = int_domain(pick(5000, 500), seed=3)
    cases = {
        "uniform": uniform_column(dom, ROWS, seed=4),
        "zipf": zipf_column(dom, ROWS, seed=5),
        "sorted": sorted_column(dom, ROWS, seed=6),
    }
    rows = []
    for name, (vals, truth) in cases.items():
        tmp = tempfile.mkdtemp()
        write_file(os.path.join(tmp, "f"), {"c": vals},
                   options=WriterOptions(row_group_size=pick(8192, 512)))
        meta = column_metadata_from_footer(read_footer(os.path.join(tmp, "f")), "c")
        t0 = time.perf_counter()
        est = estimate_columns([meta], mode="improved")[0]
        bm = predict_batch_memory(
            np.asarray([est.ndv], np.float32),
            np.asarray([VALUE_LEN], np.float32),
            np.asarray([float(len(vals))], np.float32),
            float(batch_bytes),
            layout=np.asarray([int(est.layout)], np.int32),
        )
        dt = (time.perf_counter() - t0) * 1e6
        predicted = float(bm.d_batch[0])
        measured = _measure(vals, batch_bytes)
        err = abs(predicted - measured) / measured
        rows.append((
            f"batch_memory/{name}", dt,
            f"predicted={predicted:.0f};measured={measured:.0f};err={err:.4f};"
            f"layout={est.layout.name}",
        ))
    return rows
