"""Catalog-scale behavior: cold vs warm vs incremental estimation latency,
and jit retrace counts under shape bucketing.

What a fleet cares about (ROADMAP north star) is not one estimate call but
the steady state: footers arrive continuously, most estimate() calls hit a
warm catalog, and the jit cache must not grow with the number of distinct
dataset shapes. Four measurements:

  catalog/cold         first estimate(): footer scan + merge + pack + trace
  catalog/warm         same fingerprint set: pure cache hit (no pack/trace)
  catalog/incremental  one new shard arrives: update() re-reads ONLY the new
                       footer and re-merges incrementally, then estimates
  catalog/retraces     estimate_batch traces consumed by R=1..MAX_R datasets
                       through the bucketing packer vs the naive one-shape-
                       per-dataset count
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import List

import numpy as np

from benchmarks._quick import pick
from repro.catalog import BatchPacker, StatsCatalog
from repro.core.ndv.estimator import estimate_batch
from repro.core.ndv.types import ColumnMetadata, PhysicalType
from repro.data.pipeline import synthesize_token_dataset

NUM_SHARDS = pick(6, 3)
ROWS_PER_SHARD = pick(1 << 12, 1 << 10)
ROW_GROUP = pick(512, 256)
MAX_R = pick(12, 6)


def _write_shard(root: str, index: int) -> None:
    """Append one shard with the same schema synthesize_token_dataset uses."""
    from repro.columnar.generator import int_domain, zipf_column  # noqa: F401
    from repro.columnar.writer import WriterOptions, write_file

    dom = np.arange(2048, dtype=np.int64)
    toks, _ = zipf_column(dom, ROWS_PER_SHARD, s=1.1, seed=index)
    doc_id = np.repeat(
        np.arange(ROWS_PER_SHARD // ROW_GROUP + 1), ROW_GROUP
    )[:ROWS_PER_SHARD]
    write_file(
        os.path.join(root, f"shard_{index:05d}"),
        {"tokens": toks, "doc_id": doc_id.astype(np.int64)},
        options=WriterOptions(row_group_size=ROW_GROUP),
    )


def _synthetic_column(r: int, seed: int) -> ColumnMetadata:
    """Metadata-only synthetic column with r row groups (no file IO)."""
    rng = np.random.default_rng(seed)
    rows = np.full(r, 1000.0)
    mins = np.sort(rng.integers(0, 1 << 16, r).astype(np.float64))
    maxs = mins + rng.integers(100, 5000, r).astype(np.float64)
    return ColumnMetadata(
        chunk_sizes=rng.uniform(2_000.0, 9_000.0, r),
        chunk_rows=rows,
        chunk_nulls=np.zeros(r),
        chunk_dict_encoded=np.ones(r, bool),
        mins=mins,
        maxs=maxs,
        min_lengths=np.full(r, 8.0),
        max_lengths=np.full(r, 8.0),
        distinct_min_count=float(np.unique(mins).size),
        distinct_max_count=float(np.unique(maxs).size),
        physical_type=PhysicalType.INT64,
        column_name=f"synthetic_{seed}",
    )


def run() -> List[tuple]:
    rows: List[tuple] = []
    root = tempfile.mkdtemp()
    synthesize_token_dataset(
        root,
        vocab_size=2048,
        num_shards=NUM_SHARDS,
        rows_per_shard=ROWS_PER_SHARD,
        row_group_size=ROW_GROUP,
    )

    catalog = StatsCatalog(root)
    t0 = time.perf_counter()
    cold = catalog.estimate(mode="improved")
    cold_us = (time.perf_counter() - t0) * 1e6
    rows.append((
        "catalog/cold", cold_us,
        f"files={catalog.num_files};cols={len(cold)};"
        f"footers_read={catalog.stats.footers_read};packs={catalog.stats.packs}",
    ))

    t0 = time.perf_counter()
    warm = catalog.estimate(mode="improved")
    warm_us = (time.perf_counter() - t0) * 1e6
    assert catalog.stats.packs == 1, "warm call must not re-pack"
    assert warm.keys() == cold.keys()
    rows.append((
        "catalog/warm", warm_us,
        f"hits={catalog.stats.estimate_cache_hits};"
        f"packs={catalog.stats.packs};speedup={cold_us / max(warm_us, 1e-9):.0f}x",
    ))

    reads_before = catalog.stats.footers_read
    _write_shard(root, NUM_SHARDS)
    # only the new shard's footer is ingested; the other fingerprints match
    t0 = time.perf_counter()
    summary = catalog.update()
    catalog.estimate(mode="improved")
    incr_us = (time.perf_counter() - t0) * 1e6
    rows.append((
        "catalog/incremental", incr_us,
        f"added={summary.added};updated={summary.updated};"
        f"footers_read={catalog.stats.footers_read - reads_before};"
        f"files={catalog.num_files}",
    ))

    # -- retrace count: O(log R) shapes across MAX_R distinct datasets ------
    packer = BatchPacker()
    before = estimate_batch._cache_size()
    bucketed_shapes = set()
    for r in range(1, MAX_R + 1):
        cols = [_synthetic_column(r, seed=100 * r + i) for i in range(4)]
        batch = packer.pack(cols)
        bucketed_shapes.add((batch.batch, batch.max_groups))
        estimate_batch(batch, mode="paper")
    traced = estimate_batch._cache_size() - before
    rows.append((
        "catalog/retraces", 0.0,
        f"datasets={MAX_R};naive_shapes={MAX_R};"
        f"bucketed_shapes={len(bucketed_shapes)};traces={traced}",
    ))
    assert traced <= len(bucketed_shapes) <= int(np.log2(MAX_R)) + 2
    return rows
