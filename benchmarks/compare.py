"""Diff two benchmark JSON artifacts; fail on latency regressions.

Usage:
    python benchmarks/compare.py BASE.json NEW.json [--threshold 0.2]

Rows are matched by ``name``; a row regresses when its ``us_per_call``
grows by more than ``threshold`` (fractional — 0.2 means +20%) over the
base. Exit status is nonzero iff at least one matched row regresses, so
the script can gate CI directly:

    python benchmarks/run.py --quick --json BENCH_new.json
    python benchmarks/compare.py BENCH_6.json BENCH_new.json

Rows present in only one file are listed in a dedicated "unmatched"
section — with their timings, so a renamed or dropped benchmark is
visible rather than silently excluded — but never fail the run (the
benchmark surface legitimately grows across PRs). Rows measuring
effectively nothing (< 1 us on either side) are skipped — at that scale
the timer jitter dwarfs any signal. Quick-mode artifacts compare fine
against each other but a quick-vs-full comparison is refused: the shapes
differ, so every ratio would be noise.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Tuple

MIN_US = 1.0  # rows faster than this are all timer jitter


def load_rows(path: str) -> Tuple[Dict[str, float], bool]:
    """BENCH file -> ({row name: us_per_call}, quick-mode flag)."""
    with open(path) as f:
        payload = json.load(f)
    rows = {r["name"]: float(r["us_per_call"]) for r in payload["rows"]}
    return rows, bool(payload.get("quick"))


def compare(
    base: Dict[str, float], new: Dict[str, float], threshold: float
) -> Tuple[List[tuple], List[tuple], List[str], List[str]]:
    """-> (regressions, improvements, only_in_base, only_in_new).

    Regressions/improvements are (name, base_us, new_us, ratio) for rows
    past the threshold in either direction; ratio is new/base.
    """
    regressions, improvements = [], []
    for name in sorted(set(base) & set(new)):
        b, n = base[name], new[name]
        if b < MIN_US or n < MIN_US:
            continue
        ratio = n / b
        if ratio > 1.0 + threshold:
            regressions.append((name, b, n, ratio))
        elif ratio < 1.0 - threshold:
            improvements.append((name, b, n, ratio))
    only_base = sorted(set(base) - set(new))
    only_new = sorted(set(new) - set(base))
    return regressions, improvements, only_base, only_new


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    threshold = 0.2
    if "--threshold" in argv:
        i = argv.index("--threshold")
        try:
            threshold = float(argv[i + 1])
        except (IndexError, ValueError):
            print("--threshold requires a fractional number (e.g. 0.2)",
                  file=sys.stderr)
            return 2
        del argv[i : i + 2]
    if len(argv) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    base_path, new_path = argv

    base, base_quick = load_rows(base_path)
    new, new_quick = load_rows(new_path)
    if base_quick != new_quick:
        print(
            f"refusing to compare a quick-mode artifact against a full one "
            f"({base_path}: quick={base_quick}, {new_path}: quick={new_quick})",
            file=sys.stderr,
        )
        return 2

    regressions, improvements, only_base, only_new = compare(
        base, new, threshold
    )
    for name, b, n, ratio in regressions:
        print(f"REGRESSION {name}: {b:.1f}us -> {n:.1f}us ({ratio:.2f}x)")
    for name, b, n, ratio in improvements:
        print(f"improvement {name}: {b:.1f}us -> {n:.1f}us ({ratio:.2f}x)")
    if only_base or only_new:
        # A vanished row is as loud as a regressed one: it usually means a
        # benchmark was renamed or silently dropped, and the gate above
        # would otherwise skip it without a trace.
        print(f"unmatched rows ({len(only_base) + len(only_new)} — "
              f"compared in neither direction):")
        for name in only_base:
            print(f"  only in {base_path}: {name} ({base[name]:.1f}us)")
        for name in only_new:
            print(f"  only in {new_path}: {name} ({new[name]:.1f}us)")
    compared = len(set(base) & set(new))
    print(
        f"{compared} rows compared at threshold +{threshold:.0%}: "
        f"{len(regressions)} regressed, {len(improvements)} improved, "
        f"{len(only_base) + len(only_new)} unmatched"
    )
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
