"""Paper §10.2 complexity table: single-pass O(n) metadata operations.

Measures wall time of each operation vs number of row groups n, verifying
the O(n) (and O(1) for inversion) scaling claims, plus fleet-scale batched
throughput (columns/second) of the full estimator.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._quick import pick
from repro.core.ndv import dict_inversion, distribution, minmax_diversity
from repro.core.ndv.estimator import estimate_batch
from repro.core.ndv.types import ColumnBatch


def _fake_batch(b: int, r: int, seed: int = 0) -> ColumnBatch:
    rng = np.random.default_rng(seed)
    ndv = rng.integers(10, 100000, (b, 1)).astype(np.float32)
    rows = np.full((b, r), 8192.0, np.float32)
    bits = np.maximum(np.ceil(np.log2(ndv) - 1e-9), 1)
    S = ndv * 8.0 + rows * bits / 8.0
    mins = np.sort(rng.normal(size=(b, r)).astype(np.float32), axis=1)
    maxs = mins + 0.1
    J = jnp.asarray
    return ColumnBatch(
        chunk_S=J(S.astype(np.float32)), chunk_rows=J(rows),
        chunk_nulls=J(np.zeros((b, r), np.float32)),
        chunk_dict_encoded=J(np.ones((b, r), bool)),
        N=J(rows.sum(1)), nulls=J(np.zeros(b, np.float32)),
        n_groups=J(np.full(b, r, np.int32)),
        mins=J(mins), maxs=J(maxs), valid=J(np.ones((b, r), bool)),
        m_min=J(rng.integers(1, r, b).astype(np.float32)),
        m_max=J(rng.integers(1, r, b).astype(np.float32)),
        mean_len=J(np.full(b, 8.0, np.float32)),
        len_sample=J(np.full(b, 2 * r, np.int32)),
        fixed_width=J(np.ones(b, bool)), int_like=J(np.zeros(b, bool)),
        single_byte=J(np.zeros(b, bool)),
    )


def _timeit(fn, *args, iters=5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[tuple]:
    rows: List[tuple] = []
    b = pick(256, 32)
    for r in pick((16, 64, 256, 1024), (16, 64)):
        batch = _fake_batch(b, r)
        us = _timeit(lambda bt: estimate_batch(bt, mode="paper"), batch)
        rows.append((f"complexity/estimate_batch_r{r}", us,
                     f"cols={b};row_groups={r};us_per_col={us/b:.2f}"))
    # O(1)-in-n inversion (flat batched solves)
    for m in pick((1 << 10, 1 << 14, 1 << 18), (1 << 10,)):
        s = jnp.full((m,), 1e5, jnp.float32)
        rws = jnp.full((m,), 1e6, jnp.float32)
        z = jnp.zeros((m,), jnp.float32)
        ln = jnp.full((m,), 8.0, jnp.float32)
        us = _timeit(
            lambda a, b_, c, d: dict_inversion.invert_dict_size(a, b_, c, d).ndv,
            s, rws, z, ln,
        )
        rows.append((f"complexity/dict_newton_m{m}", us,
                     f"solves={m};ns_per_solve={us*1e3/m:.1f}"))
    # detector O(n)
    for r in pick((64, 512, 4096), (64, 512)):
        batch = _fake_batch(64, r)
        us = _timeit(
            lambda mn, mx, v: distribution.detect_distribution(mn, mx, v),
            batch.mins, batch.maxs, batch.valid,
        )
        rows.append((f"complexity/detector_r{r}", us, f"cols=64;row_groups={r}"))
    # fleet throughput
    fleet_b = pick(4096, 256)
    batch = _fake_batch(fleet_b, 64)
    us = _timeit(lambda bt: estimate_batch(bt, mode="improved"), batch)
    rows.append((f"complexity/fleet_{fleet_b}cols", us,
                 f"cols_per_s={fleet_b/(us/1e6):.0f}"))
    return rows
