"""Engine-strategy throughput: local/sharded/chunked/composed over width.

The estimators are embarrassingly parallel over columns, so the interesting
axis is B — how wide a merged column set one `estimate()` call can serve.
For each width (including one wider than the chunk budget) the four
`EstimationEngine` strategies run over identical packed batches; `derived`
records columns/second plus the resolved shard count / chunk count so a
single-device CPU run (shards=1) is distinguishable from a real mesh. The
composed column reports super-chunk dispatches (each `shards * budget`
lanes wide), the working-set shape that lets a mesh of small devices
stream a catalog wider than any one device's memory.

Metadata is synthesized directly (no file IO): this measures the execution
seam, not ingestion.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from benchmarks._quick import pick
from repro.core.ndv.types import ColumnMetadata, PhysicalType
from repro.engine import EngineConfig, EstimationEngine, composed_plan

ROW_GROUPS = 8


def _columns(b: int, seed: int = 0) -> List[ColumnMetadata]:
    rng = np.random.default_rng(seed)
    out = []
    for i in range(b):
        r = ROW_GROUPS
        ndv = float(rng.integers(16, 1 << 16))
        rows = np.full(r, 8192.0)
        bits = max(np.ceil(np.log2(ndv)), 1.0)
        mins = np.sort(rng.uniform(0, 1e6, r))
        out.append(ColumnMetadata(
            chunk_sizes=np.full(r, ndv * 8.0 + 8192.0 * bits / 8.0),
            chunk_rows=rows,
            chunk_nulls=np.zeros(r),
            chunk_dict_encoded=np.ones(r, bool),
            mins=mins,
            maxs=mins + rng.uniform(1e4, 1e5, r),
            min_lengths=np.full(r, 8.0),
            max_lengths=np.full(r, 8.0),
            distinct_min_count=float(r - 1),
            distinct_max_count=float(r),
            physical_type=PhysicalType.INT64,
            column_name=f"col_{i}",
        ))
    return out


def _timeit(fn, iters=3) -> float:
    jax.block_until_ready(fn())  # warm: trace + compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[tuple]:
    # One width beyond the chunk budget so the chunked path actually splits.
    budget = pick(1024, 64)
    widths = pick((512, 2048, 8192), (32, 128, 256))
    rows: List[tuple] = []
    for width in widths:
        cols = _columns(width)
        for strategy in ("local", "sharded", "chunked", "composed"):
            eng = EstimationEngine(
                EngineConfig(strategy=strategy, max_batch=budget)
            )
            batch = eng.make_packer().pack(cols)
            resolved = eng.resolve_strategy(batch.batch)
            us = _timeit(
                lambda e=eng, bt=batch: e.estimate(bt, mode="improved").ndv
            )
            if resolved == "chunked":
                chunks = -(-batch.batch // budget)
            elif resolved == "composed":
                chunks = len(
                    composed_plan(batch.batch, eng.shard_count, budget)[1]
                )
            else:
                chunks = 1
            rows.append((
                f"engine_scale/{strategy}/B{width}", us,
                f"cols_per_s={width / (us / 1e6):.0f};"
                f"packed_B={batch.batch};shards={eng.shard_count};"
                f"chunks={chunks};budget={budget}",
            ))
    return rows
