"""Fleet-tier latency: routing overhead, failover cost, cross-replica warmth.

What a planner pays for the fleet tier over a direct per-dataset server:

  fleet/direct_warm    warm /estimate against one StatsServer (baseline)
  fleet/routed_warm    the same request through the router (placement +
                       passthrough overhead on top of the baseline)
  fleet/routed_304     revalidation through the router — the fleet's hot
                       path (zero engine work on the replica, asserted)
  fleet/failover       latency of the first request after the placed
                       replica is killed mid-run (ejection + retry on the
                       survivor; asserts the ETag survives the failover)
  fleet/warm_start     first /estimate of a freshly constructed replica
                       over an already-spilled dataset — served from the
                       shared estimate-cache spill with zero engine packs
                       (asserted)
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import List

import numpy as np

from benchmarks._quick import pick
from repro.engine import EngineConfig
from repro.fleet import (
    DatasetRegistry,
    Fleet,
    LocalReplica,
    StatsRequest,
    StatsRouter,
)
from repro.service import StatsServer, StatsService, fetch_json

NUM_DATASETS = 2
NUM_REPLICAS = 2
NUM_SHARDS = pick(4, 2)
ROWS_PER_SHARD = pick(1 << 12, 1 << 10)
WARM_REQS = pick(100, 5)


def _write_dataset(root: str, seed: int) -> str:
    from repro.columnar.writer import WriterOptions, write_file

    rng = np.random.default_rng(seed)
    for i in range(NUM_SHARDS):
        write_file(
            os.path.join(root, f"shard_{i:04d}"),
            {
                "tok": rng.integers(0, 1024, ROWS_PER_SHARD).astype(np.int64),
                "val": np.round(rng.uniform(0, 100, ROWS_PER_SHARD), 1),
            },
            options=WriterOptions(row_group_size=512),
        )
    return root


def _time_requests(url: str, n: int, etag=None) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fetch_json(url, etag=etag)
    return (time.perf_counter() - t0) * 1e6 / n


def run() -> List[tuple]:
    rows: List[tuple] = []
    base = tempfile.mkdtemp()
    cfg = EngineConfig()
    registry = DatasetRegistry()
    for i in range(NUM_DATASETS):
        root = _write_dataset(os.path.join(base, f"ds{i}"), seed=i)
        registry.add("bench", f"ds{i}", root, engine_config=cfg)

    # direct baseline: one StatsServer over dataset 0 (its own root copy —
    # a separate spill-free service so the fleet's caches are not shared)
    direct_root = _write_dataset(os.path.join(base, "direct"), seed=0)
    with StatsServer(StatsService(direct_root)) as direct:
        url = direct.url + "/estimate?mode=improved"
        fetch_json(url)  # cold: pack + engine run, excluded from the mean
        direct_us = _time_requests(url, WARM_REQS)
        rows.append((
            "fleet/direct_warm", direct_us, f"reqs={WARM_REQS};replicas=1",
        ))

    with StatsRouter(Fleet(registry, replicas_per_dataset=NUM_REPLICAS)) as router:
        url = router.url_for("bench", "ds0", "estimate") + "?mode=improved"
        status, etag, _ = fetch_json(url)  # cold
        assert status == 200 and etag
        routed_us = _time_requests(url, WARM_REQS)
        rows.append((
            "fleet/routed_warm", routed_us,
            f"reqs={WARM_REQS};replicas={NUM_REPLICAS};"
            f"overhead={routed_us - direct_us:.0f}us",
        ))

        rev_us = _time_requests(url, WARM_REQS, etag=etag)
        status304, _, _ = fetch_json(url, etag=etag)
        assert status304 == 304
        rows.append((
            "fleet/routed_304", rev_us,
            f"reqs={WARM_REQS};vs_warm={routed_us / max(rev_us, 1e-9):.1f}x",
        ))

        # failover: kill the replica that owns this placement, time the
        # next request (ejection + retry), assert the ETag survived
        rset = router.fleet.sets["bench/ds0"]
        victim = rset.rank(StatsRequest("estimate", "improved").identity)[0]
        victim.kill()
        t0 = time.perf_counter()
        status, etag_after, _ = fetch_json(url)
        failover_us = (time.perf_counter() - t0) * 1e6
        assert status == 200 and etag_after == etag
        assert rset.failovers >= 1
        rows.append((
            "fleet/failover", failover_us,
            f"failovers={rset.failovers};etag_stable=1",
        ))

        # cross-replica warm start: a brand-new replica over the spilled
        # dataset serves its first estimate with zero engine packs
        t0 = time.perf_counter()
        fresh = LocalReplica(
            "bench/ds0#fresh", registry.get("bench", "ds0").root,
            engine_config=cfg,
        ).start()
        try:
            resp = fresh.handle(StatsRequest("estimate", "improved"))
            warm_start_us = (time.perf_counter() - t0) * 1e6
            assert resp.status == 200 and resp.etag == etag
            packs = fresh.service.catalog.stats.packs
            assert packs == 0, f"expected spill hit, got {packs} packs"
        finally:
            fresh.stop()
        rows.append((
            "fleet/warm_start", warm_start_us,
            f"packs=0;spill_entries>=1",
        ))
    return rows
