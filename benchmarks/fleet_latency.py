"""Fleet-tier latency: routing overhead, failover cost, cross-replica warmth.

What a planner pays for the fleet tier over a direct per-dataset server:

  fleet/direct_warm    warm /estimate against one StatsServer (baseline)
  fleet/routed_warm    the same request through the router (placement +
                       passthrough overhead on top of the baseline)
  fleet/routed_304     revalidation through the router — the fleet's hot
                       path (zero engine work on the replica, asserted)
  fleet/failover       latency of the first request after the placed
                       replica is killed mid-run (ejection + retry on the
                       survivor; asserts the ETag survives the failover)
  fleet/warm_start     first /estimate of a freshly constructed replica
                       over an already-spilled dataset — served from the
                       shared estimate-cache spill with zero engine packs
                       (asserted)

Batched RPC + wire protocol (per-tuple / per-call microseconds):

  fleet/seq_warm_json     N warm estimates as N sequential JSON /estimate
                          requests through the router (fresh connection
                          each — the pre-batch client behavior)
  fleet/batch_warm_binary the same N tuples as ONE binary POST /batch over
                          a pooled keep-alive connection (asserts >=3x
                          faster per tuple than the sequential row outside
                          --quick)
  fleet/batch_cold        one cold batch of distinct-bounds tuples —
                          asserts exactly ONE engine dispatch and ONE pack
                          for the whole replica sub-batch
  wire/encode, wire/decode  binary codec throughput on a real /estimate
                          body (derived: size vs JSON)
  wire/conn_reuse vs wire/conn_fresh  pooled keep-alive GET vs a fresh
                          TCP connection per request (urllib)
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import List

import numpy as np

from benchmarks._quick import pick, quick
from repro.engine import EngineConfig
from repro.fleet import (
    DatasetRegistry,
    Fleet,
    LocalReplica,
    StatsRequest,
    StatsRouter,
)
from repro.service import StatsServer, StatsService, fetch_json
from repro.wire import ConnectionPool, decode_frame, encode_frame, fetch

NUM_DATASETS = 2
NUM_REPLICAS = 2
NUM_SHARDS = pick(4, 2)
ROWS_PER_SHARD = pick(1 << 12, 1 << 10)
WARM_REQS = pick(100, 5)
BATCH_N = pick(64, 8)
CODEC_REPS = pick(2000, 50)
POOL_REQS = pick(200, 10)


def _write_dataset(root: str, seed: int) -> str:
    from repro.columnar.writer import WriterOptions, write_file

    rng = np.random.default_rng(seed)
    for i in range(NUM_SHARDS):
        write_file(
            os.path.join(root, f"shard_{i:04d}"),
            {
                "tok": rng.integers(0, 1024, ROWS_PER_SHARD).astype(np.int64),
                "val": np.round(rng.uniform(0, 100, ROWS_PER_SHARD), 1),
            },
            options=WriterOptions(row_group_size=512),
        )
    return root


def _time_requests(url: str, n: int, etag=None) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fetch_json(url, etag=etag)
    return (time.perf_counter() - t0) * 1e6 / n


def run() -> List[tuple]:
    rows: List[tuple] = []
    base = tempfile.mkdtemp()
    cfg = EngineConfig()
    registry = DatasetRegistry()
    for i in range(NUM_DATASETS):
        root = _write_dataset(os.path.join(base, f"ds{i}"), seed=i)
        registry.add("bench", f"ds{i}", root, engine_config=cfg)

    # direct baseline: one StatsServer over dataset 0 (its own root copy —
    # a separate spill-free service so the fleet's caches are not shared)
    direct_root = _write_dataset(os.path.join(base, "direct"), seed=0)
    with StatsServer(StatsService(direct_root)) as direct:
        url = direct.url + "/estimate?mode=improved"
        fetch_json(url)  # cold: pack + engine run, excluded from the mean
        direct_us = _time_requests(url, WARM_REQS)
        rows.append((
            "fleet/direct_warm", direct_us, f"reqs={WARM_REQS};replicas=1",
        ))

    with StatsRouter(Fleet(registry, replicas_per_dataset=NUM_REPLICAS)) as router:
        url = router.url_for("bench", "ds0", "estimate") + "?mode=improved"
        status, etag, _ = fetch_json(url)  # cold
        assert status == 200 and etag
        routed_us = _time_requests(url, WARM_REQS)
        rows.append((
            "fleet/routed_warm", routed_us,
            f"reqs={WARM_REQS};replicas={NUM_REPLICAS};"
            f"overhead={routed_us - direct_us:.0f}us",
        ))

        rev_us = _time_requests(url, WARM_REQS, etag=etag)
        status304, _, _ = fetch_json(url, etag=etag)
        assert status304 == 304
        rows.append((
            "fleet/routed_304", rev_us,
            f"reqs={WARM_REQS};vs_warm={routed_us / max(rev_us, 1e-9):.1f}x",
        ))

        # failover: kill the replica that owns this placement, time the
        # next request (ejection + retry), assert the ETag survived
        rset = router.fleet.sets["bench/ds0"]
        victim = rset.rank(StatsRequest("estimate", "improved").identity)[0]
        victim.kill()
        t0 = time.perf_counter()
        status, etag_after, _ = fetch_json(url)
        failover_us = (time.perf_counter() - t0) * 1e6
        assert status == 200 and etag_after == etag
        assert rset.failovers >= 1
        rows.append((
            "fleet/failover", failover_us,
            f"failovers={rset.failovers};etag_stable=1",
        ))

        # cross-replica warm start: a brand-new replica over the spilled
        # dataset serves its first estimate with zero engine packs
        t0 = time.perf_counter()
        fresh = LocalReplica(
            "bench/ds0#fresh", registry.get("bench", "ds0").root,
            engine_config=cfg,
        ).start()
        try:
            resp = fresh.handle(StatsRequest("estimate", "improved"))
            warm_start_us = (time.perf_counter() - t0) * 1e6
            assert resp.status == 200 and resp.etag == etag
            packs = fresh.service.catalog.stats.packs
            assert packs == 0, f"expected spill hit, got {packs} packs"
        finally:
            fresh.stop()
        rows.append((
            "fleet/warm_start", warm_start_us,
            f"packs=0;spill_entries>=1",
        ))

        # -- batched RPC: N tuples, one frame, vs N sequential requests --
        tuples = []
        for i in range(BATCH_N):
            tuples.append({
                "namespace": "bench",
                "dataset": f"ds{i % NUM_DATASETS}",
                "mode": "improved" if i % 2 else "paper",
            })
        urls = [
            router.url_for("bench", t["dataset"], "estimate")
            + f"?mode={t['mode']}"
            for t in tuples
        ]
        for u in sorted(set(urls)):
            fetch_json(u)  # prime every (dataset, mode) warm
        t0 = time.perf_counter()
        for u in urls:
            status, _, _ = fetch_json(u)
            assert status == 200
        seq_us = (time.perf_counter() - t0) * 1e6
        rows.append((
            "fleet/seq_warm_json", seq_us / BATCH_N,
            f"n={BATCH_N};total_us={seq_us:.0f}",
        ))

        pool = ConnectionPool()
        payload = {"tuples": tuples}
        fetch(router.url + "/batch", pool=pool, method="POST",
              payload=payload)  # prime the pooled connection
        t0 = time.perf_counter()
        status, _, env = fetch(
            router.url + "/batch", pool=pool, method="POST", payload=payload
        )
        batch_us = (time.perf_counter() - t0) * 1e6
        assert status == 200
        assert all(e["status"] == 200 for e in env["responses"])
        speedup = seq_us / batch_us
        if not quick():
            assert speedup >= 3.0, (
                f"batched /batch must beat sequential /estimate by >=3x "
                f"warm at n={BATCH_N}, got {speedup:.2f}x"
            )
        rows.append((
            "fleet/batch_warm_binary", batch_us / BATCH_N,
            f"n={BATCH_N};total_us={batch_us:.0f};speedup={speedup:.1f}x",
        ))

        # representative body for the codec micro-rows below
        _, _, est_body = fetch_json(urls[0])

        # -- cold batch: one engine dispatch for the whole sub-batch --
        cold_root = _write_dataset(os.path.join(base, "cold"), seed=7)
        cold_reg = DatasetRegistry()
        cold_reg.add("bench", "cold", cold_root, engine_config=cfg)
        # one replica -> exactly one sub-batch, so the counters are exact
        with StatsRouter(Fleet(cold_reg, replicas_per_dataset=1)) as cold_r:
            cold_tuples = [
                {"namespace": "bench", "dataset": "cold",
                 "bounds": {"tok": float(8 << i)}}
                for i in range(4)
            ]
            t0 = time.perf_counter()
            status, _, env = fetch(
                cold_r.url + "/batch", pool=pool, method="POST",
                payload={"tuples": cold_tuples},
            )
            cold_us = (time.perf_counter() - t0) * 1e6
            assert status == 200
            assert all(e["status"] == 200 for e in env["responses"])
            svc = cold_r.fleet.sets["bench/cold"].replicas[0].service
            assert svc.stats.engine_runs == 1, (
                f"cold sub-batch must be ONE engine dispatch, "
                f"got {svc.stats.engine_runs}"
            )
            assert svc.catalog.stats.packs == 1
            rows.append((
                "fleet/batch_cold", cold_us,
                f"tuples={len(cold_tuples)};engine_runs=1;packs=1",
            ))

    # -- wire codec throughput on a real estimate body --
    frame = encode_frame(est_body)
    json_len = len(json.dumps(est_body).encode())
    assert decode_frame(frame) == json.loads(json.dumps(est_body))
    t0 = time.perf_counter()
    for _ in range(CODEC_REPS):
        encode_frame(est_body)
    enc_us = (time.perf_counter() - t0) * 1e6 / CODEC_REPS
    t0 = time.perf_counter()
    for _ in range(CODEC_REPS):
        decode_frame(frame)
    dec_us = (time.perf_counter() - t0) * 1e6 / CODEC_REPS
    ratio = json_len / len(frame)
    rows.append((
        "wire/encode", enc_us,
        f"bytes={len(frame)};json_bytes={json_len};ratio={ratio:.2f}x",
    ))
    rows.append(("wire/decode", dec_us, f"reps={CODEC_REPS}"))

    # -- keep-alive pool vs fresh connection per request --
    with StatsServer(StatsService(direct_root)) as srv:
        url = srv.url + "/health"
        fetch_json(url)
        t0 = time.perf_counter()
        for _ in range(POOL_REQS):
            fetch_json(url)
        fresh_us = (time.perf_counter() - t0) * 1e6 / POOL_REQS
        pool2 = ConnectionPool()
        fetch(url, pool=pool2)
        t0 = time.perf_counter()
        for _ in range(POOL_REQS):
            fetch(url, pool=pool2)
        reuse_us = (time.perf_counter() - t0) * 1e6 / POOL_REQS
        snap = pool2.stats.snapshot()
        assert snap["opened"] == 1 and snap["reused"] >= POOL_REQS
        rows.append((
            "wire/conn_fresh", fresh_us, f"reqs={POOL_REQS};keepalive=0",
        ))
        rows.append((
            "wire/conn_reuse", reuse_us,
            f"reqs={POOL_REQS};opened=1;"
            f"vs_fresh={fresh_us / max(reuse_us, 1e-9):.1f}x",
        ))
    return rows
