"""Kernel benchmarks: Pallas (interpret on CPU; compiled on TPU) vs ref.

On this CPU container the numbers characterize the REFERENCE path's
throughput (the Pallas interpret path is a correctness tool, orders of
magnitude slower than compiled TPU execution); the derived column records
bytes/lanes so the TPU roofline for each kernel can be projected.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._quick import pick
from repro.kernels import ops


def _timeit(fn, *args, iters=3) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def _dispatch_count(fn) -> int:
    """Number of `pallas_call` sites a fresh trace of `fn` dispatches.

    Every kernel module resolves `pl.pallas_call` as a module attribute at
    call time, so patching the attribute during one forced retrace
    (`jax.clear_caches()`) counts kernel launches for any entry point —
    the launch-count half of the fused-vs-separate story, which wall-clock
    on an interpret-mode CPU cannot show.
    """
    from jax.experimental import pallas as pl

    count = 0
    orig = pl.pallas_call

    def counting(*args, **kwargs):
        nonlocal count
        count += 1
        return orig(*args, **kwargs)

    pl.pallas_call = counting
    try:
        jax.clear_caches()
        jax.block_until_ready(fn())
    finally:
        pl.pallas_call = orig
    return count


def _column_batch(rng, b: int, r: int):
    """Synthetic packed ColumnBatch, shaped like a catalog estimate call."""
    from repro.core.ndv.types import ColumnBatch

    mins = np.sort(rng.uniform(0, 1e5, (b, r)).astype(np.float32), axis=1)
    maxs = mins + rng.uniform(10.0, 1e4, (b, r)).astype(np.float32)
    rows = np.full((b, r), 4096.0, np.float32)
    nulls = rng.uniform(0, 64, (b, r)).astype(np.float32)
    J = jnp.asarray
    return ColumnBatch(
        chunk_S=J(rng.uniform(2e3, 9e4, (b, r)).astype(np.float32)),
        chunk_rows=J(rows),
        chunk_nulls=J(nulls),
        chunk_dict_encoded=J(rng.uniform(size=(b, r)) > 0.2),
        N=J(rows.sum(1)),
        nulls=J(nulls.sum(1)),
        n_groups=J(np.full(b, r, np.int32)),
        mins=J(mins),
        maxs=J(maxs),
        valid=J(np.ones((b, r), bool)),
        m_min=J(np.full(b, float(max(r - 1, 1)), np.float32)),
        m_max=J(np.full(b, float(r), np.float32)),
        mean_len=J(np.full(b, 8.0, np.float32)),
        len_sample=J(np.full(b, 2 * r, np.int32)),
        fixed_width=J(np.ones(b, bool)),
        int_like=J(np.ones(b, bool)),
        single_byte=J(np.zeros(b, bool)),
    )


def _fused_vs_separate(rng) -> List[tuple]:
    """§4-§7 pipeline: one fused `pallas_call` vs separate kernel launches.

    Both paths are pinned to `backend="pallas"` so the launch structure is
    the TPU serving shape (on this CPU the kernels run interpreted — the
    latency column characterizes dispatch overhead trends, not TPU time;
    the dispatch counts are exact and platform-independent).
    """
    from repro.core.ndv.estimator import estimate_batch
    from repro.kernels import ops

    out: List[tuple] = []
    widths = pick((64, 256, 1024), (4, 8, 16))
    r = pick(32, 4)
    for b in widths:
        batch = _column_batch(rng, b, r)

        sep = lambda: estimate_batch(  # noqa: E731
            batch, None, mode="paper", backend="pallas", fuse="off"
        )
        fus = lambda: ops.fused_estimate(  # noqa: E731
            batch, None, mode="paper", backend="pallas"
        )
        d_sep = _dispatch_count(sep)
        d_fus = _dispatch_count(fus)
        us_sep = _timeit(sep)
        us_fus = _timeit(fus)
        out.append((
            f"kernels/estimate_separate_pallas_{b}x{r}", us_sep,
            f"dispatches={d_sep}",
        ))
        out.append((
            f"kernels/estimate_fused_pallas_{b}x{r}", us_fus,
            f"dispatches={d_fus};separate_dispatches={d_sep};"
            f"dispatch_reduction_x={d_sep / max(d_fus, 1):.1f}",
        ))
    return out


def run() -> List[tuple]:
    rng = np.random.default_rng(0)
    rows: List[tuple] = []

    m = pick(1 << 16, 1 << 10)
    ndv = rng.integers(1, 1_000_000, m).astype(np.float32)
    rws = ndv * 4
    z = np.zeros(m, np.float32)
    ln = np.full(m, 8.0, np.float32)
    bits = np.maximum(np.ceil(np.log2(ndv)), 1)
    S = (ndv * 8 + rws * bits / 8).astype(np.float32)
    args = [jnp.asarray(x) for x in (S, rws, z, ln)]

    us_ref = _timeit(lambda *a: ops.dict_newton(*a, backend="ref"), *args)
    rows.append((
        f"kernels/dict_newton_ref_{m}", us_ref,
        f"solves_per_s={m/(us_ref/1e6):.0f};hbm_bytes={m*20}",
    ))
    us_pal = _timeit(lambda *a: ops.dict_newton(*a), *args)
    rows.append((
        f"kernels/dict_newton_pallas_interp_{m}", us_pal,
        f"interpret_overhead_x={us_pal/us_ref:.1f}",
    ))

    n = rng.integers(2, 1024, m).astype(np.float32)
    D = rng.uniform(1, 1e6, m).astype(np.float32)
    obs = (D * (1 - np.exp(-n / D))).astype(np.float32)
    us = _timeit(lambda a, b: ops.coupon_newton(a, b, backend="ref"),
                 jnp.asarray(obs), jnp.asarray(n))
    rows.append((f"kernels/coupon_newton_ref_{m}", us,
                 f"solves_per_s={m/(us/1e6):.0f}"))

    b, r = pick((1024, 256), (128, 128))
    mins = np.sort(rng.normal(size=(b, r)).astype(np.float32), 1)
    maxs = mins + 0.2
    valid = np.ones((b, r), bool)
    us = _timeit(
        lambda a, c, d: ops.minmax_scan(a, c, d, backend="ref"),
        jnp.asarray(mins), jnp.asarray(maxs), jnp.asarray(valid),
    )
    rows.append((f"kernels/minmax_scan_ref_{b}x{r}", us,
                 f"cols_per_s={b/(us/1e6):.0f};hbm_bytes={b*r*12}"))

    keys = rng.integers(0, 2**32, size=(b, r), dtype=np.uint32)
    us = _timeit(
        lambda a, c: ops.hll_fold(a, c, p=8, backend="ref"),
        jnp.asarray(keys), jnp.asarray(valid),
    )
    rows.append((f"kernels/hll_fold_ref_{b}x{r}", us,
                 f"keys_per_s={b*r/(us/1e6):.0f}"))

    rows.extend(_fused_vs_separate(rng))
    return rows
