"""Kernel benchmarks: Pallas (interpret on CPU; compiled on TPU) vs ref.

On this CPU container the numbers characterize the REFERENCE path's
throughput (the Pallas interpret path is a correctness tool, orders of
magnitude slower than compiled TPU execution); the derived column records
bytes/lanes so the TPU roofline for each kernel can be projected.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._quick import pick
from repro.kernels import ops


def _timeit(fn, *args, iters=3) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[tuple]:
    rng = np.random.default_rng(0)
    rows: List[tuple] = []

    m = pick(1 << 16, 1 << 10)
    ndv = rng.integers(1, 1_000_000, m).astype(np.float32)
    rws = ndv * 4
    z = np.zeros(m, np.float32)
    ln = np.full(m, 8.0, np.float32)
    bits = np.maximum(np.ceil(np.log2(ndv)), 1)
    S = (ndv * 8 + rws * bits / 8).astype(np.float32)
    args = [jnp.asarray(x) for x in (S, rws, z, ln)]

    us_ref = _timeit(lambda *a: ops.dict_newton(*a, backend="ref"), *args)
    rows.append((
        f"kernels/dict_newton_ref_{m}", us_ref,
        f"solves_per_s={m/(us_ref/1e6):.0f};hbm_bytes={m*20}",
    ))
    us_pal = _timeit(lambda *a: ops.dict_newton(*a), *args)
    rows.append((
        f"kernels/dict_newton_pallas_interp_{m}", us_pal,
        f"interpret_overhead_x={us_pal/us_ref:.1f}",
    ))

    n = rng.integers(2, 1024, m).astype(np.float32)
    D = rng.uniform(1, 1e6, m).astype(np.float32)
    obs = (D * (1 - np.exp(-n / D))).astype(np.float32)
    us = _timeit(lambda a, b: ops.coupon_newton(a, b, backend="ref"),
                 jnp.asarray(obs), jnp.asarray(n))
    rows.append((f"kernels/coupon_newton_ref_{m}", us,
                 f"solves_per_s={m/(us/1e6):.0f}"))

    b, r = pick((1024, 256), (128, 128))
    mins = np.sort(rng.normal(size=(b, r)).astype(np.float32), 1)
    maxs = mins + 0.2
    valid = np.ones((b, r), bool)
    us = _timeit(
        lambda a, c, d: ops.minmax_scan(a, c, d, backend="ref"),
        jnp.asarray(mins), jnp.asarray(maxs), jnp.asarray(valid),
    )
    rows.append((f"kernels/minmax_scan_ref_{b}x{r}", us,
                 f"cols_per_s={b/(us/1e6):.0f};hbm_bytes={b*r*12}"))

    keys = rng.integers(0, 2**32, size=(b, r), dtype=np.uint32)
    us = _timeit(
        lambda a, c: ops.hll_fold(a, c, p=8, backend="ref"),
        jnp.asarray(keys), jnp.asarray(valid),
    )
    rows.append((f"kernels/hll_fold_ref_{b}x{r}", us,
                 f"keys_per_s={b*r/(us/1e6):.0f}"))
    return rows
