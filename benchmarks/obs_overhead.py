"""Telemetry overhead: warm /estimate with the obs tier on vs off.

ISSUE 8's acceptance bar: the unified telemetry tier (metrics registry +
request tracing, `repro.obs`) must cost < 5% on the warm request path,
and must be invisible to the caching contract — ETags and binary
estimate bodies byte-identical whether telemetry is enabled or not
(telemetry never enters cache_key / cache_token derivation).

  obs/warm_on      warm binary /estimate over a pooled connection,
                   telemetry enabled (spans + counters + histograms)
  obs/warm_off     same loop after ``set_enabled(False)`` — every span
                   is a null object, every inc/observe an early return;
                   derived carries overhead_pct (asserted < 5% in full
                   mode; quick shapes are too noisy to characterize)
  obs/scrape       GET /metrics exposition render, full registry
  obs/etag_parity  fresh service booted with telemetry OFF serves the
                   byte-identical ETag + wire body (asserted)
  explain/warm_on  warm /estimate?explain=1 — provenance attached from
                   the catalog's provenance cache on every response
  explain/warm_off same loop without explain; derived carries
                   overhead_pct (ISSUE 9 bar: < 5% in full mode), and
                   the explained response's ETag is asserted identical
                   to the plain one (explain never enters identity)

Loopback round-trip noise (scheduler, CPU frequency drift) is tens of
microseconds — the same order as the effect being measured — so the
estimator interleaves at the REQUEST level: telemetry flips on/off on
alternating requests of one long run, each mode's latency is summarized
by its median (discarding scheduler spikes), and the overhead is the
difference of the two medians. Per-request alternation means both modes
sample the machine's slow drift identically; this was the only estimator
that produced stable (<±0.5pp) readings on a noisy shared host, where
round-level pairing still swung by several percent.
"""
from __future__ import annotations

import os
import statistics
import tempfile
import time
from typing import List

import numpy as np

from benchmarks._quick import pick, quick
from repro import obs
from repro.service import StatsServer, StatsService
from repro.wire import ConnectionPool, fetch

NUM_SHARDS = pick(4, 2)
ROWS_PER_SHARD = pick(1 << 12, 1 << 10)
ROW_GROUP = pick(512, 256)
WARM_REQS = pick(4000, 8)        # total timed requests (alternating on/off)
SCRAPES = pick(50, 3)


def _write_shard(root: str, index: int) -> None:
    from repro.columnar.writer import WriterOptions, write_file

    rng = np.random.default_rng(index)
    write_file(
        os.path.join(root, f"shard_{index:05d}"),
        {
            "tok": rng.integers(0, 2048, ROWS_PER_SHARD).astype(np.int64),
            "val": np.round(rng.uniform(0, 100, ROWS_PER_SHARD), 1),
        },
        options=WriterOptions(row_group_size=ROW_GROUP),
    )


def _warm_medians(url: str, pool: ConnectionPool) -> tuple:
    """Alternate telemetry per request; return (on_us, off_us) medians."""
    samples = {True: [], False: []}
    for i in range(WARM_REQS):
        enabled = i % 2 == 0
        obs.set_enabled(enabled)
        t0 = time.perf_counter()
        status, _, body = fetch(url, pool=pool)
        samples[enabled].append((time.perf_counter() - t0) * 1e6)
        assert status == 200 and body["estimates"]
    obs.set_enabled(True)
    return (statistics.median(samples[True]),
            statistics.median(samples[False]))


def _explain_medians(url: str, pool: ConnectionPool) -> tuple:
    """Alternate ?explain=1 per request; return (on_us, off_us) medians.

    Same request-level interleaving as `_warm_medians` and for the same
    reason: both modes must sample the host's slow drift identically.
    """
    explained_url = url + "&explain=1"
    samples = {True: [], False: []}
    etags = {}
    for i in range(WARM_REQS):
        explain = i % 2 == 0
        t0 = time.perf_counter()
        status, etag, body = fetch(explained_url if explain else url,
                                   pool=pool)
        samples[explain].append((time.perf_counter() - t0) * 1e6)
        assert status == 200 and body["estimates"]
        assert ("provenance" in body) == explain
        etags[explain] = etag
    assert etags[True] == etags[False], "explain rotated the ETag"
    return (statistics.median(samples[True]),
            statistics.median(samples[False]))


def run() -> List[tuple]:
    rows: List[tuple] = []
    root = os.path.join(tempfile.mkdtemp(), "obs_bench")
    for i in range(NUM_SHARDS):
        _write_shard(root, i)

    try:
        with StatsServer(StatsService(root)) as server:
            url = server.url + "/estimate?mode=improved"
            pool = ConnectionPool(name="obs_bench")
            # warm the cache + connection before any timed round
            status, etag_on, _ = fetch(url, pool=pool)
            assert status == 200 and etag_on

            on_us, off_us = _warm_medians(url, pool)
            diff_us = on_us - off_us
            overhead = diff_us / off_us
            if not quick():
                assert overhead < 0.05, (
                    f"telemetry overhead {overhead:.1%} >= 5% "
                    f"(on={on_us:.1f}us off={off_us:.1f}us)"
                )
            rows.append((
                "obs/warm_on", on_us,
                f"reqs={WARM_REQS};alternating=True",
            ))
            rows.append((
                "obs/warm_off", off_us,
                f"reqs={WARM_REQS};overhead_us={diff_us:.1f};"
                f"overhead_pct={overhead * 100:.2f}",
            ))

            exp_on_us, exp_off_us = _explain_medians(url, pool)
            exp_diff_us = exp_on_us - exp_off_us
            exp_overhead = exp_diff_us / exp_off_us
            if not quick():
                assert exp_overhead < 0.05, (
                    f"explain overhead {exp_overhead:.1%} >= 5% "
                    f"(on={exp_on_us:.1f}us off={exp_off_us:.1f}us)"
                )
            rows.append((
                "explain/warm_on", exp_on_us,
                f"reqs={WARM_REQS};alternating=True",
            ))
            rows.append((
                "explain/warm_off", exp_off_us,
                f"reqs={WARM_REQS};overhead_us={exp_diff_us:.1f};"
                f"overhead_pct={exp_overhead * 100:.2f}",
            ))

            t0 = time.perf_counter()
            for _ in range(SCRAPES):
                status, _, _ = pool.request(server.url + "/metrics")
            scrape_us = (time.perf_counter() - t0) * 1e6 / SCRAPES
            assert status == 200
            exposition = obs.registry().exposition()
            rows.append((
                "obs/scrape", scrape_us,
                f"lines={len(exposition.splitlines())}",
            ))

            # the wire body with telemetry ON, to compare below
            status, _, raw_on = pool.request(
                url, headers={"Accept": "application/x-ndv-wire"}
            )
            assert status == 200
            pool.close()

        # -- cache-contract neutrality: a fresh service with telemetry OFF
        # must serve the byte-identical ETag and wire body ----------------
        obs.set_enabled(False)
        t0 = time.perf_counter()
        with StatsServer(StatsService(root)) as server:
            pool = ConnectionPool(name="obs_bench_off")
            status, etag_off, _ = fetch(server.url + "/estimate?mode=improved",
                                        pool=pool)
            assert status == 200
            assert etag_off == etag_on, (etag_off, etag_on)
            status, _, raw_off = pool.request(
                server.url + "/estimate?mode=improved",
                headers={"Accept": "application/x-ndv-wire"},
            )
            assert status == 200 and raw_off == raw_on, (
                "telemetry state changed the wire body"
            )
            pool.close()
        parity_us = (time.perf_counter() - t0) * 1e6
        rows.append((
            "obs/etag_parity", parity_us,
            f"identical=True;bytes={len(raw_on)}",
        ))
    finally:
        obs.set_enabled(True)
    return rows
