"""Planner tier: batched plan-scoring throughput + warm /cost latency.

The planner's pitch is that scoring thousands of candidate join orders
is ONE batched JAX dispatch, and that a warm `/cost` is a 304 that does
no catalog or scoring work at all. This module measures both ends:

  planner/score_N     plans-scored/sec for an N-table chain graph
                      (N = 3, 6, 10), warm jit — the batched fold alone
  planner/speedup     batched `score_plans` vs the pure-Python
                      `sequential_reference` fold over the identical
                      plan space (bit-identical costs, asserted)
  planner/cost_cold   first POST /cost against a live StatsServer:
                      tablestats + enumeration + scoring + body build
  planner/cost_304    warm revalidation with If-None-Match — the
                      zero-work path (no new scoring dispatch, asserted
                      via the planner dispatch counter)
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import List

import numpy as np

from benchmarks._quick import pick
from repro.planner import (
    ColumnStats,
    TableStats,
    enumerate_plans,
    parse_join_graph,
    score_plans,
)
from repro.planner.api import sequential_reference
from repro.planner.cost import _DISPATCHES
from repro.service import StatsServer, StatsService
from repro.wire import fetch

GRAPH_SIZES = pick((3, 6, 10), (3, 6))
MAX_PLANS = pick(4096, 256)
SCORE_REPS = pick(20, 3)
SPEEDUP_TABLES = pick(7, 5)
REVAL_REQS = pick(100, 5)

ROWS_PER_SHARD = pick(1 << 12, 1 << 9)


def _chain(n: int):
    """An n-table chain join graph with one shared key column."""
    return parse_join_graph({
        "tables": [{"name": f"t{i}"} for i in range(n)],
        "edges": [
            {"left": f"t{i}", "left_column": "k",
             "right": f"t{i + 1}", "right_column": "k"}
            for i in range(n - 1)
        ],
    })


def _stats(graph):
    rng = np.random.default_rng(0)
    return {
        t.name: TableStats(
            rows=float(rng.integers(10_000, 1_000_000)),
            columns={"k": ColumnStats(
                ndv=float(rng.integers(10, 10_000)), non_null=1,
            )},
        )
        for t in graph.tables
    }


def _lanes(graph, stats):
    """(base_rows, factors) in the shape `score_plans` consumes."""
    index = {name: i for i, name in enumerate(graph.names)}
    base_rows = np.array(
        [np.float32(stats[t.name].rows) for t in graph.tables],
        dtype=np.float32,
    )
    factors = [
        (index[e.left], index[e.right],
         float(np.float32(1.0) / np.float32(max(
             stats[e.left].columns[e.left_column].ndv,
             stats[e.right].columns[e.right_column].ndv, 1.0))))
        for e in graph.edges
    ]
    return base_rows, factors


def run() -> List[tuple]:
    rows: List[tuple] = []

    # -- batched scoring throughput by graph size ---------------------------
    for n in GRAPH_SIZES:
        graph = _chain(n)
        stats = _stats(graph)
        base_rows, factors = _lanes(graph, stats)
        plans = enumerate_plans(n, MAX_PLANS)
        score_plans(plans, base_rows, factors)  # compile + warm
        t0 = time.perf_counter()
        for _ in range(SCORE_REPS):
            costs, _ = score_plans(plans, base_rows, factors)
        us = (time.perf_counter() - t0) * 1e6 / SCORE_REPS
        p = int(plans.shape[0])
        rows.append((
            f"planner/score_{n}", us,
            f"plans={p};plans_per_s={p / (us / 1e6):.0f};"
            f"dispatches_per_call=1",
        ))

    # -- batched vs sequential over the identical plan space ----------------
    graph = _chain(SPEEDUP_TABLES)
    stats = _stats(graph)
    base_rows, factors = _lanes(graph, stats)
    plans = enumerate_plans(SPEEDUP_TABLES, MAX_PLANS)
    score_plans(plans, base_rows, factors)  # warm
    t0 = time.perf_counter()
    batched, _ = score_plans(plans, base_rows, factors)
    batched_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    sequential, _ = sequential_reference(graph, stats, max_plans=MAX_PLANS)
    seq_us = (time.perf_counter() - t0) * 1e6
    assert batched.tobytes() == sequential.tobytes(), "parity broke"
    rows.append((
        "planner/speedup", batched_us,
        f"plans={int(plans.shape[0])};sequential_us={seq_us:.0f};"
        f"speedup={seq_us / max(batched_us, 1e-9):.1f}x",
    ))

    # -- /cost end to end: cold body vs warm 304 ----------------------------
    root = os.path.join(tempfile.mkdtemp(), "planner_bench")
    rng = np.random.default_rng(7)
    from repro.columnar.writer import WriterOptions, write_file
    for i in range(2):
        write_file(
            os.path.join(root, f"shard_{i:05d}"),
            {"tok": rng.integers(0, 512, ROWS_PER_SHARD).astype(np.int64)},
            options=WriterOptions(row_group_size=256),
        )
    payload = {
        "graph": {
            "tables": [{"name": f"t{i}"} for i in range(4)],
            "edges": [
                {"left": f"t{i}", "left_column": "tok",
                 "right": f"t{i + 1}", "right_column": "tok"}
                for i in range(3)
            ],
        },
        "max_plans": MAX_PLANS,
    }
    with StatsServer(StatsService(root)) as server:
        url = server.url + "/cost"
        t0 = time.perf_counter()
        status, etag, body = fetch(url, payload=payload, binary=False)
        cold_us = (time.perf_counter() - t0) * 1e6
        assert status == 200 and body["best_order"]
        rows.append((
            "planner/cost_cold", cold_us,
            f"tables=4;plans_scored={body['plans_scored']};"
            f"enumeration={body['enumeration']}",
        ))

        dispatches_before = _DISPATCHES.value()
        t0 = time.perf_counter()
        for _ in range(REVAL_REQS):
            status, _, _ = fetch(
                url, payload=payload, etag=etag, binary=False,
            )
            assert status == 304
        rev_us = (time.perf_counter() - t0) * 1e6 / REVAL_REQS
        assert _DISPATCHES.value() == dispatches_before, "304 re-scored"
        rows.append((
            "planner/cost_304", rev_us,
            f"reqs={REVAL_REQS};score_dispatches=0;"
            f"vs_cold={cold_us / max(rev_us, 1e-9):.1f}x",
        ))
    return rows
