"""Benchmark harness — one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV (one row per measurement):

  accuracy.py     — Table 1 regime grid + coverage/rowgroup/length sweeps
  baselines.py    — zero-cost vs data-access estimators (§11 positioning)
  batch_memory.py — §8 batch dictionary prediction vs measured
  catalog_scale.py— StatsCatalog cold/warm/incremental latency + retraces
  complexity.py   — §10.2 single-pass complexity table
  engine_scale.py — EstimationEngine local/sharded/chunked/composed throughput
  fleet_latency.py — routed vs direct overhead, failover, shared-spill warmth
  kernels.py      — Pallas kernel suite throughput
  obs_overhead.py — telemetry tier on-vs-off warm latency + ETag parity
  planner.py      — batched plan-scoring throughput + warm /cost 304 latency
  service_latency.py — stats-service cold/warm/304 latency + throughput
  warehouse.py    — TPC-H-shaped lineitem accuracy via the catalog (§10.1)

``--quick`` runs every module at tiny shapes (CI smoke: exercises the
harness end to end in seconds; the numbers mean nothing).

``--json PATH`` additionally writes the rows as a machine-readable
artifact — the CI quick-benchmark step uploads it per run, so the repo
accumulates a perf trajectory across PRs instead of one-off terminal
output. The schema is deliberately flat: ``{"quick": bool, "git_sha":
str, "generated_at": iso8601, "rows": [{"name", "us_per_call",
"derived"}, ...], "errors": [module, ...]}``. `git_sha`/`generated_at`
pin each artifact to the exact tree and wall-clock it measured, so two
BENCH files can be diffed meaningfully (`benchmarks/compare.py`).
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
import sys
import traceback


def git_sha() -> str:
    """HEAD commit of the tree being measured; "unknown" outside a repo."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def build_payload(rows, errors) -> dict:
    """The BENCH artifact schema (see module docstring)."""
    return {
        "quick": bool(os.environ.get("NDV_BENCH_QUICK")),
        "git_sha": git_sha(),
        "generated_at": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "rows": rows,
        "errors": errors,
    }


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--quick" in argv:
        argv.remove("--quick")
        # Before importing any benchmark module: they read the flag at
        # module/call scope through benchmarks._quick.
        os.environ["NDV_BENCH_QUICK"] = "1"
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        try:
            json_path = argv[i + 1]
        except IndexError:
            raise SystemExit("--json requires a PATH argument")
        del argv[i : i + 2]
    if argv:
        raise SystemExit(f"unknown arguments: {argv}")

    from benchmarks import (
        accuracy,
        baselines,
        batch_memory,
        catalog_scale,
        complexity,
        engine_scale,
        fleet_latency,
        kernels,
        obs_overhead,
        planner,
        service_latency,
        warehouse,
    )

    modules = [
        ("accuracy", accuracy),
        ("warehouse", warehouse),
        ("catalog_scale", catalog_scale),
        ("engine_scale", engine_scale),
        ("service_latency", service_latency),
        ("fleet_latency", fleet_latency),
        ("obs_overhead", obs_overhead),
        ("planner", planner),
        ("baselines", baselines),
        ("batch_memory", batch_memory),
        ("complexity", complexity),
        ("kernels", kernels),
    ]
    print("name,us_per_call,derived")
    rows = []
    errors = []
    for name, mod in modules:
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}")
                rows.append({
                    "name": row_name,
                    "us_per_call": round(us, 1),
                    "derived": derived,
                })
        except Exception as e:  # pragma: no cover
            errors.append(name)
            traceback.print_exc()
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
    if json_path:
        payload = build_payload(rows, errors)
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {len(rows)} rows to {json_path}", file=sys.stderr)
    if errors:
        sys.exit(1)


if __name__ == "__main__":
    main()
