"""Stats-service latency: cold vs warm vs 304, plus concurrent throughput.

What a planner fleet sees is HTTP round trips, not library calls, so this
module measures the `repro.service` endpoint end to end over loopback:

  service/cold        first /estimate after boot: async footer ingestion
                      already done, so this is pack + trace + engine run
  service/warm        repeated /estimate, no If-None-Match: full JSON body
                      served from the catalog's estimate cache
  service/304         revalidation with If-None-Match: the zero-work path
                      (no pack, no engine run — asserted via /health)
  service/coalesce    N concurrent identical cold requests after a dataset
                      change: single-flight must collapse them onto one
                      engine execution (asserted)
  service/throughput  concurrent revalidation clients hammering /estimate
"""
from __future__ import annotations

import concurrent.futures
import os
import tempfile
import time
from typing import List

import numpy as np

from benchmarks._quick import pick
from repro.service import StatsServer, StatsService, fetch_json

NUM_SHARDS = pick(6, 3)
ROWS_PER_SHARD = pick(1 << 12, 1 << 10)
ROW_GROUP = pick(512, 256)
WARM_REQS = pick(100, 5)
CLIENTS = pick(8, 2)
REQS_PER_CLIENT = pick(50, 5)


def _write_shard(root: str, index: int) -> None:
    from repro.columnar.writer import WriterOptions, write_file

    rng = np.random.default_rng(index)
    write_file(
        os.path.join(root, f"shard_{index:05d}"),
        {
            "tok": rng.integers(0, 2048, ROWS_PER_SHARD).astype(np.int64),
            "val": np.round(rng.uniform(0, 100, ROWS_PER_SHARD), 1),
        },
        options=WriterOptions(row_group_size=ROW_GROUP),
    )


def run() -> List[tuple]:
    rows: List[tuple] = []
    root = os.path.join(tempfile.mkdtemp(), "svc_bench")
    for i in range(NUM_SHARDS):
        _write_shard(root, i)

    with StatsServer(StatsService(root)) as server:
        url = server.url + "/estimate?mode=improved"
        svc = server.service

        t0 = time.perf_counter()
        status, etag, body = fetch_json(url)
        cold_us = (time.perf_counter() - t0) * 1e6
        assert status == 200 and body["estimates"]
        rows.append((
            "service/cold", cold_us,
            f"files={NUM_SHARDS};cols={len(body['estimates'])};"
            f"engine_runs={svc.stats.engine_runs}",
        ))

        t0 = time.perf_counter()
        for _ in range(WARM_REQS):
            status, _, _ = fetch_json(url)
            assert status == 200
        warm_us = (time.perf_counter() - t0) * 1e6 / WARM_REQS
        rows.append((
            "service/warm", warm_us,
            f"reqs={WARM_REQS};engine_runs={svc.stats.engine_runs};"
            f"speedup={cold_us / max(warm_us, 1e-9):.0f}x",
        ))

        runs_before = svc.stats.engine_runs
        packs_before = svc.catalog.stats.packs
        t0 = time.perf_counter()
        for _ in range(WARM_REQS):
            status, _, _ = fetch_json(url, etag=etag)
            assert status == 304
        rev_us = (time.perf_counter() - t0) * 1e6 / WARM_REQS
        assert svc.stats.engine_runs == runs_before          # zero engine runs
        assert svc.catalog.stats.packs == packs_before       # zero packs
        rows.append((
            "service/304", rev_us,
            f"reqs={WARM_REQS};engine_runs=0;packs=0;"
            f"vs_warm={warm_us / max(rev_us, 1e-9):.1f}x",
        ))

        # -- single-flight: concurrent cold burst after a dataset change ----
        _write_shard(root, NUM_SHARDS)
        svc.refresh()
        runs_before = svc.stats.engine_runs
        t0 = time.perf_counter()
        with concurrent.futures.ThreadPoolExecutor(CLIENTS) as pool:
            statuses = list(pool.map(
                lambda _: fetch_json(url)[0], range(CLIENTS)
            ))
        burst_us = (time.perf_counter() - t0) * 1e6
        assert all(s == 200 for s in statuses)
        cold_runs = svc.stats.engine_runs - runs_before
        assert cold_runs == 1, f"single-flight leaked: {cold_runs} engine runs"
        rows.append((
            "service/coalesce", burst_us,
            f"clients={CLIENTS};engine_runs={cold_runs};"
            f"coalesced={svc.stats.coalesced_waits}",
        ))

        # -- sustained concurrent revalidation throughput -------------------
        _, etag, _ = fetch_json(url)

        def client(_) -> int:
            n = 0
            for _ in range(REQS_PER_CLIENT):
                s, _, _ = fetch_json(url, etag=etag)
                n += s == 304
            return n

        t0 = time.perf_counter()
        with concurrent.futures.ThreadPoolExecutor(CLIENTS) as pool:
            hits = sum(pool.map(client, range(CLIENTS)))
        dt = time.perf_counter() - t0
        total = CLIENTS * REQS_PER_CLIENT
        assert hits == total
        rows.append((
            "service/throughput", dt / total * 1e6,
            f"clients={CLIENTS};reqs={total};req_per_s={total / dt:.0f}",
        ))
    return rows
