"""Warehouse-shaped accuracy: TPC-H-style lineitem columns (paper §10.1's
production setting reconstructed with ground truth)."""
from __future__ import annotations

import os
import tempfile
import time
from typing import List

from repro.columnar import column_metadata_from_footer, read_footer, write_file
from repro.columnar.datasets import lineitem
from repro.columnar.writer import WriterOptions
from repro.core import estimate_columns


def run() -> List[tuple]:
    data = lineitem(rows=1 << 17, seed=0)
    cols = {k: v for k, (v, _) in data.items()}
    tmp = tempfile.mkdtemp()
    write_file(os.path.join(tmp, "lineitem"), cols,
               options=WriterOptions(row_group_size=8192))
    footer = read_footer(os.path.join(tmp, "lineitem"))
    metas = [column_metadata_from_footer(footer, n) for n in footer.column_names]

    rows: List[tuple] = []
    t0 = time.perf_counter()
    for mode in ("paper", "improved"):
        ests = estimate_columns(metas, mode=mode)
        us = (time.perf_counter() - t0) * 1e6 / len(ests)
        for e in ests:
            truth = data[e.column_name][1]
            err = abs(e.ndv - truth) / max(truth, 1)
            rows.append((
                f"warehouse/{mode}/{e.column_name}", us,
                f"est={e.ndv:.0f};true={truth};err={err:.4f};"
                f"layout={e.layout.name};lb={int(e.is_lower_bound)}",
            ))
    return rows
