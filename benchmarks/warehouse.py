"""Warehouse-shaped accuracy: TPC-H-style lineitem columns (paper §10.1's
production setting reconstructed with ground truth).

Unlike the single-file variant this now runs the production-shaped path: a
multi-shard lineitem dataset estimated through `StatsCatalog` (footer scan
-> cross-file metadata merge -> bucketed batch estimation), with ground
truth computed over the union of all shards.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import List

import numpy as np

from benchmarks._quick import pick
from repro.catalog import StatsCatalog
from repro.columnar.datasets import lineitem
from repro.columnar.writer import WriterOptions, write_file
from repro.engine import EngineConfig, EstimationEngine

NUM_SHARDS = 2


def run() -> List[tuple]:
    shard_rows = pick(1 << 16, 1 << 12)
    shards = [lineitem(rows=shard_rows, seed=s) for s in range(NUM_SHARDS)]
    tmp = tempfile.mkdtemp()
    for i, data in enumerate(shards):
        write_file(
            os.path.join(tmp, f"lineitem_{i:03d}"),
            {k: v for k, (v, _) in data.items()},
            options=WriterOptions(row_group_size=pick(8192, 512)),
        )
    truth = {
        name: int(
            np.unique(np.concatenate([d[name][0] for d in shards])).size
        )
        for name in shards[0]
    }

    engine = EstimationEngine(EngineConfig())
    catalog = StatsCatalog(tmp, engine=engine)
    rows: List[tuple] = []
    for mode in ("paper", "improved"):
        t0 = time.perf_counter()
        ests = catalog.estimate(mode=mode)
        us = (time.perf_counter() - t0) * 1e6 / max(len(ests), 1)
        # Resolve against the packed batch width (B after bucketing), which
        # is what estimate() actually dispatched on — not the column count.
        packed_b = catalog.packer.shape_for(len(ests), 1)[0]
        strategy = engine.resolve_strategy(packed_b)
        for name, e in ests.items():
            err = abs(e.ndv - truth[name]) / max(truth[name], 1)
            rows.append((
                f"warehouse/{mode}/{name}", us,
                f"est={e.ndv:.0f};true={truth[name]};err={err:.4f};"
                f"layout={e.layout.name};lb={int(e.is_lower_bound)};"
                f"files={catalog.num_files};engine={strategy}",
            ))
    return rows
