"""Fault-tolerance demo: worker failure + straggler eviction + elastic resume.

Runs a short training loop with a deterministic FaultPlan injected:
  * step 5:  worker 2 stops heartbeating -> declared DEAD -> checkpoint +
             elastic continue on the survivors;
  * step 10: worker 1 straggles at 3x median step time -> evicted;
then a SECOND trainer process resumes from LATEST, proving restartability.

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import os
import tempfile

from repro.data.pipeline import DataConfig, TokenPipeline, synthesize_token_dataset
from repro.ft.coordinator import FaultEvent, FaultPlan
from repro.models import registry
from repro.train import optimizer as opt
from repro.train.train_step import init_train_state
from repro.train.trainer import Trainer, TrainerConfig


def main():
    root = os.path.join(tempfile.mkdtemp(), "tokens")
    ckpt = os.path.join(tempfile.mkdtemp(), "ckpt")
    synthesize_token_dataset(root, vocab_size=512, num_shards=1,
                             rows_per_shard=1 << 15, row_group_size=4096)
    cfg = registry.get_smoke_config("qwen3_0_6b").scaled(
        dtype="float32", param_dtype="float32", vocab_size=512,
    )
    model = registry.build_model(cfg)
    pipe = TokenPipeline(DataConfig(root=root, batch_size=2, seq_len=64))

    plan = FaultPlan(events=[
        FaultEvent(step=5, kind="fail", worker_id=2),
        FaultEvent(step=10, kind="straggle", worker_id=1, factor=3.0),
    ])
    trainer = Trainer(
        model, cfg, opt.AdamWConfig(lr=1e-3),
        schedule=opt.cosine_schedule(3, 15),
        trainer_cfg=TrainerConfig(
            total_steps=15, ckpt_interval=5, ckpt_dir=ckpt,
            ckpt_async=False, log_interval=5, num_workers=4,
        ),
    )
    state = init_train_state(model, cfg)
    state, report = trainer.run(state, pipe.batches(epochs=20), fault_plan=plan)
    print("\nfault-tolerance events:")
    for e in report.evictions:
        print("  -", e)
    print(f"restart checkpoints taken: {report.restarts}")
    alive = trainer.coord.alive_workers()
    print(f"surviving workers: {alive} (of 4)")

    print("\n-- simulated restart (new trainer, resume from LATEST) --")
    trainer2 = Trainer(
        model, cfg, opt.AdamWConfig(lr=1e-3),
        schedule=opt.cosine_schedule(3, 20),
        trainer_cfg=TrainerConfig(
            total_steps=20, ckpt_interval=10, ckpt_dir=ckpt,
            ckpt_async=False, log_interval=5,
        ),
    )
    state2 = init_train_state(model, cfg)
    state2, report2 = trainer2.run(state2, pipe.batches(epochs=20), resume=True)
    print(f"resumed from step {report2.resumed_from}, "
          f"ran {report2.steps_run} more steps, final loss {report2.final_loss:.3f}")


if __name__ == "__main__":
    main()
