"""Data profiling from metadata only — the paper's §1 third application.

Profiles every column of every PQLite file under a root: NDV estimate,
layout class, confidence, memory forecast — WITHOUT reading any data page.
Compares footprint: bytes of metadata read vs bytes of data skipped.

    PYTHONPATH=src python examples/profile_dataset.py [root]

With ``--serve`` the same dataset is then exposed through the stats
service (`repro.service`), so remote planners can pull the numbers this
script printed without any footer access of their own:

    PYTHONPATH=src python examples/profile_dataset.py --serve [root]

    # client side — note the fingerprint ETag on every response:
    import json, urllib.request
    r = urllib.request.urlopen("http://127.0.0.1:8080/estimate?mode=improved")
    etag, ests = r.headers["ETag"], json.load(r)["estimates"]
    print(ests["key"]["ndv"])
    # revalidate for free until a file is added/removed/rewritten:
    req = urllib.request.Request(
        "http://127.0.0.1:8080/estimate?mode=improved",
        headers={"If-None-Match": etag},
    )
    urllib.request.urlopen(req)   # -> HTTPError 304: estimates unchanged

With ``--explain`` the profile table gains per-column provenance — the
route the estimator chose (dict vs minmax), its decision margins, Newton
iteration counts, clamps — plus the audited q-error where the sketch
auditor has sampled the column. The same diagnostics are served live:
``?explain=1`` attaches them to any `/estimate` response (same ETag —
explain never enters cache identity), and `/debug/explain` dumps the
server's provenance cache:

    r = urllib.request.urlopen(
        "http://127.0.0.1:8080/estimate?mode=improved&explain=1"
    )
    prov = json.load(r)["provenance"]
    print(prov["key"]["route"], prov["key"]["route_margin"],
          prov["key"].get("audit", {}).get("qerror"))
    json.load(urllib.request.urlopen(
        "http://127.0.0.1:8080/debug/explain"))   # cache + audit samples

For a whole warehouse namespace, front many datasets with the replicated
fleet router instead (`python -m repro.launch.serve_fleet`, see
`repro.fleet`) — same responses, same ETags, one endpoint:

    # client side against the router — only the path gains {ns}/{dataset}:
    r = urllib.request.urlopen(
        "http://127.0.0.1:8090/wh/lineitem/estimate?mode=improved"
    )
    etag, ests = r.headers["ETag"], json.load(r)["estimates"]
    # the same If-None-Match revalidation works across replica failover:
    # ETags derive from dataset state, not from which replica answered,
    # so a 304 survives crashes, restarts, and cold replicas.
    urllib.request.urlopen("http://127.0.0.1:8090/datasets")  # namespace map

A planner polling many datasets batches everything into ONE round trip
over a keep-alive connection, with the compact binary framing negotiated
automatically (`repro.wire`) — all cold tuples execute as a single
super-packed engine call on the serving side:

    from repro.wire import ConnectionPool, fetch
    pool = ConnectionPool()
    status, _, env = fetch(
        "http://127.0.0.1:8090/batch", pool=pool, method="POST",
        payload={"tuples": [
            {"namespace": "wh", "dataset": "lineitem", "mode": "improved"},
            {"namespace": "wh", "dataset": "orders",
             "columns": ["o_custkey"], "bounds": {"o_custkey": 150000}},
        ]},
    )
    for entry in env["responses"]:       # one per tuple, same order
        print(entry["status"], entry["etag"])
    # revalidate the whole sweep: per-tuple 304s, still one round trip
    tuples = [
        {"namespace": "wh", "dataset": "lineitem", "mode": "improved",
         "if_none_match": env["responses"][0]["etag"]},
    ]
    fetch("http://127.0.0.1:8090/batch", pool=pool, method="POST",
          payload={"tuples": tuples})    # responses[0]["status"] == 304

Both tiers expose the unified telemetry tier (`repro.obs`). `/metrics`
is Prometheus text exposition — request counters/latency histograms by
tier/route/status next to the engine, catalog, ingest, and connection
pool counters; the router re-emits every REMOTE replica's scrape under
a `replica="<name>"` label, so one scrape covers the fleet:

    print(urllib.request.urlopen("http://127.0.0.1:8090/metrics")
          .read().decode())
    # ndv_http_requests_total{route="batch",status="200",tier="router"} 2
    # ndv_http_request_seconds_bucket{le="0.005",route="batch",...} 2
    # ndv_engine_dispatches_total{...} 1 ...

`/debug/traces` returns recent request traces as JSON span trees — a
`/batch` shows the router span fanning out to per-replica sub-batches,
the service's super-pack, and the engine's pack/dispatch/d2h children,
all under one trace id (propagated via the `Traceparent` header and a
tagged section of the binary frame):

    t = json.load(urllib.request.urlopen(
        "http://127.0.0.1:8090/debug/traces?limit=5"))["traces"][0]
    def show(n, d=0):
        print("  " * d, n["name"], n["duration_ms"], "ms")
        [show(c, d + 1) for c in n["children"]]
    show(t)   # router.batch > replica.sub_batch > service.superpack > ...
"""
import argparse
import os
import tempfile
import time

import numpy as np

from repro.columnar import format as fmt
from repro.columnar import column_metadata_from_footer, scan_dataset
from repro.core import estimate_columns
from repro.core.planner import NDVPlanner


def ensure_demo_dataset(root: str):
    from repro.columnar.generator import int_domain, partitioned_column, zipf_column
    from repro.columnar.writer import WriterOptions, write_file

    for i in range(3):
        dom = int_domain(2000 + 500 * i, seed=i)
        a, _ = zipf_column(dom, 1 << 16, seed=10 + i)
        b, _ = partitioned_column(dom, 1 << 16, seed=20 + i)
        write_file(
            os.path.join(root, f"part_{i:04d}"),
            {"key": a, "range_key": b},
            options=WriterOptions(row_group_size=8192),
        )


def serve_stats(root: str, host: str, port: int) -> None:
    """Expose `root` through the fingerprint-ETag stats endpoint."""
    from repro.service import StatsServer, StatsService

    service = StatsService(root, poll_interval=10.0)
    with StatsServer(service, host=host, port=port) as server:
        print(f"\nserving stats at {server.url} (refresh every 10s)")
        print(f"  curl -s '{server.url}/estimate?mode=improved'")
        print(f"  curl -s '{server.url}/plan'")
        print(f"  curl -s '{server.url}/health'")
        print("Ctrl-C to stop")
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            print("\nshutting down")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("root", nargs="?", help="dataset root (default: demo)")
    ap.add_argument("--serve", action="store_true",
                    help="after profiling, serve the dataset's stats over "
                         "HTTP (see module docstring for a client snippet)")
    ap.add_argument("--explain", action="store_true",
                    help="add a per-column provenance table (route, margins, "
                         "Newton iterations, clamps) and audited q-error")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    args = ap.parse_args()
    root = args.root
    if root is None:
        root = os.path.join(tempfile.mkdtemp(), "demo")
        ensure_demo_dataset(root)
        print(f"(no root given — generated demo dataset at {root})")

    scanned = scan_dataset(root)
    print(f"profiling {len(scanned)} files under {root}\n")

    audits = {}
    if args.explain:
        # One sketch-audit pass over the dataset: a reference NDV from one
        # row group per file (repro.kernels.hll), q-error vs the metadata
        # estimate — the same loop the service runs in the background.
        from repro.service import StatsService

        svc = StatsService(root, audit=True)
        svc.refresh()
        audits = {a.column: a for a in svc.run_audit()}

    planner = NDVPlanner()
    meta_bytes = 0
    data_bytes = 0
    for f, footer in scanned:
        meta_bytes += os.path.getsize(fmt.footer_path(f))
        data_bytes += os.path.getsize(fmt.data_path(f))
        metas = [column_metadata_from_footer(footer, n) for n in footer.column_names]
        if args.explain:
            from repro.engine import default_engine

            ests, provs = default_engine().estimate_columns_explained(
                metas, mode="improved"
            )
        else:
            ests = estimate_columns(metas, mode="improved")
            provs = [None] * len(ests)
        print(f"{os.path.basename(f)}  rows={footer.num_rows}  "
              f"row_groups={footer.num_row_groups}")
        for e, m, p in zip(ests, metas, provs):
            plan = planner.memory_plan(e, m.non_null)
            print(f"   {e.column_name:12s} ndv~{e.ndv:9.0f} "
                  f"layout={e.layout.name:13s} conf={e.confidence:.2f} "
                  f"batch_mem={plan.d_batch_bytes/1e3:.0f}KB"
                  + (" [lower-bound]" if e.is_lower_bound else ""))
            if p is not None:
                a = audits.get(e.column_name)
                qerr = f"{a.qerror:.3f}" if a is not None else "-"
                clamps = ",".join(p.clamps) if p.clamps else "-"
                print(f"      route={p.route:6s} "
                      f"margin={p.route_margin:8.1f} "
                      f"detector_margin={p.detector_margin:6.3f} "
                      f"newton(dict={p.dict_iterations},"
                      f"coupon={p.coupon_iterations}) "
                      f"clamps={clamps} audit_qerror={qerr}")
    print(f"\nmetadata read: {meta_bytes/1e3:.1f} KB; "
          f"data pages NOT read: {data_bytes/1e6:.1f} MB "
          f"({data_bytes/max(meta_bytes,1):.0f}x saved)")
    if args.serve:
        serve_stats(root, args.host, args.port)


if __name__ == "__main__":
    main()
