"""Data profiling from metadata only — the paper's §1 third application.

Profiles every column of every PQLite file under a root: NDV estimate,
layout class, confidence, memory forecast — WITHOUT reading any data page.
Compares footprint: bytes of metadata read vs bytes of data skipped.

    PYTHONPATH=src python examples/profile_dataset.py [root]

With ``--serve`` the same dataset is then exposed through the stats
service (`repro.service`), so remote planners can pull the numbers this
script printed without any footer access of their own:

    PYTHONPATH=src python examples/profile_dataset.py --serve [root]

With ``--explain`` the profile table gains per-column provenance — the
route the estimator chose (dict vs minmax), its decision margins, Newton
iteration counts, clamps — plus the audited q-error where the sketch
auditor has sampled the column. The served twin is ``?explain=1`` on any
`/estimate` (same ETag — explain never enters cache identity) and
`/debug/explain` for the provenance cache.

With ``--cost`` the script demonstrates the planner tier end to end: it
generates two demo datasets, fronts them with an in-process replicated
fleet router (`repro.fleet`), POSTs a join graph to `/cost`, and prints
the NDV-driven join order with per-join cardinality predictions — then
revalidates the plan for free with the combined ETag.

Endpoint shapes, ETag/304 semantics, the binary wire negotiation, and
worked client snippets (revalidation, `/batch` sweeps, `/metrics`,
`/debug/traces`) for BOTH servers live in `docs/HTTP_API.md` — the
reference this docstring used to duplicate.
"""
import argparse
import os
import tempfile
import time

import numpy as np

from repro.columnar import format as fmt
from repro.columnar import column_metadata_from_footer, scan_dataset
from repro.core import estimate_columns
from repro.core.planner import NDVPlanner


def ensure_demo_dataset(root: str, seed: int = 0):
    from repro.columnar.generator import int_domain, partitioned_column, zipf_column
    from repro.columnar.writer import WriterOptions, write_file

    for i in range(3):
        dom = int_domain(2000 + 500 * (i + seed), seed=i + 100 * seed)
        a, _ = zipf_column(dom, 1 << 16, seed=10 + i + 100 * seed)
        b, _ = partitioned_column(dom, 1 << 16, seed=20 + i + 100 * seed)
        write_file(
            os.path.join(root, f"part_{i:04d}"),
            {"key": a, "range_key": b},
            options=WriterOptions(row_group_size=8192),
        )


def cost_demo() -> None:
    """Planner-tier tour: two datasets, one router, one POST /cost."""
    from repro.fleet import DatasetRegistry, Fleet, StatsRouter
    from repro.wire import ConnectionPool, fetch

    base = tempfile.mkdtemp()
    registry = DatasetRegistry()
    for name, seed in (("orders", 0), ("lines", 1)):
        root = os.path.join(base, name)
        ensure_demo_dataset(root, seed=seed)
        registry.add("demo", name, root)
    payload = {"graph": {
        "tables": [
            {"name": "o", "namespace": "demo", "dataset": "orders"},
            {"name": "l", "namespace": "demo", "dataset": "lines",
             "filter_selectivity": 0.5},
        ],
        "edges": [{"left": "o", "left_column": "key",
                   "right": "l", "right_column": "key"}],
    }}
    pool = ConnectionPool()
    with StatsRouter(Fleet(registry, replicas_per_dataset=2),
                     port=0) as router:
        status, etag, body = fetch(
            router.url + "/cost", pool=pool, payload=payload, binary=False
        )
        assert status == 200, (status, body)
        print("\n-- /cost: NDV-driven join ordering "
              f"({body['plans_scored']} plans scored, "
              f"{body['enumeration']}) --")
        print(f"   best order: {' >> '.join(body['best_order'])}   "
              f"total C_out cost: {body['total_cost']:.0f}")
        for j in body["joins"]:
            via = ", ".join(
                f"{e['left']}.{e['left_column']}={e['right']}."
                f"{e['right_column']} (sel 1/{1 / e['selectivity']:.0f})"
                for e in j["edges"]
            ) or "cross product"
            print(f"   join {j['table']:8s} card~{j['cardinality']:12.0f} "
                  f"via {via}")
        print(f"   sources: {body['sources']}")
        status, etag2, _ = fetch(
            router.url + "/cost", pool=pool, payload=payload,
            etag=etag, binary=False,
        )
        assert (status, etag2) == (304, etag), (status, etag2)
        print(f"   revalidated 304 on the combined ETag {etag[:14]}... "
              f"(valid until either dataset changes)")
    pool.close()


def serve_stats(root: str, host: str, port: int) -> None:
    """Expose `root` through the fingerprint-ETag stats endpoint."""
    from repro.service import StatsServer, StatsService

    service = StatsService(root, poll_interval=10.0)
    with StatsServer(service, host=host, port=port) as server:
        print(f"\nserving stats at {server.url} (refresh every 10s)")
        print(f"  curl -s '{server.url}/estimate?mode=improved'")
        print(f"  curl -s '{server.url}/plan'")
        print(f"  curl -s '{server.url}/health'")
        print("Ctrl-C to stop")
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            print("\nshutting down")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("root", nargs="?", help="dataset root (default: demo)")
    ap.add_argument("--serve", action="store_true",
                    help="after profiling, serve the dataset's stats over "
                         "HTTP (see module docstring for a client snippet)")
    ap.add_argument("--explain", action="store_true",
                    help="add a per-column provenance table (route, margins, "
                         "Newton iterations, clamps) and audited q-error")
    ap.add_argument("--cost", action="store_true",
                    help="after profiling, demo the planner tier: two demo "
                         "datasets behind an in-process fleet router, one "
                         "POST /cost, the chosen join order + cardinalities")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    args = ap.parse_args()
    root = args.root
    if root is None:
        root = os.path.join(tempfile.mkdtemp(), "demo")
        ensure_demo_dataset(root)
        print(f"(no root given — generated demo dataset at {root})")

    scanned = scan_dataset(root)
    print(f"profiling {len(scanned)} files under {root}\n")

    audits = {}
    if args.explain:
        # One sketch-audit pass over the dataset: a reference NDV from one
        # row group per file (repro.kernels.hll), q-error vs the metadata
        # estimate — the same loop the service runs in the background.
        from repro.service import StatsService

        svc = StatsService(root, audit=True)
        svc.refresh()
        audits = {a.column: a for a in svc.run_audit()}

    planner = NDVPlanner()
    meta_bytes = 0
    data_bytes = 0
    for f, footer in scanned:
        meta_bytes += os.path.getsize(fmt.footer_path(f))
        data_bytes += os.path.getsize(fmt.data_path(f))
        metas = [column_metadata_from_footer(footer, n) for n in footer.column_names]
        if args.explain:
            from repro.engine import default_engine

            ests, provs = default_engine().estimate_columns_explained(
                metas, mode="improved"
            )
        else:
            ests = estimate_columns(metas, mode="improved")
            provs = [None] * len(ests)
        print(f"{os.path.basename(f)}  rows={footer.num_rows}  "
              f"row_groups={footer.num_row_groups}")
        for e, m, p in zip(ests, metas, provs):
            plan = planner.memory_plan(e, m.non_null)
            print(f"   {e.column_name:12s} ndv~{e.ndv:9.0f} "
                  f"layout={e.layout.name:13s} conf={e.confidence:.2f} "
                  f"batch_mem={plan.d_batch_bytes/1e3:.0f}KB"
                  + (" [lower-bound]" if e.is_lower_bound else ""))
            if p is not None:
                a = audits.get(e.column_name)
                qerr = f"{a.qerror:.3f}" if a is not None else "-"
                clamps = ",".join(p.clamps) if p.clamps else "-"
                print(f"      route={p.route:6s} "
                      f"margin={p.route_margin:8.1f} "
                      f"detector_margin={p.detector_margin:6.3f} "
                      f"newton(dict={p.dict_iterations},"
                      f"coupon={p.coupon_iterations}) "
                      f"clamps={clamps} audit_qerror={qerr}")
    print(f"\nmetadata read: {meta_bytes/1e3:.1f} KB; "
          f"data pages NOT read: {data_bytes/1e6:.1f} MB "
          f"({data_bytes/max(meta_bytes,1):.0f}x saved)")
    if args.cost:
        cost_demo()
    if args.serve:
        serve_stats(root, args.host, args.port)


if __name__ == "__main__":
    main()
