"""Data profiling from metadata only — the paper's §1 third application.

Profiles every column of every PQLite file under a root: NDV estimate,
layout class, confidence, memory forecast — WITHOUT reading any data page.
Compares footprint: bytes of metadata read vs bytes of data skipped.

    PYTHONPATH=src python examples/profile_dataset.py [root]
"""
import os
import sys
import tempfile

import numpy as np

from repro.columnar import format as fmt
from repro.columnar import column_metadata_from_footer, scan_dataset
from repro.core import estimate_columns
from repro.core.planner import NDVPlanner


def ensure_demo_dataset(root: str):
    from repro.columnar.generator import int_domain, partitioned_column, zipf_column
    from repro.columnar.writer import WriterOptions, write_file

    for i in range(3):
        dom = int_domain(2000 + 500 * i, seed=i)
        a, _ = zipf_column(dom, 1 << 16, seed=10 + i)
        b, _ = partitioned_column(dom, 1 << 16, seed=20 + i)
        write_file(
            os.path.join(root, f"part_{i:04d}"),
            {"key": a, "range_key": b},
            options=WriterOptions(row_group_size=8192),
        )


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else None
    if root is None:
        root = os.path.join(tempfile.mkdtemp(), "demo")
        ensure_demo_dataset(root)
        print(f"(no root given — generated demo dataset at {root})")

    scanned = scan_dataset(root)
    print(f"profiling {len(scanned)} files under {root}\n")
    planner = NDVPlanner()
    meta_bytes = 0
    data_bytes = 0
    for f, footer in scanned:
        meta_bytes += os.path.getsize(fmt.footer_path(f))
        data_bytes += os.path.getsize(fmt.data_path(f))
        metas = [column_metadata_from_footer(footer, n) for n in footer.column_names]
        ests = estimate_columns(metas, mode="improved")
        print(f"{os.path.basename(f)}  rows={footer.num_rows}  "
              f"row_groups={footer.num_row_groups}")
        for e, m in zip(ests, metas):
            plan = planner.memory_plan(e, m.non_null)
            print(f"   {e.column_name:12s} ndv~{e.ndv:9.0f} "
                  f"layout={e.layout.name:13s} conf={e.confidence:.2f} "
                  f"batch_mem={plan.d_batch_bytes/1e3:.0f}KB"
                  + (" [lower-bound]" if e.is_lower_bound else ""))
    print(f"\nmetadata read: {meta_bytes/1e3:.1f} KB; "
          f"data pages NOT read: {data_bytes/1e6:.1f} MB "
          f"({data_bytes/max(meta_bytes,1):.0f}x saved)")


if __name__ == "__main__":
    main()
