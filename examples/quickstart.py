"""Quickstart: zero-cost NDV estimation on a PQLite dataset.

Generates columns with known ground truth across layouts, writes them in
the PQLite columnar format, then estimates NDV from FOOTER METADATA ONLY
(no data pages touched) and compares against exact counts.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import numpy as np

from repro.columnar import column_metadata_from_footer, read_footer, write_file
from repro.columnar.generator import (
    int_domain,
    sorted_column,
    string_domain,
    uniform_column,
    zipf_column,
)
from repro.columnar.writer import WriterOptions
from repro.core import estimate_columns
from repro.core.planner import NDVPlanner


def main():
    rows = 1 << 17
    dom_i = int_domain(4000, seed=1)
    dom_s = string_domain(1200, seed=2, dist="uniform")
    cols = {}
    truth = {}
    cols["user_id"], truth["user_id"] = uniform_column(dom_i, rows, seed=3)
    cols["event_time"], truth["event_time"] = sorted_column(dom_i, rows, seed=4)
    cols["country"], truth["country"] = zipf_column(dom_s[:200], rows, seed=5)
    cols["status"], truth["status"] = uniform_column(
        np.arange(5, dtype=np.int64), rows, seed=6
    )

    tmp = os.path.join(tempfile.mkdtemp(), "events")
    write_file(tmp, cols, options=WriterOptions(row_group_size=8192))
    print(f"wrote PQLite file: {tmp}")

    footer = read_footer(tmp)  # <- the ONLY thing the estimator reads
    metas = [column_metadata_from_footer(footer, n) for n in footer.column_names]

    print(f"\n{'column':12s} {'layout':13s} {'paper':>9s} {'improved':>9s} "
          f"{'true':>7s} {'err(imp)':>8s}  flags")
    paper = estimate_columns(metas, mode="paper")
    improved = estimate_columns(metas, mode="improved")
    for p, e in zip(paper, improved):
        t = truth[e.column_name]
        err = abs(e.ndv - t) / t
        flags = "lower-bound" if e.is_lower_bound else ""
        print(f"{e.column_name:12s} {e.layout.name:13s} {p.ndv:9.0f} "
              f"{e.ndv:9.0f} {t:7d} {err:8.3f}  {flags}")

    # The paper's application: plan batch memory without reading data.
    planner = NDVPlanner(batch_bytes=1 << 20)
    print("\nbatch-memory plan (1 MiB batches, Eq 16-17):")
    for e, m in zip(improved, metas):
        plan = planner.memory_plan(e, m.non_null)
        print(f"  {e.column_name:12s} D_global={plan.d_global_bytes/1e3:8.1f}KB "
              f"D_batch={plan.d_batch_bytes/1e3:8.1f}KB "
              f"({'conservative' if plan.conservative else 'expected'})")


if __name__ == "__main__":
    main()
