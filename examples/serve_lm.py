"""Batched serving demo: continuous batching over a shared KV cache.

    PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6_7b]

Attention archs use a ring/linear KV cache; SSM archs (rwkv6, zamba2)
demonstrate O(1)-state decode — the mechanism behind the long_500k cell.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import params as MP
from repro.models import registry
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch).scaled(
        dtype="float32", param_dtype="float32"
    )
    model = registry.build_model(cfg)
    params = MP.init_params(model.specs(), jax.random.PRNGKey(0), jnp.float32)
    engine = ServeEngine(model, cfg, params, slots=4, cache_len=128)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(1, cfg.vocab_size, 6).tolist(),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"[serve:{args.arch}] {len(done)} requests, {toks} tokens, "
          f"{dt:.1f}s ({toks/dt:.1f} tok/s on 1 CPU core)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: prompt={r.prompt[:4]}... -> {r.generated[:8]}...")


if __name__ == "__main__":
    main()
