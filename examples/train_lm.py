"""End-to-end training driver: NDV-planned data pipeline -> LM training.

The full loop the framework is built for:
  1. synthesize a PQLite token dataset;
  2. plan the pipeline from FOOTER METADATA ONLY (zero-cost NDV -> staging
     buffers + embedding-shard hint);
  3. train a small qwen3-style decoder with AdamW, microbatching,
     checkpointing; resume-safe.

    PYTHONPATH=src python examples/train_lm.py              # ~25M, 60 steps
    PYTHONPATH=src python examples/train_lm.py --full       # ~119M, 300 steps
"""
import argparse
import os
import tempfile

import jax.numpy as jnp

from repro.core.planner import NDVPlanner
from repro.data.pipeline import DataConfig, TokenPipeline, synthesize_token_dataset
from repro.models import registry
from repro.train import optimizer as opt
from repro.train.train_step import init_train_state
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~119M params, 300 steps (hours on 1 CPU core)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--data", default=None)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    vocab = 16384 if args.full else 2048
    data_root = args.data or os.path.join(tempfile.mkdtemp(), "tokens")
    ckpt_dir = args.ckpt or os.path.join(tempfile.mkdtemp(), "ckpt")
    if not os.path.exists(data_root):
        synthesize_token_dataset(
            data_root, vocab_size=vocab, num_shards=2,
            rows_per_shard=1 << 17, row_group_size=8192,
        )

    if args.full:
        cfg = registry.get_smoke_config("qwen3_0_6b").scaled(
            name="qwen3-repro-119m", dtype="float32", param_dtype="float32",
            num_layers=10, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=3072, vocab_size=vocab,
        )
        steps = args.steps or 300
        batch, seq = 4, 256
    else:
        cfg = registry.get_smoke_config("qwen3_0_6b").scaled(
            name="qwen3-repro-25m", dtype="float32", param_dtype="float32",
            num_layers=6, d_model=384, num_heads=6, num_kv_heads=2,
            head_dim=64, d_ff=1536, vocab_size=vocab,
        )
        steps = args.steps or 60
        batch, seq = 4, 128

    model = registry.build_model(cfg)
    print(f"model: {cfg.name}  params~{cfg.param_count()/1e6:.0f}M")

    # --- zero-cost planning (the paper, in the loop) -----------------------
    pipe = TokenPipeline(DataConfig(root=data_root, batch_size=batch, seq_len=seq))
    est = pipe.vocab_estimate()
    planner = NDVPlanner(device_budget_bytes=64 << 20)
    eplan = planner.embedding_shard_plan(
        est, vocab_size=cfg.vocab_size, d_model=cfg.d_model, dtype_bytes=4
    )
    print(f"[plan] tokens: ndv~{est.ndv:.0f} layout={est.layout.name} "
          f"conf={est.confidence:.2f}")
    print(f"[plan] staging buffers: {pipe.plan.total_staging_bytes/1e6:.2f} MB "
          f"(Eq 16-17, no data read)")
    print(f"[plan] embedding: shard_vocab={eplan.shard_vocab} — {eplan.reason}")

    # --- train ---------------------------------------------------------------
    trainer = Trainer(
        model, cfg, opt.AdamWConfig(lr=1e-3, weight_decay=0.01),
        schedule=opt.cosine_schedule(max(steps // 20, 5), steps),
        trainer_cfg=TrainerConfig(
            total_steps=steps, ckpt_interval=max(steps // 4, 10),
            ckpt_dir=ckpt_dir, log_interval=max(steps // 15, 5),
        ),
    )
    state = init_train_state(model, cfg)
    state, report = trainer.run(state, pipe.batches(epochs=50), resume=True)
    first = report.losses[0] if report.losses else float("nan")
    print(f"\n[train] {report.steps_run} steps  loss {first:.3f} -> "
          f"{report.final_loss:.3f}  (ckpts in {ckpt_dir})")
    assert report.final_loss < first, "loss should decrease"


if __name__ == "__main__":
    main()
