#!/usr/bin/env python3
"""Docs-consistency gate: the code is the inventory, the docs must match.

Scans the source tree (regex only — no imports, so it runs anywhere a
checkout exists) for the two surfaces the docs promise to cover:

- every HTTP route dispatched by `src/repro/service/http.py` (shared
  handler shell, so its routes exist on BOTH servers) and
  `src/repro/fleet/router.py` must appear in `docs/HTTP_API.md`;
- every metric series registered via `.counter(` / `.gauge(` /
  `.histogram(` and every `register_stats_view("prefix", ...)` family
  under `src/` must appear in `docs/METRICS.md`.

A new route or metric that lands without its documentation line fails
CI with the exact missing names. The reverse direction (documented but
gone from the code) is deliberately unchecked: docs may describe
behavior — e.g. per-tuple semantics — in prose this scanner can't parse.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"
SERVICE_HTTP = SRC / "repro" / "service" / "http.py"
FLEET_ROUTER = SRC / "repro" / "fleet" / "router.py"
HTTP_DOC = REPO / "docs" / "HTTP_API.md"
METRICS_DOC = REPO / "docs" / "METRICS.md"


def service_routes() -> set:
    """Literal paths compared against `url.path` in the handler shell."""
    text = SERVICE_HTTP.read_text()
    return set(re.findall(r'url\.path == "(/[^"]+)"', text))


def router_routes() -> set:
    """Router dispatch: top-level `parts == [...]` plus routed kinds."""
    text = FLEET_ROUTER.read_text()
    routes = {f"/{name}" for name in re.findall(r'parts == \["([^"]+)"\]', text)}
    kinds_m = re.search(r"ROUTED_KINDS = \(([^)]*)\)", text)
    if not kinds_m:
        sys.exit("check_docs: ROUTED_KINDS tuple not found in router.py")
    for kind in re.findall(r'"([^"]+)"', kinds_m.group(1)):
        routes.add("/{ns}/{ds}/" + kind)
    if re.search(r'parts\[2\] == "refresh"', text):
        routes.add("/{ns}/{ds}/refresh")
    return routes


# Registration calls put the series name in the first string argument,
# frequently on the line AFTER `.counter(` — match across the newline.
_METRIC_RE = re.compile(r'\.(?:counter|gauge|histogram)\(\s*"([a-z0-9_]+)"')
_VIEW_RE = re.compile(r'register_stats_view\(\s*"([a-z0-9_]+)"')


def metric_names() -> set:
    names = set()
    for path in sorted(SRC.rglob("*.py")):
        text = path.read_text()
        names.update(_METRIC_RE.findall(text))
        names.update(_VIEW_RE.findall(text))
    return names


def main() -> int:
    failures = []

    http_doc = HTTP_DOC.read_text() if HTTP_DOC.exists() else None
    if http_doc is None:
        failures.append(f"missing {HTTP_DOC.relative_to(REPO)}")
    else:
        for origin, routes in (
            ("service/http.py", service_routes()),
            ("fleet/router.py", router_routes()),
        ):
            for route in sorted(routes - {r for r in routes if r in http_doc}):
                failures.append(
                    f"route {route!r} ({origin}) is not documented in "
                    f"docs/HTTP_API.md"
                )

    metrics_doc = METRICS_DOC.read_text() if METRICS_DOC.exists() else None
    if metrics_doc is None:
        failures.append(f"missing {METRICS_DOC.relative_to(REPO)}")
    else:
        names = metric_names()
        if not names:
            failures.append("metric scan found nothing — scanner regex rotted?")
        for name in sorted(names):
            if name not in metrics_doc:
                failures.append(
                    f"metric series {name!r} is not documented in "
                    f"docs/METRICS.md"
                )

    # The scanner itself must stay honest: an empty route set means the
    # dispatch idiom changed and this script silently stopped guarding.
    if http_doc is not None and not service_routes():
        failures.append("service route scan found nothing — scanner rotted?")
    if http_doc is not None and not router_routes():
        failures.append("router route scan found nothing — scanner rotted?")

    if failures:
        print("docs-consistency check FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(
        "docs-consistency check passed: "
        f"{len(service_routes())} service routes, "
        f"{len(router_routes())} router routes, "
        f"{len(metric_names())} metric series documented."
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
