"""repro: zero-cost NDV estimation integrated into a JAX LM framework."""
__version__ = "1.0.0"
