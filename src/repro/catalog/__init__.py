"""Stats catalog: the system artifact around zero-cost NDV estimation.

The paper's pitch is fleet-scale NDV from footers alone (§1, §10.1); what a
production warehouse actually maintains is not a one-shot estimator call but
a *statistics catalog* — incremental, mergeable, cached per-dataset column
statistics (cf. PLM4NDV and distributed-sampling NDV, which both treat the
catalog as the deliverable). This package is that seam. It owns the whole
path from "directory of columnar files" to "cached dataset-level NDV
estimates and memory plans":

  ingestion   `MetadataSource` — pluggable footer scanning (PQLite today;
              any Parquet/ORC-shaped footer adapter later) with per-file
              *fingerprints* so re-scans skip unchanged footers.
  merging     `merge_column_metadata` — one logical `ColumnMetadata` per
              column across files. Chunk-level arrays concatenate; the
              distinct-min/max counts (§5's m_min/m_max) are re-deduped
              across files, including BYTE_ARRAY stats that collide in the
              truncated 8-byte key space (disambiguated by length + repr).
  packing     `BatchPacker` — vectorized struct-of-arrays packing (numpy
              scatter over all chunks at once, no per-column Python loop)
              with power-of-two *shape bucketing*: the padded (B, R) shape
              fed to the jit'd `estimate_batch` is rounded up to the next
              power of two, so the number of distinct traces is
              O(log B · log R) across a whole fleet instead of one trace
              per dataset shape. Padding lanes are masked out and never
              affect estimates.
  caching     `StatsCatalog` — packed batches are cached per fingerprint
              set, estimates per (fingerprint set, mode, schema bounds,
              engine config). Warm calls re-pack nothing and re-trace
              nothing; `update()` ingests only new/changed files and merges
              them into the existing per-column view instead of re-reading
              the fleet; `save_cache()`/`load_cache()` spill estimates to a
              JSON file next to the dataset so restarts serve warm
              (`save_cache` compacts away entries for stale fingerprint
              sets; `auto_load_cache=True` restores the spill, mtime-guarded,
              at construction).
  execution   estimation itself runs through an injected
              `repro.engine.EstimationEngine` (local / sharded / chunked
              behind one config) — the catalog never calls the jit'd
              `estimate_batch` directly.
  batching    `superpack_estimate` — many (catalog, mode, bounds) jobs
              concatenated along the packed B axis (`concat_batches`) and
              executed as one engine call per (engine, mode, R) group,
              bit-identical per lane to the individual calls and cached
              through the same per-catalog estimate caches. The batched
              RPC tier (`POST /batch`) rides on this seam.

Everything downstream (data/pipeline planning, NDVPlanner, benchmarks, and
the `repro.service` async-ingestion + stats-serving layer) talks to this
package instead of touching footers directly. Footer I/O and state commit
are split (`StatsCatalog.apply_footers`) so ingestion can be scattered over
threads while the merge-and-swap stays atomic.
"""
from repro.catalog.catalog import (  # noqa: F401
    CatalogStats,
    FileEntry,
    StatsCatalog,
    UpdateSummary,
    estimate_from_json,
    estimate_to_json,
)
from repro.catalog.merge import merge_column_metadata  # noqa: F401
from repro.catalog.packer import (  # noqa: F401
    BatchPacker,
    bucket_size,
    concat_batches,
)
from repro.catalog.superpack import (  # noqa: F401
    SuperpackJob,
    SuperpackResult,
    superpack_estimate,
)
from repro.catalog.source import (  # noqa: F401
    InMemoryMetadataSource,
    MetadataSource,
    PQLiteMetadataSource,
)
