"""`StatsCatalog`: cached, incremental dataset-level NDV estimation.

The catalog's contract (see the package docstring for the design):

  * `update()` scans the source, re-reading only footers whose fingerprint
    changed, and maintains one merged `ColumnMetadata` per column. Pure
    additions merge into the existing view (O(new files)); any rewrite or
    removal triggers a full re-merge. The footer I/O and the commit are
    split: `apply_footers()` is the atomic merge-and-swap seam, so the
    async ingestor (`repro.service`) can scatter-gather footers on a thread
    pool and commit through the same code path.
  * `estimate()` packs the merged view through the bucketing `BatchPacker`
    and executes through an injected `EstimationEngine` (local / sharded /
    chunked / composed — see `repro.engine`). Packed batches are cached
    per (fingerprint set, packer) and promoted once per fingerprint
    generation into a device-resident tier (`jax.device_put`, blocked until
    materialized), so every estimate call against an unchanged dataset —
    across modes, schema bounds, and engines — reuses the same on-device
    arrays with zero host-to-device traffic. Estimates are cached per
    (fingerprint set, mode, schema bounds, engine identity) — a warm call
    performs zero packing and zero tracing, just a dict hit. Engine
    identity is `cache_key`:
    only the numerics-bearing backend, so engines differing merely in
    execution shape (strategy, shards, chunk budget — all bit-identical
    by the parity contract) share entries, and a strategy change never
    cools the cache; engines that could answer differently never share.
  * `save_cache()` / `load_cache()` spill the estimate cache to a JSON file
    next to the dataset so restarts serve warm.
  * `plan()` turns estimates into `NDVPlanner` memory plans.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import tempfile
import threading
from collections import OrderedDict
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple, Union

try:
    import fcntl
except ImportError:  # non-POSIX: fall back to atomic-replace-only safety
    fcntl = None

import jax
import jax.numpy as jnp
import numpy as np

from repro.catalog.merge import merge_column_metadata
from repro.catalog.packer import BatchPacker
from repro.obs import span as _obs_span
from repro.catalog.source import MetadataSource, PQLiteMetadataSource
from repro.core.ndv.estimator import (
    Provenance,
    estimates_from_batch,
    provenance_from_batch,
    record_provenance_metrics,
)
from repro.core.ndv.types import ColumnBatch, ColumnMetadata, Layout, NDVEstimate

CACHE_FILE_NAME = ".ndv_estimate_cache.json"
# v2: engine identity in entry keys went from the 4-field config tuple to
# the backend-only `cache_key` (strategy/shards/budget are numerics-neutral).
# v1 files load as clean cold starts instead of as permanently-unreachable
# entries that the merge-not-clobber save path would re-persist forever.
_CACHE_VERSION = 2

# One lock per spill path: replicas of the same dataset inside one process
# (the fleet tier runs several `StatsService`s over one root) serialize
# their read-merge-write cycles here. Cross-PROCESS writers are covered by
# the atomic tempfile + `os.replace` protocol plus the mtime/fingerprint
# guard in `save_cache()` — a reader never observes a torn file, and a
# concurrent writer's entries are merged rather than clobbered whenever the
# mtime reveals them.
_SPILL_LOCKS: Dict[str, threading.Lock] = {}
_SPILL_LOCKS_MU = threading.Lock()


def _spill_lock(path: str) -> threading.Lock:
    key = os.path.abspath(path)
    with _SPILL_LOCKS_MU:
        lock = _SPILL_LOCKS.get(key)
        if lock is None:
            lock = _SPILL_LOCKS[key] = threading.Lock()
        return lock


@contextlib.contextmanager
def _cross_process_spill_lock(path: str):
    """Advisory flock on a sidecar `<path>.lock` spanning one writer's
    read-merge-write cycle, so two PROCESSES cannot interleave between the
    merge read and the `os.replace` and drop each other's entries. No-op
    where `fcntl` is unavailable — atomic replace still guarantees
    readers a consistent file there, only cross-process merge completeness
    degrades to best-effort."""
    if fcntl is None:
        yield
        return
    fd = os.open(path + ".lock", os.O_CREAT | os.O_RDWR, 0o644)
    try:
        fcntl.flock(fd, fcntl.LOCK_EX)
        yield
    finally:
        os.close(fd)  # closing drops the flock


def estimate_to_json(est: NDVEstimate) -> dict:
    """`NDVEstimate` -> plain-JSON dict (enums as ints, floats untouched)."""
    d = {
        f.name: getattr(est, f.name)
        for f in dataclasses.fields(NDVEstimate)
        if f.name != "layout"
    }
    d["layout"] = int(est.layout)
    return d


def estimate_from_json(d: dict) -> NDVEstimate:
    """Inverse of `estimate_to_json`.

    Bit-exact: Python's json emits shortest-round-trip float reprs, so a
    serialized estimate reconstructs `==` to the original — the cache spill
    and the stats-service wire format both rely on this.
    """
    return NDVEstimate(**{**d, "layout": Layout(d["layout"])})


@dataclasses.dataclass(frozen=True)
class FileEntry:
    """One ingested file: identity, change token, parsed footer."""

    file_id: str
    fingerprint: str
    footer: object  # FileFooter-shaped


class UpdateSummary(NamedTuple):
    added: int
    updated: int
    removed: int
    total: int

    @property
    def changed(self) -> bool:
        return bool(self.added or self.updated or self.removed)


@dataclasses.dataclass
class CatalogStats:
    """Observability counters (asserted by tests and benchmarks)."""

    footers_read: int = 0
    merges: int = 0
    packs: int = 0
    estimate_cache_hits: int = 0
    estimate_cache_misses: int = 0
    device_puts: int = 0      # batches promoted to the device-resident tier
    resident_hits: int = 0    # estimate calls served from resident arrays


class StatsCatalog:
    """Dataset-level statistics catalog over a `MetadataSource`."""

    def __init__(
        self,
        source: Union[MetadataSource, str],
        *,
        packer: Optional[BatchPacker] = None,
        engine=None,
        max_cache_entries: int = 64,
        auto_load_cache: bool = False,
    ):
        from repro import engine as engine_mod  # local: avoid import cycle

        if isinstance(source, str):
            source = PQLiteMetadataSource(source)
        self.source = source
        self.engine = engine or engine_mod.default_engine()
        self.packer = packer or self.engine.make_packer()
        self.stats = CatalogStats()
        self._entries: "OrderedDict[str, FileEntry]" = OrderedDict()
        self._merged: Optional[Dict[str, ColumnMetadata]] = None
        self._column_names: List[str] = []
        self._batch_cache: "OrderedDict[frozenset, ColumnBatch]" = OrderedDict()
        self._resident_cache: "OrderedDict[frozenset, ColumnBatch]" = (
            OrderedDict()
        )
        self._estimate_cache: "OrderedDict[tuple, Dict[str, NDVEstimate]]" = (
            OrderedDict()
        )
        # Per-estimate provenance, keyed like `_estimate_cache`. NEVER
        # spilled: the on-disk format (and with it every body/ETag the
        # service derives) stays byte-identical to the pre-provenance
        # layout; a spill-warmed entry recomputes provenance on demand.
        self._provenance_cache: "OrderedDict[tuple, Dict[str, Provenance]]" = (
            OrderedDict()
        )
        self._max_cache_entries = max_cache_entries
        self._scanned = False
        self._fp_key: Optional[frozenset] = None
        self._cache_file_mtime_ns: Optional[int] = None
        if auto_load_cache:
            self.maybe_load_cache()

    # -- ingestion -----------------------------------------------------------

    def update(self) -> UpdateSummary:
        """Re-scan the source; ingest new/changed footers, drop removed ones.

        A file that vanishes between listing and reading (its fingerprint or
        footer raises FileNotFoundError) is treated exactly like a file the
        listing never returned: it is reported as removed if it was
        previously ingested, never as added — the same semantics the async
        ingestion path (`repro.service.AsyncIngestor`) applies.

        All catalog state (entries, merged view, cached fingerprint key) is
        committed only after merging succeeds, so a failed update — e.g. a
        schema-mismatched file — leaves the previous consistent view intact.
        """
        fresh: List[FileEntry] = []
        live_ids: List[str] = []
        for fid in self.source.list_files():
            try:
                fp = self.source.fingerprint(fid)
                prev = self._entries.get(fid)
                if prev is not None and prev.fingerprint == fp:
                    live_ids.append(fid)
                    continue
                footer = self.source.read_footer(fid)
            except FileNotFoundError:
                continue  # vanished mid-scan: counted as removed, not added
            self.stats.footers_read += 1
            fresh.append(FileEntry(fid, fp, footer))
            live_ids.append(fid)
        return self.apply_footers(fresh, live_ids=live_ids)

    def apply_footers(
        self, fresh: Sequence[FileEntry], *, live_ids: Sequence[str]
    ) -> UpdateSummary:
        """Commit prefetched footers — the ingestion seam below `update()`.

        `live_ids` is the authoritative set of files that currently exist
        (its order becomes the entry iteration order); `fresh` carries a
        parsed `FileEntry` for every live id that is new or changed. Ids in
        `live_ids` with no fresh entry must already be ingested (their
        previous entry is reused); previously-ingested ids absent from
        `live_ids` are dropped and reported as removed. A fresh entry whose
        fingerprint matches the existing one (an ingestion race re-read an
        unchanged footer) is a no-op, not an update.

        This is the single commit point for both the synchronous `update()`
        loop and the scatter-gathered async path: footer I/O can happen
        anywhere, concurrently, while the merge + state swap stays atomic —
        on any failure (e.g. schema mismatch) the previous consistent view
        keeps serving.
        """
        by_id = {e.file_id: e for e in fresh}
        added = updated = 0
        new_entries: "OrderedDict[str, FileEntry]" = OrderedDict()
        applied: List[FileEntry] = []
        for fid in live_ids:
            entry = by_id.get(fid)
            prev = self._entries.get(fid)
            if entry is None:
                if prev is None:
                    raise ValueError(
                        f"live file {fid!r} has neither a previous catalog "
                        f"entry nor a prefetched footer"
                    )
                new_entries[fid] = prev
                continue
            if prev is not None and prev.fingerprint == entry.fingerprint:
                new_entries[fid] = prev
                continue
            new_entries[fid] = entry
            applied.append(entry)
            if prev is None:
                added += 1
            else:
                updated += 1
        removed = len(set(self._entries) - set(new_entries))
        pure_addition = updated == 0 and removed == 0
        if not new_entries:
            merged, names = {}, []
        elif self._merged is not None and pure_addition and not applied:
            merged, names = self._merged, self._column_names
        elif self._merged and pure_addition:
            merged, names = self._merge_into(applied)
        else:
            merged, names = self._merge_all(list(new_entries.values()))
        # commit point: merge succeeded, swap the whole view atomically
        self._scanned = True
        self._entries = new_entries
        self._merged, self._column_names = merged, names
        self._fp_key = None
        summary = UpdateSummary(added, updated, removed, len(new_entries))
        if summary.changed:
            # The resident tier holds device memory for exactly one reason:
            # serving the live fingerprint generation without re-transfer.
            # A changed commit makes every resident batch stale, so release
            # the device arrays here rather than waiting for LRU pressure.
            self._resident_cache.clear()
        return summary

    def _per_file(self, entry: FileEntry, names: Sequence[str]) -> List[ColumnMetadata]:
        try:
            return [self.source.column_metadata(entry.footer, n) for n in names]
        except KeyError as e:
            raise ValueError(
                f"file {entry.file_id!r} is missing column {e.args[0]!r} "
                f"expected by the dataset schema {list(names)}"
            ) from e

    @staticmethod
    def _check_schema(names: Sequence[str], entry: FileEntry) -> None:
        got = set(entry.footer.column_names)
        if got != set(names):
            missing = sorted(set(names) - got)
            extra = sorted(got - set(names))
            raise ValueError(
                f"file {entry.file_id!r} does not match the dataset schema: "
                f"missing columns {missing}, unexpected columns {extra}"
            )

    def _merge_all(self, entries: List[FileEntry]) -> tuple:
        names = list(entries[0].footer.column_names)
        for e in entries[1:]:
            self._check_schema(names, e)
        per_file = [self._per_file(e, names) for e in entries]
        merged = {
            name: merge_column_metadata([pf[i] for pf in per_file])
            for i, name in enumerate(names)
        }
        self.stats.merges += 1
        return merged, names

    def _merge_into(self, fresh: List[FileEntry]) -> tuple:
        names = self._column_names
        for e in fresh:
            self._check_schema(names, e)
        per_file = [self._per_file(e, names) for e in fresh]
        merged = dict(self._merged)
        for i, name in enumerate(names):
            merged[name] = merge_column_metadata(
                [merged[name]] + [pf[i] for pf in per_file]
            )
        self.stats.merges += 1
        return merged, names

    def _ensure_scanned(self) -> None:
        if not self._scanned:
            self.update()

    # -- views ---------------------------------------------------------------

    @property
    def scanned(self) -> bool:
        """Whether any scan has committed (False = no view to serve yet)."""
        return self._scanned

    @property
    def num_files(self) -> int:
        self._ensure_scanned()
        return len(self._entries)

    @property
    def column_names(self) -> List[str]:
        self._ensure_scanned()
        return list(self._column_names)

    @property
    def files(self) -> List[str]:
        self._ensure_scanned()
        return list(self._entries)

    def fingerprint_key(self) -> frozenset:
        """Identity of the current dataset state (the cache key).

        Computed once per `update()` — warm `estimate()` calls stay O(1)
        in file count (update() is the only mutation point).
        """
        self._ensure_scanned()
        if self._fp_key is None:
            self._fp_key = frozenset(
                f"{e.file_id}@{e.fingerprint}" for e in self._entries.values()
            )
        return self._fp_key

    def entry_fingerprints(self) -> Dict[str, str]:
        """Snapshot of ingested file id -> fingerprint.

        Unlike `files`, this never triggers a scan: the async ingestor uses
        it to diff a fresh fingerprint sweep against the committed state
        without forcing the synchronous `update()` path.
        """
        return {fid: e.fingerprint for fid, e in self._entries.items()}

    def merged_metadata(self) -> Dict[str, ColumnMetadata]:
        """One logical ColumnMetadata per column, across all files."""
        self._ensure_scanned()
        return dict(self._merged or {})

    def non_nulls(self) -> Dict[str, float]:
        return {n: m.non_null for n, m in self.merged_metadata().items()}

    def total_rows(self) -> int:
        """Total row count across every ingested file (footer sums only).

        The planner's base-cardinality input (`|R|` in the join-size
        formula) — like everything else here it comes from metadata the
        footers already carry, never from scanning data.
        """
        self._ensure_scanned()
        return sum(e.footer.num_rows for e in self._entries.values())

    # -- estimation ----------------------------------------------------------

    def _packed(self, key: frozenset) -> ColumnBatch:
        """Packed batch for a fingerprint generation, device-resident.

        Two tiers: `_batch_cache` holds the packer's output (one pack per
        fingerprint set), `_resident_cache` holds that batch explicitly
        `jax.device_put` and blocked until materialized — transferred ONCE
        per fingerprint generation, then reused by every estimate call
        (across modes, bounds, and engines) with zero host-to-device
        traffic on the warm path. Both tiers share the same LRU bound;
        resident entries are additionally dropped eagerly whenever an
        `apply_footers` commit changes the dataset.
        """
        resident = self._resident_cache.get(key)
        if resident is not None:
            self.stats.resident_hits += 1
            self._resident_cache.move_to_end(key)
            return resident
        batch = self._batch_cache.get(key)
        if batch is None:
            cols = [self._merged[n] for n in self._column_names]
            with _obs_span("engine.pack", columns=len(cols)):
                batch = self.packer.pack(cols)
            self.stats.packs += 1
            self._cache_put(self._batch_cache, key, batch)
        else:
            self._batch_cache.move_to_end(key)
        # No target device: placement stays uncommitted (default device), so
        # the sharded/composed strategies remain free to lay the batch out
        # across their mesh without fighting a pinned placement.
        with _obs_span("engine.h2d", batch=int(batch.batch)):
            resident = jax.device_put(batch)
            jax.block_until_ready(resident)
        self.stats.device_puts += 1
        self._cache_put(self._resident_cache, key, resident)
        return resident

    @property
    def num_resident_batches(self) -> int:
        """Batches currently held in the device-resident tier.

        Observability for the residency lifecycle: rises to 1 after the
        first estimate of a fingerprint generation, drops to 0 when an
        `apply_footers` commit changes the dataset (tests and the fleet
        tier's memory accounting read this).
        """
        return len(self._resident_cache)

    def _cache_put(self, cache: OrderedDict, key, value) -> None:
        cache[key] = value
        cache.move_to_end(key)
        while len(cache) > self._max_cache_entries:
            cache.popitem(last=False)

    def estimate_key(
        self,
        *,
        mode: str = "paper",
        schema_bounds: Optional[Dict[str, float]] = None,
        engine=None,
    ) -> tuple:
        """The estimate-cache key one `estimate()` call would use.

        Shared with `repro.catalog.superpack`, which probes and fills the
        same cache so super-packed and individual estimates are one cache
        population (and one spill file).
        """
        self._ensure_scanned()
        engine = engine or self.engine
        sb_key = (
            tuple(sorted(schema_bounds.items())) if schema_bounds else None
        )
        return (self.fingerprint_key(), mode, sb_key, engine.cache_key)

    def bounds_array(
        self, schema_bounds: Optional[Dict[str, float]], width: int
    ) -> Optional[np.ndarray]:
        """Per-lane schema-bound array for a `width`-lane packed batch.

        Unnamed and padding lanes get +inf ("no bound" — the combine step's
        identity); None when no bounds were given (the engine materializes
        the same +inf lanes itself, bit-identically).
        """
        if not schema_bounds:
            return None
        arr = np.full(width, np.inf, np.float32)
        for i, name in enumerate(self._column_names):
            if name in schema_bounds:
                arr[i] = float(schema_bounds[name])
        return arr

    def packed_batch(self) -> ColumnBatch:
        """The current fingerprint generation's packed batch (cached,
        device-resident — see `_packed`)."""
        self._ensure_scanned()
        return self._packed(self.fingerprint_key())

    def estimate_cache_peek(self, key: tuple) -> Optional[Dict[str, NDVEstimate]]:
        """Cache probe by `estimate_key()`, counting hit/miss like
        `estimate()` does. Returns a copy, or None on miss."""
        cached = self._estimate_cache.get(key)
        if cached is not None:
            self.stats.estimate_cache_hits += 1
            self._estimate_cache.move_to_end(key)
            return dict(cached)
        self.stats.estimate_cache_misses += 1
        return None

    def estimate_cache_store(
        self, key: tuple, result: Dict[str, NDVEstimate]
    ) -> None:
        """Insert an externally-computed estimate map under `estimate_key()`.

        The superpack write-back seam: results land in the same LRU the
        spill serializes, so batched cold estimates warm-start restarts
        exactly like individually-computed ones.
        """
        self._cache_put(self._estimate_cache, key, dict(result))

    def estimate(
        self,
        *,
        mode: str = "paper",
        schema_bounds: Optional[Dict[str, float]] = None,
        engine=None,
    ) -> Dict[str, NDVEstimate]:
        """Dataset-level NDV estimates for every column (cached).

        Args:
          mode: "paper" or "improved" — threaded to `estimate_batch`.
          schema_bounds: optional column -> upper-bound NDV (Eq 14-15 family
            of schema knowledge, e.g. an enum's domain size).
          engine: optional `EstimationEngine` override for this call. The
            cache key includes the engine's numeric identity
            (`engine.cache_key` — the backend), so engines that could
            answer differently are cached independently while execution
            shapes that are bit-identical by the parity contract share.
        """
        self._ensure_scanned()
        engine = engine or self.engine
        key = self.estimate_key(
            mode=mode, schema_bounds=schema_bounds, engine=engine
        )
        cached = self.estimate_cache_peek(key)
        if cached is not None:
            return cached
        if not self._column_names:
            return {}
        batch = self._packed(self.fingerprint_key())
        arr = self.bounds_array(schema_bounds, batch.batch)
        sb = None if arr is None else jnp.asarray(arr)
        out = engine.estimate(batch, sb, mode=mode)
        with _obs_span("engine.d2h", columns=len(self._column_names)):
            ests = estimates_from_batch(out, batch, self._column_names)
            provs = provenance_from_batch(out, batch, self._column_names)
        result = {e.column_name: e for e in ests}
        self._cache_put(self._estimate_cache, key, result)
        self.provenance_cache_store(key, {p.column_name: p for p in provs})
        return dict(result)

    def estimate_column(self, name: str, *, mode: str = "paper") -> NDVEstimate:
        return self.estimate(mode=mode)[name]

    # -- provenance ----------------------------------------------------------

    def provenance_cache_peek(
        self, key: tuple
    ) -> Optional[Dict[str, Provenance]]:
        """Provenance probe by `estimate_key()`; copy on hit, None on miss.

        Unlike `estimate_cache_peek` this counts nothing — provenance is a
        diagnostic sidecar, and its hit rate must not perturb the estimate
        counters tests and dashboards assert on.
        """
        cached = self._provenance_cache.get(key)
        if cached is None:
            return None
        self._provenance_cache.move_to_end(key)
        return dict(cached)

    def provenance_cache_store(
        self, key: tuple, provs: Dict[str, Provenance]
    ) -> None:
        """Insert freshly-materialized provenance and observe its metrics.

        The single funnel for both the direct `estimate()` path and the
        superpack write-back: `ndv_route_total`/`ndv_newton_iters`/
        `ndv_detector_margin` are recorded exactly once per engine run here,
        never on cache hits.
        """
        record_provenance_metrics(list(provs.values()))
        self._cache_put(self._provenance_cache, key, dict(provs))

    def provenance(
        self,
        *,
        mode: str = "paper",
        schema_bounds: Optional[Dict[str, float]] = None,
        engine=None,
    ) -> Dict[str, Provenance]:
        """Per-column provenance for the same state `estimate()` serves.

        Usually a cache hit (filled alongside every engine run). A miss —
        the estimate was warmed from the on-disk spill, which deliberately
        carries no diagnostics — recomputes through the engine; the
        estimates produced on the way are bit-identical by contract and
        refresh the estimate cache too.
        """
        self._ensure_scanned()
        engine = engine or self.engine
        key = self.estimate_key(
            mode=mode, schema_bounds=schema_bounds, engine=engine
        )
        cached = self.provenance_cache_peek(key)
        if cached is not None:
            return cached
        if not self._column_names:
            return {}
        batch = self._packed(self.fingerprint_key())
        arr = self.bounds_array(schema_bounds, batch.batch)
        sb = None if arr is None else jnp.asarray(arr)
        out = engine.estimate(batch, sb, mode=mode)
        with _obs_span("engine.d2h", columns=len(self._column_names)):
            ests = estimates_from_batch(out, batch, self._column_names)
            provs = provenance_from_batch(out, batch, self._column_names)
        self._cache_put(
            self._estimate_cache, key, {e.column_name: e for e in ests}
        )
        result = {p.column_name: p for p in provs}
        self.provenance_cache_store(key, result)
        return dict(result)

    def provenance_entries(self) -> List[Tuple[tuple, Dict[str, Provenance]]]:
        """Snapshot of the provenance cache (the `/debug/explain` source)."""
        return [(k, dict(v)) for k, v in self._provenance_cache.items()]

    # -- estimate-cache persistence ------------------------------------------

    def _default_cache_path(self) -> str:
        root = getattr(self.source, "root", None)
        if root is None:
            raise ValueError(
                "this catalog's source has no filesystem root; pass an "
                "explicit path to save_cache()/load_cache()"
            )
        return os.path.join(root, CACHE_FILE_NAME)

    @staticmethod
    def _key_to_json(key: tuple) -> dict:
        fp_key, mode, sb_key, engine_key = key
        return {
            "files": sorted(fp_key),
            "mode": mode,
            "schema_bounds": (
                [[n, v] for n, v in sb_key] if sb_key is not None else None
            ),
            "engine": list(engine_key),
        }

    @staticmethod
    def _key_from_json(d: dict) -> tuple:
        sb = d["schema_bounds"]
        return (
            frozenset(d["files"]),
            d["mode"],
            tuple((n, v) for n, v in sb) if sb is not None else None,
            tuple(d["engine"]),
        )

    def _read_spill(
        self, path: str
    ) -> Tuple[Optional[List[tuple]], Optional[int]]:
        """Parse an existing spill file -> ([(key, estimates)], mtime_ns).

        ``(None, None)`` when the file is missing, version-mismatched, or
        unparseable (a foreign writer is mid-protocol — treat as absent
        rather than fail the save).
        """
        try:
            mtime_ns = os.stat(path).st_mtime_ns
            with open(path) as f:
                payload = json.load(f)
            if payload.get("version") != _CACHE_VERSION:
                return None, None
            items = [
                (
                    self._key_from_json(entry["key"]),
                    {
                        name: estimate_from_json(d)
                        for name, d in entry["estimates"].items()
                    },
                )
                for entry in payload["entries"]
            ]
        except (FileNotFoundError, json.JSONDecodeError,
                KeyError, TypeError, ValueError, AttributeError):
            # valid-JSON-wrong-shape is as foreign as non-JSON
            return None, None
        return items, mtime_ns

    def save_cache(self, path: Optional[str] = None, *, compact: bool = True) -> str:
        """Spill the estimate cache to a JSON file next to the dataset.

        Values survive a round trip exactly: floats serialize at full
        double precision, so a warm restart serves bit-identical
        `NDVEstimate`s. Returns the path written.

        With ``compact=True`` (the default) the pass drops entries whose
        fingerprint set no longer matches the live dataset state before
        writing: stale keys are unreachable anyway (any rewrite changed the
        fingerprint set) and would otherwise accumulate in the file across
        every rewrite the LRU happened to retain. ``compact=False`` persists
        the LRU verbatim, useful when several dataset states legitimately
        coexist (e.g. snapshotting mid-migration).

        Safe under concurrent writers (replicas of one dataset spilling to
        the shared file):

          * the payload goes to a uniquely-named temp file in the target
            directory and lands via `os.replace`, so a racing reader or
            writer never observes a torn spill, no matter how many
            processes write;
          * on the compact path, live-fingerprint entries already on disk
            are merged into what we write (union; our values win — by the
            engine parity contract they are bit-identical anyway), so two
            replicas spilling different (mode, bounds, engine) entries
            enrich rather than clobber each other;
          * the write is skipped entirely when the on-disk spill is newer
            than the last state this catalog loaded or saved AND already
            fingerprint-compatible with everything we would write —
            another replica got there first with a superset.
        """
        path = path or self._default_cache_path()
        with _spill_lock(path), _cross_process_spill_lock(path):
            items = list(self._estimate_cache.items())
            if compact:
                live = self.fingerprint_key()
                items = [(k, v) for k, v in items if k[0] == live]
                disk_items, disk_mtime_ns = self._read_spill(path)
                if disk_items is not None:
                    disk_live = [(k, v) for k, v in disk_items if k[0] == live]
                    ours = {k for k, _ in items}
                    if (
                        disk_mtime_ns != self._cache_file_mtime_ns
                        and ours <= {k for k, _ in disk_live}
                    ):
                        return path
                    merged = OrderedDict(disk_live)
                    merged.update(items)
                    items = list(merged.items())
            entries = []
            for key, ests in items:
                entries.append({
                    "key": self._key_to_json(key),
                    "estimates": {
                        name: estimate_to_json(e) for name, e in ests.items()
                    },
                })
            payload = {"version": _CACHE_VERSION, "entries": entries}
            fd, tmp = tempfile.mkstemp(
                dir=os.path.dirname(path) or ".",
                prefix=os.path.basename(path) + ".",
                suffix=".tmp",
            )
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(payload, f)
                # Record the temp file's mtime BEFORE the replace: it is
                # the mtime our bytes carry into `path` (os.replace keeps
                # the inode), whereas re-statting the shared path after the
                # replace could capture a sibling's even-newer write and
                # alias it as already-loaded forever.
                mtime_ns = os.stat(tmp).st_mtime_ns
                os.replace(tmp, path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
            self._cache_file_mtime_ns = mtime_ns
        return path

    def load_cache(self, path: Optional[str] = None) -> int:
        """Load spilled estimates; returns the number of entries restored.

        Missing file is not an error (cold start). Entries whose
        fingerprint set no longer matches the live dataset are still
        loaded — the fingerprint set in the key makes stale entries
        unreachable, and LRU eviction discards them.
        """
        path = path or self._default_cache_path()
        with _spill_lock(path):
            items, _ = self._read_spill(path)
        if items is None:
            return 0
        for key, ests in items:
            self._cache_put(self._estimate_cache, key, ests)
        return len(items)

    def maybe_load_cache(self, path: Optional[str] = None) -> int:
        """mtime-guarded `load_cache()`: load only when the file changed.

        Remembers the cache file's mtime at each load, so construction with
        ``auto_load_cache=True`` and periodic service-side refresh calls are
        free when nothing rewrote the file. Returns the number of entries
        restored (0 when the file is missing or unchanged).
        """
        path = path or self._default_cache_path()
        try:
            mtime_ns = os.stat(path).st_mtime_ns
        except FileNotFoundError:
            return 0
        if mtime_ns == self._cache_file_mtime_ns:
            return 0
        loaded = self.load_cache(path)
        self._cache_file_mtime_ns = mtime_ns
        return loaded

    def compact_caches(self) -> int:
        """Drop in-memory batch/estimate entries for stale fingerprint sets.

        The service layer calls this after each committed refresh that
        changed the dataset, so long-running servers do not pin packed
        batches and estimate maps for states that can never be requested
        again. Returns the number of entries dropped.
        """
        live = self.fingerprint_key()
        dropped = 0
        for key in [k for k in self._batch_cache if k != live]:
            del self._batch_cache[key]
            dropped += 1
        for key in [k for k in self._resident_cache if k != live]:
            del self._resident_cache[key]
            dropped += 1
        for key in [k for k in self._estimate_cache if k[0] != live]:
            del self._estimate_cache[key]
            dropped += 1
        for key in [k for k in self._provenance_cache if k[0] != live]:
            del self._provenance_cache[key]
            dropped += 1
        return dropped

    # -- planning ------------------------------------------------------------

    def plan(self, planner=None, *, mode: str = "paper", engine=None):
        """Memory plans for every column via `NDVPlanner.plan_catalog`."""
        from repro.core.planner import NDVPlanner

        return (planner or NDVPlanner()).plan_catalog(
            self, mode=mode, engine=engine
        )
