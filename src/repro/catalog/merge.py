"""Cross-file metadata merging: N per-file column views -> one logical view.

Chunk-granular fields (sizes, rows, nulls, encodings, min/max stats) simply
concatenate — the estimator is already chunk-oriented and does not care
which file a chunk came from. The subtle part is §5's m_min/m_max: the
number of *distinct* row-group min (max) statistics must be deduped across
the whole file set, not summed per file.

For numeric types the float64 order key IS the value, so uniqueness over
the concatenated key arrays is exact. For BYTE_ARRAY the key is only an
order-preserving 8-byte prefix: two distinct strings can share a key. We
disambiguate by (key, byte length, repr) when reprs are carried (the PQLite
reader always carries them) and by (key, byte length) otherwise — the same
resolution `column_metadata_from_footer` applies within a single file, so
single-file merges are exact fixed points: merge([m]) keeps m's counts.

`merge_column_metadata` is associative in the fields the estimator reads:
merging an already-merged view with newly-arrived per-file views gives the
same result as merging everything from scratch, which is what makes
`StatsCatalog.update()` incremental.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.ndv.types import ColumnMetadata, PhysicalType

_BYTES_LIKE = (PhysicalType.BYTE_ARRAY, PhysicalType.FIXED_LEN_BYTE_ARRAY)


def _concat_reprs(parts: Sequence[ColumnMetadata], field: str) -> Optional[np.ndarray]:
    arrs = [getattr(p, field) for p in parts]
    if any(a is None for a in arrs):
        return None
    return np.concatenate([np.asarray(a, object) for a in arrs])


def distinct_stat_count(
    keys: np.ndarray,
    lengths: np.ndarray,
    reprs: Optional[np.ndarray],
    ptype: PhysicalType,
) -> float:
    """Count distinct min (or max) statistics across row groups.

    Numeric keys are exact; byte-array keys are truncated prefixes and are
    refined by length and, when available, the stat repr.
    """
    keys = np.asarray(keys, np.float64)
    if ptype not in _BYTES_LIKE:
        return float(np.unique(keys).size)
    lengths = np.asarray(lengths)
    if reprs is not None and len(reprs) == len(keys):
        ident = {
            (float(k), int(l), str(r))
            for k, l, r in zip(keys, lengths, reprs)
        }
    else:
        ident = {(float(k), int(l)) for k, l in zip(keys, lengths)}
    return float(len(ident))


def merge_column_metadata(parts: Sequence[ColumnMetadata]) -> ColumnMetadata:
    """Merge per-file views of ONE column into a single logical view."""
    if not parts:
        raise ValueError("merge_column_metadata: empty input")
    first = parts[0]
    for p in parts[1:]:
        if p.physical_type != first.physical_type:
            raise ValueError(
                f"column {first.column_name!r}: physical type mismatch "
                f"{first.physical_type.name} vs {p.physical_type.name}"
            )
        if p.column_name != first.column_name:
            raise ValueError(
                f"cannot merge columns {first.column_name!r} and {p.column_name!r}"
            )
    if len(parts) == 1:
        return first

    cat = lambda f, dt: np.concatenate(  # noqa: E731
        [np.asarray(getattr(p, f), dt) for p in parts]
    )
    mins = cat("mins", np.float64)
    maxs = cat("maxs", np.float64)
    min_lengths = cat("min_lengths", np.float64)
    max_lengths = cat("max_lengths", np.float64)
    min_reprs = _concat_reprs(parts, "min_reprs")
    max_reprs = _concat_reprs(parts, "max_reprs")
    return ColumnMetadata(
        chunk_sizes=cat("chunk_sizes", np.float64),
        chunk_rows=cat("chunk_rows", np.float64),
        chunk_nulls=cat("chunk_nulls", np.float64),
        chunk_dict_encoded=cat("chunk_dict_encoded", bool),
        mins=mins,
        maxs=maxs,
        min_lengths=min_lengths,
        max_lengths=max_lengths,
        distinct_min_count=distinct_stat_count(
            mins, min_lengths, min_reprs, first.physical_type
        ),
        distinct_max_count=distinct_stat_count(
            maxs, max_lengths, max_reprs, first.physical_type
        ),
        physical_type=first.physical_type,
        column_name=first.column_name,
        min_reprs=min_reprs,
        max_reprs=max_reprs,
    )
