"""Vectorized ColumnBatch packing with power-of-two shape bucketing.

Replaces the historical per-column Python loop in `ColumnBatch.from_columns`
with whole-batch numpy operations: every per-chunk field of every column is
concatenated once and scattered into the padded (B, R) plane with a single
fancy-indexed assignment; per-column scalars (row counts, mean statistic
lengths, distinct min/max counts) come from `np.bincount` segment sums over
the same flat layout.

Shape bucketing is the retrace control: `estimate_batch` is jit-compiled
per (B, R) shape, so a fleet where every dataset has a different column
count / row-group count would retrace once per dataset. Rounding both axes
up to the next power of two (with small floors) caps distinct shapes at
O(log B · log R) while the padding lanes stay fully masked (`valid=False`,
`n_groups=0`) — estimates for real lanes are bit-identical to the unpadded
pack because every estimator reduction is masked or per-lane.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ndv.types import ColumnBatch, ColumnMetadata, PhysicalType

# Per-PhysicalType lookup tables, indexed by the enum value.
_N_TYPES = max(int(t) for t in PhysicalType) + 1
_FIXED_WIDTH = np.zeros(_N_TYPES, np.float32)
_INT_LIKE = np.zeros(_N_TYPES, bool)
for _t in PhysicalType:
    _FIXED_WIDTH[int(_t)] = float(_t.fixed_width or 0)
    _INT_LIKE[int(_t)] = _t.is_integer_like
_BYTE_ARRAY = int(PhysicalType.BYTE_ARRAY)


def bucket_size(n: int, floor: int = 1) -> int:
    """Round n up to the next power of two, at least `floor`."""
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()


def concat_batches(
    batches: Sequence[ColumnBatch], *, pad_to: Optional[int] = None
) -> ColumnBatch:
    """Concatenate packed batches along the column (B) axis.

    The super-pack primitive: several already-packed `ColumnBatch`es become
    one batch of `sum(B_i)` lanes (optionally zero-padded up to `pad_to`),
    executable as a single engine call. Lane `offset_i + j` of the result is
    lane `j` of batch `i`, where `offset_i = sum(B_k for k < i)`.

    Exactness: concatenation along B is bit-identical per lane because no
    estimator op mixes information across the B axis (the engine re-tiling
    contract), and B padding lanes are the packer's own fully-masked zeros.
    Batches with ragged row-group (R) axes are zero-padded to the common
    max — those cells are masked (`valid=False`) so results stay correct,
    but masked R-axis *reductions* may re-associate at the longer width, so
    callers that need bit-identity with each batch's standalone estimate
    should group same-R batches (as `superpack_estimate` does) rather than
    mix widths.
    """
    if not batches:
        raise ValueError("concat_batches needs at least one batch")
    R = max(b.max_groups for b in batches)
    total = sum(b.batch for b in batches)
    target = max(int(pad_to or 0), total)

    def cat(*leaves):
        parts = []
        for x in leaves:
            if x.ndim == 2 and x.shape[1] < R:
                x = jnp.pad(x, ((0, 0), (0, R - x.shape[1])))
            parts.append(x)
        out = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
        if out.shape[0] < target:
            pad = [(0, target - out.shape[0])] + [(0, 0)] * (out.ndim - 1)
            out = jnp.pad(out, pad)
        return out

    if len(batches) == 1 and batches[0].batch == target:
        return batches[0]  # nothing to do — keep the (resident) arrays as-is
    return jax.tree.map(cat, *batches)


@dataclasses.dataclass(frozen=True)
class BatchPacker:
    """Packs ColumnMetadata sequences into (optionally bucketed) batches.

    Attributes:
      bucket_rows / bucket_cols: round the row-group / column axis up to a
        power of two. Both default True — the catalog path wants bounded
        trace counts; `ColumnBatch.from_columns` disables both for its
        historical exact-shape contract.
      row_floor / col_floor: minimum bucketed sizes, so tiny datasets share
        one trace instead of exercising 1/2/4-wide shapes separately.
      col_multiple: round B up to a multiple of this after bucketing, so a
        sharded engine can split the batch evenly on the B axis. The extra
        lanes are ordinary masked padding (`valid=False`, `n_groups=0`).
      col_chunk: the composed engine's per-shard chunk budget. When
        nonzero, a batch wider than one super-chunk
        (`col_multiple * col_chunk` lanes — one dispatch of `col_chunk`
        per shard) rounds B up to a whole number of super-chunks, so every
        shard's slice splits into equal full chunks: one jit trace shape,
        no ragged tail, no engine-side re-padding. Batches that fit a
        single super-chunk only round to `col_multiple` (plain even
        sharding) — narrow datasets never pad out to a full super-chunk.
    """

    bucket_rows: bool = True
    bucket_cols: bool = True
    row_floor: int = 8
    col_floor: int = 1
    col_multiple: int = 1
    col_chunk: int = 0

    def shape_for(self, num_columns: int, max_groups: int) -> tuple:
        b = (
            bucket_size(num_columns, self.col_floor)
            if self.bucket_cols
            else max(int(num_columns), 1)
        )
        m = max(int(self.col_multiple), 1)
        b = -(-b // m) * m
        stride = m * max(int(self.col_chunk), 0)
        if stride and b > stride:
            b = -(-b // stride) * stride
        r = (
            bucket_size(max_groups, self.row_floor)
            if self.bucket_rows
            else max(int(max_groups), 1)
        )
        return b, r

    def pack(self, cols: Sequence[ColumnMetadata]) -> ColumnBatch:
        """Pack per-column metadata into a padded struct-of-arrays batch."""
        nb = len(cols)
        n_per = np.fromiter((c.num_row_groups for c in cols), np.int64, count=nb)
        max_r = int(n_per.max()) if nb else 1
        B, R = self.shape_for(nb, max_r)

        total = int(n_per.sum())
        # Flat chunk layout: chunk j of column i lands at plane[(i, j)].
        row_idx = np.repeat(np.arange(nb), n_per)
        starts = np.zeros(nb, np.int64)
        np.cumsum(n_per[:-1], out=starts[1:])
        col_idx = np.arange(total) - np.repeat(starts, n_per)

        def scatter(field: str, dtype) -> np.ndarray:
            out = np.zeros((B, R), dtype)
            if total:
                flat = np.concatenate(
                    [np.asarray(getattr(c, field)).ravel()[:n] for c, n in zip(cols, n_per)]
                )
                out[row_idx, col_idx] = flat.astype(dtype, copy=False)
            return out

        chunk_S = scatter("chunk_sizes", np.float32)
        chunk_rows = scatter("chunk_rows", np.float32)
        chunk_nulls = scatter("chunk_nulls", np.float32)
        chunk_dict = scatter("chunk_dict_encoded", bool)
        mins = scatter("mins", np.float32)
        maxs = scatter("maxs", np.float32)
        valid = np.zeros((B, R), bool)
        valid[row_idx, col_idx] = True

        def segsum(field: str) -> np.ndarray:
            if not total:
                return np.zeros(nb, np.float64)
            flat = np.concatenate(
                [np.asarray(getattr(c, field), np.float64).ravel()[:n] for c, n in zip(cols, n_per)]
            )
            return np.bincount(row_idx, weights=flat, minlength=nb)

        N = segsum("chunk_rows")
        nulls = segsum("chunk_nulls")
        sum_min_len = segsum("min_lengths")
        sum_max_len = segsum("max_lengths")
        max_max_len = np.zeros(nb, np.float64)
        if total:
            flat_max_len = np.concatenate(
                [np.asarray(c.max_lengths, np.float64).ravel()[:n] for c, n in zip(cols, n_per)]
            )
            np.maximum.at(max_max_len, row_idx, flat_max_len)

        ptypes = np.fromiter((int(c.physical_type) for c in cols), np.int64, count=nb)
        m_min = np.fromiter((c.distinct_min_count for c in cols), np.float64, count=nb)
        m_max = np.fromiter((c.distinct_max_count for c in cols), np.float64, count=nb)

        width = _FIXED_WIDTH[ptypes]
        is_fixed = width > 0
        # Variable-width mean statistic length (Eq 4): the mean over all 2n
        # recorded min/max byte lengths; for n == 1 this is the paper §4.3
        # (|min| + |max|) / 2 fallback.
        denom = np.maximum(2.0 * n_per, 1.0)
        var_mean_len = (sum_min_len + sum_max_len) / denom
        var_mean_len = np.where(n_per > 0, var_mean_len, 1.0)
        mean_len = np.where(is_fixed, width, var_mean_len).astype(np.float32)
        len_sample = np.where(
            is_fixed,
            2 * n_per,
            np.where(n_per == 1, 2, (m_min + m_max).astype(np.int64)),
        ).astype(np.int32)
        int_like = _INT_LIKE[ptypes]
        single_byte = (ptypes == _BYTE_ARRAY) & (max_max_len <= 1.0)

        def padded(a: np.ndarray, dtype) -> np.ndarray:
            out = np.zeros(B, dtype)
            out[:nb] = a.astype(dtype, copy=False)
            return out

        J = jnp.asarray
        return ColumnBatch(
            chunk_S=J(chunk_S),
            chunk_rows=J(chunk_rows),
            chunk_nulls=J(chunk_nulls),
            chunk_dict_encoded=J(chunk_dict),
            N=J(padded(N, np.float32)),
            nulls=J(padded(nulls, np.float32)),
            n_groups=J(padded(n_per, np.int32)),
            mins=J(mins),
            maxs=J(maxs),
            valid=J(valid),
            m_min=J(padded(m_min, np.float32)),
            m_max=J(padded(m_max, np.float32)),
            mean_len=J(padded(mean_len, np.float32)),
            len_sample=J(padded(len_sample, np.int32)),
            fixed_width=J(padded(is_fixed, bool)),
            int_like=J(padded(int_like, bool)),
            single_byte=J(padded(single_byte, bool)),
        )
