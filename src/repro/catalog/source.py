"""Metadata ingestion sources for the stats catalog.

A `MetadataSource` is the catalog's only view of storage: it can list file
ids, fingerprint a file cheaply, and read a file's footer. Everything else
(merging, packing, caching) is format-agnostic, so supporting a real
Parquet or ORC footer reader later means writing one adapter class — the
footer just has to expose the `FileFooter` surface (`column_names`,
`chunks(name)`, `column_type(name)`).

Fingerprints are the cache/invalidation currency: `StatsCatalog.update()`
re-reads a footer only when its fingerprint changed, and estimate caches
are keyed by the set of fingerprints, so any file addition, removal, or
rewrite invalidates exactly the affected dataset-level entries.
"""
from __future__ import annotations

import abc
import hashlib
import os
from typing import Dict, List

from repro.columnar import format as fmt
from repro.columnar import reader as rd
from repro.core.ndv.types import ColumnMetadata


class MetadataSource(abc.ABC):
    """Abstract footer provider for one dataset."""

    @abc.abstractmethod
    def list_files(self) -> List[str]:
        """Stable ids (paths) of the dataset's files, sorted."""

    @abc.abstractmethod
    def fingerprint(self, file_id: str) -> str:
        """Cheap change token for one file's footer.

        Must change whenever the footer content may have changed; must NOT
        require parsing the footer (that is what it exists to avoid).
        """

    @abc.abstractmethod
    def read_footer(self, file_id: str) -> fmt.FileFooter:
        """Parse one file's footer (the only non-free ingestion step)."""

    def column_metadata(self, footer: fmt.FileFooter, name: str) -> ColumnMetadata:
        """Estimator view of one column; override for non-PQLite footers."""
        return rd.column_metadata_from_footer(footer, name)


class PQLiteMetadataSource(MetadataSource):
    """Footer scanning over a PQLite dataset root directory."""

    def __init__(self, root: str):
        self.root = root

    def list_files(self) -> List[str]:
        return rd.list_files(self.root)

    def fingerprint(self, file_id: str) -> str:
        # stat-only: (size, mtime_ns) — no footer bytes are read, keeping
        # the re-scan path O(files) stat calls, not O(footer bytes).
        st = os.stat(fmt.footer_path(file_id))
        return f"{st.st_size}:{st.st_mtime_ns}"

    def read_footer(self, file_id: str) -> fmt.FileFooter:
        return rd.read_footer(file_id)


class InMemoryMetadataSource(MetadataSource):
    """Footers held in memory — tests, synthetic fleets, RPC ingestion stubs."""

    def __init__(self, footers: Dict[str, fmt.FileFooter]):
        self._footers = dict(footers)

    def list_files(self) -> List[str]:
        return sorted(self._footers)

    def fingerprint(self, file_id: str) -> str:
        payload = self._footers[file_id].to_json().encode()
        return hashlib.sha1(payload).hexdigest()

    def read_footer(self, file_id: str) -> fmt.FileFooter:
        return self._footers[file_id]

    # mutation helpers for incremental-ingestion tests
    def add(self, file_id: str, footer: fmt.FileFooter) -> None:
        self._footers[file_id] = footer

    def remove(self, file_id: str) -> None:
        del self._footers[file_id]
