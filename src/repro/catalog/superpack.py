"""Cross-dataset super-pack execution: many estimate jobs, few engine calls.

A batched RPC (`POST /batch`) hands the serving tier T cold
(catalog, mode, bounds) tuples at once. Running them as T `estimate()`
calls costs T engine dispatches; this module concatenates the jobs'
already-packed (and device-resident) `ColumnBatch`es along the B axis —
`repro.catalog.packer.concat_batches` — and runs one composed-strategy
engine call per compatibility group, then materializes each job's
estimates from its own lane span (`estimates_from_batch(offset=...)`).

Jobs group by (engine, mode, R):

  * engine — jobs pinned to different engines cannot share a dispatch;
  * mode — a static jit argument of `estimate_batch`;
  * R (the packed row-group axis) — same-R batches concatenate with zero
    re-padding, which keeps every lane's result BIT-IDENTICAL to the
    job's standalone `estimate()`. That exactness is load-bearing: the
    stats tier's state-derived ETags promise one deterministic body per
    tag, so a super-packed replica and a sequential replica must emit
    the same bytes. (Ragged-R concat is masked-correct but lets masked
    R reductions re-associate, so it is deliberately not used here.)

Results are read through and written back to each catalog's estimate
cache (`estimate_cache_peek` / `estimate_cache_store`): a warm job costs
a dict hit, a cold job's result is spillable and LRU-managed exactly as
if `estimate()` had produced it.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional

import jax.numpy as jnp

from repro.catalog.packer import concat_batches
from repro.core.ndv.estimator import estimates_from_batch, provenance_from_batch
from repro.core.ndv.types import NDVEstimate
from repro.obs import span as _obs_span

import numpy as np


class SuperpackJob(NamedTuple):
    """One estimate request against one catalog."""

    catalog: object  # StatsCatalog
    mode: str = "paper"
    schema_bounds: Optional[Dict[str, float]] = None


class SuperpackResult(NamedTuple):
    """Per-job estimate maps plus execution counters (test material)."""

    estimates: List[Dict[str, NDVEstimate]]
    engine_calls: int    # engine dispatches performed (0 if all warm)
    cold_jobs: int       # jobs that missed their catalog's cache


class _ColdJob(NamedTuple):
    index: int           # position in the caller's job list
    job: SuperpackJob
    key: tuple           # the catalog cache key to fill
    batch: object        # the catalog's packed ColumnBatch


def superpack_estimate(
    jobs: List[SuperpackJob], *, engine=None
) -> SuperpackResult:
    """Run many (catalog, mode, bounds) estimate jobs, batched.

    Returns one estimate map per job, in order, each `==` (bit-identical
    to) what `job.catalog.estimate(mode=..., schema_bounds=...)` returns.
    Warm jobs are served from their catalog's cache; all cold jobs of a
    compatibility group execute as ONE engine call over the concatenated
    batch. `engine` overrides every job's engine (the service tier pins
    its own); None uses each catalog's.
    """
    results: List[Optional[Dict[str, NDVEstimate]]] = [None] * len(jobs)
    groups: Dict[tuple, List[_ColdJob]] = {}
    engines: Dict[tuple, object] = {}
    cold = 0
    for i, job in enumerate(jobs):
        eng = engine or job.catalog.engine
        key = job.catalog.estimate_key(
            mode=job.mode, schema_bounds=job.schema_bounds, engine=eng
        )
        cached = job.catalog.estimate_cache_peek(key)
        if cached is not None:
            results[i] = cached
            continue
        if not job.catalog.column_names:
            results[i] = {}
            continue
        cold += 1
        batch = job.catalog.packed_batch()
        gkey = (id(eng), job.mode, batch.max_groups)
        engines[gkey] = eng
        groups.setdefault(gkey, []).append(_ColdJob(i, job, key, batch))

    engine_calls = 0
    for gkey, members in groups.items():
        eng = engines[gkey]
        _run_group(eng, members, results)
        engine_calls += 1
    return SuperpackResult(
        estimates=results, engine_calls=engine_calls, cold_jobs=cold
    )


def _run_group(eng, members: List[_ColdJob], results: list) -> None:
    """One engine call for one (engine, mode, R) group of cold jobs."""
    mode = members[0].job.mode
    batches = [m.batch for m in members]
    total = sum(b.batch for b in batches)
    R = batches[0].max_groups
    # Bound trace shapes the same way individual packs are bounded: round
    # the concatenated width up to the engine packer's bucket for it.
    target_b, _ = eng.make_packer().shape_for(total, R)
    batch = concat_batches(batches, pad_to=target_b)

    offsets = []
    lo = 0
    for b in batches:
        offsets.append(lo)
        lo += b.batch

    sb = None
    if any(m.job.schema_bounds for m in members):
        # Per-job bound lanes at each job's offset; +inf elsewhere is the
        # combine step's identity, same as the engine's own materialization.
        arr = np.full(batch.batch, np.inf, np.float32)
        for m, off in zip(members, offsets):
            if m.job.schema_bounds:
                part = m.job.catalog.bounds_array(
                    m.job.schema_bounds, m.batch.batch
                )
                arr[off:off + m.batch.batch] = part
        sb = jnp.asarray(arr)

    out = eng.estimate(batch, sb, mode=mode)
    with _obs_span("engine.d2h", jobs=len(members), batch=int(batch.batch)):
        for m, off in zip(members, offsets):
            names = m.job.catalog.column_names
            ests = estimates_from_batch(out, batch, names, offset=off)
            result = {e.column_name: e for e in ests}
            m.job.catalog.estimate_cache_store(m.key, result)
            # Same lane span, same output — the super-packed path fills the
            # provenance cache exactly as a standalone estimate() would.
            provs = provenance_from_batch(out, batch, names, offset=off)
            m.job.catalog.provenance_cache_store(
                m.key, {p.column_name: p for p in provs}
            )
            results[m.index] = dict(result)
