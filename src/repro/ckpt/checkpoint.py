"""Fault-tolerant sharded checkpointing (numpy-backed, tensorstore-shaped).

Layout per step:

    <root>/step_<N>.tmp/            # staging dir (crash-invisible)
        shard_<host>.npz            # this host's param/opt shard payloads
        manifest.json               # tree structure, shapes, dtypes, shardings
    <root>/step_<N>/                # atomic rename on commit
    <root>/LATEST                   # pointer file, written last (atomic)

Guarantees:
  * atomic commit — a checkpoint is visible iff complete (rename + LATEST);
  * async save — the host-side serialization runs on a background thread,
    overlapping with the next training steps (device->host copy happens
    synchronously, then the thread owns the buffers);
  * elastic restore — leaves are saved UNSHARDED per host here (single-host
    container); on a real cluster each host writes its addressable shards
    and `restore` re-shards onto the *current* mesh, so save-mesh != restore
    -mesh works (exercised by tests with different device counts).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LATEST = "LATEST"


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


def _tree_structure_of(tree):
    return jax.tree_util.tree_structure(tree)


class CheckpointManager:
    """Save/restore pytrees of arrays with atomic commit and async writes."""

    def __init__(self, root: str, *, keep: int = 3, host_id: int = 0):
        self.root = root
        self.keep = keep
        self.host_id = host_id
        os.makedirs(root, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = True) -> None:
        """Snapshot to host memory now; serialize (a)synchronously."""
        flat = _flatten_with_paths(tree)
        host = {}
        for k, v in flat.items():
            a = np.asarray(v)  # device->host
            if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
                # npz can't serialize ml_dtypes; store as f32 (lossless for
                # bf16), restore() recasts to the template dtype.
                a = np.asarray(jnp.asarray(a).astype(jnp.float32))
            host[k] = a
        manifest = {
            "step": step,
            "keys": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in host.items()
            },
        }
        if blocking:
            self._write(step, host, manifest)
        else:
            self._ensure_worker()
            self._q.put((step, host, manifest))

    def _ensure_worker(self):
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                self._write(*item)
            except BaseException as e:  # surfaced on wait()
                self._error = e

    def wait(self):
        """Block until queued async saves are durable."""
        if self._worker is not None and self._worker.is_alive():
            self._q.put(None)
            self._worker.join()
            self._worker = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host: Dict[str, np.ndarray], manifest: dict):
        final = os.path.join(self.root, f"step_{step:08d}")
        tmp = final + f".tmp{self.host_id}"
        os.makedirs(tmp, exist_ok=True)
        with open(os.path.join(tmp, f"shard_{self.host_id}.npz"), "wb") as f:
            np.savez(f, **{k: v for k, v in host.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                       # atomic commit
        ptr = os.path.join(self.root, LATEST)
        fd, ptmp = tempfile.mkstemp(dir=self.root)
        with os.fdopen(fd, "w") as f:
            f.write(os.path.basename(final))
        os.replace(ptmp, ptr)                        # atomic pointer flip
        self._gc()

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.root)
            if d.startswith("step_") and not d.endswith("tmp")
        )
        for d in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.root, LATEST)
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.root, name)):
            return None
        return int(name.split("_")[1])

    def restore(
        self,
        template: Any,
        step: Optional[int] = None,
        *,
        shardings: Any = None,
    ) -> Tuple[int, Any]:
        """Restore into the structure of `template`.

        With `shardings` given (a matching pytree of NamedSharding), each
        leaf is placed with jax.device_put onto the CURRENT mesh — this is
        the elastic-resume path (the saved mesh layout is irrelevant).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = os.path.join(self.root, f"step_{step:08d}")
        data = np.load(os.path.join(d, f"shard_{self.host_id}.npz"))
        flat_t = _flatten_with_paths(template)
        sh_flat = _flatten_with_paths(shardings) if shardings is not None else {}
        out_flat = {}
        for k, tmpl in flat_t.items():
            if k not in data.files:
                raise KeyError(f"checkpoint missing leaf {k}")
            arr = data[k]
            want_dtype = getattr(tmpl, "dtype", arr.dtype)
            if arr.dtype != want_dtype:
                # numpy lacks cast kernels for bf16 etc. — go through jnp
                arr = np.asarray(jnp.asarray(arr).astype(want_dtype))
            if k in sh_flat:
                out_flat[k] = jax.device_put(arr, sh_flat[k])
            else:
                out_flat[k] = jnp.asarray(arr)
        # Rebuild tree in template order.
        paths = jax.tree_util.tree_flatten_with_path(template)
        keys = [
            "/".join(
                str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                for p in path
            )
            for path, _ in paths[0]
        ]
        leaves = [out_flat[k] for k in keys]
        return step, jax.tree_util.tree_unflatten(paths[1], leaves)
