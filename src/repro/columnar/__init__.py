from repro.columnar.format import FileFooter, ColumnChunkMeta, RowGroupMeta  # noqa: F401
from repro.columnar.reader import (  # noqa: F401
    DataReader,
    column_metadata_from_footer,
    dataset_column_metadata,
    list_files,
    read_footer,
    scan_dataset,
)
from repro.columnar.writer import WriterOptions, write_dataset, write_file  # noqa: F401
