"""Benchmark-style dataset suites (TPC-H-shaped lineitem columns).

The paper's production evaluation ran on real warehouse tables; this module
reconstructs the CLASSIC column shapes those tables exhibit — with exact
ground truth — so EXPERIMENTS can report per-column-kind accuracy the way a
warehouse user would encounter it:

  l_orderkey       clustered ascending int (4 rows per order)   ~sorted
  l_partkey        uniform FK int                               well-spread
  l_suppkey        uniform FK int, small domain                 well-spread
  l_quantity       1..50                                        low NDV
  l_extendedprice  ~continuous float -> near-unique             plain fallback
  l_discount       11 distinct decimals                         low NDV
  l_returnflag     3 single-char flags                          Eq 15 bound
  l_shipdate       dates over ~7 years, order-correlated        pseudo-sorted
  l_comment        random strings                               near-unique
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

Column = Tuple[np.ndarray, int]


def lineitem(rows: int = 1 << 17, seed: int = 0) -> Dict[str, Column]:
    rng = np.random.default_rng(seed)
    orders = rows // 4
    orderkey = np.repeat(np.arange(1, orders + 1, dtype=np.int64) * 4, 4)[:rows]

    partkey = rng.integers(1, 20000, rows).astype(np.int64)
    suppkey = rng.integers(1, 1000, rows).astype(np.int64)
    quantity = rng.integers(1, 51, rows).astype(np.int64)
    price = np.round(rng.uniform(900.0, 104949.5, rows), 2)
    discount = np.round(rng.integers(0, 11, rows) / 100.0, 2)
    returnflag = rng.choice(np.array(["A", "N", "R"]), rows)
    base = np.datetime64("1992-01-01").astype(np.int64)
    ship_offset = (orderkey / orderkey.max() * 2400).astype(np.int64)
    shipdate = (base + ship_offset + rng.integers(0, 90, rows)).astype(np.int64)

    alphabet = np.array(list("abcdefghijklmnopqrstuvwxyz "))
    comments = np.array([
        "".join(rng.choice(alphabet, size=rng.integers(12, 30)))
        for _ in range(rows // 16)
    ])
    comment = comments[rng.integers(0, len(comments), rows)]

    def truth(v) -> int:
        return int(np.unique(v).size)

    cols = {
        "l_orderkey": orderkey, "l_partkey": partkey, "l_suppkey": suppkey,
        "l_quantity": quantity, "l_extendedprice": price,
        "l_discount": discount, "l_returnflag": returnflag,
        "l_shipdate": shipdate, "l_comment": comment,
    }
    return {k: (v, truth(v)) for k, v in cols.items()}
