"""PQLite — a minimal, faithful columnar file format for this framework.

Parquet-shaped on the metadata plane (the only plane the paper reads):

  file
   ├── row group 0..n-1
   │     └── column chunk per column:
   │           total_uncompressed_size  (dict page + data pages, Eq 1's S)
   │           num_values, null_count
   │           encodings  ("DICTIONARY" | "PLAIN")
   │           statistics: min / max (+ byte lengths for BYTE_ARRAY)
   └── footer: schema + row-group metadata (JSON)

Data pages are stored as npz arrays — real enough for the data-access
baselines (HLL/CVM/sampling/exact) and the training data pipeline, while the
footer is bit-for-bit sufficient for the paper's zero-cost estimators.

Why not real Parquet: no pyarrow in this container; PQLite keeps exactly the
fields the paper consumes (`total_uncompressed_size`, min/max stats, null
counts, encodings) with a writer whose size accounting follows the same
dictionary-encoding storage equation the paper inverts.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.ndv.types import PhysicalType

FORMAT_VERSION = "pqlite-1.0"
FOOTER_NAME = "footer.json"
DATA_NAME = "data.npz"


# ---------------------------------------------------------------------------
# Order-preserving float keys for statistics
# ---------------------------------------------------------------------------


def stat_key(value, ptype: PhysicalType) -> float:
    """Map a statistics value to an order-preserving float64 key.

    Numeric types use the value itself. Byte arrays use the big-endian
    integer of the first 8 bytes (zero-padded), which preserves
    lexicographic order of the prefixes — the same trick engines use for
    truncated Parquet statistics.
    """
    if ptype == PhysicalType.BYTE_ARRAY or ptype == PhysicalType.FIXED_LEN_BYTE_ARRAY:
        b = value.encode() if isinstance(value, str) else bytes(value)
        b = (b[:8] + b"\x00" * 8)[:8]
        return float(struct.unpack(">Q", b)[0])
    return float(value)


# ---------------------------------------------------------------------------
# Footer dataclasses
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ColumnChunkMeta:
    """Per-row-group, per-column metadata (the paper's entire input)."""

    name: str
    physical_type: int                 # PhysicalType value
    num_values: int
    null_count: int
    total_uncompressed_size: int       # dict page + data pages, bytes
    dict_page_size: int
    data_page_size: int
    encodings: List[str]               # ["DICTIONARY"] or ["PLAIN"]
    min_key: float                     # order-preserving stat keys
    max_key: float
    min_len: int                       # byte length of the min value
    max_len: int
    min_repr: str = ""                 # human-readable stat (debug only)
    max_repr: str = ""

    @property
    def dictionary_encoded(self) -> bool:
        return "DICTIONARY" in self.encodings

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ColumnChunkMeta":
        return cls(**d)


@dataclasses.dataclass
class RowGroupMeta:
    num_rows: int
    columns: Dict[str, ColumnChunkMeta]

    def to_dict(self) -> dict:
        return {
            "num_rows": self.num_rows,
            "columns": {k: v.to_dict() for k, v in self.columns.items()},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "RowGroupMeta":
        return cls(
            num_rows=d["num_rows"],
            columns={
                k: ColumnChunkMeta.from_dict(v) for k, v in d["columns"].items()
            },
        )


@dataclasses.dataclass
class FileFooter:
    num_rows: int
    schema: Dict[str, int]             # column -> PhysicalType value
    row_groups: List[RowGroupMeta]
    created_by: str = FORMAT_VERSION
    key_value_metadata: Dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def column_names(self) -> List[str]:
        return list(self.schema.keys())

    @property
    def num_row_groups(self) -> int:
        return len(self.row_groups)

    def column_type(self, name: str) -> PhysicalType:
        return PhysicalType(self.schema[name])

    def chunks(self, name: str) -> List[ColumnChunkMeta]:
        return [rg.columns[name] for rg in self.row_groups]

    def to_json(self) -> str:
        return json.dumps(
            {
                "num_rows": self.num_rows,
                "schema": self.schema,
                "created_by": self.created_by,
                "key_value_metadata": self.key_value_metadata,
                "row_groups": [rg.to_dict() for rg in self.row_groups],
            }
        )

    @classmethod
    def from_json(cls, s: str) -> "FileFooter":
        d = json.loads(s)
        return cls(
            num_rows=d["num_rows"],
            schema=d["schema"],
            created_by=d.get("created_by", FORMAT_VERSION),
            key_value_metadata=d.get("key_value_metadata", {}),
            row_groups=[RowGroupMeta.from_dict(r) for r in d["row_groups"]],
        )


# ---------------------------------------------------------------------------
# On-disk layout helpers
# ---------------------------------------------------------------------------


def footer_path(file_dir: str) -> str:
    return os.path.join(file_dir, FOOTER_NAME)


def data_path(file_dir: str) -> str:
    return os.path.join(file_dir, DATA_NAME)


def infer_physical_type(arr: np.ndarray) -> PhysicalType:
    k = arr.dtype.kind
    if k in ("U", "S", "O"):
        return PhysicalType.BYTE_ARRAY
    if k == "b":
        return PhysicalType.BOOL
    if k in ("i", "u"):
        return PhysicalType.INT32 if arr.dtype.itemsize <= 4 else PhysicalType.INT64
    if k == "f":
        return (
            PhysicalType.FLOAT32 if arr.dtype.itemsize <= 4 else PhysicalType.FLOAT64
        )
    if k == "M":  # datetime64
        return PhysicalType.TIMESTAMP64
    raise TypeError(f"unsupported dtype {arr.dtype}")


def value_byte_length(value, ptype: PhysicalType) -> int:
    w = ptype.fixed_width
    if w is not None:
        return w
    if isinstance(value, str):
        return len(value.encode())
    return len(bytes(value))
