"""Synthetic column generators with KNOWN ground-truth NDV.

The paper's original evaluation data was lost; its claims are regime-level
(Table 1, "<10% error on well-spread", sorted-underestimation repair). These
generators produce every regime controllably, so EXPERIMENTS.md can validate
each claim against exact ground truth.

Each generator returns (values, true_ndv). Layout regimes:

  uniform       — i.i.d. uniform over ndv values -> well-spread
  zipf          — skewed frequencies, shuffled -> well-spread w/ heavy skew
                  (tests Eq 1's indifference to within-group frequency)
  sorted        — globally sorted -> sorted
  partitioned   — values clustered into contiguous key ranges per partition,
                  partition order shuffled -> pseudo-sorted / mixed
  clustered     — runs of repeated values (time-series-ish) -> mixed
  low_ndv       — tiny dictionaries (status codes / flags)
  unique        — all-distinct (IDs) -> triggers plain fallback at scale
"""
from __future__ import annotations

import dataclasses
import string
from typing import Callable, Dict, Optional, Tuple

import numpy as np

Column = Tuple[np.ndarray, int]  # (values, true_ndv)


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# Value domains
# ---------------------------------------------------------------------------


def int_domain(ndv: int, spread: int = 10, seed: int = 0) -> np.ndarray:
    """ndv distinct int64 values, sparsely spread to avoid range-bound
    trivially pinning the estimate (Eq 14 should help, not answer)."""
    rng = _rng(seed)
    vals = rng.choice(ndv * spread, size=ndv, replace=False).astype(np.int64)
    return np.sort(vals)


def string_domain(
    ndv: int, mean_len: int = 12, seed: int = 0, dist: str = "geometric"
) -> np.ndarray:
    """ndv distinct strings.

    dist="geometric": heavy-tailed lengths (stresses Eq 4 — row-group
    extrema lengths are then unrepresentative and the paper's len estimate
    biases low; characterized in benchmarks/accuracy.py).
    dist="uniform": lengths in [mean_len-4, mean_len+4] (representative
    extrema — the regime the paper's <10% claim assumes).
    """
    rng = _rng(seed)
    alphabet = np.array(list(string.ascii_lowercase + string.digits))
    out = set()
    while len(out) < ndv:
        if dist == "uniform":
            length = int(rng.integers(max(mean_len - 4, 2), mean_len + 5))
        else:
            length = max(int(rng.geometric(1.0 / mean_len)), 2)
        out.add("".join(rng.choice(alphabet, size=length)))
    return np.sort(np.array(list(out)))


def float_domain(ndv: int, seed: int = 0) -> np.ndarray:
    rng = _rng(seed)
    return np.sort(rng.standard_normal(ndv) * 1e3).astype(np.float64)


# ---------------------------------------------------------------------------
# Frequency / layout generators (domain-agnostic)
# ---------------------------------------------------------------------------


def uniform_column(domain: np.ndarray, rows: int, seed: int = 0) -> Column:
    rng = _rng(seed)
    idx = rng.integers(0, domain.size, size=rows)
    # Guarantee every domain value appears at least once when rows >> ndv
    # (true NDV == domain size); otherwise true ndv is whatever was drawn.
    vals = domain[idx]
    return vals, int(np.unique(idx).size)


def zipf_column(
    domain: np.ndarray, rows: int, s: float = 1.2, seed: int = 0
) -> Column:
    rng = _rng(seed)
    ranks = np.arange(1, domain.size + 1, dtype=np.float64)
    p = ranks ** (-s)
    p /= p.sum()
    idx = rng.choice(domain.size, size=rows, p=p)
    return domain[idx], int(np.unique(idx).size)


def sorted_column(domain: np.ndarray, rows: int, seed: int = 0) -> Column:
    vals, ndv = uniform_column(domain, rows, seed)
    return np.sort(vals), ndv


def partitioned_column(
    domain: np.ndarray,
    rows: int,
    partitions: int = 16,
    shuffle_partitions: bool = True,
    seed: int = 0,
) -> Column:
    """Contiguous key ranges per partition (hive-style), partition order
    optionally shuffled. Within a partition values are i.i.d. uniform."""
    rng = _rng(seed)
    dom_parts = np.array_split(np.arange(domain.size), partitions)
    row_parts = np.array_split(np.arange(rows), partitions)
    order = np.arange(partitions)
    if shuffle_partitions:
        rng.shuffle(order)
    chunks = []
    seen = set()
    for p in order:
        d = dom_parts[p]
        r = row_parts[p].size
        if d.size == 0 or r == 0:
            continue
        idx = d[rng.integers(0, d.size, size=r)]
        seen.update(np.unique(idx).tolist())
        chunks.append(domain[idx])
    return np.concatenate(chunks), len(seen)


def clustered_column(
    domain: np.ndarray, rows: int, mean_run: int = 64, seed: int = 0
) -> Column:
    """Runs of repeated values — sensor/time-series-like locality."""
    rng = _rng(seed)
    out = np.empty(rows, dtype=domain.dtype)
    pos = 0
    seen = set()
    while pos < rows:
        v = int(rng.integers(0, domain.size))
        run = min(max(int(rng.exponential(mean_run)), 1), rows - pos)
        out[pos : pos + run] = domain[v]
        seen.add(v)
        pos += run
    return out, len(seen)


def unique_column(rows: int, seed: int = 0) -> Column:
    rng = _rng(seed)
    vals = rng.permutation(rows).astype(np.int64) * 7 + 13
    return vals, rows


# ---------------------------------------------------------------------------
# Regime suite used by tests/benchmarks
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ColumnSpec:
    """A generated column with its expected layout regime."""

    name: str
    regime: str            # uniform|zipf|sorted|partitioned|clustered|low|unique
    dtype: str             # int|str|float
    ndv: int
    rows: int
    seed: int = 0
    extra: Optional[dict] = None

    def generate(self) -> Column:
        if self.regime == "unique":
            return unique_column(self.rows, self.seed)
        if self.dtype == "int":
            dom = int_domain(self.ndv, seed=self.seed)
        elif self.dtype == "str":
            mean_len = (self.extra or {}).get("mean_len", 12)
            dom = string_domain(self.ndv, mean_len=mean_len, seed=self.seed)
        else:
            dom = float_domain(self.ndv, seed=self.seed)
        x = dict(self.extra or {})
        x.pop("mean_len", None)
        gen: Dict[str, Callable[..., Column]] = {
            "uniform": uniform_column,
            "zipf": zipf_column,
            "sorted": sorted_column,
            "partitioned": partitioned_column,
            "clustered": clustered_column,
            "low": uniform_column,
        }
        return gen[self.regime](dom, self.rows, seed=self.seed, **x)


def standard_suite(rows: int = 1 << 18, seed: int = 0) -> list[ColumnSpec]:
    """The benchmark suite: every regime x dtype x cardinality band."""
    specs = []
    bands = {"small": 100, "medium": 5_000, "large": 100_000}
    for regime in ("uniform", "zipf", "sorted", "partitioned", "clustered"):
        for dtype in ("int", "str"):
            for band, ndv in bands.items():
                specs.append(
                    ColumnSpec(
                        name=f"{regime}_{dtype}_{band}",
                        regime=regime,
                        dtype=dtype,
                        ndv=ndv,
                        rows=rows,
                        seed=seed + hash((regime, dtype, band)) % 1000,
                    )
                )
    specs.append(ColumnSpec("low_int_flags", "low", "int", 8, rows, seed))
    specs.append(ColumnSpec("unique_ids", "unique", "int", rows, rows, seed))
    return specs
