"""PQLite readers.

Two access paths, mirroring the paper's cost model:

  * ``read_footer`` / ``column_metadata_from_footer`` — METADATA-ONLY. This
    is the zero-cost path: O(footer bytes), never touches data.npz.
  * ``read_column`` / ``read_row_group`` — DATA access, used only by the
    baselines (exact/HLL/CVM/sampling) and the training pipeline.
"""
from __future__ import annotations

import glob
import os
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.columnar import format as fmt
from repro.core.ndv.types import ColumnMetadata, PhysicalType


def read_footer(file_dir: str) -> fmt.FileFooter:
    """Read ONLY the footer (zero-cost path)."""
    with open(fmt.footer_path(file_dir)) as f:
        return fmt.FileFooter.from_json(f.read())


def list_files(root: str) -> List[str]:
    """Discover PQLite files under a dataset root."""
    out = []
    for p in sorted(glob.glob(os.path.join(root, "**", fmt.FOOTER_NAME), recursive=True)):
        out.append(os.path.dirname(p))
    return out


def column_metadata_from_footer(
    footer: fmt.FileFooter, name: str
) -> ColumnMetadata:
    """Assemble the estimator's ColumnMetadata view for one column.

    Distinct min/max counts are computed from the footer's statistics values
    (the ``*_repr``-level exact values via their order keys plus lengths —
    for byte arrays we distinguish values that share an 8-byte prefix by the
    (key, len) pair, matching what an engine comparing truncated stats sees).
    """
    chunks = footer.chunks(name)
    ptype = footer.column_type(name)
    n = len(chunks)
    chunk_sizes = np.array([c.total_uncompressed_size for c in chunks], np.float64)
    chunk_rows = np.array([c.num_values for c in chunks], np.float64)
    chunk_nulls = np.array([c.null_count for c in chunks], np.float64)
    chunk_dict = np.array([c.dictionary_encoded for c in chunks], bool)
    mins = np.array([c.min_key for c in chunks], np.float64)
    maxs = np.array([c.max_key for c in chunks], np.float64)
    min_lens = np.array([c.min_len for c in chunks], np.float64)
    max_lens = np.array([c.max_len for c in chunks], np.float64)
    if ptype == PhysicalType.BYTE_ARRAY:
        # (key, len, repr) — same identity repro.catalog.merge uses, so the
        # single-file counts are exact fixed points of cross-file merging.
        m_min = len({(c.min_key, c.min_len, c.min_repr) for c in chunks})
        m_max = len({(c.max_key, c.max_len, c.max_repr) for c in chunks})
    else:
        m_min = int(np.unique(mins).size)
        m_max = int(np.unique(maxs).size)
    return ColumnMetadata(
        chunk_sizes=chunk_sizes,
        chunk_rows=chunk_rows,
        chunk_nulls=chunk_nulls,
        chunk_dict_encoded=chunk_dict,
        mins=mins,
        maxs=maxs,
        min_lengths=min_lens,
        max_lengths=max_lens,
        distinct_min_count=float(m_min),
        distinct_max_count=float(m_max),
        physical_type=ptype,
        column_name=name,
        min_reprs=np.array([c.min_repr for c in chunks], object),
        max_reprs=np.array([c.max_repr for c in chunks], object),
    )


def dataset_column_metadata(root: str, name: str) -> List[ColumnMetadata]:
    """Metadata views for one column across every file of a dataset."""
    return [
        column_metadata_from_footer(read_footer(d), name) for d in list_files(root)
    ]


def scan_dataset(root: str) -> List[tuple]:
    """Footer scan of a whole dataset: [(file_dir, FileFooter), ...].

    Still the zero-cost path — one footer read per file, no data pages.
    Convenience for whole-dataset consumers (profiling, ad-hoc analysis)
    that want every footer eagerly; `repro.catalog.StatsCatalog` instead
    reads footers selectively via fingerprints.
    """
    return [(d, read_footer(d)) for d in list_files(root)]


# ---------------------------------------------------------------------------
# Data access (baselines + pipeline only)
# ---------------------------------------------------------------------------


class DataReader:
    """Lazily-opened npz-backed data reader for one file."""

    def __init__(self, file_dir: str):
        self.file_dir = file_dir
        self.footer = read_footer(file_dir)
        self._npz = None

    @property
    def npz(self):
        if self._npz is None:
            self._npz = np.load(fmt.data_path(self.file_dir), allow_pickle=False)
        return self._npz

    def read_column(self, name: str) -> np.ndarray:
        return self.npz[name]

    def null_mask(self, name: str) -> Optional[np.ndarray]:
        key = f"__nulls__{name}"
        return self.npz[key] if key in self.npz.files else None

    def read_row_group(self, name: str, index: int) -> np.ndarray:
        start = sum(rg.num_rows for rg in self.footer.row_groups[:index])
        stop = start + self.footer.row_groups[index].num_rows
        return self.npz[name][start:stop]

    def iter_row_groups(self, name: str) -> Iterator[np.ndarray]:
        for i in range(self.footer.num_row_groups):
            yield self.read_row_group(name, i)

    def non_null_values(self, name: str) -> np.ndarray:
        col = self.read_column(name)
        mask = self.null_mask(name)
        return col[~mask] if mask is not None else col
