"""PQLite writer: dictionary encoding with plain fallback, per-chunk stats.

Size accounting follows the dictionary storage equation the paper inverts
(Eq 1), per column chunk:

    dict_page_size = sum(byte_length(v) for v in chunk-distinct values)
                     (+ length_prefix_bytes per entry for BYTE_ARRAY, to
                      model Parquet's 4-byte length prefixes when desired)
    data_page_size = ceil(non_null_rows * ceil(log2(local_ndv)) / 8)
    total_uncompressed_size = dict_page_size + data_page_size

Fallback: when dict_page_size would exceed ``dictionary_page_limit``
(Parquet's ~1 MiB default), the chunk is written PLAIN:

    data_page_size = non_null_rows * byte lengths (+ prefixes)
    total_uncompressed_size = data_page_size

This is exactly the writer behaviour Eq 5 detects from the outside.
"""
from __future__ import annotations

import dataclasses
import math
import os
import tempfile
from typing import Dict, Optional, Sequence

import numpy as np

from repro.columnar import format as fmt
from repro.core.ndv.types import PhysicalType

DEFAULT_ROW_GROUP_SIZE = 65536
DEFAULT_DICT_PAGE_LIMIT = 1 << 20  # 1 MiB, parquet-mr default


@dataclasses.dataclass
class WriterOptions:
    row_group_size: int = DEFAULT_ROW_GROUP_SIZE
    dictionary_page_limit: int = DEFAULT_DICT_PAGE_LIMIT
    # 0 = the paper's idealized model (S = ndv*len + rows*bits/8).
    # 4 = Parquet-realistic BYTE_ARRAY length prefixes (model-mismatch study).
    length_prefix_bytes: int = 0
    # Minimum bits per dictionary index (Parquet RLE/bit-pack needs >= 1).
    min_index_bits: int = 1


def _ceil_log2(n: int, min_bits: int = 1) -> int:
    if n <= 1:
        return min_bits
    return max(int(math.ceil(math.log2(n))), min_bits)


def _chunk_sizes(
    values: np.ndarray,
    nulls: np.ndarray,
    ptype: PhysicalType,
    opts: WriterOptions,
) -> tuple[int, int, int, bool, int]:
    """Compute (dict_page, data_page, total, dictionary_encoded, local_ndv)."""
    non_null = values[~nulls]
    n_rows = int(non_null.size)
    if ptype == PhysicalType.BYTE_ARRAY:
        distinct = np.unique(non_null.astype(str))
        lens = np.char.str_len(np.char.encode(distinct.astype(str)))
        per_value = lens + opts.length_prefix_bytes
        dict_page = int(per_value.sum())
        plain_lens = np.char.str_len(np.char.encode(non_null.astype(str)))
        plain_page = int((plain_lens + opts.length_prefix_bytes).sum())
    else:
        distinct = np.unique(non_null)
        width = ptype.fixed_width or non_null.dtype.itemsize
        dict_page = int(distinct.size * width)
        plain_page = int(n_rows * width)
    local_ndv = int(distinct.size)
    if dict_page > opts.dictionary_page_limit or local_ndv == 0:
        return 0, plain_page, plain_page, False, local_ndv
    bits = _ceil_log2(local_ndv, opts.min_index_bits)
    data_page = int(math.ceil(n_rows * bits / 8.0))
    return dict_page, data_page, dict_page + data_page, True, local_ndv


def _stats(
    values: np.ndarray, nulls: np.ndarray, ptype: PhysicalType
) -> tuple[float, float, int, int, str, str]:
    non_null = values[~nulls]
    if non_null.size == 0:
        return 0.0, 0.0, 0, 0, "", ""
    if ptype == PhysicalType.BYTE_ARRAY:
        s = non_null.astype(str).tolist()
        mn, mx = min(s), max(s)
        return (
            fmt.stat_key(mn, ptype),
            fmt.stat_key(mx, ptype),
            len(mn.encode()),
            len(mx.encode()),
            mn[:64],
            mx[:64],
        )
    mn, mx = non_null.min(), non_null.max()
    w = ptype.fixed_width or non_null.dtype.itemsize
    return float(mn), float(mx), w, w, repr(mn), repr(mx)


def write_file(
    file_dir: str,
    columns: Dict[str, np.ndarray],
    *,
    null_masks: Optional[Dict[str, np.ndarray]] = None,
    options: Optional[WriterOptions] = None,
    key_value_metadata: Optional[Dict[str, str]] = None,
) -> fmt.FileFooter:
    """Write a PQLite file (directory with footer.json + data.npz).

    Args:
      file_dir: output directory (created if missing).
      columns: column name -> 1-D numpy array (all equal length).
      null_masks: optional name -> bool mask (True = null).
      options: writer options.

    Returns:
      The FileFooter that was written.
    """
    opts = options or WriterOptions()
    names = list(columns.keys())
    if not names:
        raise ValueError("no columns")
    n_rows = len(columns[names[0]])
    for k, v in columns.items():
        if len(v) != n_rows:
            raise ValueError(f"column {k} length {len(v)} != {n_rows}")
    null_masks = null_masks or {}

    schema = {k: int(fmt.infer_physical_type(np.asarray(v))) for k, v in columns.items()}
    row_groups = []
    rg = opts.row_group_size
    for start in range(0, n_rows, rg):
        stop = min(start + rg, n_rows)
        cols_meta: Dict[str, fmt.ColumnChunkMeta] = {}
        for name in names:
            arr = np.asarray(columns[name])[start:stop]
            ptype = PhysicalType(schema[name])
            nulls = null_masks.get(name)
            nulls = (
                np.asarray(nulls[start:stop], bool)
                if nulls is not None
                else np.zeros(arr.shape[0], bool)
            )
            dict_page, data_page, total, dict_enc, _ = _chunk_sizes(
                arr, nulls, ptype, opts
            )
            mn_k, mx_k, mn_l, mx_l, mn_r, mx_r = _stats(arr, nulls, ptype)
            cols_meta[name] = fmt.ColumnChunkMeta(
                name=name,
                physical_type=int(ptype),
                num_values=int(arr.shape[0]),
                null_count=int(nulls.sum()),
                total_uncompressed_size=total,
                dict_page_size=dict_page,
                data_page_size=data_page,
                encodings=["DICTIONARY"] if dict_enc else ["PLAIN"],
                min_key=mn_k,
                max_key=mx_k,
                min_len=mn_l,
                max_len=mx_l,
                min_repr=mn_r,
                max_repr=mx_r,
            )
        row_groups.append(fmt.RowGroupMeta(num_rows=stop - start, columns=cols_meta))

    footer = fmt.FileFooter(
        num_rows=n_rows,
        schema=schema,
        row_groups=row_groups,
        key_value_metadata=key_value_metadata or {},
    )

    os.makedirs(file_dir, exist_ok=True)
    # Atomic-ish write: temp files then rename (crash consistency for the
    # data pipeline's shard discovery).
    data = {}
    for name in names:
        arr = np.asarray(columns[name])
        if arr.dtype.kind in ("U", "S", "O"):
            arr = arr.astype(str)
        data[name] = arr
        mask = null_masks.get(name)
        if mask is not None:
            data[f"__nulls__{name}"] = np.asarray(mask, bool)
    fd, tmp = tempfile.mkstemp(dir=file_dir, suffix=".npz.tmp")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez_compressed(f, **data)
    os.replace(tmp, fmt.data_path(file_dir))
    fd, tmp = tempfile.mkstemp(dir=file_dir, suffix=".json.tmp")
    os.close(fd)
    with open(tmp, "w") as f:
        f.write(footer.to_json())
    os.replace(tmp, fmt.footer_path(file_dir))
    return footer


def write_dataset(
    root: str,
    shards: Sequence[Dict[str, np.ndarray]],
    *,
    options: Optional[WriterOptions] = None,
) -> list[fmt.FileFooter]:
    """Write a multi-file dataset (one PQLite file per shard)."""
    footers = []
    for i, cols in enumerate(shards):
        footers.append(
            write_file(os.path.join(root, f"shard_{i:05d}"), cols, options=options)
        )
    return footers
