"""Granite-MoE 3B-a800m: 40 experts top-8 (pool spec line; the hf card in
the pool bracket mentions 32e — we follow the explicit `MoE 40e top-8`)."""
from repro.models.config import ModelConfig, MoEConfig

# Production default adopts the §Perf winners: per-sub-row local dispatch
# (buffers shard over "model" via the sequence axis -> no buffer
# collectives; 24x better roofline bound than the global-dispatch baseline,
# see EXPERIMENTS.md §Perf).
CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    moe=MoEConfig(num_experts=40, top_k=8, dispatch="local", sub_rows=16),
)

SMOKE_CONFIG = CONFIG.scaled(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=64,
    vocab_size=256, moe=MoEConfig(num_experts=8, top_k=2),
)
