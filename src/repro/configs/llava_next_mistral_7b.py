"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Vision tower is a stub: input_specs supplies anyres patch embeddings."""
from repro.models.config import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    rope_theta=1e6,
    vlm=VLMConfig(vision_dim=1024, num_patches=576),
)

SMOKE_CONFIG = CONFIG.scaled(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, vlm=VLMConfig(vision_dim=32, num_patches=8),
)
