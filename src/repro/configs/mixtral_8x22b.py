"""Mixtral-8x22B [arXiv:2401.04088]: 8 experts top-2, sliding-window attn
(window per pool spec; SWA makes the long_500k cell sub-quadratic)."""
from repro.models.config import ModelConfig, MoEConfig

# Production default adopts the §Perf winners: per-sub-row local dispatch
# with TP-gathered buffers (expert weights keep ff-TP; 6x better roofline
# bound than the global-dispatch baseline, see EXPERIMENTS.md §Perf).
CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768,
    sliding_window=4096, rope_theta=1e6,
    moe=MoEConfig(num_experts=8, top_k=2, dispatch="local", sub_rows=16),
    train_microbatches=8,  # §Perf: fits 16GB HBM (13.7GB/dev)
)

SMOKE_CONFIG = CONFIG.scaled(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, sliding_window=32, moe=MoEConfig(num_experts=4, top_k=2),
)
