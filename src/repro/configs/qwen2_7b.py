"""Qwen2-7B [arXiv:2407.10671]: dense GQA decoder, QKV bias."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6,
    train_microbatches=1,  # §Perf: fewer per-mb FSDP gathers (13.2GB/dev fits)
)

SMOKE_CONFIG = CONFIG.scaled(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256,
)
