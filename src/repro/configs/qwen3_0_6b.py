"""Qwen3-0.6B [hf:Qwen/Qwen3-8B family]: dense GQA decoder with qk-norm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=3072, vocab_size=151936,
    qk_norm=True, head_dim=128, rope_theta=1e6,
)

SMOKE_CONFIG = CONFIG.scaled(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16,
)
