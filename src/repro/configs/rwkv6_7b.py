"""RWKV-6 "Finch" 7B [arXiv:2404.05892]: attention-free, data-dependent decay."""
from repro.models.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536,
    rwkv=RWKVConfig(head_dim=64, chunk=16),
)

SMOKE_CONFIG = CONFIG.scaled(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=256, rwkv=RWKVConfig(head_dim=16, chunk=8),
)
