"""SeamlessM4T-large-v2 [arXiv:2308.11596]: enc-dec multimodal backbone.

Audio frontend is a stub: input_specs supplies frame embeddings."""
from repro.models.config import ModelConfig, EncDecConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    encdec=EncDecConfig(num_encoder_layers=24, frontend_dim=1024),
)

SMOKE_CONFIG = CONFIG.scaled(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=256, encdec=EncDecConfig(num_encoder_layers=2, frontend_dim=32),
)
