"""Assigned input shapes (same 4 for every LM arch).

``train_4k``   lowers ``train_step``; ``prefill_32k`` lowers the prefill
forward; ``decode_32k`` / ``long_500k`` lower ``serve_step`` (one new token
against a KV cache / recurrent state of the given length).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode
    microbatches: int = 1      # gradient-accumulation steps (train only)


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train", microbatches=4)
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")


def cell_supported(cfg, shape: ShapeConfig) -> Tuple[bool, str]:
    """(supported, reason). long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (skip per assignment)"
        )
    return True, ""
