"""Yi-6B [arXiv:2403.04652]: llama-arch dense GQA."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
    d_ff=11008, vocab_size=64000,
    rope_theta=5e6,
)

SMOKE_CONFIG = CONFIG.scaled(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256,
)
