"""Zamba2-1.2B [arXiv:2411.15242]: Mamba2 backbone + shared attention."""
from repro.models.config import ModelConfig, SSMConfig, HybridConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk=256),
    hybrid=HybridConfig(attn_every=13),
)

SMOKE_CONFIG = CONFIG.scaled(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=256,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk=16),
)
