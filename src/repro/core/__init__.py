"""Paper core: zero-cost NDV estimation from columnar file metadata."""
from repro.core.ndv.estimator import (  # noqa: F401
    BatchEstimates,
    estimate_batch,
    estimate_columns,
    estimate_file,
)
from repro.core.ndv.types import (  # noqa: F401
    ColumnBatch,
    ColumnMetadata,
    Layout,
    NDVEstimate,
    PhysicalType,
)
