from repro.core.baselines.sketches import (  # noqa: F401
    cvm_ndv,
    exact_ndv,
    hll_estimate,
    hll_merge,
    hll_ndv,
    hll_registers,
    sampling_chao,
    sampling_gee,
    sampling_ndv,
    splitmix64,
)
