"""Data-access cardinality baselines: exact, HyperLogLog, CVM.

The paper's "zero-cost" claim is only meaningful against estimators that DO
read data. These are the comparison points used in benchmarks/baselines.py:

  * exact_ndv        — ground truth (hash set / np.unique).
  * HyperLogLog      — Flajolet et al. 2007, O(2^p) registers; also used
                       internally by the metadata path to count distinct
                       row-group extrema in O(1) space (paper §10.2).
  * CVM              — Chakraborty-Vinodchandran-Meel 2022 streaming sampler.

HLL here is a jnp implementation (batched register folds) with a numpy
streaming variant; the Pallas kernel (`repro.kernels.hll`) accelerates the
register-construction fold and is validated against `hll_registers` below.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Exact
# ---------------------------------------------------------------------------


def exact_ndv(values: np.ndarray) -> int:
    """Ground-truth distinct count (reads all data)."""
    return int(np.unique(values).size)


# ---------------------------------------------------------------------------
# Hashing (splitmix64 — deterministic, vectorizable, good avalanche)
# ---------------------------------------------------------------------------

_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)
_C = np.uint64(0x9E3779B97F4A7C15)


def splitmix64(x: np.ndarray) -> np.ndarray:
    """64-bit splitmix hash, numpy uint64 vectorized."""
    with np.errstate(over="ignore"):
        z = (x.astype(np.uint64) + _C)
        z = (z ^ (z >> np.uint64(30))) * _M1
        z = (z ^ (z >> np.uint64(27))) * _M2
        return z ^ (z >> np.uint64(31))


def splitmix64_jnp(x: jnp.ndarray) -> jnp.ndarray:
    """splitmix64 in jnp uint32-pair arithmetic-free form (uint64 path).

    CPU jax supports uint64 only with x64 enabled; to stay portable we use
    a 32-bit variant (two rounds of murmur3-style finalization) that the
    Pallas kernel also implements. Collision rate at 2^32 is fine for the
    register-indexing use (p <= 14, 18 bits consumed).
    """
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


# ---------------------------------------------------------------------------
# HyperLogLog
# ---------------------------------------------------------------------------


def _alpha(m: int) -> float:
    if m == 16:
        return 0.673
    if m == 32:
        return 0.697
    if m == 64:
        return 0.709
    return 0.7213 / (1.0 + 1.079 / m)


def hll_registers(hashes: jnp.ndarray, p: int = 12) -> jnp.ndarray:
    """Build HLL registers from 32-bit hashes.

    Args:
      hashes: (N,) uint32 pre-hashed values.
      p: register index bits; m = 2^p registers.

    Returns:
      (m,) int32 registers = max rho (leading-zero rank) per bucket.
    """
    m = 1 << p
    idx = (hashes >> (32 - p)).astype(jnp.int32)          # top p bits
    rest = (hashes << p).astype(jnp.uint32)               # remaining 32-p bits
    # rho = position of leftmost 1 in `rest` within (32-p) bits, else 32-p+1.
    # Exact leading-zero count via bit trick (float log2 is off at boundaries).
    nbits = 32 - p
    lz = _clz32(rest)
    rho = jnp.minimum(lz + 1, nbits + 1).astype(jnp.int32)
    regs = jnp.zeros((m,), jnp.int32)
    return regs.at[idx].max(rho)


def _clz32(x: jnp.ndarray) -> jnp.ndarray:
    """Count leading zeros of uint32, exact, branch-free."""
    x = x.astype(jnp.uint32)
    n = jnp.full(x.shape, 32, jnp.int32)
    c = jnp.zeros(x.shape, jnp.int32)
    for shift in (16, 8, 4, 2, 1):
        y = x >> shift
        move = y != 0
        c = jnp.where(move, c + shift, c)
        x = jnp.where(move, y, x)
    return jnp.where(x != 0, 31 - c, n).astype(jnp.int32)


def hll_estimate(registers: jnp.ndarray) -> jnp.ndarray:
    """Cardinality estimate from registers, with small-range correction."""
    m = registers.shape[-1]
    alpha = _alpha(m)
    inv_sum = jnp.sum(2.0 ** (-registers.astype(jnp.float32)), axis=-1)
    raw = alpha * m * m / inv_sum
    zeros = jnp.sum(registers == 0, axis=-1)
    # Linear counting for small cardinalities.
    lc = m * jnp.log(m / jnp.maximum(zeros.astype(jnp.float32), 1e-9))
    small = (raw <= 2.5 * m) & (zeros > 0)
    return jnp.where(small, lc, raw)


def hll_merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Merge two register arrays (sketch union)."""
    return jnp.maximum(a, b)


def hll_ndv(values: np.ndarray, p: int = 12) -> float:
    """End-to-end HLL over raw values (data-access baseline)."""
    v = np.asarray(values)
    if v.dtype.kind in "OUS":
        h = np.array(
            [hash(x) & 0xFFFFFFFF for x in v.tolist()], dtype=np.uint32
        )
    else:
        h64 = splitmix64(v.view(np.uint64) if v.dtype.itemsize == 8
                         else v.astype(np.uint64))
        h = (h64 >> np.uint64(32)).astype(np.uint32)
    regs = hll_registers(jnp.asarray(h), p)
    return float(hll_estimate(regs))


# ---------------------------------------------------------------------------
# CVM (Chakraborty-Vinodchandran-Meel 2022)
# ---------------------------------------------------------------------------


def cvm_ndv(values: np.ndarray, buffer_size: int = 4096, seed: int = 0) -> float:
    """CVM streaming distinct-elements estimate with a fixed buffer."""
    rng = np.random.default_rng(seed)
    p = 1.0
    buf: set = set()
    for x in np.asarray(values).tolist():
        buf.discard(x)
        if rng.random() < p:
            buf.add(x)
        if len(buf) >= buffer_size:
            # halve: keep each element with prob 1/2
            buf = {e for e in buf if rng.random() < 0.5}
            p /= 2.0
            if len(buf) >= buffer_size:  # pathological; one more halving
                buf = {e for e in buf if rng.random() < 0.5}
                p /= 2.0
    return len(buf) / p


# ---------------------------------------------------------------------------
# Sampling-based estimators (Haas et al. 1995)
# ---------------------------------------------------------------------------


def sampling_gee(sample: np.ndarray, total_rows: int) -> float:
    """Guaranteed-Error Estimator: d_gee = sqrt(N/n)*f1 + sum_{j>=2} f_j."""
    n = sample.size
    if n == 0:
        return 0.0
    _, counts = np.unique(sample, return_counts=True)
    f1 = float(np.sum(counts == 1))
    rest = float(np.sum(counts >= 2))
    return float(np.sqrt(total_rows / max(n, 1)) * f1 + rest)


def sampling_chao(sample: np.ndarray, total_rows: int) -> float:
    """Chao84 estimator: d + f1^2 / (2 f2)."""
    _, counts = np.unique(sample, return_counts=True)
    d = float(counts.size)
    f1 = float(np.sum(counts == 1))
    f2 = float(np.sum(counts == 2))
    if f2 == 0:
        return d + f1 * (f1 - 1) / 2.0
    return d + f1 * f1 / (2.0 * f2)


def sampling_ndv(
    values: np.ndarray, frac: float = 0.01, method: str = "gee", seed: int = 0
) -> Tuple[float, int]:
    """Uniform row sample + scale-up estimate. Returns (estimate, rows_read)."""
    rng = np.random.default_rng(seed)
    v = np.asarray(values)
    n = max(int(v.size * frac), 1)
    idx = rng.choice(v.size, size=n, replace=False)
    sample = v[idx]
    est = sampling_gee(sample, v.size) if method == "gee" else sampling_chao(
        sample, v.size
    )
    return min(est, float(v.size)), n
