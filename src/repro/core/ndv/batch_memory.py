"""Batch dictionary-memory prediction (paper §8).

Given a global NDV estimate, predict the dictionary memory a size-B-bytes
batch needs — without reading the batch:

    D_global = ndv * len
    D_batch  = D_global * (1 - exp(-B / D_global))              (Eq 16)
    D_total  = n_batches * D_batch,
    n_batches = (N - nulls) * len / B                           (Eq 17)

Limitation (paper): Eq 16 assumes well-spread data. For sorted layouts each
batch holds a *distinct* value subset; the per-batch dictionary approaches
min(B-rows, D_global / n_batches)-style coverage instead, and the safe
planning figure is D_global. ``predict_batch_memory`` therefore takes the
detected layout and switches to the conservative model for sorted /
pseudo-sorted columns — this is the planner integration the paper describes
for the Theseus engine (GPU there, TPU host→HBM staging here).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.ndv.types import Layout


class BatchMemoryEstimate(NamedTuple):
    d_global: jnp.ndarray    # (B,) bytes — full-column dictionary size
    d_batch: jnp.ndarray     # (B,) bytes — expected per-batch dictionary
    d_total: jnp.ndarray     # (B,) bytes — across all batches (Eq 17)
    n_batches: jnp.ndarray   # (B,)


def expected_batch_dictionary(
    batch_bytes: jnp.ndarray, d_global: jnp.ndarray
) -> jnp.ndarray:
    """Eq 16, numerically safe."""
    d = jnp.maximum(jnp.asarray(d_global, jnp.float32), 1e-6)
    return d * -jnp.expm1(-jnp.asarray(batch_bytes, jnp.float32) / d)


def predict_batch_memory(
    ndv: jnp.ndarray,
    mean_len: jnp.ndarray,
    non_null: jnp.ndarray,
    batch_bytes: float,
    *,
    layout: jnp.ndarray | None = None,
) -> BatchMemoryEstimate:
    """Eq 16-17 batched over columns; sorted-layout conservative switch.

    Args:
      ndv: (B,) final NDV estimates.
      mean_len: (B,) mean value byte length.
      non_null: (B,) N - nulls.
      batch_bytes: planner batch size B in bytes (scalar).
      layout: optional (B,) detector codes. When given, sorted and
        pseudo-sorted columns use the conservative D_batch = min(D_global,
        rows_per_batch * len) bound instead of Eq 16 (paper §8 Limitation).

    Returns:
      BatchMemoryEstimate.
    """
    ndv = jnp.asarray(ndv, jnp.float32)
    mean_len = jnp.maximum(jnp.asarray(mean_len, jnp.float32), 1e-6)
    non_null = jnp.maximum(jnp.asarray(non_null, jnp.float32), 0.0)
    B = jnp.float32(batch_bytes)

    d_global = ndv * mean_len
    d_batch = expected_batch_dictionary(B, d_global)

    if layout is not None:
        lay = jnp.asarray(layout)
        is_sorted = (lay == int(Layout.SORTED)) | (lay == int(Layout.PSEUDO_SORTED))
        # Sorted: each batch sees a fresh slice of the dictionary; expected
        # per-batch distinct bytes ~ min(D_global, batch rows * len), i.e.
        # every row may introduce a new value.
        conservative = jnp.minimum(d_global, B)
        d_batch = jnp.where(is_sorted, conservative, d_batch)

    total_bytes = non_null * mean_len
    n_batches = jnp.maximum(jnp.ceil(total_bytes / jnp.maximum(B, 1.0)), 0.0)
    d_total = n_batches * d_batch                               # Eq 17
    return BatchMemoryEstimate(
        d_global=d_global, d_batch=d_batch, d_total=d_total, n_batches=n_batches
    )
