"""Hybrid combination of the two estimators + bounds (paper §7).

    ndv_final = min(max(ndv_dict, ndv_minmax), N - nulls)       (Eq 13)

Type-specific bounds:
    integer/date:       ndv <= max - min + 1                    (Eq 14)
    single-byte string: ndv <= ~128 (printable ASCII)           (Eq 15)

Schema constraints (FK bounds etc.) enter through ``schema_bound``.

Both component estimators *underestimate* in different regimes (Table 1), so
the max of the two is the better point estimate; the deterministic bounds are
then applied on top. A heuristic confidence score summarizes agreement and
reliability signals for downstream planners.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.core.ndv.types import Layout, SINGLE_BYTE_BOUND


class CombineResult(NamedTuple):
    ndv: jnp.ndarray            # (B,) final estimate
    is_lower_bound: jnp.ndarray  # (B,) bool
    confidence: jnp.ndarray     # (B,) in [0, 1]
    route: jnp.ndarray          # (B,) int32 — ROUTE_MINMAX / ROUTE_DICT
    route_margin: jnp.ndarray   # (B,) in [0, 1) — decisiveness of Eq 13's max
    clamp_flags: jnp.ndarray    # (B,) int32 CLAMP_* bitmask — bounds that bit


# Which of the paper's two signals won Eq 13's max for a lane.
ROUTE_MINMAX = 0   # §5 coupon-collector inversion
ROUTE_DICT = 1     # §4 dictionary-size inversion

# Bits of ``clamp_flags``: set when the corresponding deterministic bound
# actually reduced the estimate (strict decrease, not mere applicability).
CLAMP_NON_NULL = 1      # Eq 13 cap: ndv <= N - nulls
CLAMP_INT_RANGE = 2     # Eq 14: ndv <= max - min + 1
CLAMP_SINGLE_BYTE = 4   # Eq 15: single-byte string bound
CLAMP_SCHEMA = 8        # §7.3 schema constraint


def combine_estimates(
    ndv_dict: jnp.ndarray,
    ndv_minmax: jnp.ndarray,
    *,
    non_null: jnp.ndarray,
    layout: jnp.ndarray,
    likely_fallback: jnp.ndarray,
    minmax_saturated: jnp.ndarray,
    int_like: jnp.ndarray,
    gmin: jnp.ndarray,
    gmax: jnp.ndarray,
    single_byte: jnp.ndarray,
    len_sample: jnp.ndarray,
    dict_encoded: Optional[jnp.ndarray] = None,
    schema_bound: Optional[jnp.ndarray] = None,
    suspect_clustered: Optional[jnp.ndarray] = None,
) -> CombineResult:
    """Eq 13-15 (+ §7.3 schema bound), batched.

    Args:
      ndv_dict / ndv_minmax: component estimates, (B,).
      non_null: N - nulls, (B,).
      layout: int32 Layout codes from the detector, (B,).
      likely_fallback: Eq 5 indicator from dictionary inversion, (B,) bool.
      minmax_saturated: m == n saturation flag from coupon inversion, (B,).
      int_like: Eq 14 applies, (B,) bool.
      gmin / gmax: global column min / max (for Eq 14), (B,).
      single_byte: Eq 15 applies, (B,) bool.
      len_sample: |V| reliability indicator (Eq 4), (B,) int.
      dict_encoded: False where the writer recorded plain encoding. When the
        metadata *tells us* there is no dictionary, Eq 1 does not describe S
        and the dict estimate is meaningless — route around it.
      schema_bound: optional per-column upper bound from catalog constraints
        (§7.3), e.g. referenced-table row count for FK columns.

    Returns:
      CombineResult(final ndv, lower-bound flag, confidence).
    """
    ndv_dict = jnp.asarray(ndv_dict, jnp.float32)
    ndv_minmax = jnp.asarray(ndv_minmax, jnp.float32)
    non_null = jnp.maximum(jnp.asarray(non_null, jnp.float32), 0.0)

    # When the writer recorded plain encoding for every chunk, Eq 1's premise
    # is void; dictionary inversion degenerates to S/len ~ N which Eq 5 also
    # flags. Null out the dict estimate in that case.
    if dict_encoded is not None:
        dict_ok = jnp.asarray(dict_encoded, bool) & ~likely_fallback
    else:
        dict_ok = ~likely_fallback

    # On explicit plain-encoding metadata the dict estimate is *no* signal at
    # all; under Eq 5 detection it is a lower bound. In both cases Eq 13's max
    # still wants the larger component — keep the dict value as a floor but
    # mark the result as a lower bound.
    ndv = jnp.maximum(ndv_dict, ndv_minmax)                    # Eq 13 (max)
    pre = ndv
    ndv = jnp.minimum(ndv, jnp.maximum(non_null, 1.0))         # Eq 13 (cap)
    clamp_flags = jnp.where(ndv < pre, CLAMP_NON_NULL, 0).astype(jnp.int32)

    # Eq 14: integer-like range bound.
    range_bound = jnp.maximum(
        jnp.asarray(gmax, jnp.float32) - jnp.asarray(gmin, jnp.float32) + 1.0,
        1.0,
    )
    pre = ndv
    ndv = jnp.where(int_like, jnp.minimum(ndv, range_bound), ndv)
    clamp_flags = clamp_flags | jnp.where(ndv < pre, CLAMP_INT_RANGE, 0)

    # Eq 15: single-byte strings.
    pre = ndv
    ndv = jnp.where(
        single_byte,
        jnp.minimum(ndv, jnp.minimum(SINGLE_BYTE_BOUND, jnp.maximum(non_null, 1.0))),
        ndv,
    )
    clamp_flags = clamp_flags | jnp.where(ndv < pre, CLAMP_SINGLE_BYTE, 0)

    # §7.3: schema constraint.
    if schema_bound is not None:
        sb = jnp.asarray(schema_bound, jnp.float32)
        pre = ndv
        ndv = jnp.where(sb > 0, jnp.minimum(ndv, sb), ndv)
        clamp_flags = clamp_flags | jnp.where(ndv < pre, CLAMP_SCHEMA, 0)

    ndv = jnp.maximum(ndv, 1.0)

    # The estimate is only a lower bound when the *winning* signal said so:
    #  - dict wins while flagged as plain-encoding fallback, or
    #  - minmax wins while coupon-saturated (m == n) on sorted data.
    dict_wins = ndv_dict >= ndv_minmax
    is_lower_bound = jnp.where(
        dict_wins,
        ~dict_ok,
        minmax_saturated & (jnp.asarray(layout) != int(Layout.SORTED)),
    )
    if suspect_clustered is not None:
        # Clustered signature (overlapping ranges + saturated extrema
        # diversity): runs shrink each chunk's effective sample, so every
        # metadata estimator under-sees the domain — report a lower bound.
        is_lower_bound = is_lower_bound | jnp.asarray(suspect_clustered, bool)
    # Saturated coupon on *detected sorted* layout is the designed regime
    # (each row group covers its own range): the paper treats it as accurate,
    # not merely a bound. Anywhere else, saturation means "at least this".

    # Heuristic confidence: agreement of the two estimators (within 2x),
    # detector decisiveness, and len-sample reliability.
    ratio = jnp.minimum(ndv_dict, ndv_minmax) / jnp.maximum(
        jnp.maximum(ndv_dict, ndv_minmax), 1.0
    )
    agree = jnp.clip(ratio * 2.0, 0.0, 1.0)
    len_rel = jnp.clip(jnp.asarray(len_sample, jnp.float32) / 16.0, 0.1, 1.0)
    layout_conf = jnp.where(
        jnp.asarray(layout) == int(Layout.MIXED), 0.6, 1.0
    )
    confidence = jnp.clip(
        0.25 + 0.45 * agree + 0.3 * len_rel * layout_conf, 0.0, 1.0
    )
    confidence = jnp.where(is_lower_bound, confidence * 0.5, confidence)
    # Route margin: how decisively Eq 13's max picked its winner. 0 means
    # the two signals tied (a coin-flip route); -> 1 means the loser was
    # negligible. Complements `agree` — provenance consumers read both.
    route_margin = 1.0 - ratio
    return CombineResult(
        ndv=ndv,
        is_lower_bound=is_lower_bound,
        confidence=confidence,
        route=jnp.where(dict_wins, ROUTE_DICT, ROUTE_MINMAX).astype(jnp.int32),
        route_margin=route_margin.astype(jnp.float32),
        clamp_flags=clamp_flags.astype(jnp.int32),
    )
