"""Dictionary size inversion (paper §4).

Inverts the dictionary-encoded storage equation

    S = ndv * len + (N - nulls) * ceil(log2(ndv)) / 8          (Eq 1)

for ``ndv`` via Newton-Raphson, using the *exact* residual f but a smooth
approximation of the derivative (the ceiling has zero derivative a.e.):

    f'(ndv) ~= len + (N - nulls) / (8 * ndv * ln 2)            (Eq 3)

Everything is vectorized over a batch of columns and expressed with
fixed-iteration ``lax.fori_loop`` so it jits cleanly and maps 1:1 onto the
Pallas kernel (`repro.kernels.newton_ndv`).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

NEWTON_ITERS = 32          # paper reports 5-10 to 1e-6; 32 is belt-and-braces
NEWTON_TOL = 1e-6
LN2 = 0.6931471805599453

# Eq 5 thresholds for plain-encoding fallback detection.
FALLBACK_NDV_RATIO = 0.9
FALLBACK_SIZE_LO = 0.8
FALLBACK_SIZE_HI = 1.2


def ceil_log2(ndv: jnp.ndarray) -> jnp.ndarray:
    """ceil(log2(ndv)) with ceil_log2(1) == 1 (1 bit minimum index width).

    Parquet's RLE/bit-packed hybrid needs at least 1 bit per index even for a
    single-entry dictionary, so we clamp below at 1 bit. Uses float log2 with
    a tiny epsilon nudge so exact powers of two are stable.
    """
    ndv = jnp.maximum(ndv, 1.0)
    bits = jnp.ceil(jnp.log2(ndv) - 1e-9)
    return jnp.maximum(bits, 1.0)


def smooth_log2(ndv: jnp.ndarray) -> jnp.ndarray:
    """Continuous relaxation of ceil(log2(ndv)) used for derivative only."""
    return jnp.maximum(jnp.log2(jnp.maximum(ndv, 1.0)), 1.0)


def dict_size_model(
    ndv: jnp.ndarray, mean_len: jnp.ndarray, non_null: jnp.ndarray
) -> jnp.ndarray:
    """Forward model: Eq 1 (what the writer's uncompressed size should be)."""
    return ndv * mean_len + non_null * ceil_log2(ndv) / 8.0


def residual(
    ndv: jnp.ndarray,
    size: jnp.ndarray,
    mean_len: jnp.ndarray,
    non_null: jnp.ndarray,
) -> jnp.ndarray:
    """Exact residual f(ndv) (Eq 2)."""
    return dict_size_model(ndv, mean_len, non_null) - size


def residual_derivative(
    ndv: jnp.ndarray, mean_len: jnp.ndarray, non_null: jnp.ndarray
) -> jnp.ndarray:
    """Smooth derivative approximation (Eq 3)."""
    return mean_len + non_null / (8.0 * jnp.maximum(ndv, 1.0) * LN2)


class DictInversionResult(NamedTuple):
    ndv: jnp.ndarray            # (B,) point estimate (>= 1)
    iterations: jnp.ndarray     # (B,) iterations to convergence
    converged: jnp.ndarray      # (B,) bool — |f| <= tol * scale at exit
    likely_fallback: jnp.ndarray  # (B,) bool — Eq 5 fired; treat as lower bound


def fallback_flags(
    size: jnp.ndarray,
    num_values: jnp.ndarray,
    null_count: jnp.ndarray,
    mean_len: jnp.ndarray,
) -> jnp.ndarray:
    """Eq 5 plain-encoding fallback indicator (closed form, solver-free).

    The first indicator uses the solver's degenerate-high-NDV interpretation
    S/len (the converged root absorbs index overhead and sits at
    (1 - bits/(8 len)) * rows for plain-encoded chunks, which would miss the
    0.9 threshold for narrow fixed-width types).
    """
    size = jnp.asarray(size, jnp.float32)
    non_null = jnp.maximum(
        jnp.asarray(num_values, jnp.float32)
        - jnp.asarray(null_count, jnp.float32),
        0.0,
    )
    mean_len = jnp.maximum(jnp.asarray(mean_len, jnp.float32), 1e-6)
    ndv_ratio = (size / mean_len) / jnp.maximum(non_null, 1.0)
    size_ratio = size / jnp.maximum(non_null * mean_len, 1e-6)
    return (
        (ndv_ratio >= FALLBACK_NDV_RATIO)
        & (size_ratio >= FALLBACK_SIZE_LO)
        & (size_ratio <= FALLBACK_SIZE_HI)
    )


def invert_dict_size(
    size: jnp.ndarray,
    num_values: jnp.ndarray,
    null_count: jnp.ndarray,
    mean_len: jnp.ndarray,
    *,
    iters: int = NEWTON_ITERS,
    tol: float = NEWTON_TOL,
    backend: str = "auto",
) -> DictInversionResult:
    """Solve Eq 2 for ndv, batched over columns.

    Args:
      size: (B,) total_uncompressed_size S in bytes.
      num_values: (B,) row count N.
      null_count: (B,) null count.
      mean_len: (B,) mean value byte length (Eq 4 / schema width).
      backend: execution route. "auto"/"ref" solve here in jnp — the route
        the fused megakernel's body (`repro.kernels.fused_estimate`) also
        takes, since a nested `pallas_call` is not allowed; "pallas" (or
        "auto" on TPU) routes the Newton solve through the `repro.kernels`
        Pallas kernel, with the Eq 5 flags and fixed iteration count filled
        in from the closed forms.

    Returns:
      DictInversionResult with ndv clamped to [1, N - nulls].
    """
    from repro.kernels import ops  # local: kernels.ref imports this module

    if ops.use_pallas(backend):
        shape = jnp.shape(size)
        mean_b = jnp.broadcast_to(jnp.asarray(mean_len, jnp.float32), shape)
        flat = lambda x: jnp.asarray(x, jnp.float32).reshape(-1)  # noqa: E731
        ndv = ops.dict_newton(
            flat(size), flat(num_values), flat(null_count), flat(mean_b),
            backend="pallas",
        ).reshape(shape)
        # The kernel is fixed-iteration and branch-free: it always runs
        # DICT_ITERS steps and converges by construction on Eq 2's
        # monotone residual.
        from repro.kernels.newton_ndv import DICT_ITERS

        return DictInversionResult(
            ndv=ndv,
            iterations=jnp.full(shape, DICT_ITERS, jnp.int32),
            converged=jnp.ones(shape, bool),
            likely_fallback=fallback_flags(
                size, num_values, null_count, mean_len
            ),
        )

    size = jnp.asarray(size, jnp.float32)
    non_null = jnp.maximum(
        jnp.asarray(num_values, jnp.float32) - jnp.asarray(null_count, jnp.float32),
        0.0,
    )
    mean_len = jnp.maximum(jnp.asarray(mean_len, jnp.float32), 1e-6)

    # Initial guess: index overhead assumed small (paper §4.2).
    ndv0 = jnp.maximum(size / mean_len, 1.0)

    # Relative tolerance scale: sizes span bytes..TB, so scale by S.
    scale = jnp.maximum(size, 1.0)

    def body(_, carry):
        ndv, it, done = carry
        f = residual(ndv, size, mean_len, non_null)
        fp = residual_derivative(ndv, mean_len, non_null)
        step = f / fp
        new_ndv = jnp.clip(ndv - step, 1.0, jnp.maximum(non_null, 1.0))
        now_done = jnp.abs(f) <= tol * scale
        ndv = jnp.where(done | now_done, ndv, new_ndv)
        it = it + jnp.where(done | now_done, 0, 1).astype(jnp.int32)
        return ndv, it, done | now_done

    ndv, iters_used, converged = jax.lax.fori_loop(
        0,
        iters,
        body,
        (ndv0, jnp.zeros_like(size, jnp.int32), jnp.zeros_like(size, bool)),
    )
    # The ceiling makes f piecewise-linear in ndv with jumps at powers of 2;
    # after Newton converges on the smooth surrogate's root, snap within the
    # final bit-width plateau by re-solving the linear piece exactly:
    #   ndv = (S - non_null*bits/8) / len   with bits = ceil_log2(ndv*)
    bits = ceil_log2(ndv)
    linear_ndv = (size - non_null * bits / 8.0) / mean_len
    # Only accept the snap if it stays inside the same bit plateau.
    same_plateau = ceil_log2(jnp.maximum(linear_ndv, 1.0)) == bits
    ndv = jnp.where(
        same_plateau & (linear_ndv >= 1.0),
        linear_ndv,
        ndv,
    )
    ndv = jnp.clip(ndv, 1.0, jnp.maximum(non_null, 1.0))

    likely_fallback = fallback_flags(size, num_values, null_count, mean_len)
    return DictInversionResult(
        ndv=ndv,
        iterations=iters_used,
        converged=converged,
        likely_fallback=likely_fallback,
    )


def invert_dict_size_scalar(
    size: float, num_values: float, null_count: float, mean_len: float
) -> Tuple[float, bool]:
    """Convenience scalar wrapper. Returns (ndv, likely_fallback)."""
    res = invert_dict_size(
        jnp.asarray([size]),
        jnp.asarray([num_values]),
        jnp.asarray([null_count]),
        jnp.asarray([mean_len]),
    )
    return float(res.ndv[0]), bool(res.likely_fallback[0])
