"""Distribution detection from row-group range patterns (paper §6).

Classifies each column's physical layout from the sequence of per-row-group
(min_i, max_i) ranges:

  overlap(r_i, r_{i+1}) = max(0, min(max_i, max_{i+1}) - max(min_i, min_{i+1}))
  overlap_ratio = sum_i overlap(r_i, r_{i+1}) / total_span          (Eq 10-11)
  monotonicity  = 1 - sign_changes(delta midpoints) / (n - 2)       (Eq 12)

Classes (§6.2):
  Sorted:        overlap_ratio < 0.1 and monotonicity > 0.9
  Pseudo-sorted: overlap_ratio < 0.3 and monotonicity > 0.7
  Well-spread:   overlap_ratio > 0.7
  Mixed:         otherwise

All metrics are masked for padded row groups and vectorized over columns so
the same code serves the scalar API, the batched estimator, and the oracle
for the `minmax_scan` Pallas kernel.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.ndv.types import Layout

SORTED_OVERLAP = 0.1
SORTED_MONO = 0.9
PSEUDO_OVERLAP = 0.3
PSEUDO_MONO = 0.7
WELL_SPREAD_OVERLAP = 0.7


class DistributionMetrics(NamedTuple):
    overlap_ratio: jnp.ndarray   # (B,)
    monotonicity: jnp.ndarray    # (B,)
    total_span: jnp.ndarray      # (B,) global max - global min
    layout: jnp.ndarray          # (B,) int32 Layout codes


def detect_distribution(
    mins: jnp.ndarray,
    maxs: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    backend: str = "auto",
) -> DistributionMetrics:
    """Compute Eq 10-12 metrics and classify (§6.2), batched.

    Args:
      mins / maxs: (B, R) per-row-group extrema (float key space).
      valid: (B, R) bool mask; row groups are packed to the left.
      backend: "auto"/"ref" compute the reductions here in jnp; "pallas"
        (or "auto" on TPU) takes them from the `minmax_scan` kernel. The
        ratio/classification tail is shared.

    Returns:
      DistributionMetrics with int32 layout codes from `Layout`.
    """
    mins = jnp.asarray(mins, jnp.float32)
    maxs = jnp.asarray(maxs, jnp.float32)
    valid = jnp.asarray(valid, bool)

    from repro.kernels import ops  # local: kernels.ref imports this package

    if ops.use_pallas(backend):
        mm = ops.minmax_scan(mins, maxs, valid, backend="pallas")
        n = mm.n_valid
        gmin, gmax = mm.gmin, mm.gmax
        overlap_sum = mm.overlap_sum
        sign_changes = mm.sign_changes
        # Row groups are packed to the left, so "any valid consecutive
        # pair" is exactly n >= 2.
        any_pairs = n >= 2.0
    else:
        n = jnp.sum(valid, axis=-1).astype(jnp.float32)  # (B,)

        big = jnp.float32(3.4e38)
        gmin = jnp.min(jnp.where(valid, mins, big), axis=-1)
        gmax = jnp.max(jnp.where(valid, maxs, -big), axis=-1)

        # Consecutive-pair overlap (Eq 10), masked to valid pairs.
        pair_valid = valid[:, :-1] & valid[:, 1:]
        lo = jnp.maximum(mins[:, :-1], mins[:, 1:])
        hi = jnp.minimum(maxs[:, :-1], maxs[:, 1:])
        overlap = jnp.where(pair_valid, jnp.maximum(hi - lo, 0.0), 0.0)
        overlap_sum = jnp.sum(overlap, axis=-1)

        # Midpoint monotonicity (Eq 12).
        mid = (mins + maxs) * 0.5
        d = mid[:, 1:] - mid[:, :-1]                  # (B, R-1)
        d = jnp.where(pair_valid, d, 0.0)
        sgn = jnp.sign(d)
        # Sign changes between consecutive non-zero deltas, masked.
        step_valid = pair_valid[:, :-1] & pair_valid[:, 1:]
        changes = jnp.where(
            step_valid & (sgn[:, :-1] * sgn[:, 1:] < 0), 1.0, 0.0
        )
        sign_changes = jnp.sum(changes, axis=-1)
        any_pairs = jnp.sum(pair_valid, axis=-1) > 0

    total_span = jnp.maximum(gmax - gmin, 0.0)

    # Degenerate spans (constant column / single row group): define the
    # overlap ratio as 1 when consecutive ranges coincide (full overlap) —
    # a constant column IS maximally well-spread.
    span_safe = jnp.maximum(total_span, 1e-30)
    degenerate = (total_span <= 0.0) & any_pairs
    overlap_ratio = jnp.where(
        degenerate, 1.0, jnp.clip(overlap_sum / span_safe, 0.0, None)
    )
    # (ratio can legitimately exceed 1 for heavy overlap with many groups;
    #  classification only needs thresholds, keep the raw value.)

    denom = jnp.maximum(n - 2.0, 1.0)
    monotonicity = jnp.where(
        n >= 3.0, 1.0 - sign_changes / denom, 1.0
    )

    layout = classify(overlap_ratio, monotonicity, n)
    return DistributionMetrics(
        overlap_ratio=overlap_ratio,
        monotonicity=monotonicity,
        total_span=total_span,
        layout=layout,
    )


def classify(
    overlap_ratio: jnp.ndarray,
    monotonicity: jnp.ndarray,
    n_groups: jnp.ndarray,
) -> jnp.ndarray:
    """§6.2 decision rules -> int32 Layout codes."""
    sorted_ = (overlap_ratio < SORTED_OVERLAP) & (monotonicity > SORTED_MONO)
    pseudo = (overlap_ratio < PSEUDO_OVERLAP) & (monotonicity > PSEUDO_MONO)
    spread = overlap_ratio > WELL_SPREAD_OVERLAP
    out = jnp.full_like(overlap_ratio, float(Layout.MIXED))
    out = jnp.where(spread, float(Layout.WELL_SPREAD), out)
    out = jnp.where(pseudo & ~spread, float(Layout.PSEUDO_SORTED), out)
    out = jnp.where(sorted_, float(Layout.SORTED), out)
    # With a single row group there is no layout signal: treat as well-spread
    # (dictionary inversion is exact for one group).
    out = jnp.where(n_groups <= 1, float(Layout.WELL_SPREAD), out)
    return out.astype(jnp.int32)
