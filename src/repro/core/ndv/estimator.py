"""Top-level zero-cost NDV estimator (paper §3-§7 end to end).

`estimate_batch` is the pure jit-compiled per-shard kernel: metadata arrays
in, estimates out, no knowledge of devices or batch budgets. Execution —
local vs sharded vs chunked, and the kernel backend knob — is owned by
`repro.engine.EstimationEngine`, which every consumer (catalog, pipeline,
planner, benchmarks) routes through. `estimate_columns` is the convenience
object API over `ColumnMetadata`, delegating to the default engine.

Pipeline per column (all batched over B columns x R chunks):
  1. distribution detection from (min_i, max_i) patterns         (§6)
  2. PER-CHUNK dictionary size inversion w/ fallback detection,
     aggregated across chunks by masked max                      (§4)
  3. min/max diversity via coupon-collector inversion            (§5)
  4. hybrid combination + type/schema bounds                     (§7)

Why max-aggregation for §4: each chunk's dictionary holds the distinct
values OF THAT CHUNK, so a chunk inversion lower-bounds the global NDV. When
values are well-spread, every chunk sees nearly all distinct values and the
max is tight; when sorted, each chunk sees ~NDV/n values and the max
underestimates — exactly the complementarity of paper Table 1.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ndv import combine as combine_mod
from repro.core.ndv import dict_inversion, distribution, improved, minmax_diversity
from repro.core.ndv.types import ColumnBatch, ColumnMetadata, Layout, NDVEstimate


class BatchEstimates(NamedTuple):
    """Struct-of-arrays estimation output for B columns.

    The trailing provenance fields (route onward) are per-lane diagnostics
    of HOW each estimate was produced. They are emitted by the same
    single-definition pipeline body as the estimates themselves — fused and
    unfused paths, every engine strategy — so they obey the identical
    bit-parity contract, and they never enter cache keys or ETags.
    """

    ndv: jnp.ndarray
    ndv_dict: jnp.ndarray
    ndv_minmax: jnp.ndarray
    layout: jnp.ndarray
    is_lower_bound: jnp.ndarray
    confidence: jnp.ndarray
    overlap_ratio: jnp.ndarray
    monotonicity: jnp.ndarray
    mean_len: jnp.ndarray
    dict_iterations: jnp.ndarray
    route: jnp.ndarray             # int32 — combine.ROUTE_DICT / ROUTE_MINMAX
    route_margin: jnp.ndarray      # float32 in [0, 1) — Eq 13 decisiveness
    detector_margin: jnp.ndarray   # float32 — distance to nearest §6 threshold
    dict_residual: jnp.ndarray     # float32 — worst normalized Eq 2 residual
    coupon_iterations: jnp.ndarray  # int32 — §5 Newton iters, winning side
    clamp_flags: jnp.ndarray       # int32 — combine.CLAMP_* bounds that bit


def dict_estimate_column(
    batch: ColumnBatch,
    *,
    backend: str = "auto",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """§4 per-chunk inversion -> (ndv_dict, likely_fallback, iters, residual).

    Chunks whose writer-recorded encoding is plain are excluded from the max
    (their S does not obey Eq 1); if ALL chunks of a column are plain, the
    column-level fallback flag is raised and ndv_dict falls back to the
    plain-size implied bound S/len ~ rows (a lower-bound signal).

    ``residual`` is the worst |Eq 2 residual| / S across the column's valid
    chunks at the converged roots — the solver's own error signal, surfaced
    for provenance (a large value means Eq 1 never fit that chunk's size).
    """
    inv = dict_inversion.invert_dict_size(
        batch.chunk_S,
        batch.chunk_rows,
        batch.chunk_nulls,
        batch.mean_len[:, None],
        backend=backend,
    )
    usable = batch.valid & batch.chunk_dict_encoded & ~inv.likely_fallback
    neg = jnp.float32(-1.0)
    ndv_usable = jnp.max(jnp.where(usable, inv.ndv, neg), axis=-1)
    # Fallback path: no usable dictionary chunk -> max over ALL valid chunks
    # (plain chunks invert to ~rows; Eq 5 semantics: a lower bound).
    ndv_any = jnp.max(jnp.where(batch.valid, inv.ndv, neg), axis=-1)
    no_usable = ndv_usable < 0.0
    ndv_col = jnp.where(no_usable, ndv_any, ndv_usable)
    ndv_col = jnp.maximum(ndv_col, 1.0)
    fallback_col = no_usable
    iters = jnp.max(jnp.where(batch.valid, inv.iterations, 0), axis=-1)
    chunk_non_null = jnp.maximum(batch.chunk_rows - batch.chunk_nulls, 0.0)
    resid = jnp.abs(
        dict_inversion.residual(
            inv.ndv, batch.chunk_S, batch.mean_len[:, None], chunk_non_null
        )
    ) / jnp.maximum(batch.chunk_S, 1.0)
    resid = jnp.max(jnp.where(batch.valid, resid, 0.0), axis=-1)
    return ndv_col, fallback_col, iters, resid.astype(jnp.float32)


def estimate_batch_core(
    batch: ColumnBatch,
    schema_bound: Optional[jnp.ndarray] = None,
    *,
    mode: str = "paper",
    backend: str = "auto",
) -> BatchEstimates:
    """The unjitted §4-§7 pipeline body: ColumnBatch tiles in, estimates out.

    Shared verbatim by the unfused `estimate_batch` path and (with
    ``backend="ref"``) by the fused megakernel's body and its oracle
    (`repro.kernels.fused_estimate` / `repro.kernels.ref.ref_fused_estimate`)
    — one definition of the numerics is what makes the fuse knob provably
    numerics-neutral.
    """
    # --- §6: distribution detection --------------------------------------
    metrics = distribution.detect_distribution(
        batch.mins, batch.maxs, batch.valid, backend=backend
    )

    # --- §4: dictionary size inversion (per chunk -> column aggregate) ----
    if mode == "improved":
        imp = improved.improved_dict_estimate(
            batch, metrics.overlap_ratio, backend=backend
        )
        ndv_dict, likely_fallback = imp.ndv, imp.likely_fallback
        _, _, dict_iters, dict_resid = dict_estimate_column(
            batch, backend=backend
        )
    else:
        ndv_dict, likely_fallback, dict_iters, dict_resid = (
            dict_estimate_column(batch, backend=backend)
        )

    # --- §5: min/max diversity --------------------------------------------
    mm = minmax_diversity.estimate_minmax_diversity(
        batch.m_min,
        batch.m_max,
        batch.n_groups.astype(jnp.float32),
        backend=backend,
    )

    # --- §7: combine -------------------------------------------------------
    big = jnp.float32(3.4e38)
    gmin = jnp.min(jnp.where(batch.valid, batch.mins, big), axis=-1)
    gmax = jnp.max(jnp.where(batch.valid, batch.maxs, -big), axis=-1)
    non_null = batch.N - batch.nulls
    # Clustered signature: range overlap says "well-spread" while the
    # extrema diversity saturates — runs are hiding the domain tail.
    n_f = batch.n_groups.astype(jnp.float32)
    suspect_clustered = (
        (metrics.layout == int(Layout.WELL_SPREAD))
        & mm.saturated
        & (n_f >= 8.0)
    ) if mode == "improved" else None
    comb = combine_mod.combine_estimates(
        ndv_dict,
        mm.ndv,
        non_null=non_null,
        layout=metrics.layout,
        likely_fallback=likely_fallback,
        minmax_saturated=mm.saturated,
        int_like=batch.int_like,
        gmin=gmin,
        gmax=gmax,
        single_byte=batch.single_byte,
        len_sample=batch.len_sample,
        schema_bound=schema_bound,
        suspect_clustered=suspect_clustered,
    )
    # Detector margin: distance of the (overlap, monotonicity) metrics to
    # the NEAREST §6 classification threshold. A small margin means the
    # layout class — and with it the aggregation route — was a near-tie.
    ov, mono = metrics.overlap_ratio, metrics.monotonicity
    detector_margin = jnp.minimum(
        jnp.minimum(
            jnp.minimum(
                jnp.abs(ov - distribution.SORTED_OVERLAP),
                jnp.abs(mono - distribution.SORTED_MONO),
            ),
            jnp.minimum(
                jnp.abs(ov - distribution.PSEUDO_OVERLAP),
                jnp.abs(mono - distribution.PSEUDO_MONO),
            ),
        ),
        jnp.abs(ov - distribution.WELL_SPREAD_OVERLAP),
    ).astype(jnp.float32)
    return BatchEstimates(
        ndv=comb.ndv,
        ndv_dict=ndv_dict,
        ndv_minmax=mm.ndv,
        layout=metrics.layout,
        is_lower_bound=comb.is_lower_bound,
        confidence=comb.confidence,
        overlap_ratio=metrics.overlap_ratio,
        monotonicity=metrics.monotonicity,
        mean_len=batch.mean_len,
        dict_iterations=dict_iters,
        route=comb.route,
        route_margin=comb.route_margin,
        detector_margin=detector_margin,
        dict_residual=dict_resid,
        coupon_iterations=mm.iterations,
        clamp_flags=comb.clamp_flags,
    )


@functools.partial(jax.jit, static_argnames=("mode", "backend", "fuse"))
def estimate_batch(
    batch: ColumnBatch,
    schema_bound: Optional[jnp.ndarray] = None,
    *,
    mode: str = "paper",
    backend: str = "auto",
    fuse: str = "auto",
) -> BatchEstimates:
    """Vectorized zero-cost NDV estimation over a ColumnBatch.

    This is the pure per-shard kernel: the `repro.engine` package is the
    public path onto it and owns sharding/chunking of the B axis.

    Args:
      mode: "paper" — faithful reproduction (per-chunk max + Eq 13 hybrid);
            "improved" — beyond-paper layout-aware aggregation
            (coverage-corrected mean / disjoint-sum routing, see improved.py).
      backend: `repro.kernels.ops` execution knob, threaded through the
        engine config. "auto" = fastest correct path per platform (Pallas
        kernels on TPU, jnp reference elsewhere); "pallas"/"ref" force one.
      fuse: megakernel routing knob ("auto"/"on"/"off", threaded from
        `EngineConfig.fuse`). "on" (and "auto" on TPU) runs the whole §4-§7
        pipeline as one fused computation of the REFERENCE numerics: a
        single `pallas_call` (`repro.kernels.fused_estimate`) where the
        kernel path is production, the pure-XLA twin elsewhere — instead of
        3-4 kernel dispatches plus XLA glue. Numerics-neutral by the engine
        parity contract (the fused body IS `estimate_batch_core` with the
        reference backend), so the knob never enters
        `cache_key`/`cache_token`. "off" pins the unfused per-stage path.
    """
    from repro.kernels import ops  # local: kernels.ref imports this module

    if ops.use_fused(fuse):
        return ops.fused_estimate(batch, schema_bound, mode=mode, backend=backend)
    return estimate_batch_core(batch, schema_bound, mode=mode, backend=backend)


def estimates_from_batch(
    out: BatchEstimates, batch: ColumnBatch, names: Sequence[str],
    *, offset: int = 0
) -> List[NDVEstimate]:
    """Materialize per-column NDVEstimate objects from batched output.

    `names` may be shorter than the batch axis: the packer pads B up to a
    shape bucket, and the padding lanes carry no column. `offset` selects
    where on the B axis the named lanes start — a super-packed batch
    (`repro.catalog.superpack`) concatenates several column sets along B
    and materializes each set from its own lane span.

    Each field is pulled to the host once (one device-to-host copy per
    field, not one per column) and indexed as numpy from there — per-column
    indexing of device arrays would dispatch a device gather per scalar,
    shipping every Python index host-to-device, which both scales badly on
    wide catalogs and breaks the catalog's zero-H2D warm-path contract.
    """
    host = {f: np.asarray(getattr(out, f)) for f in out._fields}
    len_sample = np.asarray(batch.len_sample)
    res: List[NDVEstimate] = []
    for j, name in enumerate(names):
        i = offset + j
        res.append(
            NDVEstimate(
                ndv=float(host["ndv"][i]),
                ndv_dict=float(host["ndv_dict"][i]),
                ndv_minmax=float(host["ndv_minmax"][i]),
                layout=Layout(int(host["layout"][i])),
                is_lower_bound=bool(host["is_lower_bound"][i]),
                mean_len=float(host["mean_len"][i]),
                len_sample_size=int(len_sample[i]),
                overlap_ratio=float(host["overlap_ratio"][i]),
                monotonicity=float(host["monotonicity"][i]),
                confidence=float(host["confidence"][i]),
                column_name=name,
            )
        )
    return res


ROUTE_NAMES = {
    int(combine_mod.ROUTE_MINMAX): "minmax",
    int(combine_mod.ROUTE_DICT): "dict",
}

_CLAMP_NAMES = (
    (combine_mod.CLAMP_NON_NULL, "non_null"),
    (combine_mod.CLAMP_INT_RANGE, "int_range"),
    (combine_mod.CLAMP_SINGLE_BYTE, "single_byte"),
    (combine_mod.CLAMP_SCHEMA, "schema_bound"),
)


def clamp_names(flags: int) -> List[str]:
    """Human-readable names of the CLAMP_* bits set in ``flags``."""
    return [name for bit, name in _CLAMP_NAMES if flags & bit]


@dataclasses.dataclass(frozen=True)
class Provenance:
    """How one column's estimate was produced (per-lane diagnostics).

    Deliberately a SEPARATE record from `NDVEstimate`: estimate identity
    (bodies, ETags, caches, spills) is derived by iterating NDVEstimate's
    fields, so diagnostics must live outside it to stay bit-neutral.
    Attached to responses only on explicit `?explain=1` request.
    """

    column_name: str
    route: str              # "dict" (§4 won Eq 13's max) or "minmax" (§5)
    route_margin: float     # [0, 1): 0 = the two signals tied
    detector_margin: float  # distance to the nearest §6 threshold
    overlap_ratio: float
    monotonicity: float
    layout: str
    dict_iterations: int    # §4 Newton iterations (max over chunks)
    dict_residual: float    # worst |Eq 2 residual| / S at the roots
    coupon_iterations: int  # §5 Newton iterations, winning side
    clamp_flags: int        # raw combine.CLAMP_* bitmask
    clamps: tuple           # decoded clamp names, e.g. ("schema_bound",)
    schema_bound_hit: bool
    is_lower_bound: bool
    confidence: float


def provenance_from_batch(
    out: BatchEstimates, batch: ColumnBatch, names: Sequence[str],
    *, offset: int = 0
) -> List[Provenance]:
    """Materialize per-column Provenance from batched output.

    Mirrors `estimates_from_batch` (one device-to-host copy per field,
    `offset` selects the lane span of a super-packed batch). Reads ONLY
    `out` — callers that cached the BatchEstimates can materialize
    provenance later without re-running the engine.
    """
    host = {
        f: np.asarray(getattr(out, f))
        for f in (
            "route", "route_margin", "detector_margin", "dict_iterations",
            "dict_residual", "coupon_iterations", "clamp_flags", "layout",
            "overlap_ratio", "monotonicity", "is_lower_bound", "confidence",
        )
    }
    res: List[Provenance] = []
    for j, name in enumerate(names):
        i = offset + j
        flags = int(host["clamp_flags"][i])
        res.append(
            Provenance(
                column_name=name,
                route=ROUTE_NAMES[int(host["route"][i])],
                route_margin=float(host["route_margin"][i]),
                detector_margin=float(host["detector_margin"][i]),
                overlap_ratio=float(host["overlap_ratio"][i]),
                monotonicity=float(host["monotonicity"][i]),
                layout=Layout(int(host["layout"][i])).name,
                dict_iterations=int(host["dict_iterations"][i]),
                dict_residual=float(host["dict_residual"][i]),
                coupon_iterations=int(host["coupon_iterations"][i]),
                clamp_flags=flags,
                clamps=tuple(clamp_names(flags)),
                schema_bound_hit=bool(flags & combine_mod.CLAMP_SCHEMA),
                is_lower_bound=bool(host["is_lower_bound"][i]),
                confidence=float(host["confidence"][i]),
            )
        )
    return res


_PROVENANCE_FIELDS = tuple(f.name for f in dataclasses.fields(Provenance))


def provenance_to_json(p: Provenance) -> dict:
    """JSON-representable dict form (lists instead of tuples).

    Built by direct attribute access, not `dataclasses.asdict` — asdict
    runs the recursive deep-copy machinery, which dominated the warm
    explain path (every `?explain=1` response serializes every column).
    """
    d = {name: getattr(p, name) for name in _PROVENANCE_FIELDS}
    d["clamps"] = list(p.clamps)
    return d


def record_provenance_metrics(provs: Sequence[Provenance]) -> None:
    """Observe freshly-computed provenance into the metrics registry.

    Called once per engine run at materialization time (never on cache
    hits), so the `ndv_route_total` / `ndv_newton_iters` /
    `ndv_detector_margin` series count estimator work, not request traffic.
    """
    from repro.obs import metrics as obs_metrics

    reg = obs_metrics.registry()
    route_total = reg.counter(
        "ndv_route_total", "estimates produced per winning estimator route"
    )
    newton = reg.histogram(
        "ndv_newton_iters",
        "Newton iterations per estimate, by solver",
        buckets=obs_metrics.ITER_BUCKETS,
    )
    margin = reg.histogram(
        "ndv_detector_margin",
        "distance of detector metrics to the nearest layout threshold",
        buckets=obs_metrics.MARGIN_BUCKETS,
    )
    for p in provs:
        route_total.inc(route=p.route)
        newton.observe(p.dict_iterations, solver="dict")
        newton.observe(p.coupon_iterations, solver="coupon")
        margin.observe(p.detector_margin)


def estimate_columns(
    cols: Sequence[ColumnMetadata],
    schema_bounds: Optional[Sequence[float]] = None,
    *,
    mode: str = "paper",
    engine=None,
) -> List[NDVEstimate]:
    """Object API: list of ColumnMetadata -> list of NDVEstimate.

    Delegates to the process-wide default `EstimationEngine` (or the one
    passed in), which packs through ONE shared bucketing `BatchPacker` —
    ad-hoc calls get the same bucketing (and trace reuse) as the catalog
    path, with O(log B · log R) jit traces of `estimate_batch` across all
    callers instead of one per distinct shape.
    """
    from repro import engine as engine_mod  # local: avoid import cycle

    if not cols:
        return []
    engine = engine or engine_mod.default_engine()
    return engine.estimate_columns(cols, schema_bounds, mode=mode)


def estimate_file(
    file_meta, schema_bounds=None, *, mode: str = "paper", engine=None
) -> List[NDVEstimate]:
    """Estimate every column of a PQLite file from its footer only."""
    from repro.columnar.reader import column_metadata_from_footer

    cols = [
        column_metadata_from_footer(file_meta, name)
        for name in file_meta.column_names
    ]
    return estimate_columns(cols, schema_bounds, mode=mode, engine=engine)
