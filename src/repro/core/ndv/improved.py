"""Beyond-paper improved NDV estimator (layout-aware chunk aggregation).

The paper aggregates per-chunk dictionary inversions implicitly through the
"well-spread" assumption and routes to min/max diversity otherwise. Two
refinements — both derived from equations already *in* the paper — close most
of the residual error (see EXPERIMENTS.md §Accuracy for ablations):

1. **Coverage correction** (well-spread regimes). A chunk with k non-null
   rows drawn from NDV values only contains E[local] = NDV(1 - e^{-k/NDV})
   distinct values — the paper's own batch-dictionary equation (Eq 16) read
   in reverse. So after inverting Eq 1 for local_ndv we invert Eq 16 for the
   global NDV:   local_ndv = NDV * (1 - exp(-k/NDV)).
   This removes the systematic ~e^{-k/NDV} underestimate of max-aggregation
   when rows-per-group is not >> NDV.

2. **Disjoint-sum aggregation** (sorted / partitioned regimes). When row
   group ranges do not overlap, chunk dictionaries are (nearly) disjoint, so
   the global NDV is the SUM of local dictionary cardinalities, not the max.
   Boundary values shared by consecutive chunks are visible in metadata
   (max_i == min_{i+1}) and subtracted exactly.

Routing interpolates between the two aggregations in log space using the
detector's overlap ratio, and the final estimate takes the max with the
paper's min/max-diversity estimate and applies the same §7 bounds.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.ndv import dict_inversion, minmax_diversity
from repro.core.ndv.types import ColumnBatch


class ImprovedDictResult(NamedTuple):
    ndv: jnp.ndarray              # (B,) layout-aware estimate
    ndv_corrected_max: jnp.ndarray  # coverage-corrected, max-aggregated
    ndv_disjoint_sum: jnp.ndarray   # sum-aggregated (sorted/partitioned)
    likely_fallback: jnp.ndarray  # (B,) bool


def improved_dict_estimate(
    batch: ColumnBatch,
    overlap_ratio: jnp.ndarray,
    *,
    backend: str = "auto",
) -> ImprovedDictResult:
    """Layout-aware aggregation of per-chunk dictionary inversions."""
    inv = dict_inversion.invert_dict_size(
        batch.chunk_S,
        batch.chunk_rows,
        batch.chunk_nulls,
        batch.mean_len[:, None],
        backend=backend,
    )
    usable = batch.valid & batch.chunk_dict_encoded & ~inv.likely_fallback
    chunk_non_null = jnp.maximum(batch.chunk_rows - batch.chunk_nulls, 1.0)

    # --- (1) coverage correction: invert Eq 16 per chunk ------------------
    # local = NDV (1 - e^{-k/NDV})  with k = chunk rows (draws).
    corr = minmax_diversity.invert_coupon(
        jnp.where(usable, inv.ndv, 1.0),
        chunk_non_null,
        backend=backend,
    )
    corrected = jnp.where(usable, corr.ndv, -1.0)
    # Aggregate robustly: mean over usable chunks (each chunk is an i.i.d.
    # estimate of the same global NDV under the well-spread assumption).
    n_usable = jnp.maximum(jnp.sum(usable, axis=-1), 1)
    corrected_mean = (
        jnp.sum(jnp.where(usable, corr.ndv, 0.0), axis=-1) / n_usable
    )
    corrected_max = jnp.max(corrected, axis=-1)
    # Saturated correction (local_ndv ~ rows) means the chunk cannot bound
    # NDV from metadata; fall back to the uncorrected max there.
    ndv_corrected = jnp.where(corrected_max > 0, corrected_mean, 1.0)

    # --- (2) disjoint-sum aggregation --------------------------------------
    local_sum = jnp.sum(jnp.where(usable, inv.ndv, 0.0), axis=-1)
    # Exact boundary dedup: consecutive chunks sharing a value have
    # max_i == min_{i+1} in the footer stats.
    shared = (
        (batch.maxs[:, :-1] == batch.mins[:, 1:])
        & batch.valid[:, :-1]
        & batch.valid[:, 1:]
    )
    local_sum = jnp.maximum(local_sum - jnp.sum(shared, axis=-1), 1.0)

    # --- routing ------------------------------------------------------------
    # overlap_ratio ~ 0  -> ranges disjoint -> sum is (near) exact.
    # overlap_ratio >~ 0.7 -> well-spread -> coverage-corrected mean.
    w = jnp.clip((overlap_ratio - 0.05) / (0.65 - 0.05), 0.0, 1.0)
    log_est = w * jnp.log(jnp.maximum(ndv_corrected, 1.0)) + (1.0 - w) * jnp.log(
        jnp.maximum(local_sum, 1.0)
    )
    ndv = jnp.exp(log_est)

    # Never below the plain per-chunk max (that is a hard lower bound).
    hard_floor = jnp.maximum(jnp.max(jnp.where(usable, inv.ndv, 1.0), axis=-1), 1.0)
    ndv = jnp.maximum(ndv, hard_floor)

    # Column-level fallback: no usable dictionary chunk at all.
    no_usable = jnp.sum(usable, axis=-1) == 0
    neg = jnp.float32(-1.0)
    ndv_any = jnp.max(jnp.where(batch.valid, inv.ndv, neg), axis=-1)
    ndv = jnp.where(no_usable, jnp.maximum(ndv_any, 1.0), ndv)
    return ImprovedDictResult(
        ndv=ndv,
        ndv_corrected_max=ndv_corrected,
        ndv_disjoint_sum=local_sum,
        likely_fallback=no_usable,
    )
