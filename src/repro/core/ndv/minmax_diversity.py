"""Min/max diversity estimation via coupon-collector inversion (paper §5).

The n row-group minima are modeled as n uniform draws (with replacement)
from a population of NDV distinct values:

    E[m] = NDV * (1 - exp(-n / NDV))                            (Eq 7)

Given the observed distinct-extrema count m, invert

    g(NDV) = NDV * (1 - exp(-n/NDV)) - m = 0                    (Eq 8)

with Newton-Raphson and derivative

    g'(NDV) = 1 - exp(-n/NDV) * (1 + n/NDV)                     (Eq 9)

Separate estimates from m_min and m_max; keep the larger (paper §5.3).

Numerical notes:
  * g is monotonically increasing in NDV with g(NDV) -> n - m as NDV -> inf,
    so a root exists only when m < n. When m == n (every row group exposed a
    different extremum — the sorted case), the MLE diverges; we return the
    standard regularized estimate from the (m = n-1/2) continuity-corrected
    count, and flag saturation so the combiner can treat it as a lower bound.
  * We iterate in log-space (NDV = exp(t)) which keeps Newton stable for the
    huge dynamic range (NDV in [1, 1e12]).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEWTON_ITERS = 40
NEWTON_TOL = 1e-6


def coupon_expected(ndv: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """E[distinct] = NDV*(1-exp(-n/NDV)) (Eq 6), safe at ndv -> 0."""
    ndv = jnp.maximum(ndv, 1e-9)
    return ndv * -jnp.expm1(-n / ndv)


def coupon_derivative(ndv: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """g'(NDV) (Eq 9)."""
    ndv = jnp.maximum(ndv, 1e-9)
    r = n / ndv
    return -jnp.expm1(-r) - jnp.exp(-r) * r


class CouponInversionResult(NamedTuple):
    ndv: jnp.ndarray         # (B,) estimate
    saturated: jnp.ndarray   # (B,) bool — m ~= n, estimate is a lower bound
    iterations: jnp.ndarray  # (B,)


def invert_coupon(
    m: jnp.ndarray,
    n: jnp.ndarray,
    *,
    iters: int = NEWTON_ITERS,
    tol: float = NEWTON_TOL,
    backend: str = "auto",
) -> CouponInversionResult:
    """Solve Eq 8 for NDV given observed distinct count m out of n draws.

    Args:
      m: (B,) observed number of distinct extrema (1 <= m <= n).
      n: (B,) number of row groups (draws).
      backend: "auto"/"ref" solve here in jnp; "pallas" (or "auto" on TPU)
        routes the full inversion — including saturation handling — through
        the `repro.kernels` Pallas kernel.

    Returns:
      CouponInversionResult. For the saturated case (m == n) we return the
      inversion at m_eff = n - 0.5 (continuity correction) and set
      ``saturated`` so the caller treats it as a lower bound.
    """
    m = jnp.asarray(m, jnp.float32)
    n = jnp.asarray(n, jnp.float32)

    from repro.kernels import ops  # local: kernels.ref imports this module

    if ops.use_pallas(backend):
        from repro.kernels.newton_ndv import COUPON_ITERS

        ndv = ops.coupon_newton(
            m.reshape(-1), n.reshape(-1), backend="pallas"
        ).reshape(jnp.shape(m))
        return CouponInversionResult(
            ndv=ndv,
            saturated=m >= n - 0.5,
            iterations=jnp.full(jnp.shape(m), COUPON_ITERS, jnp.int32),
        )

    # Saturation band of half a coupon: observed counts are integral, and
    # the inversion is hopelessly ill-conditioned within < 0.5 of n anyway.
    saturated = m >= n - 0.5
    # Continuity-corrected observation for the saturated case.
    m_eff = jnp.where(saturated, jnp.maximum(n - 0.5, 0.5), m)
    m_eff = jnp.clip(m_eff, 0.5, jnp.maximum(n - 1e-3, 0.5))

    # Initial guess. Expanding Eq 7 to second order: m ~ n - n^2/(2 NDV)
    # => NDV0 ~ n^2 / (2 (n - m)). Good near saturation; clamp elsewhere.
    ndv0 = jnp.clip(n * n / (2.0 * jnp.maximum(n - m_eff, 1e-3)), 1.0, 1e12)
    t0 = jnp.log(ndv0)

    def body(_, carry):
        t, it, done = carry
        ndv = jnp.exp(t)
        g = coupon_expected(ndv, n) - m_eff
        gp = coupon_derivative(ndv, n)
        # d/dt g(exp(t)) = g'(ndv) * ndv
        step = g / jnp.maximum(gp * ndv, 1e-12)
        new_t = jnp.clip(t - step, 0.0, 28.0)  # NDV in [1, ~1.4e12]
        now_done = jnp.abs(g) <= tol * jnp.maximum(m_eff, 1.0)
        t = jnp.where(done | now_done, t, new_t)
        it = it + jnp.where(done | now_done, 0, 1).astype(jnp.int32)
        return t, it, done | now_done

    t, iters_used, _ = jax.lax.fori_loop(
        0, iters, body, (t0, jnp.zeros_like(m, jnp.int32), jnp.zeros_like(m, bool))
    )
    ndv = jnp.exp(t)
    # Saturated observations (m == n) carry no upper-bound information: the
    # MLE diverges, and the continuity-corrected root (~n^2/2) is far too
    # aggressive as a POINT estimate (it would dominate Eq 13's max). Report
    # the observable itself — m, a hard lower bound — and let the saturation
    # flag drive lower-bound semantics downstream.
    ndv = jnp.where(saturated, jnp.maximum(m, 1.0), ndv)
    # Degenerate inputs: n == 0 -> no information; m <= 1 -> at least 1 value.
    ndv = jnp.where(n <= 0, 1.0, ndv)
    ndv = jnp.where(m_eff <= 0.5001, jnp.maximum(m, 1.0), ndv)
    return CouponInversionResult(
        ndv=jnp.maximum(ndv, jnp.maximum(m, 1.0)),
        saturated=saturated,
        iterations=iters_used,
    )


class MinMaxDiversityResult(NamedTuple):
    ndv: jnp.ndarray          # (B,) max of min-side / max-side estimates
    ndv_from_min: jnp.ndarray
    ndv_from_max: jnp.ndarray
    saturated: jnp.ndarray    # (B,) bool — the winning side saturated
    iterations: jnp.ndarray   # (B,) int32 — Newton iterations, winning side


def estimate_minmax_diversity(
    m_min: jnp.ndarray,
    m_max: jnp.ndarray,
    n_groups: jnp.ndarray,
    *,
    backend: str = "auto",
) -> MinMaxDiversityResult:
    """Paper §5.3: invert both sides, retain the larger estimate."""
    lo = invert_coupon(m_min, n_groups, backend=backend)
    hi = invert_coupon(m_max, n_groups, backend=backend)
    take_hi = hi.ndv >= lo.ndv
    ndv = jnp.where(take_hi, hi.ndv, lo.ndv)
    saturated = jnp.where(take_hi, hi.saturated, lo.saturated)
    return MinMaxDiversityResult(
        ndv=ndv,
        ndv_from_min=lo.ndv,
        ndv_from_max=hi.ndv,
        saturated=saturated,
        iterations=jnp.where(take_hi, hi.iterations, lo.iterations),
    )
