"""Core datatypes for zero-cost NDV estimation.

The estimator consumes *only* file metadata: per-column-chunk uncompressed
sizes, row counts, null counts, and per-row-group min/max statistics. These
types mirror what a columnar footer (Parquet / ORC / PQLite) exposes, in a
batched struct-of-arrays layout so that thousands of columns (millions of
chunks) can be estimated in one vectorized pass.

Granularity note: Eq 1's ``total_uncompressed_size`` is a PER-COLUMN-CHUNK
field (one chunk per row group per column). Dictionary inversion therefore
runs per chunk and the column-level estimate aggregates chunk estimates by
max — tight when distinct values are well-spread across row groups, an
underestimate for sorted layouts (paper Table 1).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np


class Layout(enum.IntEnum):
    """Data-layout classes produced by the distribution detector (paper §6.2)."""

    WELL_SPREAD = 0
    SORTED = 1
    PSEUDO_SORTED = 2
    MIXED = 3


class PhysicalType(enum.IntEnum):
    """Physical column types, as a columnar format would record them."""

    INT32 = 0
    INT64 = 1
    FLOAT32 = 2
    FLOAT64 = 3
    BYTE_ARRAY = 4  # variable-length (strings / binary)
    FIXED_LEN_BYTE_ARRAY = 5
    DATE32 = 6
    TIMESTAMP64 = 7
    BOOL = 8

    @property
    def fixed_width(self) -> Optional[int]:
        return {
            PhysicalType.INT32: 4,
            PhysicalType.INT64: 8,
            PhysicalType.FLOAT32: 4,
            PhysicalType.FLOAT64: 8,
            PhysicalType.DATE32: 4,
            PhysicalType.TIMESTAMP64: 8,
            PhysicalType.BOOL: 1,
        }.get(self)

    @property
    def is_integer_like(self) -> bool:
        """Types for which the range bound ndv <= max-min+1 applies (Eq 14)."""
        return self in (
            PhysicalType.INT32,
            PhysicalType.INT64,
            PhysicalType.DATE32,
            PhysicalType.BOOL,
        )


@dataclasses.dataclass(frozen=True)
class ColumnMetadata:
    """Everything the estimator may read for ONE column of ONE file.

    All fields come from footer metadata; none require touching data pages.
    Per-row-group arrays have shape (n,) with n = num_row_groups.

    Attributes:
      chunk_sizes: per-chunk ``total_uncompressed_size`` (dictionary page +
        data pages before compression) — Eq 1's S, per chunk.
      chunk_rows / chunk_nulls: per-chunk value and null counts.
      chunk_dict_encoded: per-chunk bit — False where the writer recorded a
        plain-encoding fallback for that chunk.
      mins / maxs: per-row-group min/max statistics as float64 *keys*
        (numeric value for numeric types; order-preserving 8-byte prefix for
        byte arrays).
      min_lengths / max_lengths: byte lengths of the min/max values.
      distinct_min_count / distinct_max_count: m_min, m_max — number of
        distinct min (max) values across row groups (computed exactly for
        small n, via HLL sketch at fleet scale).
      min_reprs / max_reprs: optional per-row-group human-readable stat
        values. Not consumed by the estimator; carried so that cross-file
        merging (repro.catalog.merge) can dedup BYTE_ARRAY statistics that
        collide in the truncated 8-byte key space.
      physical_type: the column's physical type.
    """

    chunk_sizes: np.ndarray
    chunk_rows: np.ndarray
    chunk_nulls: np.ndarray
    chunk_dict_encoded: np.ndarray
    mins: np.ndarray
    maxs: np.ndarray
    min_lengths: np.ndarray
    max_lengths: np.ndarray
    distinct_min_count: float
    distinct_max_count: float
    physical_type: PhysicalType
    column_name: str = ""
    min_reprs: Optional[np.ndarray] = None
    max_reprs: Optional[np.ndarray] = None

    @property
    def num_row_groups(self) -> int:
        return int(np.asarray(self.chunk_sizes).size)

    @property
    def total_uncompressed_size(self) -> float:
        return float(np.sum(self.chunk_sizes))

    @property
    def num_values(self) -> float:
        return float(np.sum(self.chunk_rows))

    @property
    def null_count(self) -> float:
        return float(np.sum(self.chunk_nulls))

    @property
    def non_null(self) -> float:
        return self.num_values - self.null_count


@dataclasses.dataclass(frozen=True)
class NDVEstimate:
    """Result of hybrid estimation for one column (paper §7)."""

    ndv: float                  # final hybrid estimate (Eq 13 + bounds)
    ndv_dict: float             # dictionary-inversion estimate (§4)
    ndv_minmax: float           # coupon-collector estimate (§5)
    layout: Layout              # detector classification (§6.2)
    is_lower_bound: bool        # plain-encoding fallback / saturation
    mean_len: float             # len used for inversion (Eq 4 or schema width)
    len_sample_size: int        # |V|, reliability indicator for len
    overlap_ratio: float        # detector metric (Eq 11)
    monotonicity: float         # detector metric (Eq 12)
    confidence: float           # heuristic 0-1 quality score
    column_name: str = ""

    @property
    def relative_error(self) -> Optional[float]:
        return None


@dataclasses.dataclass
class ColumnBatch:
    """Struct-of-arrays metadata for B columns with up to R row groups each.

    This is the layout the vectorized estimators and the Pallas kernels
    consume. Ragged row-group counts are padded to R with ``valid`` masks.
    """

    chunk_S: jnp.ndarray            # (B, R) float32 — per-chunk size (Eq 1 S)
    chunk_rows: jnp.ndarray         # (B, R) float32
    chunk_nulls: jnp.ndarray        # (B, R) float32
    chunk_dict_encoded: jnp.ndarray  # (B, R) bool
    N: jnp.ndarray                  # (B,) float32 — total row count
    nulls: jnp.ndarray              # (B,) float32
    n_groups: jnp.ndarray           # (B,) int32 — row groups per column
    mins: jnp.ndarray               # (B, R) float32 key space
    maxs: jnp.ndarray               # (B, R) float32
    valid: jnp.ndarray              # (B, R) bool — row-group mask
    m_min: jnp.ndarray              # (B,) float32 — distinct min count
    m_max: jnp.ndarray              # (B,) float32 — distinct max count
    mean_len: jnp.ndarray           # (B,) float32 — Eq 4 (or schema width)
    len_sample: jnp.ndarray         # (B,) int32 — |V|
    fixed_width: jnp.ndarray        # (B,) bool
    int_like: jnp.ndarray           # (B,) bool — Eq 14 applies
    single_byte: jnp.ndarray        # (B,) bool — Eq 15 applies

    @property
    def batch(self) -> int:
        return int(self.chunk_S.shape[0])

    @property
    def max_groups(self) -> int:
        return int(self.chunk_S.shape[1])

    @classmethod
    def from_columns(cls, cols: Sequence[ColumnMetadata]) -> "ColumnBatch":
        """Pack per-column metadata into padded struct-of-arrays.

        Delegates to the vectorized ``repro.catalog.packer.BatchPacker`` with
        shape bucketing disabled, preserving this method's historical shape
        contract: (B, R) == (len(cols), max row groups).
        """
        from repro.catalog.packer import BatchPacker  # local: avoid cycle

        return BatchPacker(bucket_rows=False, bucket_cols=False).pack(cols)


# Register ColumnBatch as a pytree so it can cross jit boundaries.
def _cb_flatten(cb: "ColumnBatch"):
    fields = [f.name for f in dataclasses.fields(ColumnBatch)]
    return tuple(getattr(cb, k) for k in fields), tuple(fields)


def _cb_unflatten(fields, children):
    return ColumnBatch(**dict(zip(fields, children)))


import jax.tree_util as _tree_util  # noqa: E402

_tree_util.register_pytree_node(ColumnBatch, _cb_flatten, _cb_unflatten)


# Printable-ASCII cardinality bound for single-byte strings (Eq 15).
SINGLE_BYTE_BOUND = 128.0
