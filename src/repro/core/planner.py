"""Cost-based planning driven by zero-cost NDV estimates.

This is the paper's application layer (§1, §8, §10.1) retargeted from the
Theseus GPU engine to this framework's TPU data plane. Three consumers:

1. **Batch memory planning** — size host-side dictionary staging buffers and
   device prefetch allocations from Eq 16-17 without reading batches.
2. **Embedding shard planning** — decide vocab-axis sharding of embedding
   tables from the estimated distinct-token count (the analogue of Theseus'
   aggregate-pushdown memory model: shard when the estimated working set
   exceeds a per-device budget).
3. **Aggregate pushdown** — the paper's original optimization: push a
   partial aggregate below a join/shuffle when the estimated group count
   (NDV) makes the partial result smaller than the input.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.ndv.batch_memory import predict_batch_memory
from repro.core.ndv.types import Layout, NDVEstimate


@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    """Per-column staging-memory plan for the data pipeline."""

    column: str
    d_global_bytes: float      # full-column dictionary size
    d_batch_bytes: float       # expected per-batch dictionary (Eq 16)
    n_batches: int
    total_bytes: float         # Eq 17
    conservative: bool         # sorted layout -> D_global provisioning


@dataclasses.dataclass(frozen=True)
class EmbeddingShardPlan:
    """Vocab-axis sharding decision for an embedding table."""

    column: str
    vocab_size: int            # table rows (schema vocab)
    estimated_active: float    # NDV estimate = distinct tokens actually used
    embed_bytes_per_row: int
    shard_vocab: bool          # shard vocab axis over `model`?
    num_shards: int
    reason: str


@dataclasses.dataclass(frozen=True)
class PushdownDecision:
    column: str
    ndv: float
    input_rows: float
    reduction_ratio: float     # estimated |aggregate| / |input|
    push_down: bool


class NDVPlanner:
    """Plans pipeline memory + sharding from metadata-only NDV estimates."""

    def __init__(
        self,
        *,
        batch_bytes: int = 64 << 20,
        device_budget_bytes: int = 256 << 20,
        num_model_shards: int = 16,
        pushdown_threshold: float = 0.5,
    ):
        self.batch_bytes = batch_bytes
        self.device_budget_bytes = device_budget_bytes
        self.num_model_shards = num_model_shards
        self.pushdown_threshold = pushdown_threshold

    # -- (1) batch memory ---------------------------------------------------
    def memory_plan(
        self, est: NDVEstimate, non_null: float
    ) -> MemoryPlan:
        conservative = est.layout in (Layout.SORTED, Layout.PSEUDO_SORTED)
        bm = predict_batch_memory(
            np.asarray([est.ndv], np.float32),
            np.asarray([est.mean_len], np.float32),
            np.asarray([non_null], np.float32),
            float(self.batch_bytes),
            layout=np.asarray([int(est.layout)], np.int32),
        )
        return MemoryPlan(
            column=est.column_name,
            d_global_bytes=float(bm.d_global[0]),
            d_batch_bytes=float(bm.d_batch[0]),
            n_batches=int(bm.n_batches[0]),
            total_bytes=float(bm.d_total[0]),
            conservative=conservative,
        )

    # -- (2) embedding sharding ----------------------------------------------
    def embedding_shard_plan(
        self,
        est: NDVEstimate,
        *,
        vocab_size: int,
        d_model: int,
        dtype_bytes: int = 2,
    ) -> EmbeddingShardPlan:
        """Shard the vocab axis when the *active* working set is too big.

        The gather working set during a step is roughly
        min(ndv, vocab) * d_model * dtype_bytes (the distinct rows touched).
        If even the active set exceeds the device budget, vocab-sharding the
        table (and paying an all-gather on activations instead) is required;
        when the active set is tiny, replicating or data-sharding the table
        avoids the collective entirely.
        """
        row_bytes = d_model * dtype_bytes
        active = min(est.ndv, float(vocab_size))
        # Lower-bound estimates must be treated pessimistically (§4.4).
        if est.is_lower_bound:
            active = float(vocab_size)
        active_bytes = active * row_bytes
        table_bytes = vocab_size * row_bytes
        if table_bytes <= self.device_budget_bytes:
            return EmbeddingShardPlan(
                est.column_name, vocab_size, active, row_bytes,
                shard_vocab=False, num_shards=1,
                reason=f"table {table_bytes/1e6:.0f}MB fits budget",
            )
        if active_bytes <= self.device_budget_bytes * 0.25:
            # Few distinct tokens touched: keep table sharded over data axis
            # (FSDP-style), gather only rows needed.
            return EmbeddingShardPlan(
                est.column_name, vocab_size, active, row_bytes,
                shard_vocab=False, num_shards=1,
                reason=(
                    f"active set {active_bytes/1e6:.0f}MB << budget; "
                    "row-gather beats vocab sharding"
                ),
            )
        shards = min(
            self.num_model_shards,
            max(1, math.ceil(table_bytes / self.device_budget_bytes)),
        )
        return EmbeddingShardPlan(
            est.column_name, vocab_size, active, row_bytes,
            shard_vocab=True, num_shards=shards,
            reason=f"active {active_bytes/1e6:.0f}MB needs {shards} vocab shards",
        )

    # -- (3) aggregate pushdown ----------------------------------------------
    def pushdown(self, est: NDVEstimate, input_rows: float) -> PushdownDecision:
        ratio = min(est.ndv / max(input_rows, 1.0), 1.0)
        if est.is_lower_bound:
            ratio = 1.0  # unknown-high NDV: do not push down
        return PushdownDecision(
            column=est.column_name,
            ndv=est.ndv,
            input_rows=input_rows,
            reduction_ratio=ratio,
            push_down=ratio < self.pushdown_threshold,
        )

    # -- dataset-level convenience -------------------------------------------
    def plan_dataset(
        self,
        estimates: Sequence[NDVEstimate],
        non_nulls: Sequence[float],
    ) -> Dict[str, MemoryPlan]:
        return {
            e.column_name: self.memory_plan(e, nn)
            for e, nn in zip(estimates, non_nulls)
        }

    def plan_catalog(
        self, catalog, *, mode: str = "paper", engine=None
    ) -> Dict[str, MemoryPlan]:
        """Memory plans for every column of a `repro.catalog.StatsCatalog`.

        Estimates come from the catalog's cache (warm after the first call);
        non-null counts from its merged per-column metadata. `engine`
        optionally overrides the catalog's `EstimationEngine` for this plan.
        """
        estimates = catalog.estimate(mode=mode, engine=engine)
        non_nulls = catalog.non_nulls()
        return {
            name: self.memory_plan(est, non_nulls[name])
            for name, est in estimates.items()
        }
