"""Training data pipeline over PQLite shards, planned by zero-cost NDV.

This is where the paper becomes framework infrastructure:

  1. At startup the pipeline reads ONLY footers, runs the batched NDV
     estimator over every column, and builds an `NDVPlanner` memory plan —
     staging-buffer sizes (Eq 16-17), dictionary-vs-plain materialization
     choices, and embedding-shard hints — before any data page is touched.
  2. Shard -> worker assignment is deterministic in (epoch, step, worker),
     so restarts and elastic rescales resume without sample loss.
  3. Batches are token blocks assembled from the `tokens` column; host
     staging uses the planned buffer sizes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.catalog import StatsCatalog
from repro.columnar import reader as rd
from repro.core.ndv.types import NDVEstimate
from repro.core.planner import MemoryPlan, NDVPlanner
from repro.engine import EngineConfig, EstimationEngine


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    estimates: Dict[str, NDVEstimate]
    memory: Dict[str, MemoryPlan]
    total_staging_bytes: float


@dataclasses.dataclass
class DataConfig:
    root: str
    token_column: str = "tokens"
    batch_size: int = 8          # sequences per batch (this worker)
    seq_len: int = 256
    seed: int = 0
    mode: str = "improved"       # NDV estimator mode for planning
    engine: Optional[EngineConfig] = None  # estimation engine (None = default)


class TokenPipeline:
    """Deterministic, restartable token-block loader."""

    def __init__(self, cfg: DataConfig, worker_id: int = 0, num_workers: int = 1):
        self.cfg = cfg
        self.worker_id = worker_id
        self.num_workers = num_workers
        self.files = rd.list_files(cfg.root)
        if not self.files:
            raise FileNotFoundError(f"no PQLite files under {cfg.root}")
        engine = EstimationEngine(cfg.engine) if cfg.engine else None
        self.catalog = StatsCatalog(cfg.root, engine=engine)
        self.plan = self._plan()

    # -- metadata-only planning (the paper's zero-cost path) -----------------
    def _plan(self) -> PipelinePlan:
        """Plan memory from the stats catalog (merged multi-file metadata)."""
        ests = self.catalog.estimate(mode=self.cfg.mode)
        memory = self.catalog.plan(NDVPlanner(), mode=self.cfg.mode)
        return PipelinePlan(
            estimates=ests,
            memory=memory,
            total_staging_bytes=float(
                sum(m.d_batch_bytes for m in memory.values())
            ),
        )

    # -- deterministic iteration ------------------------------------------------
    def _file_order(self, epoch: int) -> List[int]:
        rng = np.random.default_rng(self.cfg.seed + epoch)
        order = rng.permutation(len(self.files))
        return [int(i) for i in order]

    def batches(
        self, start_step: int = 0, epochs: int = 1
    ) -> Iterator[Dict[str, np.ndarray]]:
        """Yield {tokens: (B, S)} blocks; resumable via start_step."""
        cfg = self.cfg
        step = 0
        for epoch in range(epochs):
            for fi in self._file_order(epoch):
                if fi % self.num_workers != self.worker_id:
                    continue
                reader = rd.DataReader(self.files[fi])
                toks = np.asarray(
                    reader.read_column(cfg.token_column), np.int64
                )
                blocks = len(toks) // (cfg.batch_size * cfg.seq_len)
                toks = toks[: blocks * cfg.batch_size * cfg.seq_len]
                toks = toks.reshape(blocks, cfg.batch_size, cfg.seq_len)
                for b in range(blocks):
                    if step >= start_step:
                        yield {"tokens": toks[b].astype(np.int32)}
                    step += 1

    def vocab_estimate(self) -> Optional[NDVEstimate]:
        return self.plan.estimates.get(self.cfg.token_column)


def synthesize_token_dataset(
    root: str,
    *,
    vocab_size: int = 4096,
    num_shards: int = 2,
    rows_per_shard: int = 1 << 16,
    row_group_size: int = 8192,
    seed: int = 0,
) -> None:
    """Write a synthetic zipf-token PQLite dataset (examples/tests)."""
    from repro.columnar.generator import int_domain, zipf_column
    from repro.columnar.writer import WriterOptions, write_file
    import os

    dom = np.arange(vocab_size, dtype=np.int64)
    for i in range(num_shards):
        toks, _ = zipf_column(dom, rows_per_shard, s=1.1, seed=seed + i)
        meta = np.repeat(
            np.arange(rows_per_shard // row_group_size + 1), row_group_size
        )[:rows_per_shard]
        write_file(
            os.path.join(root, f"shard_{i:05d}"),
            {"tokens": toks, "doc_id": meta.astype(np.int64)},
            options=WriterOptions(row_group_size=row_group_size),
        )
