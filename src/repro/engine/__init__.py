"""Estimation engine: the single execution seam between packed batches and
estimates.

The paper's estimators are embarrassingly parallel over columns — every
reduction inside `estimate_batch` runs along the row-group axis (R) or is
per-lane, never across the column axis (B). That makes the B axis free to
split, which is exactly what fleet-scale serving needs: a warehouse with
100k+ merged columns should not run on one device or OOM because the packed
batch grew with dataset width.

`EstimationEngine` owns that split. Every consumer (`StatsCatalog`,
`estimate_columns`, `NDVPlanner.plan_catalog`, the data pipeline, the
benchmarks) goes through `engine.estimate(batch, ...)` instead of calling
the jit'd `estimate_batch` directly; `estimate_batch` itself remains the
pure per-shard kernel. Three execution strategies hide behind one config:

  local    today's single-device jit path. The default on one device.
  sharded  split the bucketed batch on the B axis across a 1-D
           `jax.sharding.Mesh` via `shard_map`, one `estimate_batch` body
           per device, per-shard `BatchEstimates` combined by the runtime.
           The engine's packer rounds B up to a multiple of the shard count
           so the split is even and the extra lanes are ordinary masked
           padding.
  chunked  stream batches wider than a budget (`max_batch`) through
           equal-size sub-batches, so B — and therefore device memory and
           trace shapes — stays bounded regardless of dataset width. The
           budget is either a fixed power of two or "auto", derived from
           the device's reported memory (`resolve_max_batch()`).
  composed sharded AND chunked: the batch streams through the mesh in
           super-chunks of `num_shards * max_batch` lanes, so each device
           sees at most its per-shard budget per dispatch. This is the
           strategy that lets a mesh of small devices serve a catalog
           wider than any single device's memory; "auto" picks it when
           both >1 device and over-the-mesh-budget hold. The shape math
           lives in `composed_plan()` (pure, property-tested).

The parity contract is strict: for real (non-padding) lanes, the sharded,
chunked, and composed paths produce bit-identical outputs to the local path
(asserted by tests/test_engine.py, run as a strategy×device CI matrix on
simulated multi-device CPU). That holds because padding lanes are fully
masked and no estimator op mixes information across B — the engine only
ever re-tiles the same per-lane program. The contract extends upward: since
strategies are numerics-neutral, they never enter `cache_key`/`cache_token`,
so estimate caches, on-disk spills, and client ETag caches all survive
strategy changes unchanged.

The config also carries the `kernels/ops` backend knob ("auto" / "pallas" /
"ref"), which used to be unreachable from the public API: the engine threads
it into `estimate_batch`, which routes the Newton inversions and the
detector scan through the Pallas kernels or the jnp reference accordingly.
"""
from repro.engine.config import DEFAULT_MAX_BATCH, EngineConfig  # noqa: F401
from repro.engine.engine import (  # noqa: F401
    EstimationEngine,
    auto_chunk_budget,
    composed_plan,
    default_engine,
    default_packer,
    detect_device_memory,
)
