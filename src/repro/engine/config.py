"""Engine configuration: one frozen record that names an execution plan.

`EngineConfig` is deliberately tiny and hashable — `StatsCatalog` keys its
estimate caches by it (via `EstimationEngine.cache_key`), so two engines
with the same config are interchangeable and two engines that would execute
differently never share a cache line.
"""
from __future__ import annotations

import dataclasses
from typing import Union

STRATEGIES = ("auto", "local", "sharded", "chunked", "composed")
BACKENDS = ("auto", "pallas", "ref")
FUSE_MODES = ("auto", "on", "off")

# The chunk budget used when max_batch="auto" finds no usable device memory
# report (host CPU backends return no `memory_stats()`), and the historical
# fixed default.
DEFAULT_MAX_BATCH = 4096


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Execution plan for `EstimationEngine`.

    Attributes:
      strategy: "local" (single-device jit), "sharded" (split B across a
        device mesh), "chunked" (bounded-B streaming), "composed" (split B
        across the mesh AND chunk-stream each shard's slice under a
        per-shard budget — the strategy for meshes of small devices serving
        catalogs wider than any one device's memory), or "auto" — composed
        when more than one device is visible and the batch exceeds the
        mesh-wide budget (`num_shards * per-shard max_batch`), sharded when
        more than one device is visible, otherwise chunked only when the
        batch exceeds `max_batch`, otherwise local.
      backend: the `repro.kernels.ops` knob, threaded into `estimate_batch`.
        "auto" picks the fastest correct path per platform (compiled Pallas
        kernels on TPU, the jnp reference elsewhere — interpret-mode Pallas
        is a correctness tool, not a serving path); "pallas" forces the
        kernels (interpreted off-TPU); "ref" forces the jnp reference.
      num_shards: device count for the sharded and composed strategies; 0
        means all visible devices. Clamped to the visible device count at
        run time (the clamp is logged once per engine: under composed a
        silently wrong shard count would also silently change the
        per-shard chunk budget).
      max_batch: the chunk budget — the widest B a single `estimate_batch`
        call may see under the chunked strategy, and the widest slice a
        single SHARD may see under composed. Must be a power of two so
        power-of-two-bucketed batches always split into equal full chunks
        (one jit trace shape, no ragged tail). "auto" derives the budget
        from the accelerator's reported memory at first use
        (`EstimationEngine.resolve_max_batch()`), falling back to
        `DEFAULT_MAX_BATCH` where no report exists (host CPU); under
        composed the report is divided by the shard count first (simulated
        host meshes share one physical pool), so the per-shard budget
        shrinks as the mesh grows.

      fuse: the estimation-megakernel knob, threaded into `estimate_batch`
        and resolved by `repro.kernels.ops.use_fused`. "on" (and "auto" on
        TPU, where the separate path costs 3-4 kernel launches plus XLA
        glue per estimate) runs the whole §4-§7 pipeline as ONE fused
        computation of the reference numerics — a single `pallas_call`
        (`repro.kernels.fused_estimate`) where the kernel path is
        production, its pure-XLA twin elsewhere. "off" pins the unfused
        per-stage path. Off-TPU the twin is literally the same program as
        the unfused reference path, so the knob is bit-neutral by
        construction; pinning ``backend="pallas"`` off-TPU remains the
        interpret-mode validation configuration, not a serving path.

    Cache-key neutrality rules: by the engine parity contract every
    strategy produces bit-identical estimates for real lanes, so
    `strategy`, `num_shards`, and `max_batch` are execution-shape knobs
    that never enter `EstimationEngine.cache_key` or `cache_token`.
    Estimate caches, on-disk spills, and client ETag caches therefore stay
    valid across strategy changes — switching a dataset from local to
    composed invalidates nothing. `fuse` is the same kind of knob one level
    down — dispatch shape over the same reference numerics, bit-identical
    by the fused parity cells — so it too stays out of both identities and
    a fuse flip invalidates no cache line or client ETag. Only `backend`
    can change numerics, and only it is identity.
    """

    strategy: str = "auto"
    backend: str = "auto"
    num_shards: int = 0
    max_batch: Union[int, str] = DEFAULT_MAX_BATCH
    fuse: str = "auto"

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"strategy {self.strategy!r} not in {STRATEGIES}"
            )
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")
        if self.fuse not in FUSE_MODES:
            raise ValueError(f"fuse {self.fuse!r} not in {FUSE_MODES}")
        if self.num_shards < 0:
            raise ValueError("num_shards must be >= 0 (0 = all devices)")
        mb = self.max_batch
        if isinstance(mb, str):
            if mb != "auto":
                raise ValueError(
                    f'max_batch must be "auto" or a power of two, got {mb!r}'
                )
        elif mb < 1 or (mb & (mb - 1)) != 0:
            raise ValueError(f"max_batch must be a power of two, got {mb}")
