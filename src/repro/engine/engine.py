"""`EstimationEngine`: strategy-routed execution of `estimate_batch`.

See the package docstring for the seam design. The engine is stateless
apart from its config — all caching lives in `StatsCatalog`, keyed by
`engine.cache_key` so differently-configured engines never share entries.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.catalog.packer import BatchPacker
from repro.obs import registry, span as _obs_span
from repro.core.ndv.estimator import (
    BatchEstimates,
    Provenance,
    estimate_batch,
    estimates_from_batch,
    provenance_from_batch,
)
from repro.core.ndv.types import ColumnBatch, ColumnMetadata, NDVEstimate
from repro.engine.config import DEFAULT_MAX_BATCH, EngineConfig

# max_batch="auto" sizing. A packed lane (one column) costs ~22 bytes per
# (lane, row-group) cell across the seven (B, R) planes plus ~50 bytes of
# per-lane scalars; at the bucketed R ceilings real warehouses hit (<=256)
# that is ~6 KB, and the estimators' masked intermediates (several
# temporaries per plane across the Newton iterations) multiply it by a
# small constant. 64 KB/lane is that footprint with ~10x headroom — the
# budget only needs the right order of magnitude, since chunk width is
# numerics-neutral and merely bounds peak memory.
AUTO_MEM_FRACTION = 0.25
NOMINAL_LANE_BYTES = 1 << 16
AUTO_MIN_BATCH = 1024
AUTO_MAX_BATCH = 1 << 20

logger = logging.getLogger(__name__)

_DISPATCHES = registry().counter(
    "ndv_engine_dispatches_total",
    "Engine estimate() dispatches, by resolved strategy and mode",
)


def detect_device_memory() -> Optional[int]:
    """Bytes of memory on the first visible device, or None.

    Uses the allocator's `memory_stats()` report (present on TPU/GPU
    backends; host CPU returns nothing). Any failure means "unknown" — the
    auto budget then falls back to `DEFAULT_MAX_BATCH`.
    """
    try:
        stats = jax.local_devices()[0].memory_stats()
    except Exception:
        return None
    if not stats:
        return None
    limit = stats.get("bytes_limit") or stats.get("bytes_reservable_limit")
    return int(limit) if limit else None


def auto_chunk_budget(mem_bytes: Optional[int], shards: int = 1) -> int:
    """Device memory -> chunk budget: the largest power of two of nominal
    lanes fitting in `AUTO_MEM_FRACTION` of memory, clamped to
    [AUTO_MIN_BATCH, AUTO_MAX_BATCH]. None -> `DEFAULT_MAX_BATCH`.

    `shards > 1` (the composed strategy) divides the memory report first:
    `memory_stats()` on a forced-host-platform mesh reports the one shared
    physical pool from every simulated device, so the per-shard budget must
    shrink as the mesh grows. On real accelerators with dedicated HBM the
    division is merely conservative — chunk width is numerics-neutral, so
    a smaller budget bounds the working set tighter at no accuracy cost.
    """
    if not mem_bytes:
        return DEFAULT_MAX_BATCH
    lanes = int(mem_bytes * AUTO_MEM_FRACTION / NOMINAL_LANE_BYTES / max(shards, 1))
    lanes = max(AUTO_MIN_BATCH, min(lanes, AUTO_MAX_BATCH))
    return 1 << (lanes.bit_length() - 1)  # previous power of two


def composed_plan(
    width: int, shards: int, chunk: int
) -> Tuple[int, List[Tuple[int, int]]]:
    """(padded B, super-chunk spans) for the composed strategy.

    A super-chunk is one `shard_map` dispatch: `shards * chunk` lanes, of
    which each shard sees exactly `chunk`. A batch wider than one
    super-chunk pads up to a whole number of them — every span has the same
    width (one jit trace shape) and every shard's slice of every span is a
    full `chunk` (no ragged tail). A batch that already fits one dispatch
    pads only to a multiple of the shard count and runs as plain sharding,
    so narrow catalogs never blow up to `shards * chunk` lanes of padding.

    Pure shape math (no device access) — the hypothesis coverage property
    in tests runs directly against this function.
    """
    if width < 1 or shards < 1 or chunk < 1:
        raise ValueError(f"need positive width/shards/chunk, got "
                         f"({width}, {shards}, {chunk})")
    stride = shards * chunk
    if width <= stride:
        padded = -(-width // shards) * shards
        return padded, [(0, padded)]
    padded = -(-width // stride) * stride
    return padded, [(lo, lo + stride) for lo in range(0, padded, stride)]


@functools.lru_cache(maxsize=None)
def _sharded_fn(devices: tuple, mode: str, backend: str, fuse: str = "auto"):
    """Jitted shard_map of `estimate_batch` over a 1-D column mesh.

    Cached per (device tuple, mode, backend, fuse): shard_map construction
    and tracing are not free, and warm engine calls must stay dispatch-only
    (the jit cache then keys on batch shape as usual). `fuse` is in the
    MEMO key because it changes the traced computation (megakernel vs
    separate launches) — never in the engine's cache identity, because it
    does not change the results.
    """
    mesh = Mesh(np.asarray(devices), ("cols",))
    return jax.jit(
        shard_map(
            functools.partial(
                estimate_batch, mode=mode, backend=backend, fuse=fuse
            ),
            mesh=mesh,
            in_specs=(P("cols"), P("cols")),
            out_specs=P("cols"),
            check_rep=False,
        )
    )


def _pad_axis0(x: jnp.ndarray, target: int) -> jnp.ndarray:
    """Zero-pad the leading (B) axis up to `target` lanes.

    Zero is the packer's own padding value for every field — it yields
    `valid=False` / `n_groups=0` lanes that the estimator fully masks.
    """
    if x.shape[0] == target:
        return x
    pad = [(0, target - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


class EstimationEngine:
    """Routes a packed `ColumnBatch` to one of three execution strategies."""

    def __init__(self, config: Optional[EngineConfig] = None):
        self.config = config or EngineConfig()
        self._packer: Optional[BatchPacker] = None
        self._mem_checked = False
        self._mem_bytes: Optional[int] = None
        self._auto_budgets: Dict[int, int] = {}
        self._clamp_logged = False

    # -- identity ------------------------------------------------------------

    @property
    def shard_count(self) -> int:
        """Resolved shard count: config, clamped to visible devices.

        The clamp is surfaced (one log line per engine, not per call): a
        `num_shards` larger than the mesh silently becoming "all devices"
        used to be invisible, and under the composed strategy a wrong shard
        count also silently changes the per-shard chunk budget.
        """
        n_dev = jax.device_count()
        want = self.config.num_shards or n_dev
        if want > n_dev and not self._clamp_logged:
            self._clamp_logged = True
            logger.warning(
                "EngineConfig(num_shards=%d) exceeds the %d visible "
                "device(s); clamping to %d (this also sets the composed "
                "per-shard chunk budget)",
                want, n_dev, n_dev,
            )
        return max(1, min(want, n_dev))

    @property
    def cache_key(self) -> tuple:
        """Hashable config identity (catalog cache key component).

        Deliberately only the fields that can change numerics — which, by
        the engine parity contract, is `backend` alone. Strategy, shard
        count, and chunk budget are execution-shape knobs with bit-identical
        outputs, so engines that differ only in those SHARE cache lines: a
        persisted cache written under "local" on one topology stays warm
        under "composed" on another (the whole point of `save_cache()`).
        The backend stays unresolved ("auto" as configured) so spills stay
        portable across hosts of one platform class.
        """
        return (self.config.backend,)

    @property
    def cache_token(self) -> str:
        """Engine identity as a compact stable string — wire/ETag material.

        The stats service folds this into every response's ETag so that two
        servers fronting the same dataset through engines that could answer
        differently can never validate each other's cached responses.
        Unlike `cache_key`, the backend appears RESOLVED ("auto" becomes
        the kernel path it picks on this platform): a TPU replica and a CPU
        replica both configured "auto" execute different numerics, so their
        tags must differ even though their configs match. Nothing else
        enters the token — strategy, shard count, and chunk budget are
        numerics-neutral by the parity contract, so a composed replica and
        a local replica of one dataset emit byte-identical ETags and a
        strategy change invalidates no client cache.
        """
        from repro.kernels import ops

        backend = "pallas" if ops.use_pallas(self.config.backend) else "ref"
        return f"k.{backend}"

    def make_packer(self) -> BatchPacker:
        """Shard- and chunk-aware packer, coordinated with this engine.

        B rounds up to a multiple of the shard count so the sharded split
        is even; under the composed strategy (and "auto", which may resolve
        to it on a mesh) the packer additionally carries the per-shard
        chunk budget (`col_chunk`), so batches wider than one super-chunk
        round up to `num_shards * chunk` — every shard's slice then splits
        into equal full chunks with no engine-side re-padding copy.

        One instance per engine (packers are stateless frozen dataclasses;
        sharing keeps every caller on the same bucketing policy object).
        """
        if self._packer is None:
            strategy = self.config.strategy
            mult = (
                self.shard_count
                if strategy in ("auto", "sharded", "composed")
                else 1
            )
            chunk = 0
            if mult > 1 and strategy in ("auto", "composed"):
                chunk = self.resolve_max_batch(shards=mult)
            self._packer = BatchPacker(col_multiple=mult, col_chunk=chunk)
        return self._packer

    # -- strategy resolution --------------------------------------------------

    def resolve_max_batch(self, *, shards: int = 1) -> int:
        """The chunk budget this engine executes with.

        A fixed config value passes through; "auto" is derived per engine
        from the first device's reported memory, detected once (fallback:
        `DEFAULT_MAX_BATCH` where the backend reports none, e.g. host CPU).
        `shards > 1` is the composed strategy's PER-SHARD budget: the memory
        report is divided across the mesh before sizing (see
        `auto_chunk_budget`), so the budget shrinks as the mesh grows.
        Resolution never enters `cache_key`/`cache_token` — chunk width is
        numerics-neutral by the parity contract, so caches and ETags stay
        portable across differently-sized hosts.
        """
        mb = self.config.max_batch
        if mb != "auto":
            return mb
        if not self._mem_checked:
            self._mem_bytes = detect_device_memory()
            self._mem_checked = True
        budget = self._auto_budgets.get(shards)
        if budget is None:
            budget = self._auto_budgets[shards] = auto_chunk_budget(
                self._mem_bytes, shards
            )
        return budget

    def per_shard_budget(self) -> int:
        """The composed strategy's per-shard chunk budget on this engine."""
        return self.resolve_max_batch(shards=self.shard_count)

    def resolve_strategy(self, batch_width: int) -> str:
        s = self.config.strategy
        if s != "auto":
            return s
        n = self.shard_count
        if n > 1:
            # Over the mesh-wide budget: plain sharding would hand some
            # device a slice wider than its chunk budget — stream instead.
            if batch_width > n * self.per_shard_budget():
                return "composed"
            return "sharded"
        if batch_width > self.resolve_max_batch():
            return "chunked"
        return "local"

    # -- execution -----------------------------------------------------------

    def estimate(
        self,
        batch: ColumnBatch,
        schema_bound: Optional[jnp.ndarray] = None,
        *,
        mode: str = "paper",
    ) -> BatchEstimates:
        """ColumnBatch -> BatchEstimates under the configured strategy.

        For real (non-padding) lanes the output is bit-identical across
        strategies: padding lanes are fully masked and no estimator op
        mixes information across the B axis, so re-tiling B is exact.
        """
        strategy = self.resolve_strategy(batch.batch)
        _DISPATCHES.inc(strategy=strategy, mode=mode)
        with _obs_span(
            "engine.dispatch",
            strategy=strategy, mode=mode, batch=int(batch.batch),
        ):
            if strategy == "sharded":
                return self._estimate_sharded(batch, schema_bound, mode)
            if strategy == "chunked":
                return self._estimate_chunked(batch, schema_bound, mode)
            if strategy == "composed":
                return self._estimate_composed(batch, schema_bound, mode)
            return estimate_batch(
                batch, schema_bound, mode=mode,
                backend=self.config.backend, fuse=self.config.fuse,
            )

    def _padded_to_multiple(self, batch, schema_bound, multiple):
        """(batch, schema_bound, original B) with B padded to `multiple`."""
        b = batch.batch
        target = -(-b // multiple) * multiple
        if target == b:
            return batch, schema_bound, b
        batch = jax.tree.map(lambda x: _pad_axis0(x, target), batch)
        if schema_bound is not None:
            # +inf = "no bound": combine() keeps the estimate unchanged.
            schema_bound = jnp.pad(
                schema_bound, (0, target - b), constant_values=np.inf
            )
        return batch, schema_bound, b

    def _estimate_sharded(self, batch, schema_bound, mode) -> BatchEstimates:
        n = self.shard_count
        batch, schema_bound, b = self._padded_to_multiple(batch, schema_bound, n)
        if schema_bound is None:
            # Materialize "no bound" so one shard_map signature serves both;
            # min(ndv, +inf) is the identity, bit-for-bit.
            schema_bound = jnp.full(batch.batch, np.inf, jnp.float32)
        fn = _sharded_fn(
            tuple(jax.devices()[:n]), mode, self.config.backend,
            self.config.fuse,
        )
        out = fn(batch, schema_bound)
        return self._trim(out, b)

    def _estimate_chunked(self, batch, schema_bound, mode) -> BatchEstimates:
        c = self.resolve_max_batch()
        if batch.batch <= c:
            return estimate_batch(
                batch, schema_bound, mode=mode,
                backend=self.config.backend, fuse=self.config.fuse,
            )
        batch, schema_bound, b = self._padded_to_multiple(batch, schema_bound, c)
        spans = [(lo, lo + c) for lo in range(0, batch.batch, c)]
        return self._stream_spans(
            batch, schema_bound, b, spans,
            lambda sub, sb: estimate_batch(
                sub, sb, mode=mode,
                backend=self.config.backend, fuse=self.config.fuse,
            ),
        )

    def _estimate_composed(self, batch, schema_bound, mode) -> BatchEstimates:
        """Sharded AND chunked: stream super-chunks through the mesh.

        Each super-chunk is one `shard_map` dispatch of `shards * chunk`
        lanes — every device sees exactly `chunk` lanes per dispatch, so
        the per-device working set stays bounded by the per-shard budget
        no matter how wide the catalog grows, while all `shards` devices
        advance in lockstep through the stream. `composed_plan` guarantees
        equal spans (one jit trace shape) and no ragged tail; concatenating
        span outputs in order preserves lane order because `shard_map`'s
        `P("cols")` out-spec already concatenates device outputs in order.
        Bit-identical to local for real lanes: this path only re-tiles the
        B axis twice (chunk-of-sharded), and both tilings are proven
        numerics-neutral by the parity contract.
        """
        n = self.shard_count
        chunk = self.per_shard_budget()
        target, spans = composed_plan(batch.batch, n, chunk)
        batch, schema_bound, b = self._padded_to_multiple(
            batch, schema_bound, target
        )
        if schema_bound is None:
            schema_bound = jnp.full(batch.batch, np.inf, jnp.float32)
        fn = _sharded_fn(
            tuple(jax.devices()[:n]), mode, self.config.backend,
            self.config.fuse,
        )
        return self._stream_spans(batch, schema_bound, b, spans, fn)

    def _stream_spans(
        self, batch, schema_bound, b, spans, fn
    ) -> BatchEstimates:
        """Run `fn` over each B-axis span, concatenate in order, trim to `b`.

        The one streaming loop shared by the chunked (fn = estimate_batch)
        and composed (fn = the sharded dispatch) strategies — span order is
        lane order, so concatenation reassembles the unstreamed result.
        """
        parts: List[BatchEstimates] = []
        for lo, hi in spans:
            sub = jax.tree.map(lambda x: x[lo:hi], batch)
            sb = None if schema_bound is None else schema_bound[lo:hi]
            parts.append(fn(sub, sb))
        if len(parts) == 1:
            return self._trim(parts[0], b)
        out = BatchEstimates(
            *[jnp.concatenate(field) for field in zip(*parts)]
        )
        return self._trim(out, b)

    @staticmethod
    def _trim(out: BatchEstimates, b: int) -> BatchEstimates:
        """Drop engine-added padding lanes (keep packer padding intact)."""
        if out.ndv.shape[0] == b:
            return out
        return BatchEstimates(*[field[:b] for field in out])

    # -- object API ----------------------------------------------------------

    def estimate_columns(
        self,
        cols: Sequence[ColumnMetadata],
        schema_bounds: Optional[Sequence[float]] = None,
        *,
        mode: str = "paper",
        packer: Optional[BatchPacker] = None,
    ) -> List[NDVEstimate]:
        """List of ColumnMetadata -> list of NDVEstimate via this engine."""
        if not cols:
            return []
        batch = (packer or self.make_packer()).pack(cols)
        sb = None
        if schema_bounds is not None:
            arr = np.full(batch.batch, np.inf, np.float32)
            arr[: len(cols)] = np.asarray(schema_bounds, np.float32)
            sb = jnp.asarray(arr)
        out = self.estimate(batch, sb, mode=mode)
        return estimates_from_batch(out, batch, [c.column_name for c in cols])

    def estimate_columns_explained(
        self,
        cols: Sequence[ColumnMetadata],
        schema_bounds: Optional[Sequence[float]] = None,
        *,
        mode: str = "paper",
        packer: Optional[BatchPacker] = None,
    ) -> Tuple[List[NDVEstimate], List[Provenance]]:
        """`estimate_columns` plus per-column `Provenance`, one engine run.

        Both views are materialized from the same `BatchEstimates`, so the
        estimates are bit-identical to the unexplained call and the
        provenance describes exactly the numbers returned beside it.
        """
        if not cols:
            return [], []
        batch = (packer or self.make_packer()).pack(cols)
        sb = None
        if schema_bounds is not None:
            arr = np.full(batch.batch, np.inf, np.float32)
            arr[: len(cols)] = np.asarray(schema_bounds, np.float32)
            sb = jnp.asarray(arr)
        out = self.estimate(batch, sb, mode=mode)
        names = [c.column_name for c in cols]
        return (
            estimates_from_batch(out, batch, names),
            provenance_from_batch(out, batch, names),
        )


@dataclasses.dataclass
class _Defaults:
    engine: Optional[EstimationEngine] = None


_DEFAULTS = _Defaults()


def default_engine() -> EstimationEngine:
    """Process-wide default engine (strategy "auto", backend "auto").

    Shared by `estimate_columns`, `estimate_file`, and every `StatsCatalog`
    constructed without an explicit engine, so ad-hoc calls and catalog
    calls agree on bucketing and execution.
    """
    if _DEFAULTS.engine is None:
        _DEFAULTS.engine = EstimationEngine(EngineConfig())
    return _DEFAULTS.engine


def default_packer() -> BatchPacker:
    """The default engine's shared packer (one bucketing policy per process)."""
    return default_engine().make_packer()
