"""`EstimationEngine`: strategy-routed execution of `estimate_batch`.

See the package docstring for the seam design. The engine is stateless
apart from its config — all caching lives in `StatsCatalog`, keyed by
`engine.cache_key` so differently-configured engines never share entries.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.catalog.packer import BatchPacker
from repro.core.ndv.estimator import (
    BatchEstimates,
    estimate_batch,
    estimates_from_batch,
)
from repro.core.ndv.types import ColumnBatch, ColumnMetadata, NDVEstimate
from repro.engine.config import DEFAULT_MAX_BATCH, EngineConfig

# max_batch="auto" sizing. A packed lane (one column) costs ~22 bytes per
# (lane, row-group) cell across the seven (B, R) planes plus ~50 bytes of
# per-lane scalars; at the bucketed R ceilings real warehouses hit (<=256)
# that is ~6 KB, and the estimators' masked intermediates (several
# temporaries per plane across the Newton iterations) multiply it by a
# small constant. 64 KB/lane is that footprint with ~10x headroom — the
# budget only needs the right order of magnitude, since chunk width is
# numerics-neutral and merely bounds peak memory.
AUTO_MEM_FRACTION = 0.25
NOMINAL_LANE_BYTES = 1 << 16
AUTO_MIN_BATCH = 1024
AUTO_MAX_BATCH = 1 << 20


def detect_device_memory() -> Optional[int]:
    """Bytes of memory on the first visible device, or None.

    Uses the allocator's `memory_stats()` report (present on TPU/GPU
    backends; host CPU returns nothing). Any failure means "unknown" — the
    auto budget then falls back to `DEFAULT_MAX_BATCH`.
    """
    try:
        stats = jax.local_devices()[0].memory_stats()
    except Exception:
        return None
    if not stats:
        return None
    limit = stats.get("bytes_limit") or stats.get("bytes_reservable_limit")
    return int(limit) if limit else None


def auto_chunk_budget(mem_bytes: Optional[int]) -> int:
    """Device memory -> chunk budget: the largest power of two of nominal
    lanes fitting in `AUTO_MEM_FRACTION` of memory, clamped to
    [AUTO_MIN_BATCH, AUTO_MAX_BATCH]. None -> `DEFAULT_MAX_BATCH`."""
    if not mem_bytes:
        return DEFAULT_MAX_BATCH
    lanes = int(mem_bytes * AUTO_MEM_FRACTION / NOMINAL_LANE_BYTES)
    lanes = max(AUTO_MIN_BATCH, min(lanes, AUTO_MAX_BATCH))
    return 1 << (lanes.bit_length() - 1)  # previous power of two


@functools.lru_cache(maxsize=None)
def _sharded_fn(devices: tuple, mode: str, backend: str):
    """Jitted shard_map of `estimate_batch` over a 1-D column mesh.

    Cached per (device tuple, mode, backend): shard_map construction and
    tracing are not free, and warm engine calls must stay dispatch-only
    (the jit cache then keys on batch shape as usual).
    """
    mesh = Mesh(np.asarray(devices), ("cols",))
    return jax.jit(
        shard_map(
            functools.partial(estimate_batch, mode=mode, backend=backend),
            mesh=mesh,
            in_specs=(P("cols"), P("cols")),
            out_specs=P("cols"),
            check_rep=False,
        )
    )


def _pad_axis0(x: jnp.ndarray, target: int) -> jnp.ndarray:
    """Zero-pad the leading (B) axis up to `target` lanes.

    Zero is the packer's own padding value for every field — it yields
    `valid=False` / `n_groups=0` lanes that the estimator fully masks.
    """
    if x.shape[0] == target:
        return x
    pad = [(0, target - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


class EstimationEngine:
    """Routes a packed `ColumnBatch` to one of three execution strategies."""

    def __init__(self, config: Optional[EngineConfig] = None):
        self.config = config or EngineConfig()
        self._packer: Optional[BatchPacker] = None
        self._auto_max_batch: Optional[int] = None

    # -- identity ------------------------------------------------------------

    @property
    def shard_count(self) -> int:
        """Resolved shard count: config, clamped to visible devices."""
        n_dev = jax.device_count()
        want = self.config.num_shards or n_dev
        return max(1, min(want, n_dev))

    @property
    def cache_key(self) -> tuple:
        """Hashable config identity (catalog cache key component).

        Deliberately the CONFIG, not the resolved device topology: by the
        parity contract, estimates are bit-identical across strategies and
        shard counts, so a persisted cache written on one topology must
        stay warm on another (the whole point of `save_cache()`). Only
        `backend` can change numerics, and it is part of the config.
        """
        c = self.config
        return (c.strategy, c.backend, c.num_shards, c.max_batch)

    @property
    def cache_token(self) -> str:
        """Engine identity as a compact stable string — wire/ETag material.

        The stats service folds this into every response's ETag so that two
        servers fronting the same dataset through engines that could answer
        differently can never validate each other's cached responses.
        Unlike `cache_key`, the backend appears RESOLVED ("auto" becomes
        the kernel path it picks on this platform): a TPU replica and a CPU
        replica both configured "auto" execute different numerics, so their
        tags must differ even though their configs match. The strategy
        fields stay unresolved — the parity contract makes them
        numerics-neutral, and `cache_key` portability covers them.
        """
        from repro.kernels import ops

        c = self.config
        backend = "pallas" if ops.use_pallas(c.backend) else "ref"
        return f"{c.strategy}.{backend}.s{c.num_shards}.b{c.max_batch}"

    def make_packer(self) -> BatchPacker:
        """Shard-aware packer: B rounds up to a multiple of the shard count
        so the sharded split is even and padding lanes stay masked.

        One instance per engine (packers are stateless frozen dataclasses;
        sharing keeps every caller on the same bucketing policy object).
        """
        if self._packer is None:
            mult = (
                self.shard_count
                if self.config.strategy in ("auto", "sharded")
                else 1
            )
            self._packer = BatchPacker(col_multiple=mult)
        return self._packer

    # -- strategy resolution --------------------------------------------------

    def resolve_max_batch(self) -> int:
        """The chunk budget this engine executes with.

        A fixed config value passes through; "auto" is derived once per
        engine from the first device's reported memory (fallback:
        `DEFAULT_MAX_BATCH` where the backend reports none, e.g. host CPU).
        Resolution never enters `cache_key`/`cache_token` — chunk width is
        numerics-neutral by the parity contract, so caches and ETags stay
        portable across differently-sized hosts.
        """
        mb = self.config.max_batch
        if mb != "auto":
            return mb
        if self._auto_max_batch is None:
            self._auto_max_batch = auto_chunk_budget(detect_device_memory())
        return self._auto_max_batch

    def resolve_strategy(self, batch_width: int) -> str:
        s = self.config.strategy
        if s != "auto":
            return s
        if self.shard_count > 1:
            return "sharded"
        if batch_width > self.resolve_max_batch():
            return "chunked"
        return "local"

    # -- execution -----------------------------------------------------------

    def estimate(
        self,
        batch: ColumnBatch,
        schema_bound: Optional[jnp.ndarray] = None,
        *,
        mode: str = "paper",
    ) -> BatchEstimates:
        """ColumnBatch -> BatchEstimates under the configured strategy.

        For real (non-padding) lanes the output is bit-identical across
        strategies: padding lanes are fully masked and no estimator op
        mixes information across the B axis, so re-tiling B is exact.
        """
        strategy = self.resolve_strategy(batch.batch)
        if strategy == "sharded":
            return self._estimate_sharded(batch, schema_bound, mode)
        if strategy == "chunked":
            return self._estimate_chunked(batch, schema_bound, mode)
        return estimate_batch(
            batch, schema_bound, mode=mode, backend=self.config.backend
        )

    def _padded_to_multiple(self, batch, schema_bound, multiple):
        """(batch, schema_bound, original B) with B padded to `multiple`."""
        b = batch.batch
        target = -(-b // multiple) * multiple
        if target == b:
            return batch, schema_bound, b
        batch = jax.tree.map(lambda x: _pad_axis0(x, target), batch)
        if schema_bound is not None:
            # +inf = "no bound": combine() keeps the estimate unchanged.
            schema_bound = jnp.pad(
                schema_bound, (0, target - b), constant_values=np.inf
            )
        return batch, schema_bound, b

    def _estimate_sharded(self, batch, schema_bound, mode) -> BatchEstimates:
        n = self.shard_count
        batch, schema_bound, b = self._padded_to_multiple(batch, schema_bound, n)
        if schema_bound is None:
            # Materialize "no bound" so one shard_map signature serves both;
            # min(ndv, +inf) is the identity, bit-for-bit.
            schema_bound = jnp.full(batch.batch, np.inf, jnp.float32)
        fn = _sharded_fn(
            tuple(jax.devices()[:n]), mode, self.config.backend
        )
        out = fn(batch, schema_bound)
        return self._trim(out, b)

    def _estimate_chunked(self, batch, schema_bound, mode) -> BatchEstimates:
        c = self.resolve_max_batch()
        if batch.batch <= c:
            return estimate_batch(
                batch, schema_bound, mode=mode, backend=self.config.backend
            )
        batch, schema_bound, b = self._padded_to_multiple(batch, schema_bound, c)
        parts: List[BatchEstimates] = []
        for lo in range(0, batch.batch, c):
            sub = jax.tree.map(lambda x: x[lo : lo + c], batch)
            sb = None if schema_bound is None else schema_bound[lo : lo + c]
            parts.append(
                estimate_batch(sub, sb, mode=mode, backend=self.config.backend)
            )
        out = BatchEstimates(
            *[jnp.concatenate(field) for field in zip(*parts)]
        )
        return self._trim(out, b)

    @staticmethod
    def _trim(out: BatchEstimates, b: int) -> BatchEstimates:
        """Drop engine-added padding lanes (keep packer padding intact)."""
        if out.ndv.shape[0] == b:
            return out
        return BatchEstimates(*[field[:b] for field in out])

    # -- object API ----------------------------------------------------------

    def estimate_columns(
        self,
        cols: Sequence[ColumnMetadata],
        schema_bounds: Optional[Sequence[float]] = None,
        *,
        mode: str = "paper",
        packer: Optional[BatchPacker] = None,
    ) -> List[NDVEstimate]:
        """List of ColumnMetadata -> list of NDVEstimate via this engine."""
        if not cols:
            return []
        batch = (packer or self.make_packer()).pack(cols)
        sb = None
        if schema_bounds is not None:
            arr = np.full(batch.batch, np.inf, np.float32)
            arr[: len(cols)] = np.asarray(schema_bounds, np.float32)
            sb = jnp.asarray(arr)
        out = self.estimate(batch, sb, mode=mode)
        return estimates_from_batch(out, batch, [c.column_name for c in cols])


@dataclasses.dataclass
class _Defaults:
    engine: Optional[EstimationEngine] = None


_DEFAULTS = _Defaults()


def default_engine() -> EstimationEngine:
    """Process-wide default engine (strategy "auto", backend "auto").

    Shared by `estimate_columns`, `estimate_file`, and every `StatsCatalog`
    constructed without an explicit engine, so ad-hoc calls and catalog
    calls agree on bucketing and execution.
    """
    if _DEFAULTS.engine is None:
        _DEFAULTS.engine = EstimationEngine(EngineConfig())
    return _DEFAULTS.engine


def default_packer() -> BatchPacker:
    """The default engine's shared packer (one bucketing policy per process)."""
    return default_engine().make_packer()
