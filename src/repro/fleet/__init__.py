"""Fleet tier: replicated, multi-dataset stats serving with one router.

One `StatsServer` fronts one dataset; a planner fleet polls a whole
warehouse namespace. This package is the tier in between — N interchangeable
replicas per dataset, a registry of datasets, and a single stdlib-HTTP
router that any client can treat as "the warehouse":

                          StatsRouter (HTTP)
          /datasets  /health  /{ns}/{ds}/{columns|estimate|plan}  /refresh
                                   |
                                 Fleet ---------------- DatasetRegistry
                  (routing, counters, health prober)    ns/ds -> root +
                           |                 |          EngineConfig
                  ReplicaSet "ns/a"   ReplicaSet "ns/b"
                  rendezvous hashing over (dataset, request identity);
                  eject on failure, retry next, rejoin on probe
                   |           |           |           |
               LocalReplica LocalReplica  ...    RemoteReplica
               StatsService StatsService         (HTTP proxy to a
                   \\          /                   StatsServer)
                .ndv_estimate_cache.json
                (shared on-disk estimate spill: atomic merge-not-
                 clobber writes; a cold replica's first estimate is
                 a cache hit, zero engine packs)

Why replicas are interchangeable — the invariant everything rests on:
response ETags are SHA-1 over (dataset fingerprint set, engine cache
token, request identity) and nothing else. The registry pins one
`EngineConfig` per dataset, every replica ingests the same files, so two
independently-constructed replicas emit byte-identical tags. Failover is
therefore invisible to clients: a revalidation that lands on a different
replica than the one that minted the tag still returns 304, and a replica
that crashes mid-burst costs one retry, not a cache flush.

Placement is rendezvous (highest-random-weight) hashing: identical
requests always land on the same healthy replica (maximizing its estimate
cache), distinct identities spread across the set, and an ejection moves
only the ejected replica's keys. Cold starts ride the shared spill:
replicas run `StatsService(shared_spill=True)`, so every computed entry is
merged into the dataset's on-disk cache file and a freshly booted replica
loads it before serving.

Batched RPC: the router's `POST /batch` accepts tuples spanning any mix of
registered datasets in one frame (JSON or the binary wire encoding,
negotiated per request). `Fleet.batch` groups tuples by dataset, each
`ReplicaSet.call_batch` groups its tuples by rendezvous-chosen replica and
forwards one `handle_batch` sub-batch RPC per replica over a keep-alive
connection pool; the serving side executes all cold tuples of a sub-batch
as a single cross-dataset super-pack engine call. Per-tuple ETags, 304s,
and failover semantics are identical to the singleton routes — a sub-batch
whose replica dies mid-flight requeues whole onto the next candidate.

Planner tier: the router's `POST /cost` costs a join graph that spans
registered datasets. `Fleet.cost` fetches one routed `/tablestats` body
per referenced dataset (restricted to the join columns the graph uses),
scores the plan space in the router process (`repro.planner`), and mints
a combined ETag over the per-dataset tablestats ETags — 304 exactly when
every input dataset's stats are unchanged, stable across replica
failover because the constituent tags are state-derived. Cost tuples
ride `POST /batch` next to estimate tuples.

Entry points: `repro.launch.serve_fleet` (CLI; `--smoke` is the CI boot
test), `serve_fleet()` (library), `Fleet` + `StatsRouter` for embedding.
"""
from repro.fleet.registry import (  # noqa: F401
    DatasetRegistry,
    DatasetSpec,
    parse_spec,
)
from repro.fleet.replica import (  # noqa: F401
    FAILOVER_ERRORS,
    LocalReplica,
    NoReplicaAvailable,
    RemoteReplica,
    ReplicaError,
    ReplicaSet,
    StatsRequest,
)
from repro.fleet.router import (  # noqa: F401
    Fleet,
    FleetStats,
    StatsRouter,
    default_replica_factory,
    make_router_handler,
    serve_fleet,
)
