"""`DatasetRegistry`: the warehouse namespace the fleet tier serves.

One registry maps `namespace/dataset` keys to `DatasetSpec`s — the dataset
root on disk plus the per-dataset `EngineConfig` the replicas must share.
The engine config lives HERE, not on individual replicas, deliberately:
every response ETag folds in the engine's `cache_token`, so replicas of one
dataset may only be interchangeable (byte-identical tags, shared estimate
caches) if they run numerically identical engines. The registry is the
single place that invariant is pinned. Since the parity contract makes
execution strategy numerics-neutral (and the token backend-only), a spec
may freely name "composed" — or be migrated between strategies across a
deploy — without rotating a single tag or cooling a single cache; only a
backend change is a real identity change.

Keys are two URL path segments (`{namespace}/{dataset}`), validated at
registration so the router can mount them directly as HTTP paths.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterator, List, Optional, Tuple

from repro.engine import EngineConfig

_SEGMENT = re.compile(r"^[A-Za-z0-9._-]+$")


def _check_segment(kind: str, value: str) -> str:
    if not _SEGMENT.match(value or ""):
        raise ValueError(
            f"{kind} {value!r} must be a non-empty URL path segment "
            f"([A-Za-z0-9._-]+)"
        )
    return value


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """One served dataset: identity, location, shared engine config."""

    namespace: str
    dataset: str
    root: str
    engine_config: EngineConfig = dataclasses.field(
        default_factory=EngineConfig
    )

    def __post_init__(self):
        _check_segment("namespace", self.namespace)
        _check_segment("dataset", self.dataset)

    @property
    def key(self) -> str:
        """The routing key, `namespace/dataset` — also the HTTP mount path."""
        return f"{self.namespace}/{self.dataset}"


def parse_spec(text: str) -> Tuple[str, str, str]:
    """CLI form `namespace/dataset=/path/to/root` -> (ns, ds, root)."""
    key, sep, root = text.partition("=")
    if not sep or not root:
        raise ValueError(
            f"bad dataset spec {text!r}; want namespace/dataset=/path"
        )
    ns, sep, ds = key.partition("/")
    if not sep:
        raise ValueError(
            f"bad dataset key {key!r}; want namespace/dataset"
        )
    return _check_segment("namespace", ns), _check_segment("dataset", ds), root


class DatasetRegistry:
    """Ordered `namespace/dataset` -> `DatasetSpec` mapping."""

    def __init__(self, specs: Optional[List[DatasetSpec]] = None):
        self._specs: Dict[str, DatasetSpec] = {}
        for spec in specs or []:
            self.register(spec)

    def register(self, spec: DatasetSpec) -> DatasetSpec:
        if spec.key in self._specs:
            raise ValueError(f"dataset {spec.key!r} is already registered")
        self._specs[spec.key] = spec
        return spec

    def add(
        self,
        namespace: str,
        dataset: str,
        root: str,
        *,
        engine_config: Optional[EngineConfig] = None,
    ) -> DatasetSpec:
        return self.register(DatasetSpec(
            namespace, dataset, root,
            engine_config=engine_config or EngineConfig(),
        ))

    def get(self, namespace: str, dataset: str) -> DatasetSpec:
        """KeyError (with the known keys) when the dataset is not served."""
        key = f"{namespace}/{dataset}"
        try:
            return self._specs[key]
        except KeyError:
            raise KeyError(
                f"dataset {key!r} is not registered (serving: {self.keys()})"
            ) from None

    def keys(self) -> List[str]:
        return list(self._specs)

    def specs(self) -> List[DatasetSpec]:
        return list(self._specs.values())

    def __len__(self) -> int:
        return len(self._specs)

    def __iter__(self) -> Iterator[DatasetSpec]:
        return iter(self._specs.values())

    def __contains__(self, key: str) -> bool:
        return key in self._specs
