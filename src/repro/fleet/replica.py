"""Replicas and `ReplicaSet`: health-checked request placement per dataset.

A replica is anything that can answer the stats-serving contract —
`StatsRequest` in, `repro.service.Response` out — plus a cheap liveness
probe. Two implementations:

  `LocalReplica`   a process-local `StatsService` in shared-spill mode: it
                   warms from, and contributes to, the dataset's on-disk
                   estimate-cache spill, so any replica of the set can
                   serve any entry a sibling has computed. `kill()` is the
                   fault-injection hook (smoke test, failover benchmark):
                   the replica starts refusing requests and failing probes,
                   exactly like a crashed process behind a proxy.
  `RemoteReplica`  an HTTP proxy to a `StatsServer` owned elsewhere; the
                   probe is `GET /health`, requests forward with their
                   `If-None-Match` intact.

`ReplicaSet` places requests with rendezvous (highest-random-weight)
hashing over (dataset, request identity): identical requests always land on
the same healthy replica — maximizing that replica's estimate-cache hit
rate — while distinct (mode, bounds, endpoint) identities spread across the
set. When a replica is ejected, only the keys it owned move (classic
rendezvous property); everything else keeps its placement. Failover is
retry-down-the-preference-order: a replica that raises is marked down and
the request continues to the next candidate, so one crash loses no
requests. Ejected replicas rejoin when `probe_all()` sees them healthy —
correct because ETags are derived from dataset state, not server identity,
so a rejoining (or brand-new) replica validates the same client tags
byte-for-byte.
"""
from __future__ import annotations

import dataclasses
import hashlib
import http.client
import json
import threading
import time
from typing import Dict, List, Optional, Tuple
from urllib.parse import urlencode

from repro.engine import EngineConfig, EstimationEngine
from repro.obs import span as _obs_span
from repro.service import (
    EstimateQuery,
    Response,
    StatsService,
    format_bounds,
    format_columns,
)
from repro.wire import ConnectionPool, WireError, fetch


@dataclasses.dataclass(frozen=True)
class StatsRequest:
    """One transport-agnostic routed request (the router's unit of work)."""

    kind: str  # "columns" | "estimate" | "plan" | "tablestats" | "health"
               # | "refresh"
    mode: str = "paper"
    schema_bounds: Optional[Tuple[Tuple[str, float], ...]] = None
    if_none_match: Optional[str] = None
    # Batched-estimate column filter. None = every column; a tuple narrows
    # the body and extends the identity/ETag (a filtered response is a
    # different cacheable thing than the full one).
    columns: Optional[Tuple[str, ...]] = None
    # Diagnostics request: attach per-column provenance to the body. Like
    # `if_none_match`, NOT part of `identity` — an explained request must
    # land on the same replica (same warm caches) as its plain twin.
    explain: bool = False

    @property
    def identity(self) -> tuple:
        """The placement key: everything that names the cached response —
        and nothing that does not (`if_none_match` must not move a request
        between replicas, or revalidations would land cold; `explain`
        must not either, or diagnostics would probe a cold sibling)."""
        base = (self.kind, self.mode, self.schema_bounds or ())
        # Appended only when present, so pre-existing identities (and the
        # rendezvous placement derived from them) are unchanged.
        return base if self.columns is None else base + (self.columns,)

    @property
    def bounds_dict(self) -> Optional[Dict[str, float]]:
        if not self.schema_bounds:
            return None
        return dict(self.schema_bounds)

    def to_query(self) -> EstimateQuery:
        """The service-level batch tuple this request maps onto."""
        return EstimateQuery(
            columns=self.columns,
            mode=self.mode,
            schema_bounds=self.bounds_dict,
            if_none_match=self.if_none_match,
            explain=self.explain,
        )

    @classmethod
    def from_query(cls, q: EstimateQuery) -> "StatsRequest":
        """Inverse of `to_query` for estimate tuples (router `/batch`)."""
        sb = (
            tuple(sorted(q.schema_bounds.items()))
            if q.schema_bounds else None
        )
        return cls(
            kind="estimate",
            mode=q.mode,
            schema_bounds=sb,
            if_none_match=q.if_none_match,
            columns=q.columns,
            explain=q.explain,
        )

    def to_wire(self) -> dict:
        """The `/batch` tuple dict (absent fields elided, compact frames)."""
        d: dict = {}
        if self.columns is not None:
            d["columns"] = list(self.columns)
        if self.mode != "paper":
            d["mode"] = self.mode
        if self.schema_bounds:
            d["bounds"] = self.bounds_dict
        if self.if_none_match is not None:
            d["if_none_match"] = self.if_none_match
        if self.explain:
            # Elided when false: explain-off frames are byte-identical to
            # pre-provenance peers' frames (and those peers never see the
            # field at all).
            d["explain"] = True
        return d


class ReplicaError(ConnectionError):
    """A replica refused or failed a request (triggers failover)."""


class NoReplicaAvailable(RuntimeError):
    """Every replica of the set failed the request."""


# What ejects a replica: transport-shaped failures only (`ReplicaError` is
# a `ConnectionError` is an `OSError`). Anything else — a ValueError from a
# schema-mismatched dataset, a bug — is request- or dataset-scoped: every
# replica would fail it identically, so ejecting (let alone cascading
# through the whole set) would turn one poison request into a fleet-wide
# "degraded" for no benefit. Those propagate to the HTTP layer's 500
# instead, leaving health state untouched.
FAILOVER_ERRORS = (OSError, TimeoutError)


class LocalReplica:
    """One process-local `StatsService` replica in shared-spill mode."""

    def __init__(
        self,
        name: str,
        root: str,
        *,
        engine_config: Optional[EngineConfig] = None,
        poll_interval: Optional[float] = None,
        max_workers: int = 8,
        audit: bool = False,
        audit_columns: int = 4,
    ):
        self.name = name
        self.service = StatsService(
            root,
            engine=EstimationEngine(engine_config or EngineConfig()),
            poll_interval=poll_interval,
            max_workers=max_workers,
            shared_spill=True,
            name=name,  # /metrics series labeled {service="<replica name>"}
            audit=audit,
            audit_columns=audit_columns,
        )
        self._killed = False

    def start(self) -> "LocalReplica":
        self.service.start()
        return self

    def stop(self) -> None:
        self.service.stop()

    def kill(self) -> None:
        """Simulate a crash: refuse all requests and fail probes until
        `revive()`. The underlying ingestion loop is stopped too."""
        self._killed = True
        self.service.stop()

    def revive(self) -> None:
        self._killed = False
        self.service.start()

    def probe(self) -> bool:
        return not self._killed and self.service.probe()

    def handle(self, req: StatsRequest) -> Response:
        if self._killed:
            raise ReplicaError(f"replica {self.name} is down")
        if req.kind == "columns":
            return self.service.columns(if_none_match=req.if_none_match)
        if req.kind == "estimate":
            return self.service.estimate(
                mode=req.mode,
                schema_bounds=req.bounds_dict,
                if_none_match=req.if_none_match,
                explain=req.explain,
            )
        if req.kind == "plan":
            return self.service.plan(
                mode=req.mode, if_none_match=req.if_none_match
            )
        if req.kind == "tablestats":
            return self.service.table_stats(
                mode=req.mode,
                columns=req.columns,
                if_none_match=req.if_none_match,
            )
        if req.kind == "health":
            return self.service.health()
        if req.kind == "refresh":
            return self.service.refresh()
        return Response(400, {"error": f"unknown kind {req.kind!r}"}, None)

    def handle_batch(self, reqs: List[StatsRequest]) -> List[Response]:
        """One sub-batch: all cold tuples share one super-pack engine call."""
        if self._killed:
            raise ReplicaError(f"replica {self.name} is down")
        return self.service.batch([r.to_query() for r in reqs])


class RemoteReplica:
    """HTTP proxy to a `StatsServer` whose lifecycle is owned elsewhere.

    The hop runs over a keep-alive `ConnectionPool` (one TCP connection
    serves the replica's whole request stream, stale sockets retried once
    on a fresh connection — `repro.wire.client`) and negotiates the binary
    wire encoding; both are transparent to callers because the two
    encodings decode to bit-identical bodies with the same ETags.
    """

    def __init__(
        self,
        name: str,
        base_url: str,
        *,
        timeout: float = 30.0,
        pool: Optional[ConnectionPool] = None,
        binary: bool = True,
    ):
        self.name = name
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout
        self.binary = binary
        self._own_pool = pool is None
        self.pool = pool or ConnectionPool(timeout=timeout, name=name)

    def start(self) -> "RemoteReplica":
        return self

    def stop(self) -> None:
        if self._own_pool:
            self.pool.close()

    def probe(self) -> bool:
        try:
            status, _, body = self._fetch(self.base_url + "/health")
        except ReplicaError:
            return False
        return status == 200 and (body or {}).get("status") == "serving"

    def _fetch(
        self, url: str, *, etag=None, method: str = "GET", payload=None
    ) -> Tuple[int, Optional[str], Optional[dict]]:
        """Pooled fetch with replica-shaped error wrapping."""
        try:
            return fetch(
                url,
                pool=self.pool,
                etag=etag,
                method=method,
                payload=payload,
                binary=self.binary,
            )
        except (OSError, http.client.HTTPException, WireError,
                json.JSONDecodeError) as e:
            # unreachable, hung, or answering garbage: all replica-shaped
            raise ReplicaError(
                f"replica {self.name} at {self.base_url}: {e}"
            ) from e

    def handle(self, req: StatsRequest) -> Response:
        path, method = f"/{req.kind}", "GET"
        if req.kind == "refresh":
            method = "POST"
        params = {}
        if req.kind in ("estimate", "plan", "tablestats"):
            params["mode"] = req.mode
        if req.kind == "tablestats" and req.columns:
            params["columns"] = format_columns(req.columns)
        if req.kind == "estimate" and req.schema_bounds:
            # Percent-escaped per side: a column name containing ':' or ','
            # survives the trip (parse_bounds unescapes after splitting).
            params["bounds"] = format_bounds(req.schema_bounds)
        if req.kind == "estimate" and req.explain:
            params["explain"] = "1"
        url = self.base_url + path + (
            "?" + urlencode(params) if params else ""
        )
        status, etag, body = self._fetch(
            url, etag=req.if_none_match, method=method
        )
        # A 5xx passes through as a response, NOT as a ReplicaError: the
        # upstream _Handler turns application errors (e.g. a ValueError
        # from a schema-mismatched dataset) into 500s, and those would
        # fail identically on every replica — same contract as a
        # LocalReplica propagating the exception (see FAILOVER_ERRORS).
        # Replica-local sickness is the probe loop's job to catch.
        return Response(status, body, etag)

    def scrape_metrics(self) -> Optional[str]:
        """This replica's `/metrics` exposition text, or None if unreachable.

        Only REMOTE replicas are scraped by the router's aggregate —
        local replicas already write the router process's own registry,
        so re-scraping them would double-count every series.
        """
        try:
            status, _, raw = self.pool.request(self.base_url + "/metrics")
        except Exception:
            return None
        if status != 200:
            return None
        try:
            return raw.decode("utf-8")
        except UnicodeDecodeError:
            return None

    def scrape_explain(self) -> Optional[dict]:
        """This replica's `/debug/explain` body, or None if unreachable.

        Mirrors `scrape_metrics`: best-effort, remote replicas only (a
        local replica's service is queried directly by the router)."""
        try:
            status, _, body = self._fetch(self.base_url + "/debug/explain")
        except Exception:
            return None
        if status != 200 or not isinstance(body, dict):
            return None
        return body

    def handle_batch(self, reqs: List[StatsRequest]) -> List[Response]:
        """Forward one sub-batch as a single binary `POST /batch` frame."""
        payload = {"tuples": [r.to_wire() for r in reqs]}
        status, _, body = self._fetch(
            self.base_url + "/batch", method="POST", payload=payload
        )
        entries = (body or {}).get("responses")
        if status != 200 or not isinstance(entries, list) \
                or len(entries) != len(reqs):
            # A replica that cannot answer the batch shape is as failed as
            # an unreachable one — the caller retries the sub-batch whole.
            raise ReplicaError(
                f"replica {self.name} at {self.base_url}: bad /batch "
                f"answer (status {status})"
            )
        return [
            Response(e.get("status", 500), e.get("body"), e.get("etag"))
            for e in entries
        ]


@dataclasses.dataclass
class ReplicaHealth:
    """Mutable health record the set keeps per replica."""

    healthy: bool = True
    consecutive_failures: int = 0
    last_error: Optional[str] = None
    last_change_monotonic: float = 0.0
    ejections: int = 0


class ReplicaSet:
    """N interchangeable replicas of one dataset behind rendezvous hashing."""

    def __init__(self, dataset_key: str, replicas: List):
        if not replicas:
            raise ValueError(f"replica set {dataset_key!r} needs >= 1 replica")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names in set: {names}")
        self.dataset_key = dataset_key
        self.replicas = list(replicas)
        self.health: Dict[str, ReplicaHealth] = {
            r.name: ReplicaHealth() for r in replicas
        }
        self.failovers = 0
        self._mu = threading.Lock()

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "ReplicaSet":
        for r in self.replicas:
            r.start()
        return self

    def stop(self) -> None:
        for r in self.replicas:
            r.stop()

    # -- placement -----------------------------------------------------------

    def rank(self, identity: tuple) -> List:
        """All replicas, best placement first (rendezvous hashing).

        Weight = SHA-1(dataset key, request identity, replica name): stable
        across processes and restarts, so a router restart or an
        independently-built second router places identically.
        """
        def weight(replica) -> str:
            h = hashlib.sha1(
                f"{self.dataset_key}|{identity!r}|{replica.name}".encode()
            )
            return h.hexdigest()

        return sorted(self.replicas, key=weight, reverse=True)

    def _candidates(self, identity: tuple) -> List:
        """Healthy replicas in rank order, then ejected ones as last
        resorts — an all-down set still attempts every replica (and a
        successful hail-mary resurrects the one that answered)."""
        ranked = self.rank(identity)
        with self._mu:
            up = [r for r in ranked if self.health[r.name].healthy]
            down = [r for r in ranked if not self.health[r.name].healthy]
        return up + down

    def _mark(self, name: str, healthy: bool, error: Optional[str]) -> None:
        with self._mu:
            rec = self.health[name]
            if healthy:
                rec.consecutive_failures = 0
                rec.last_error = None
            else:
                rec.consecutive_failures += 1
                rec.last_error = error
                if rec.healthy:
                    rec.ejections += 1
            if rec.healthy != healthy:
                rec.healthy = healthy
                rec.last_change_monotonic = time.monotonic()

    # -- serving -------------------------------------------------------------

    def call(self, req: StatsRequest) -> Tuple[Response, str, int]:
        """Route one request; returns (response, replica name, attempts).

        A replica that fails transport-shaped (`FAILOVER_ERRORS`) is
        ejected and the request retries on the next candidate — the caller
        sees a failure only when every replica failed
        (`NoReplicaAvailable`, carrying each replica's error). Any other
        exception is request/dataset-scoped and propagates immediately,
        with no ejection: every replica would fail it the same way.
        """
        errors: List[str] = []
        for attempt, replica in enumerate(self._candidates(req.identity), 1):
            try:
                # Each attempt gets its own span, parented to the CURRENT
                # (router) span — so a failed attempt's retry shows up as
                # a re-parented sibling, never an orphan of the dead span.
                with _obs_span(
                    "replica.call",
                    replica=replica.name, kind=req.kind, attempt=attempt,
                ):
                    resp = replica.handle(req)
            except FAILOVER_ERRORS as e:
                self._mark(replica.name, False, f"{type(e).__name__}: {e}")
                errors.append(f"{replica.name}: {type(e).__name__}: {e}")
                with self._mu:
                    self.failovers += 1
                continue
            self._mark(replica.name, True, None)
            return resp, replica.name, attempt
        raise NoReplicaAvailable(
            f"all {len(self.replicas)} replicas of {self.dataset_key!r} "
            f"failed: {'; '.join(errors)}"
        )

    def call_batch(
        self, reqs: List[StatsRequest]
    ) -> Tuple[List[Response], int]:
        """Route a batch of estimate tuples; returns (responses aligned
        with `reqs`, sub-batch dispatches performed).

        Tuples are grouped by their rendezvous-chosen replica — one
        `handle_batch` RPC per distinct placement, so every tuple still
        lands where its singleton `/estimate` would (same cache locality),
        while the common case (all tuples share a placement) is a single
        RPC. A failed dispatch (`FAILOVER_ERRORS`) ejects the replica and
        requeues its whole sub-batch for the next pass, where
        `_candidates` re-ranks around the ejection; passes are bounded by
        the replica count, and tuples that outlive every pass answer 503
        in place (the batch envelope itself never fails partway).
        """
        responses: List[Optional[Response]] = [None] * len(reqs)
        pending = list(range(len(reqs)))
        dispatches = 0
        for _ in range(len(self.replicas)):
            if not pending:
                break
            groups: Dict[str, List[int]] = {}
            chosen: Dict[str, object] = {}
            for i in pending:
                replica = self._candidates(reqs[i].identity)[0]
                chosen[replica.name] = replica
                groups.setdefault(replica.name, []).append(i)
            requeued: List[int] = []
            for name, indices in groups.items():
                replica = chosen[name]
                dispatches += 1
                try:
                    # One span per dispatch attempt, parented to the
                    # current (router) span: a requeued sub-batch's retry
                    # span is a SIBLING of the failed attempt's span (its
                    # `error` attribute marks the failure), not a child of
                    # it — failover re-parents instead of orphaning.
                    with _obs_span(
                        "replica.sub_batch",
                        replica=name, tuples=len(indices),
                    ):
                        answers = replica.handle_batch(
                            [reqs[i] for i in indices]
                        )
                except FAILOVER_ERRORS as e:
                    self._mark(name, False, f"{type(e).__name__}: {e}")
                    with self._mu:
                        self.failovers += 1
                    requeued.extend(indices)
                    continue
                self._mark(name, True, None)
                for i, resp in zip(indices, answers):
                    responses[i] = resp
            pending = requeued
        for i in pending:
            responses[i] = Response(
                503,
                {
                    "error": f"all {len(self.replicas)} replicas of "
                    f"{self.dataset_key!r} failed"
                },
                None,
            )
        return list(responses), dispatches

    def refresh_all(self) -> List[Tuple[str, Optional[Response]]]:
        """Broadcast a refresh to every replica (each replica ingests
        independently; all must see a dataset change for their ETags to
        agree). Transport failures eject, as in `call()`; a dataset-scoped
        refresh error (e.g. a schema-mismatched new file — every replica
        rejects it identically, last-good state keeps serving) is reported
        as a failed entry without ejecting anyone."""
        out: List[Tuple[str, Optional[Response]]] = []
        for replica in self.replicas:
            try:
                resp = replica.handle(StatsRequest("refresh"))
            except Exception as e:
                if isinstance(e, FAILOVER_ERRORS):
                    self._mark(replica.name, False, f"{type(e).__name__}: {e}")
                out.append((replica.name, None))
                continue
            self._mark(replica.name, True, None)
            out.append((replica.name, resp))
        return out

    # -- health --------------------------------------------------------------

    def probe_all(self) -> Dict[str, bool]:
        """Probe every replica; ejected replicas that pass rejoin."""
        results: Dict[str, bool] = {}
        for replica in self.replicas:
            try:
                ok = bool(replica.probe())
            except Exception as e:
                ok = False
                self._mark(replica.name, False, f"{type(e).__name__}: {e}")
            else:
                self._mark(replica.name, ok, None if ok else "probe failed")
            results[replica.name] = ok
        return results

    def health_view(self) -> dict:
        # Connection-pool counters (opened/reused/retried_stale) per
        # replica that carries its own pool (remote hops) — collected
        # since PR 7 but previously never exposed over HTTP.
        pools = {
            r.name: r.pool.stats.snapshot()
            for r in self.replicas
            if getattr(r, "pool", None) is not None
        }
        with self._mu:
            view = {
                "replicas": {
                    name: {
                        "healthy": rec.healthy,
                        "consecutive_failures": rec.consecutive_failures,
                        "ejections": rec.ejections,
                        "last_error": rec.last_error,
                    }
                    for name, rec in self.health.items()
                },
                "healthy": sum(r.healthy for r in self.health.values()),
                "total": len(self.replicas),
                "failovers": self.failovers,
            }
        if pools:
            view["pools"] = pools
        return view
