"""`Fleet` + `StatsRouter`: one HTTP endpoint over many replicated datasets.

`Fleet` is the transport-agnostic core: it owns a `DatasetRegistry`, one
`ReplicaSet` per registered dataset (built by a pluggable `replica_factory`
— process-local `StatsService` replicas by default, `RemoteReplica` HTTP
proxies for out-of-process deployments), an optional background health
prober, and the routing counters. `StatsRouter` is the stdlib HTTP shell
over it, the same shape as `repro.service.StatsServer`:

  GET  /datasets                              registry + replica health
  GET  /health                                router + per-dataset health
                                              (incl. connection-pool stats)
  GET  /metrics                               Prometheus exposition, router +
                                              remote replicas (`replica` label)
  GET  /debug/traces?limit=N                  recent traces, JSON span trees
  GET  /debug/explain?dataset=&namespace=     provenance caches + audit
                                              samples, aggregated per replica
                                              (local queried in-process,
                                              remote scraped best-effort)
  POST /refresh                               broadcast refresh, all datasets
  POST /batch                                 estimate + cost tuples, one frame
  POST /cost?explain=                         join ordering over registered
                                              datasets        [combined ETag]
  GET  /{ns}/{ds}/columns                     routed        [ETag passthrough]
  GET  /{ns}/{ds}/estimate?mode=&bounds=      routed        [ETag passthrough]
  GET  /{ns}/{ds}/plan?mode=                  routed        [ETag passthrough]
  GET  /{ns}/{ds}/tablestats?mode=&columns=   routed        [ETag passthrough]
  GET  /{ns}/{ds}/health                      routed (any healthy replica)
  POST /{ns}/{ds}/refresh                     broadcast refresh, one dataset

`POST /batch` tuples carry `namespace`/`dataset` alongside the per-dataset
batch fields (`repro.service.parse_query_tuple` shape) and may span any
mix of registered datasets: the router groups tuples by their
rendezvous-chosen replica and forwards one sub-batch RPC per replica
(`ReplicaSet.call_batch`), each of which executes its cold tuples as one
cross-dataset super-pack on the serving side. Content negotiation
(`Accept: application/x-ndv-wire`) applies to the envelope exactly as to
single requests.

The router adds nothing to response bodies and nothing to ETags: a tag
minted by any replica validates on any other, because tags are derived from
(dataset fingerprint set, engine cache token, request identity) and the
registry pins one engine config per dataset. That is the whole failover
story — clients keep their `If-None-Match` caches across replica deaths,
router restarts, and replica cold starts.

`POST /cost` is the fleet's planner entry point (`repro.planner`): a join
graph whose tables name registered datasets (`namespace`/`dataset` on every
table) is costed in the router process. The router fetches one
`/tablestats` body per referenced dataset from that dataset's replica set
(restricted to the join columns the graph actually uses), scores the plan
space with `compute_cost`, and mints a combined ETag hashed over (graph
identity, mode, max_plans, the sorted per-dataset `/tablestats` ETags) —
so `/cost` answers 304 exactly when *every* input dataset's stats are
unchanged, and the tag is identical no matter which replica served each
`/tablestats`, because those tags are state-derived. Cost tuples (dicts
carrying a `"cost"` key) ride `POST /batch` next to estimate tuples.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from http.server import ThreadingHTTPServer
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union
from urllib.parse import parse_qs, urlsplit

from repro.fleet.registry import DatasetRegistry, DatasetSpec
from repro.fleet.replica import (
    LocalReplica,
    NoReplicaAvailable,
    ReplicaSet,
    StatsRequest,
)
from repro.obs import WIDTH_BUCKETS, registry as obs_registry
from repro.obs.metrics import add_label_to_exposition
from repro.planner import (
    ColumnStats,
    DEFAULT_MAX_PLANS,
    JoinGraph,
    TableStats,
    compute_cost,
)
from repro.planner.api import provenance_block
from repro.service import (
    CostQuery,
    Response,
    batch_envelope,
    etag_matches,
    parse_bounds,
    parse_columns,
    parse_cost_request,
    parse_explain,
    parse_query_tuple,
)
from repro.service.http import JSONResponseHandler

ROUTED_KINDS = ("columns", "estimate", "plan", "tablestats", "health")

# Same metric family the service tier observes — the `tier` label keeps
# router envelopes and replica sub-batches distinguishable.
_BATCH_WIDTH = obs_registry().histogram(
    "ndv_batch_tuples",
    "Estimate tuples carried per /batch request",
    WIDTH_BUCKETS,
)


def default_replica_factory(
    spec: DatasetSpec, index: int, **replica_kwargs
) -> LocalReplica:
    """Process-local replicas sharing the dataset's estimate-cache spill."""
    return LocalReplica(
        f"{spec.key}#{index}",
        spec.root,
        engine_config=spec.engine_config,
        **replica_kwargs,
    )


@dataclasses.dataclass
class FleetStats:
    """Router-side counters (per-replica health lives on the sets)."""

    requests: int = 0
    routed: int = 0
    retried: int = 0          # requests that needed >1 replica attempt
    unavailable: int = 0      # 503s: every replica of a set failed
    not_found: int = 0        # 404s: unregistered dataset or bad path
    batches: int = 0          # /batch envelopes handled
    batch_tuples: int = 0     # tuples carried inside those envelopes


class Fleet:
    """Replica sets for every registered dataset, behind one routing seam."""

    def __init__(
        self,
        registry: DatasetRegistry,
        *,
        replicas_per_dataset: int = 2,
        probe_interval: Optional[float] = None,
        replica_factory: Optional[Callable] = None,
        **replica_kwargs,
    ):
        if replicas_per_dataset < 1:
            raise ValueError("replicas_per_dataset must be >= 1")
        if len(registry) == 0:
            raise ValueError("fleet needs at least one registered dataset")
        self.registry = registry
        self.probe_interval = probe_interval
        self.stats = FleetStats()
        # ThreadingHTTPServer handles requests on concurrent threads; bare
        # `+=` on the counters would lose increments under load.
        self._stats_mu = threading.Lock()
        factory = replica_factory or default_replica_factory
        self.sets: Dict[str, ReplicaSet] = {
            spec.key: ReplicaSet(
                spec.key,
                [
                    factory(spec, i, **replica_kwargs)
                    for i in range(replicas_per_dataset)
                ],
            )
            for spec in registry
        }
        self._stop = threading.Event()
        self._prober: Optional[threading.Thread] = None
        obs_registry().register_stats_view("ndv_fleet", {}, self.stats)

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "Fleet":
        for rset in self.sets.values():
            rset.start()
        if self.probe_interval:
            self._stop.clear()
            self._prober = threading.Thread(
                target=self._probe_loop, name="ndv-fleet-probe", daemon=True
            )
            self._prober.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._prober is not None:
            self._prober.join(timeout=10.0)
            self._prober = None
        for rset in self.sets.values():
            rset.stop()

    def __enter__(self) -> "Fleet":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _probe_loop(self) -> None:
        while not self._stop.wait(self.probe_interval):
            self.probe_all()

    def probe_all(self) -> Dict[str, Dict[str, bool]]:
        """One probe sweep: ejected replicas that answer rejoin service."""
        return {key: rset.probe_all() for key, rset in self.sets.items()}

    def _bump(self, **fields: int) -> None:
        with self._stats_mu:
            for name, delta in fields.items():
                setattr(self.stats, name, getattr(self.stats, name) + delta)

    # -- endpoints -----------------------------------------------------------

    def route(self, namespace: str, dataset: str, req: StatsRequest) -> Response:
        """Place one request on the dataset's replica set, with failover."""
        self._bump(requests=1)
        try:
            rset = self.sets[self.registry.get(namespace, dataset).key]
        except KeyError as e:
            self._bump(not_found=1)
            return Response(404, {"error": str(e)}, None)
        try:
            resp, replica_name, attempts = rset.call(req)
        except NoReplicaAvailable as e:
            self._bump(unavailable=1)
            return Response(503, {"error": str(e)}, None)
        self._bump(routed=1, retried=int(attempts > 1))
        return resp

    @staticmethod
    def _cost_etag(
        graph: JoinGraph, mode: str, max_plans: int,
        source_etags: Dict[str, str],
    ) -> str:
        """Combined planner tag: rotates iff any input dataset's stats did.

        Hashes the request identity (graph identity is order-insensitive,
        so listing the same tables/edges in a different order revalidates)
        plus every referenced dataset's `/tablestats` ETag in sorted key
        order. Those tags are state-derived and replica-independent, so
        this one is too.
        """
        h = hashlib.sha1()
        h.update(
            f"cost|{mode}|{graph.identity()!r}|{int(max_plans)}".encode()
        )
        for key in sorted(source_etags):
            h.update(f"|{key}={source_etags[key]}".encode())
        return f'"{h.hexdigest()}"'

    def cost(
        self,
        *,
        graph: JoinGraph,
        mode: str = "paper",
        max_plans: int = DEFAULT_MAX_PLANS,
        if_none_match: Optional[str] = None,
        explain: bool = False,
    ) -> Response:
        """Cost a cross-dataset join graph; the fleet's `POST /cost`.

        Every graph table must carry `namespace`/`dataset` naming a
        registered dataset (404 otherwise). Per referenced dataset, one
        `/tablestats` request — restricted to the join columns the graph
        uses on that dataset — goes through the replica set with the usual
        rendezvous placement and failover; scoring happens here in the
        router process. The 304 check runs after the (warm, cheap)
        tablestats fetches but before any plan enumeration or scoring.
        """
        self._bump(requests=1)
        needed = graph.columns_by_table()
        key_by_alias: Dict[str, str] = {}
        cols_by_key: Dict[str, set] = {}
        for t in graph.tables:
            if t.dataset_key is None:
                return Response(
                    400,
                    {"error": f"table {t.name!r} must name a registered "
                              f"dataset (namespace/dataset)"},
                    None,
                )
            try:
                key = self.registry.get(t.namespace, t.dataset).key
            except KeyError as e:
                self._bump(not_found=1)
                return Response(404, {"error": str(e)}, None)
            key_by_alias[t.name] = key
            cols_by_key.setdefault(key, set()).update(needed[t.name])
        bodies: Dict[str, dict] = {}
        source_etags: Dict[str, str] = {}
        for key in sorted(cols_by_key):
            req = StatsRequest(
                kind="tablestats",
                mode=mode,
                columns=tuple(sorted(cols_by_key[key])) or None,
            )
            try:
                resp, _name, attempts = self.sets[key].call(req)
            except NoReplicaAvailable as e:
                self._bump(unavailable=1)
                return Response(503, {"error": str(e)}, None)
            self._bump(routed=1, retried=int(attempts > 1))
            if resp.status != 200:
                err = (resp.body or {}).get("error", f"status {resp.status}")
                return Response(
                    resp.status,
                    {"error": f"tablestats for dataset {key!r}: {err}"},
                    None,
                )
            bodies[key] = resp.body
            source_etags[key] = resp.body["etag"]
        etag = self._cost_etag(graph, mode, max_plans, source_etags)
        if if_none_match is not None and etag_matches(if_none_match, etag):
            return Response(304, None, etag)
        stats: Dict[str, TableStats] = {}
        for t in graph.tables:
            body = bodies[key_by_alias[t.name]]
            columns: Dict[str, ColumnStats] = {}
            for col in needed[t.name]:
                cs = body["columns"].get(col)
                if cs is None:
                    # The replica validated the column list, so this only
                    # fires on a body-shape drift — still a client-visible
                    # 400, not a 500.
                    return Response(
                        400,
                        {"error": f"dataset {key_by_alias[t.name]!r} has "
                                  f"no column {col!r}"},
                        None,
                    )
                columns[col] = ColumnStats(
                    ndv=float(cs["ndv"]),
                    non_null=int(cs["non_null"]),
                    confidence=cs.get("confidence"),
                    route=cs.get("route"),
                )
            stats[t.name] = TableStats(
                rows=float(body["rows"]), columns=columns
            )
        try:
            cost_body = compute_cost(
                graph, stats, mode=mode, max_plans=max_plans
            )
        except ValueError as e:
            return Response(400, {"error": str(e)}, None)
        out = {
            "etag": etag,
            "sources": dict(sorted(source_etags.items())),
            **cost_body,
        }
        if explain:
            out["provenance"] = provenance_block(graph, stats)
        return Response(200, out, etag)

    def batch(
        self, items: Sequence[Tuple[str, str, StatsRequest]]
    ) -> List[Response]:
        """Route `(namespace, dataset, estimate request)` tuples in bulk.

        Tuples are grouped per registered dataset and each group forwards
        through its replica set's `call_batch` — rendezvous placement,
        sub-batch failover, and the serving-side super-pack all happen
        there. Per-tuple errors answer in place (404 unknown dataset, 400
        non-estimate kind, 503 when every replica of a set failed); the
        envelope itself only fails on transport-level problems.
        """
        self._bump(requests=1, batches=1, batch_tuples=len(items))
        responses: List[Optional[Response]] = [None] * len(items)
        groups: Dict[str, List[int]] = {}
        for i, (ns, ds, req) in enumerate(items):
            if req.kind != "estimate":
                responses[i] = Response(
                    400,
                    {"error": f"batch tuples must be estimates, "
                              f"got kind {req.kind!r}"},
                    None,
                )
                continue
            try:
                key = self.registry.get(ns, ds).key
            except KeyError as e:
                self._bump(not_found=1)
                responses[i] = Response(404, {"error": str(e)}, None)
                continue
            groups.setdefault(key, []).append(i)
        for key, indices in groups.items():
            answers, _ = self.sets[key].call_batch(
                [items[i][2] for i in indices]
            )
            served = sum(1 for r in answers if r.status != 503)
            self._bump(routed=served, unavailable=len(answers) - served)
            for i, resp in zip(indices, answers):
                responses[i] = resp
        return list(responses)

    def refresh(
        self, namespace: Optional[str] = None, dataset: Optional[str] = None
    ) -> Response:
        """Broadcast a refresh to one dataset's replicas, or to all."""
        self._bump(requests=1)
        if namespace is not None:
            try:
                keys = [self.registry.get(namespace, dataset).key]
            except KeyError as e:
                self._bump(not_found=1)
                return Response(404, {"error": str(e)}, None)
        else:
            keys = list(self.sets)
        body: Dict[str, dict] = {}
        for key in keys:
            results = self.sets[key].refresh_all()
            body[key] = {
                name: (resp.body if resp is not None else None)
                for name, resp in results
            }
        return Response(200, {"refreshed": body}, None)

    def datasets(self) -> Response:
        self._bump(requests=1)
        body = {
            "datasets": [
                {
                    "key": spec.key,
                    "namespace": spec.namespace,
                    "dataset": spec.dataset,
                    "root": spec.root,
                    "engine": dataclasses.asdict(spec.engine_config),
                    **self.sets[spec.key].health_view(),
                }
                for spec in self.registry
            ]
        }
        return Response(200, body, None)

    def metrics_text(self) -> str:
        """Aggregate exposition: this process's registry plus every
        REMOTE replica's `/metrics` scrape re-emitted under a
        `replica="<name>"` label.

        Local replicas are deliberately not scraped — they already write
        this process's registry, so re-emitting them would double-count.
        Remote sample lines are appended comment-free (the aggregate is a
        concatenation; duplicate TYPE headers would be invalid), and an
        unreachable replica contributes nothing rather than failing the
        scrape.
        """
        parts = [obs_registry().exposition()]
        for rset in self.sets.values():
            for replica in rset.replicas:
                scrape = getattr(replica, "scrape_metrics", None)
                if scrape is None:
                    continue
                text = scrape()
                if text:
                    parts.append(
                        add_label_to_exposition(text, {"replica": replica.name})
                    )
        return "".join(parts)

    def explain_view(self, dataset_key: Optional[str] = None) -> Response:
        """Router-aggregated `/debug/explain`, patterned on `metrics_text`.

        Local replicas are queried in-process (their service owns the
        provenance cache); remote replicas are scraped best-effort — an
        unreachable replica contributes nothing rather than failing the
        view. `dataset_key` narrows to one registered dataset (404 when
        unknown); None aggregates all of them.
        """
        self._bump(requests=1)
        if dataset_key is not None:
            if dataset_key not in self.sets:
                self._bump(not_found=1)
                return Response(
                    404, {"error": f"unknown dataset {dataset_key!r}"}, None
                )
            keys = [dataset_key]
        else:
            keys = list(self.sets)
        datasets: Dict[str, dict] = {}
        for key in keys:
            per_replica: Dict[str, dict] = {}
            for replica in self.sets[key].replicas:
                service = getattr(replica, "service", None)
                if service is not None:
                    per_replica[replica.name] = service.debug_explain().body
                    continue
                scrape = getattr(replica, "scrape_explain", None)
                payload = scrape() if scrape is not None else None
                if payload is not None:
                    per_replica[replica.name] = payload
            datasets[key] = per_replica
        return Response(200, {"datasets": datasets}, None)

    def health(self) -> Response:
        self._bump(requests=1)
        views = {key: rset.health_view() for key, rset in self.sets.items()}
        all_up = all(v["healthy"] > 0 for v in views.values())
        with self._stats_mu:
            router_stats = dataclasses.asdict(self.stats)
        return Response(200, {
            "status": "serving" if all_up else "degraded",
            "datasets": views,
            "router": router_stats,
        }, None)


# -- HTTP shell ---------------------------------------------------------------


class _RouterHandler(JSONResponseHandler):
    """Routes one request onto the shared `Fleet`."""

    fleet: Fleet  # injected by make_router_handler
    server_version = "ndv-stats-router"
    tier = "router"

    _TOP_ROUTES = frozenset({"datasets", "health", "refresh", "batch", "cost"})

    def _route_label(self, path: str) -> str:
        # `/{ns}/{ds}/{kind}` collapses to the kind — dataset names must
        # not mint unbounded label values.
        parts = [p for p in path.split("/") if p]
        if len(parts) == 1 and parts[0] in self._TOP_ROUTES:
            return parts[0]
        if len(parts) == 3 and parts[2] in ROUTED_KINDS + ("refresh",):
            return parts[2]
        return "other"

    def _metrics_text(self) -> str:
        return self.fleet.metrics_text()

    def _explain_body(self, query) -> Response:
        # /debug/* params are validated here, not trusted: junk answers a
        # 400 JSON error (raised ValueError), never an unhandled 500.
        ds = query.get("dataset", [None])[0]
        ns = query.get("namespace", [None])[0]
        if ds is not None and not ds.strip():
            raise ValueError("dataset must be a non-empty dataset key")
        if ns is not None:
            if not ns.strip():
                raise ValueError("namespace must be a non-empty string")
            if ds is None:
                raise ValueError("namespace requires a dataset")
            try:
                ds = self.fleet.registry.get(ns, ds).key
            except KeyError as e:
                self.fleet._bump(not_found=1)
                return Response(404, {"error": str(e)}, None)
        return self.fleet.explain_view(ds)

    def _split(self) -> Tuple[List[str], dict]:
        url = urlsplit(self.path)
        parts = [p for p in url.path.split("/") if p]
        return parts, parse_qs(url.query)

    @staticmethod
    def _parse_batch(
        payload,
    ) -> List[Union[Tuple[str, str, StatsRequest], CostQuery]]:
        """Router `/batch` body -> routable items (ValueError on junk).

        Estimate tuples carry `namespace`/`dataset` alongside the service
        tuple fields. Cost tuples (a `"cost"` key) carry no top-level
        dataset fields — every table inside the graph names its own —
        and come back as `CostQuery` for `Fleet.cost`.
        """
        if not isinstance(payload, dict) or not isinstance(
            payload.get("tuples"), list
        ):
            raise ValueError(
                "batch body must be an object with a 'tuples' list"
            )
        items: List[Union[Tuple[str, str, StatsRequest], CostQuery]] = []
        for t in payload["tuples"]:
            if isinstance(t, dict) and "cost" in t:
                unknown = set(t) - {"cost", "if_none_match", "explain"}
                if unknown:
                    raise ValueError(
                        f"unknown cost tuple fields: {sorted(unknown)}"
                    )
                graph, mode, max_plans = parse_cost_request(
                    t["cost"], require_datasets=True
                )
                inm = t.get("if_none_match")
                if inm is not None and not isinstance(inm, str):
                    raise ValueError("if_none_match must be a string")
                items.append(CostQuery(
                    graph=graph,
                    mode=mode,
                    max_plans=max_plans,
                    if_none_match=inm,
                    explain=bool(t.get("explain", False)),
                ))
                continue
            query = parse_query_tuple(t)
            ns, ds = t.get("namespace"), t.get("dataset")
            if not isinstance(ns, str) or not isinstance(ds, str):
                raise ValueError(
                    "router batch tuples need string 'namespace' and "
                    "'dataset' fields"
                )
            items.append((ns, ds, StatsRequest.from_query(query)))
        return items

    def handle_get(self, url) -> None:
        parts, query = self._split()
        try:
            if parts == ["datasets"]:
                return self._send(self.fleet.datasets())
            if parts == ["health"]:
                return self._send(self.fleet.health())
            if len(parts) == 3 and parts[2] in ROUTED_KINDS:
                ns, ds, kind = parts
                bounds = None
                if "bounds" in query:
                    try:
                        bounds = tuple(sorted(
                            parse_bounds(query["bounds"][0]).items()
                        ))
                    except ValueError as e:
                        return self._error(400, str(e))
                try:
                    explain = parse_explain(query)
                except ValueError as e:
                    return self._error(400, str(e))
                columns = None
                if kind == "tablestats" and "columns" in query:
                    try:
                        columns = parse_columns(query["columns"][0])
                    except ValueError as e:
                        return self._error(400, str(e))
                req = StatsRequest(
                    kind=kind,
                    mode=query.get("mode", ["paper"])[0],
                    schema_bounds=bounds,
                    if_none_match=self.headers.get("If-None-Match"),
                    columns=columns,
                    explain=explain,
                )
                return self._send(self.fleet.route(ns, ds, req))
            self.fleet._bump(not_found=1)
            self._error(404, f"no such route: {self.path}")
        except Exception as e:
            self._error(500, f"{type(e).__name__}: {e}")

    def handle_post(self, url) -> None:
        parts, _ = self._split()
        try:
            if parts == ["refresh"]:
                return self._send(self.fleet.refresh())
            if parts == ["cost"]:
                try:
                    explain = parse_explain(
                        parse_qs(urlsplit(self.path).query,
                                 keep_blank_values=True)
                    )
                    graph, mode, max_plans = parse_cost_request(
                        self._read_body(), require_datasets=True
                    )
                except ValueError as e:
                    return self._error(400, str(e))
                return self._send(self.fleet.cost(
                    graph=graph,
                    mode=mode,
                    max_plans=max_plans,
                    if_none_match=self.headers.get("If-None-Match"),
                    explain=explain,
                ))
            if parts == ["batch"]:
                try:
                    items = self._parse_batch(self._read_body())
                except ValueError as e:
                    return self._error(400, str(e))
                _BATCH_WIDTH.observe(len(items), tier=self.tier)
                responses: List[Optional[Response]] = [None] * len(items)
                est_items: List[Tuple[str, str, StatsRequest]] = []
                est_idx: List[int] = []
                for i, item in enumerate(items):
                    if isinstance(item, CostQuery):
                        responses[i] = self.fleet.cost(
                            graph=item.graph,
                            mode=item.mode,
                            max_plans=item.max_plans,
                            if_none_match=item.if_none_match,
                            explain=item.explain,
                        )
                    else:
                        est_idx.append(i)
                        est_items.append(item)
                if est_items:
                    for i, resp in zip(
                        est_idx, self.fleet.batch(est_items)
                    ):
                        responses[i] = resp
                return self._send(batch_envelope(responses))
            if len(parts) == 3 and parts[2] == "refresh":
                return self._send(self.fleet.refresh(parts[0], parts[1]))
            self.fleet._bump(not_found=1)
            self._error(404, f"no such route: {self.path}")
        except Exception as e:
            self._error(500, f"{type(e).__name__}: {e}")


def make_router_handler(fleet: Fleet, *, slow_request_ms: Optional[float] = None):
    return type(
        "BoundRouterHandler",
        (_RouterHandler,),
        {"fleet": fleet, "slow_request_ms": slow_request_ms},
    )


class StatsRouter:
    """Owns a `ThreadingHTTPServer` fronting one `Fleet`.

    Same lifecycle contract as `repro.service.StatsServer`: port 0 binds an
    ephemeral port, `start()` runs the accept loop on a daemon thread,
    `stop()` shuts down the HTTP loop and then the fleet (replica sets and
    the health prober). Usable as a context manager.
    """

    def __init__(
        self,
        fleet: Fleet,
        host: str = "127.0.0.1",
        port: int = 0,
        slow_request_ms: Optional[float] = None,
    ):
        self.fleet = fleet
        self.httpd = ThreadingHTTPServer(
            (host, port),
            make_router_handler(fleet, slow_request_ms=slow_request_ms),
        )
        self.host, self.port = self.httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def url_for(self, namespace: str, dataset: str, kind: str) -> str:
        return f"{self.url}/{namespace}/{dataset}/{kind}"

    def start(self) -> "StatsRouter":
        self.fleet.start()
        self._thread = threading.Thread(
            target=self.httpd.serve_forever,
            name="ndv-stats-router-http",
            daemon=True,
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        # shutdown() blocks on an event only serve_forever() sets — guard
        # against a start() that never reached the accept loop.
        if self._thread is not None:
            self.httpd.shutdown()
            self._thread.join(timeout=10.0)
            self._thread = None
        self.httpd.server_close()
        self.fleet.stop()

    def __enter__(self) -> "StatsRouter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def serve_fleet(
    registry: DatasetRegistry,
    *,
    host: str = "127.0.0.1",
    port: int = 0,
    **fleet_kwargs,
) -> StatsRouter:
    """One-call convenience: build a `Fleet` and start routing it."""
    return StatsRouter(Fleet(registry, **fleet_kwargs), host=host, port=port).start()
