"""Fault-tolerance coordinator: heartbeats, stragglers, elastic rescale.

On a real multi-pod deployment this wraps the cluster-coordination service
(GCS runtime / Borg events). The container is single-host, so the
coordinator is driven either by real wall-clock heartbeats (trainer loop)
or by an injectable ``FaultPlan`` that simulates node failures and
stragglers deterministically — which is what the integration tests and the
`examples/fault_tolerant_train.py` driver exercise.

Policies implemented:
  * failure detection — a worker missing `miss_threshold` consecutive
    heartbeats is declared dead; the trainer restores from the latest
    checkpoint and continues on the surviving mesh (elastic data split).
  * straggler mitigation — per-step duration EWMA; a worker slower than
    `straggler_factor` x the fleet median for `patience` steps is evicted
    (same elastic path) rather than capping fleet throughput.
  * elastic rescale — data-parallel degree changes between runs; the
    deterministic data pipeline re-seeds by (step, epoch) so no sample is
    skipped or double-visited beyond one batch boundary.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class WorkerState:
    worker_id: int
    last_heartbeat: float
    missed: int = 0
    step_ewma: Optional[float] = None
    slow_streak: int = 0
    alive: bool = True


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    step: int
    kind: str            # "fail" | "straggle" | "recover"
    worker_id: int
    factor: float = 1.0  # slowdown factor for stragglers


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault injection for tests/examples."""

    events: Sequence[FaultEvent] = ()

    def at(self, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.step == step]


class Coordinator:
    def __init__(
        self,
        num_workers: int,
        *,
        heartbeat_interval: float = 10.0,
        miss_threshold: int = 3,
        straggler_factor: float = 1.5,
        patience: int = 5,
        ewma: float = 0.9,
    ):
        now = time.monotonic()
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState(i, now) for i in range(num_workers)
        }
        self.heartbeat_interval = heartbeat_interval
        self.miss_threshold = miss_threshold
        self.straggler_factor = straggler_factor
        self.patience = patience
        self.ewma = ewma
        self.log: List[str] = []

    # -- signals ----------------------------------------------------------------
    def heartbeat(self, worker_id: int, step_time: Optional[float] = None):
        w = self.workers[worker_id]
        w.last_heartbeat = time.monotonic()
        w.missed = 0
        if step_time is not None:
            w.step_ewma = (
                step_time
                if w.step_ewma is None
                else self.ewma * w.step_ewma + (1 - self.ewma) * step_time
            )

    def tick(self) -> None:
        """Periodic scan: mark missed heartbeats."""
        now = time.monotonic()
        for w in self.workers.values():
            if not w.alive:
                continue
            if now - w.last_heartbeat > self.heartbeat_interval:
                w.missed += 1
                w.last_heartbeat = now

    # -- decisions -----------------------------------------------------------------
    def dead_workers(self) -> List[int]:
        out = []
        for w in self.workers.values():
            if w.alive and w.missed >= self.miss_threshold:
                w.alive = False
                self.log.append(f"worker {w.worker_id} declared DEAD")
                out.append(w.worker_id)
        return out

    def stragglers(self) -> List[int]:
        times = [
            w.step_ewma for w in self.workers.values() if w.alive and w.step_ewma
        ]
        if len(times) < 2:
            return []
        med = float(np.median(times))
        out = []
        for w in self.workers.values():
            if not w.alive or w.step_ewma is None:
                continue
            if w.step_ewma > self.straggler_factor * med:
                w.slow_streak += 1
            else:
                w.slow_streak = 0
            if w.slow_streak >= self.patience:
                w.alive = False
                self.log.append(
                    f"worker {w.worker_id} evicted as STRAGGLER "
                    f"({w.step_ewma:.3f}s vs median {med:.3f}s)"
                )
                out.append(w.worker_id)
        return out

    def alive_workers(self) -> List[int]:
        return [w.worker_id for w in self.workers.values() if w.alive]

    # -- fault injection --------------------------------------------------------
    def apply_plan(self, plan: FaultPlan, step: int) -> bool:
        """Apply simulated events; True if membership changed."""
        changed = False
        for e in plan.at(step):
            w = self.workers[e.worker_id]
            if e.kind == "fail":
                w.missed = self.miss_threshold
                changed |= bool(self.dead_workers())
            elif e.kind == "straggle":
                w.step_ewma = (w.step_ewma or 1.0) * e.factor
                w.slow_streak = self.patience
                changed |= bool(self.stragglers())
            elif e.kind == "recover":
                w.alive = True
                w.missed = 0
                w.slow_streak = 0
                self.log.append(f"worker {e.worker_id} rejoined")
                changed = True
        return changed


def elastic_batch_split(global_batch: int, alive: int, total: int) -> int:
    """Per-step global batch after losing workers: keep per-worker batch
    constant (reduce global batch) — the standard elastic-DP policy that
    avoids OOM on survivors; the LR is rescaled linearly by the caller."""
    per = global_batch // total
    return per * alive
