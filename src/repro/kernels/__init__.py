"""Pallas kernels for metadata-only NDV estimation.

Architecture sketch — how an estimate call reaches silicon::

    estimate_batch (core/ndv/estimator.py)
      |  ops.use_fused(fuse)?           fuse: "auto" | "on" | "off"
      |-- yes -> ops.fused_estimate ----+-- TPU / backend="pallas" pin:
      |                                 |     fused_estimate.py — ONE
      |                                 |     pallas_call running the whole
      |                                 |     detector + SS4 dict + SS5 coupon
      |                                 |     pipeline on packed (B, R) tiles
      |                                 +-- otherwise: ref.ref_fused_estimate,
      |                                       the pure-XLA twin — literally
      |                                       estimate_batch_core(backend="ref"),
      |                                       so fuse on/off is bit-identical
      |                                       by construction off-TPU
      +-- no  -> estimate_batch_core, which dispatches per stage through
            ops.dict_newton / ops.coupon_newton (newton_ndv.py),
            ops.minmax_scan (minmax_scan.py), ops.hll_fold (hll.py),
            each resolving pallas-vs-ref via ops.use_pallas(backend)

Each kernel module is layered the same way:

  * ``*_math`` functions — the numerics (fixed-iteration Newton solves,
    masked reductions) as plain jnp on unpadded values, shared verbatim
    by the kernel bodies and testable without tiling geometry;
  * kernel bodies — the ``*_math`` functions applied inside a
    ``pallas_call`` over lane-padded tiles (BLOCK_M x LANES);
  * wrappers — jit entry points owning pad/unpad and block specs;
  * ``ref.py`` — pure-XLA oracles every kernel is swept against.

Serving contract: off-TPU, ``backend="pallas"`` runs interpret-mode
Pallas — a correctness tool, never a serving path — so production
dispatch off-TPU is always the reference program, fused or not. The
``fuse`` knob therefore changes launch structure only, never numerics,
and stays out of engine cache identity (see engine/config.py).
"""
from repro.kernels import ops  # noqa: F401
