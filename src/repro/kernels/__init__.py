"""Pallas kernels for metadata-only NDV estimation.

Architecture sketch — how an estimate call reaches silicon::

    estimate_batch (core/ndv/estimator.py)
      |  ops.use_fused(fuse)?           fuse: "auto" | "on" | "off"
      |-- yes -> ops.fused_estimate ----+-- TPU / backend="pallas" pin:
      |                                 |     fused_estimate.py — ONE
      |                                 |     pallas_call running the whole
      |                                 |     detector + SS4 dict + SS5 coupon
      |                                 |     pipeline on packed (B, R) tiles
      |                                 +-- otherwise: ref.ref_fused_estimate,
      |                                       the pure-XLA twin — literally
      |                                       estimate_batch_core(backend="ref"),
      |                                       so fuse on/off is bit-identical
      |                                       by construction off-TPU
      +-- no  -> estimate_batch_core, which dispatches per stage through
            ops.dict_newton / ops.coupon_newton (newton_ndv.py),
            ops.minmax_scan (minmax_scan.py), ops.hll_fold (hll.py),
            each resolving pallas-vs-ref via ops.use_pallas(backend)

Each kernel module is layered the same way:

  * ``*_math`` functions — the numerics (fixed-iteration Newton solves,
    masked reductions) as plain jnp on unpadded values, shared verbatim
    by the kernel bodies and testable without tiling geometry;
  * kernel bodies — the ``*_math`` functions applied inside a
    ``pallas_call`` over lane-padded tiles (BLOCK_M x LANES);
  * wrappers — jit entry points owning pad/unpad and block specs;
  * ``ref.py`` — pure-XLA oracles every kernel is swept against.

Serving contract: off-TPU, ``backend="pallas"`` runs interpret-mode
Pallas — a correctness tool, never a serving path — so production
dispatch off-TPU is always the reference program, fused or not. The
``fuse`` knob therefore changes launch structure only, never numerics,
and stays out of engine cache identity (see engine/config.py).

Provenance lanes: both the megakernel and the unfused pipeline emit
per-column diagnostics as extra output lanes of the SAME program —
route chosen + decision margin, detector margin, Newton iteration
counts and final dict residual, clamp flags (see fused_estimate.py
``_OUT_ROUTE..._OUT_CLAMP_FLAGS``). Because they are outputs of the
shared numerics rather than a side channel, fused and ref twins agree
on them bit-for-bit off-TPU, the strategy x device parity matrix pins
them across serving topologies, and they can never perturb estimates
or cache identity. The service tier surfaces them as `Provenance`
records (?explain=1, /debug/explain) and the sketch auditor scores
them against an hll.py reference — see repro.obs for the metrics side.
"""
from repro.kernels import ops  # noqa: F401
