"""Pallas TPU megakernel: the whole §4-§7 estimation pipeline in one dispatch.

The separate kernel path costs 4 `pallas_call` launches per estimate — the
§6 detector scan, the §4 dict Newton, and two §5 coupon Newtons — plus the
XLA glue (masked aggregations, Eq 13-15 combine) between them, with every
intermediate bouncing through HBM. Serving-shaped workloads (one catalog
lookup per query-optimizer probe) are launch-bound, not FLOP-bound, so this
kernel runs the entire pipeline per (BLOCK_B, R) column tile in one launch:
detector metrics, both Newton inversions, and the branchless
`jnp.where`-select of Eq 13 on the detector verdict, all on VMEM-resident
tiles.

Numerics contract (what lets `EngineConfig.fuse` stay out of
`cache_key`/`cache_token`): the body does not reimplement anything — it
reconstructs a tile-shaped `ColumnBatch` from its refs and calls
`estimate_batch_core(..., backend="ref")`, i.e. the REFERENCE pipeline, the
same function the unfused production path runs. The dispatch layer
(`repro.kernels.ops.fused_estimate`) compiles this kernel only where the
kernel path is the production path (TPU, or an explicit ``backend="pallas"``
pin); everywhere else it routes to the pure-XLA twin
(`repro.kernels.ref.ref_fused_estimate`) — which is *the same program* as
the unfused path, so fuse=on vs fuse=off is bit-identical by construction
there, not by hoping two compilations of the same ops agree. (They don't:
measured on CPU, wrapping identical math in an interpret-mode `pallas_call`
flips last-ulp bits in transcendental tails — codegen context changes
fusion/FMA decisions. That is the normal kernel-vs-oracle gap every kernel
in this repo carries, and the interpret path is validated against the twin
the same way: tight allclose plus exact discrete fields.)

I/O layout: seven (B, R) float32 planes (bools as 0/1, reconstructed with
`> 0.5`), per-column scalars packed into one (B, LANES) float32 array, and
one (B, LANES) float32 output with results in the leading lanes. Lane
packing follows `minmax_scan`: every scalar is either an exact small int, a
0/1 flag, or already float32, so the trip through lanes is exact.

The whole batch is ONE block (grid=(1,)), B and R both carried whole — no
in-kernel re-tiling. Bounding B per dispatch is the ENGINE's job: the
chunked/composed strategies already stream `max_batch`-wide slices, so each
fused launch sees an engine-bounded block (size that budget to VMEM when
compiling for real TPUs).

These kernels target TPU; in this container they are validated with
``interpret=True`` against `repro.kernels.ref.ref_fused_estimate` (the same
core called outside any kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128    # TPU vector lane count

# Scalar input lanes.
_IN_N = 0
_IN_NULLS = 1
_IN_NGROUPS = 2
_IN_M_MIN = 3
_IN_M_MAX = 4
_IN_MEAN_LEN = 5
_IN_LEN_SAMPLE = 6
_IN_FIXED_WIDTH = 7
_IN_INT_LIKE = 8
_IN_SINGLE_BYTE = 9
_IN_SCHEMA_BOUND = 10

# Output lanes. Lanes 9-14 carry the per-lane provenance diagnostics —
# same exact-through-lanes properties (small ints, flags, float32) as the
# estimate lanes, so fused provenance is bit-identical to the twin's.
_OUT_NDV = 0
_OUT_NDV_DICT = 1
_OUT_NDV_MINMAX = 2
_OUT_LAYOUT = 3
_OUT_LOWER_BOUND = 4
_OUT_CONFIDENCE = 5
_OUT_OVERLAP = 6
_OUT_MONOTONICITY = 7
_OUT_DICT_ITERS = 8
_OUT_ROUTE = 9
_OUT_ROUTE_MARGIN = 10
_OUT_DETECTOR_MARGIN = 11
_OUT_DICT_RESIDUAL = 12
_OUT_COUPON_ITERS = 13
_OUT_CLAMP_FLAGS = 14


def _fused_body(
    mode,
    s_ref,
    rows_ref,
    nulls_ref,
    dict_ref,
    mins_ref,
    maxs_ref,
    valid_ref,
    scal_ref,
    out_ref,
):
    # Local imports: this module is imported by repro.kernels.ops, which the
    # estimator stack imports lazily — importing the stack at module scope
    # here would close the cycle.
    from repro.core.ndv.estimator import estimate_batch_core
    from repro.core.ndv.types import ColumnBatch

    scal = scal_ref[...]
    tile = ColumnBatch(
        chunk_S=s_ref[...],
        chunk_rows=rows_ref[...],
        chunk_nulls=nulls_ref[...],
        chunk_dict_encoded=dict_ref[...] > 0.5,
        N=scal[:, _IN_N],
        nulls=scal[:, _IN_NULLS],
        n_groups=scal[:, _IN_NGROUPS].astype(jnp.int32),
        mins=mins_ref[...],
        maxs=maxs_ref[...],
        valid=valid_ref[...] > 0.5,
        m_min=scal[:, _IN_M_MIN],
        m_max=scal[:, _IN_M_MAX],
        mean_len=scal[:, _IN_MEAN_LEN],
        len_sample=scal[:, _IN_LEN_SAMPLE].astype(jnp.int32),
        fixed_width=scal[:, _IN_FIXED_WIDTH] > 0.5,
        int_like=scal[:, _IN_INT_LIKE] > 0.5,
        single_byte=scal[:, _IN_SINGLE_BYTE] > 0.5,
    )
    est = estimate_batch_core(
        tile, scal[:, _IN_SCHEMA_BOUND], mode=mode, backend="ref"
    )

    out = jnp.zeros((scal.shape[0], LANES), jnp.float32)
    out = out.at[:, _OUT_NDV].set(est.ndv)
    out = out.at[:, _OUT_NDV_DICT].set(est.ndv_dict)
    out = out.at[:, _OUT_NDV_MINMAX].set(est.ndv_minmax)
    out = out.at[:, _OUT_LAYOUT].set(est.layout.astype(jnp.float32))
    out = out.at[:, _OUT_LOWER_BOUND].set(
        est.is_lower_bound.astype(jnp.float32)
    )
    out = out.at[:, _OUT_CONFIDENCE].set(est.confidence)
    out = out.at[:, _OUT_OVERLAP].set(est.overlap_ratio)
    out = out.at[:, _OUT_MONOTONICITY].set(est.monotonicity)
    out = out.at[:, _OUT_DICT_ITERS].set(
        est.dict_iterations.astype(jnp.float32)
    )
    out = out.at[:, _OUT_ROUTE].set(est.route.astype(jnp.float32))
    out = out.at[:, _OUT_ROUTE_MARGIN].set(est.route_margin)
    out = out.at[:, _OUT_DETECTOR_MARGIN].set(est.detector_margin)
    out = out.at[:, _OUT_DICT_RESIDUAL].set(est.dict_residual)
    out = out.at[:, _OUT_COUPON_ITERS].set(
        est.coupon_iterations.astype(jnp.float32)
    )
    out = out.at[:, _OUT_CLAMP_FLAGS].set(
        est.clamp_flags.astype(jnp.float32)
    )
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def fused_estimate(batch, schema_bound=None, *, mode: str = "paper",
                   interpret: bool = True):
    """One-dispatch §4-§7 estimation over a packed `ColumnBatch`.

    Computes the reference pipeline
    (`estimate_batch_core(batch, schema_bound, mode=mode, backend="ref")`)
    inside one `pallas_call`; agreement with that oracle is exact on
    discrete fields and last-ulp-tight on floats (kernel-vs-oracle codegen
    gap, see module docstring). ``schema_bound=None`` materializes as +inf —
    `min(ndv, +inf)` is the identity bit-for-bit, the same trick the
    sharded engine path uses to keep one kernel signature.
    """
    from repro.core.ndv.estimator import BatchEstimates

    b, r = batch.chunk_S.shape
    plane = lambda x: x.astype(jnp.float32)  # noqa: E731

    if schema_bound is None:
        sb = jnp.full((b,), jnp.inf, jnp.float32)
    else:
        sb = schema_bound.astype(jnp.float32)

    scal = jnp.zeros((b, LANES), jnp.float32)
    lane = lambda i, x: scal.at[:, i].set(x.astype(jnp.float32))  # noqa: E731
    scal = lane(_IN_N, batch.N)
    scal = lane(_IN_NULLS, batch.nulls)
    scal = lane(_IN_NGROUPS, batch.n_groups)
    scal = lane(_IN_M_MIN, batch.m_min)
    scal = lane(_IN_M_MAX, batch.m_max)
    scal = lane(_IN_MEAN_LEN, batch.mean_len)
    scal = lane(_IN_LEN_SAMPLE, batch.len_sample)
    scal = lane(_IN_FIXED_WIDTH, batch.fixed_width)
    scal = lane(_IN_INT_LIKE, batch.int_like)
    scal = lane(_IN_SINGLE_BYTE, batch.single_byte)
    scal = scal.at[:, _IN_SCHEMA_BOUND].set(sb)

    plane_spec = pl.BlockSpec((b, r), lambda i: (0, 0))
    lane_spec = pl.BlockSpec((b, LANES), lambda i: (0, 0))
    out = pl.pallas_call(
        functools.partial(_fused_body, mode),
        out_shape=jax.ShapeDtypeStruct((b, LANES), jnp.float32),
        grid=(1,),
        in_specs=[plane_spec] * 7 + [lane_spec],
        out_specs=lane_spec,
        interpret=interpret,
    )(
        plane(batch.chunk_S),
        plane(batch.chunk_rows),
        plane(batch.chunk_nulls),
        plane(batch.chunk_dict_encoded),
        plane(batch.mins),
        plane(batch.maxs),
        plane(batch.valid),
        scal,
    )

    return BatchEstimates(
        ndv=out[:, _OUT_NDV],
        ndv_dict=out[:, _OUT_NDV_DICT],
        ndv_minmax=out[:, _OUT_NDV_MINMAX],
        layout=out[:, _OUT_LAYOUT].astype(jnp.int32),
        is_lower_bound=out[:, _OUT_LOWER_BOUND] > 0.5,
        confidence=out[:, _OUT_CONFIDENCE],
        overlap_ratio=out[:, _OUT_OVERLAP],
        monotonicity=out[:, _OUT_MONOTONICITY],
        mean_len=batch.mean_len.astype(jnp.float32),
        dict_iterations=out[:, _OUT_DICT_ITERS].astype(jnp.int32),
        route=out[:, _OUT_ROUTE].astype(jnp.int32),
        route_margin=out[:, _OUT_ROUTE_MARGIN],
        detector_margin=out[:, _OUT_DETECTOR_MARGIN],
        dict_residual=out[:, _OUT_DICT_RESIDUAL],
        coupon_iterations=out[:, _OUT_COUPON_ITERS].astype(jnp.int32),
        clamp_flags=out[:, _OUT_CLAMP_FLAGS].astype(jnp.int32),
    )
