"""Pallas TPU kernel: batched HyperLogLog register folds.

The paper (§10.2) counts distinct row-group extrema with an O(1)-space
HyperLogLog sketch. At fleet scale this is a fold over (columns x row-groups)
hash matrices into per-column register banks:

    regs[b, j] = max over r of rho(hash[b, r])  where bucket(hash[b, r]) == j

TPU has no scatter-max in the VPU, so the kernel materializes the bucket
comparison against a broadcast register iota — a (R_tile, m) one-hot-max —
and reduces over the row-group axis. With p <= 8 (m = 256 registers,
sigma ~ 1.04/sqrt(256) = 6.5%) and R_tile = 128, the intermediate is
(128, 256) f32 = 128 KiB — VMEM-friendly. The grid walks (column blocks,
row-group blocks) with the row-group axis innermost ("arbitrary" semantics)
accumulating max into the output block, which Pallas keeps resident in VMEM
across the inner grid steps (same output block index).

Hashing itself (splitmix / murmur finalizers) is elementwise uint32 work
done in the kernel from the raw 32-bit keys, so HBM traffic is 4 B/lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 8        # columns per grid step
BLOCK_R = 128      # row groups per inner grid step
DEFAULT_P = 8      # 2^p registers


def _murmur32(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _clz32(x: jnp.ndarray) -> jnp.ndarray:
    n = jnp.full(x.shape, 32, jnp.int32)
    c = jnp.zeros(x.shape, jnp.int32)
    for shift in (16, 8, 4, 2, 1):
        y = x >> shift
        move = y != 0
        c = jnp.where(move, c + shift, c)
        x = jnp.where(move, y, x)
    return jnp.where(x != 0, 31 - c, n).astype(jnp.int32)


def _hll_body(keys_ref, valid_ref, regs_ref, *, p: int):
    r_step = pl.program_id(1)
    m = 1 << p
    nbits = 32 - p

    keys = keys_ref[...].astype(jnp.uint32)          # (BLOCK_B, BLOCK_R)
    valid = valid_ref[...] > 0.5
    h = _murmur32(keys)
    idx = (h >> (32 - p)).astype(jnp.int32)          # bucket
    rest = (h << p).astype(jnp.uint32)
    rho = jnp.minimum(_clz32(rest) + 1, nbits + 1)
    rho = jnp.where(valid, rho, 0)

    # one-hot max: (BLOCK_B, BLOCK_R, m) -> max over R
    buckets = jax.lax.broadcasted_iota(jnp.int32, (1, 1, m), 2)
    onehot = jnp.where(idx[:, :, None] == buckets, rho[:, :, None], 0)
    tile_regs = jnp.max(onehot, axis=1).astype(jnp.float32)  # (BLOCK_B, m)

    @pl.when(r_step == 0)
    def _init():
        regs_ref[...] = tile_regs

    @pl.when(r_step != 0)
    def _acc():
        regs_ref[...] = jnp.maximum(regs_ref[...], tile_regs)


@functools.partial(jax.jit, static_argnames=("p", "interpret"))
def hll_fold(
    keys: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    p: int = DEFAULT_P,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fold (B, R) uint32 keys into (B, 2^p) HLL registers (float32 ranks)."""
    b, r = keys.shape
    m = 1 << p
    pb = (b + BLOCK_B - 1) // BLOCK_B * BLOCK_B
    pr = (r + BLOCK_R - 1) // BLOCK_R * BLOCK_R
    keys2 = jnp.pad(keys.astype(jnp.uint32), ((0, pb - b), (0, pr - r)))
    valid2 = jnp.pad(
        valid.astype(jnp.float32), ((0, pb - b), (0, pr - r)), constant_values=0.0
    )
    grid = (pb // BLOCK_B, pr // BLOCK_R)
    out = pl.pallas_call(
        functools.partial(_hll_body, p=p),
        out_shape=jax.ShapeDtypeStruct((pb, m), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, BLOCK_R), lambda i, j: (i, j)),
            pl.BlockSpec((BLOCK_B, BLOCK_R), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, m), lambda i, j: (i, 0)),
        interpret=interpret,
    )(keys2, valid2)
    return out[:b]


def hll_count(registers: jnp.ndarray) -> jnp.ndarray:
    """Register banks (B, m) -> cardinality estimates (B,)."""
    m = registers.shape[-1]
    alpha = 0.7213 / (1.0 + 1.079 / m) if m >= 128 else {16: 0.673, 32: 0.697, 64: 0.709}.get(m, 0.7213 / (1.0 + 1.079 / m))
    inv_sum = jnp.sum(2.0 ** (-registers.astype(jnp.float32)), axis=-1)
    raw = alpha * m * m / inv_sum
    zeros = jnp.sum(registers == 0, axis=-1)
    lc = m * jnp.log(m / jnp.maximum(zeros.astype(jnp.float32), 1e-9))
    small = (raw <= 2.5 * m) & (zeros > 0)
    return jnp.where(small, lc, raw)
