"""Pallas TPU kernel: distribution-detector metrics over row-group stats.

Computes, for a tile of columns at once, the paper's §6 metrics from the
(B, R) min/max statistic matrices:

  lane 0: overlap_sum   = sum_i max(0, min(max_i,max_{i+1}) - max(min_i,min_{i+1}))
  lane 1: gmin          = global min
  lane 2: gmax          = global max
  lane 3: sign_changes  = # midpoint-delta sign flips
  lane 4: n_valid       = row-group count
  lane 5: shared_bounds = # boundaries with max_i == min_{i+1}  (improved mode)

Tiling: one grid step owns a (BLOCK_B, R) block of mins/maxs/valid — the
row-group axis is kept whole per step (R <= 4096 keeps the working set
~3 * BLOCK_B * R * 4 B = 1.5 MiB at BLOCK_B=32, well inside VMEM) so all
consecutive-pair terms stay tile-local and no cross-step carry is needed.
Output is a (BLOCK_B, 128) tile with metrics packed in the first lanes
(lane-padded to the TPU vector width).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 32
LANES = 128
BIG = 3.0e38


class MinMaxMetrics(NamedTuple):
    overlap_sum: jnp.ndarray
    gmin: jnp.ndarray
    gmax: jnp.ndarray
    sign_changes: jnp.ndarray
    n_valid: jnp.ndarray
    shared_bounds: jnp.ndarray


def lane_padded_groups(r: int) -> int:
    """The row-group axis padded to the vector lane width.

    This is the kernel's reduction extent — reduction extent is part of the
    numerics, so it is named here rather than inlined at the pad site.
    """
    return max((r + LANES - 1) // LANES * LANES, LANES)


def minmax_metrics_math(
    mins: jnp.ndarray, maxs: jnp.ndarray, valid: jnp.ndarray
) -> MinMaxMetrics:
    """The §6 metric reductions over a (b, r) tile (``valid`` is bool).

    Factored out of the pallas_call plumbing so the metric math is testable
    independent of tiling; the kernel body packs these reductions into the
    lane-aligned output tile.
    """
    n = jnp.sum(valid.astype(jnp.float32), axis=1)
    gmin = jnp.min(jnp.where(valid, mins, BIG), axis=1)
    gmax = jnp.max(jnp.where(valid, maxs, -BIG), axis=1)

    pv = valid[:, :-1] & valid[:, 1:]
    lo = jnp.maximum(mins[:, :-1], mins[:, 1:])
    hi = jnp.minimum(maxs[:, :-1], maxs[:, 1:])
    overlap = jnp.sum(jnp.where(pv, jnp.maximum(hi - lo, 0.0), 0.0), axis=1)

    mid = (mins + maxs) * 0.5
    d = jnp.where(pv, mid[:, 1:] - mid[:, :-1], 0.0)
    sgn = jnp.sign(d)
    sv = pv[:, :-1] & pv[:, 1:]
    changes = jnp.sum(
        jnp.where(sv & (sgn[:, :-1] * sgn[:, 1:] < 0), 1.0, 0.0), axis=1
    )

    shared = jnp.sum(
        jnp.where(pv & (maxs[:, :-1] == mins[:, 1:]), 1.0, 0.0), axis=1
    )
    return MinMaxMetrics(
        overlap_sum=overlap,
        gmin=gmin,
        gmax=gmax,
        sign_changes=changes,
        n_valid=n,
        shared_bounds=shared,
    )


def _minmax_body(mins_ref, maxs_ref, valid_ref, out_ref):
    mins = mins_ref[...]
    maxs = maxs_ref[...]
    m = minmax_metrics_math(mins, maxs, valid_ref[...] > 0.5)
    overlap, gmin, gmax = m.overlap_sum, m.gmin, m.gmax
    changes, n, shared = m.sign_changes, m.n_valid, m.shared_bounds

    block_b = mins.shape[0]
    out = jnp.zeros((block_b, LANES), jnp.float32)
    out = out.at[:, 0].set(overlap)
    out = out.at[:, 1].set(gmin)
    out = out.at[:, 2].set(gmax)
    out = out.at[:, 3].set(changes)
    out = out.at[:, 4].set(n)
    out = out.at[:, 5].set(shared)
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("interpret",))
def minmax_scan(
    mins: jnp.ndarray,
    maxs: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    interpret: bool = True,
) -> MinMaxMetrics:
    """Detector metrics for (B, R) row-group stats. Returns (B,) metrics."""
    b, r = mins.shape
    pb = (b + BLOCK_B - 1) // BLOCK_B * BLOCK_B
    # Pad R to the lane width so the tile is vector-aligned.
    pr = lane_padded_groups(r)
    pad = lambda x, fill: jnp.pad(  # noqa: E731
        x.astype(jnp.float32), ((0, pb - b), (0, pr - r)), constant_values=fill
    )
    mins2 = pad(mins, 0.0)
    maxs2 = pad(maxs, 0.0)
    valid2 = pad(valid.astype(jnp.float32), 0.0)

    in_spec = pl.BlockSpec((BLOCK_B, pr), lambda i: (i, 0))
    out_spec = pl.BlockSpec((BLOCK_B, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        _minmax_body,
        out_shape=jax.ShapeDtypeStruct((pb, LANES), jnp.float32),
        grid=(pb // BLOCK_B,),
        in_specs=[in_spec, in_spec, in_spec],
        out_specs=out_spec,
        interpret=interpret,
    )(mins2, maxs2, valid2)
    out = out[:b]
    return MinMaxMetrics(
        overlap_sum=out[:, 0],
        gmin=out[:, 1],
        gmax=out[:, 2],
        sign_changes=out[:, 3],
        n_valid=out[:, 4],
        shared_bounds=out[:, 5],
    )
