"""Pallas TPU kernel: batched Newton-Raphson NDV solves.

Fleet-scale planning runs the paper's two inversions over MILLIONS of column
chunks in one pass (one lane per chunk). The solves are fixed-iteration and
branch-free, which maps perfectly onto the TPU VPU's (8, 128) vector tiles:

  * ``dict_newton``   — invert  S = ndv*len + rows*ceil(log2 ndv)/8   (Eq 2)
  * ``coupon_newton`` — invert  m = D*(1 - exp(-n/D))  in log-space   (Eq 8)

Tiling: inputs are flat (M,) float32 arrays padded to BLOCK_M*128; each grid
step processes a (BLOCK_M, 128) VMEM tile (4 input tiles + 1 output tile
= 5 * BLOCK_M * 512 bytes; BLOCK_M=64 -> 160 KiB working set, far below
VMEM). No MXU involvement — pure VPU transcendental/elementwise work, so the
roofline term that matters is HBM streaming: 16 B/lane in, 4 B/lane out at
~20 flops*iters/lane.

These kernels target TPU; in this container they are validated with
``interpret=True`` against ``repro.kernels.ref`` oracles (see tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DICT_ITERS = 16
COUPON_ITERS = 40  # matches repro.core.ndv.minmax_diversity.NEWTON_ITERS
LN2 = 0.6931471805599453

BLOCK_M = 64      # sublane-tile rows per grid step
LANES = 128       # TPU vector lane count


# ---------------------------------------------------------------------------
# Solve math (shared by the standalone kernel bodies and the fused megakernel)
# ---------------------------------------------------------------------------
#
# The `*_math` functions are the kernels' numerics, factored out of the
# pallas_call plumbing: pure elementwise array -> array, shape-polymorphic,
# so the fixed-iteration solves are testable (and reusable) independent of
# tiling and padding geometry.


def _ceil_log2(x):
    return jnp.maximum(jnp.ceil(jnp.log2(jnp.maximum(x, 1.0)) - 1e-9), 1.0)


def dict_newton_math(s, rows, nulls, mean_len):
    """Eq-2 fixed-iteration Newton inversion, elementwise over any shape."""
    non_null = jnp.maximum(rows - nulls, 0.0)
    mean_len = jnp.maximum(mean_len, 1e-6)
    cap = jnp.maximum(non_null, 1.0)

    ndv = jnp.clip(s / mean_len, 1.0, cap)
    for _ in range(DICT_ITERS):
        f = ndv * mean_len + non_null * _ceil_log2(ndv) / 8.0 - s
        fp = mean_len + non_null / (8.0 * jnp.maximum(ndv, 1.0) * LN2)
        ndv = jnp.clip(ndv - f / fp, 1.0, cap)
    # Plateau snap: solve the linear piece at the converged bit width.
    bits = _ceil_log2(ndv)
    lin = (s - non_null * bits / 8.0) / mean_len
    keep = (_ceil_log2(jnp.maximum(lin, 1.0)) == bits) & (lin >= 1.0)
    return jnp.clip(jnp.where(keep, lin, ndv), 1.0, cap)


def coupon_newton_math(m, n):
    """Eq-8 fixed-iteration log-space Newton inversion, elementwise."""
    saturated = m >= n - 0.5
    m_eff = jnp.where(saturated, jnp.maximum(n - 0.5, 0.5), m)
    m_eff = jnp.clip(m_eff, 0.5, jnp.maximum(n - 1e-3, 0.5))

    t = jnp.log(jnp.clip(n * n / (2.0 * jnp.maximum(n - m_eff, 1e-3)), 1.0, 1e12))
    for _ in range(COUPON_ITERS):
        ndv = jnp.exp(t)
        r = n / jnp.maximum(ndv, 1e-9)
        em1 = -jnp.expm1(-r)           # 1 - e^{-r}
        g = ndv * em1 - m_eff
        gp = em1 - jnp.exp(-r) * r     # g'(D)
        t = jnp.clip(t - g / jnp.maximum(gp * ndv, 1e-12), 0.0, 28.0)
    ndv = jnp.exp(t)
    # saturated (m == n): the MLE diverges — report the observable m
    # (a hard lower bound), matching repro.core.ndv.minmax_diversity.
    ndv = jnp.where(saturated, jnp.maximum(m, 1.0), ndv)
    ndv = jnp.where(n <= 0, 1.0, ndv)
    ndv = jnp.where(m_eff <= 0.5001, jnp.maximum(m, 1.0), ndv)
    return jnp.maximum(ndv, jnp.maximum(m, 1.0))


# ---------------------------------------------------------------------------
# Kernel bodies (operate on (BLOCK_M, 128) tiles)
# ---------------------------------------------------------------------------


def _dict_newton_body(s_ref, rows_ref, nulls_ref, len_ref, out_ref):
    out_ref[...] = dict_newton_math(
        s_ref[...], rows_ref[...], nulls_ref[...], len_ref[...]
    )


def _coupon_newton_body(m_ref, n_ref, out_ref):
    out_ref[...] = coupon_newton_math(m_ref[...], n_ref[...])


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1024)
def _tile_geometry(m: int) -> tuple[int, int]:
    """(padded length, tile-row count) for a flat (m,) input.

    Pure shape math, memoized per length: `_pad_to_tiles` runs inside every
    traced call of the kernel wrappers, and the fleet path re-pads the same
    handful of bucketed shapes millions of times.
    """
    per = BLOCK_M * LANES
    padded = (m + per - 1) // per * per
    return padded, padded // LANES


def _pad_to_tiles(x: jnp.ndarray, fill: float) -> tuple[jnp.ndarray, int]:
    m = x.shape[0]
    padded, rows = _tile_geometry(m)
    x = jnp.pad(x, (0, padded - m), constant_values=fill)
    return x.reshape(rows, LANES), m


@functools.partial(jax.jit, static_argnames=("interpret",))
def dict_newton(
    size: jnp.ndarray,
    rows: jnp.ndarray,
    nulls: jnp.ndarray,
    mean_len: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched Eq-2 inversion. Flat (M,) float32 in, (M,) ndv out."""
    s2, m = _pad_to_tiles(size.astype(jnp.float32), 1.0)
    r2, _ = _pad_to_tiles(rows.astype(jnp.float32), 1.0)
    n2, _ = _pad_to_tiles(nulls.astype(jnp.float32), 0.0)
    l2, _ = _pad_to_tiles(mean_len.astype(jnp.float32), 1.0)
    rows_tiles = s2.shape[0] // BLOCK_M
    spec = pl.BlockSpec((BLOCK_M, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        _dict_newton_body,
        out_shape=jax.ShapeDtypeStruct(s2.shape, jnp.float32),
        grid=(rows_tiles,),
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        interpret=interpret,
    )(s2, r2, n2, l2)
    return out.reshape(-1)[:m]


@functools.partial(jax.jit, static_argnames=("interpret",))
def coupon_newton(
    m_obs: jnp.ndarray,
    n_draws: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched Eq-8 inversion. Flat (M,) float32 in, (M,) NDV out."""
    m2, m = _pad_to_tiles(m_obs.astype(jnp.float32), 1.0)
    n2, _ = _pad_to_tiles(n_draws.astype(jnp.float32), 2.0)
    rows_tiles = m2.shape[0] // BLOCK_M
    spec = pl.BlockSpec((BLOCK_M, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        _coupon_newton_body,
        out_shape=jax.ShapeDtypeStruct(m2.shape, jnp.float32),
        grid=(rows_tiles,),
        in_specs=[spec, spec],
        out_specs=spec,
        interpret=interpret,
    )(m2, n2)
    return out.reshape(-1)[:m]
