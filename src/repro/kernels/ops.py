"""Public jit'd entry points for the NDV kernels.

Dispatch policy: on TPU the Pallas kernels run compiled; elsewhere they run
in ``interpret=True`` mode (bit-faithful kernel-body execution on CPU). The
``backend`` argument forces either path or the pure-jnp reference
(``"ref"``) — benchmarks use that to measure kernel-vs-XLA deltas.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import hll as _hll
from repro.kernels import minmax_scan as _mm
from repro.kernels import newton_ndv as _newton
from repro.kernels import ref as _ref

Backend = Literal["auto", "pallas", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def use_pallas(backend: Backend) -> bool:
    """Resolve an estimator-level backend request to a kernel-path decision.

    "pallas" always takes the kernels (interpreted off-TPU — bit-faithful
    but slow, a correctness knob). "ref" never does. "auto" takes them only
    where they are the fast path (compiled on TPU); elsewhere the jnp
    reference IS the production path, so "auto" resolves to it.
    """
    if backend == "pallas":
        return True
    if backend == "ref":
        return False
    return _on_tpu()


def dict_newton(size, rows, nulls, mean_len, *, backend: Backend = "auto"):
    """Batched Eq-2 dictionary-size inversion (flat float32 arrays)."""
    if backend == "ref":
        return _ref.ref_dict_newton(size, rows, nulls, mean_len)
    return _newton.dict_newton(
        size, rows, nulls, mean_len, interpret=_interpret()
    )


def coupon_newton(m_obs, n_draws, *, backend: Backend = "auto"):
    """Batched Eq-8 coupon-collector inversion (flat float32 arrays)."""
    if backend == "ref":
        return _ref.ref_coupon_newton(m_obs, n_draws)
    return _newton.coupon_newton(m_obs, n_draws, interpret=_interpret())


def minmax_scan(mins, maxs, valid, *, backend: Backend = "auto"):
    """Detector metric reductions over (B, R) row-group statistics."""
    if backend == "ref":
        return _ref.ref_minmax_scan(mins, maxs, valid)
    return _mm.minmax_scan(mins, maxs, valid, interpret=_interpret())


def hll_fold(keys, valid, *, p: int = 8, backend: Backend = "auto"):
    """HLL register fold over (B, R) uint32 keys -> (B, 2^p) registers."""
    if backend == "ref":
        return _ref.ref_hll_fold(keys, valid, p=p)
    return _hll.hll_fold(keys, valid, p=p, interpret=_interpret())


def hll_count(registers):
    """Register banks -> cardinality estimates."""
    return _hll.hll_count(registers)
