"""Public jit'd entry points for the NDV kernels.

Dispatch policy: on TPU the Pallas kernels run compiled; elsewhere they run
in ``interpret=True`` mode (bit-faithful kernel-body execution on CPU). The
``backend`` argument forces either path or the pure-jnp reference
(``"ref"``) — benchmarks use that to measure kernel-vs-XLA deltas.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import fused_estimate as _fused
from repro.kernels import hll as _hll
from repro.kernels import minmax_scan as _mm
from repro.kernels import newton_ndv as _newton
from repro.kernels import ref as _ref

Backend = Literal["auto", "pallas", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def use_pallas(backend: Backend) -> bool:
    """Resolve an estimator-level backend request to a kernel-path decision.

    "pallas" always takes the kernels (interpreted off-TPU — bit-faithful
    but slow, a correctness knob). "ref" never does. "auto" takes them only
    where they are the fast path (compiled on TPU); elsewhere the jnp
    reference IS the production path, so "auto" resolves to it.
    """
    if backend == "pallas":
        return True
    if backend == "ref":
        return False
    return _on_tpu()


def use_fused(fuse: str) -> bool:
    """Resolve the `EngineConfig.fuse` knob to a fused-pipeline decision.

    "on" always takes the fused pipeline; "off" never does; "auto" takes it
    exactly where fusing buys anything — on TPU, where the separate path
    costs 3-4 kernel launches plus XLA glue per estimate. The fused pipeline
    computes the REFERENCE numerics (`fused_estimate`'s body runs
    `estimate_batch_core(..., backend="ref")`), and `fused_estimate` below
    only compiles the kernel where the kernel path is the production path —
    elsewhere the pure-XLA twin runs, which is the same program as the
    unfused reference path. That is why the knob is numerics-neutral and
    never enters `cache_key`/`cache_token`.
    """
    if fuse == "off":
        return False
    if fuse == "on":
        return True
    if fuse != "auto":
        raise ValueError(f'fuse must be "auto", "on", or "off", got {fuse!r}')
    return _on_tpu()


def fused_estimate(batch, schema_bound=None, *, mode: str = "paper",
                   backend: Backend = "auto"):
    """One-dispatch §4-§7 pipeline over a packed ColumnBatch (megakernel).

    Backend resolution mirrors `use_pallas`: the Pallas megakernel runs
    where the kernel path is production (compiled on TPU) or explicitly
    pinned (``backend="pallas"``, interpreted off-TPU — the validation
    configuration). Otherwise the pure-jnp twin (`ref.ref_fused_estimate`)
    serves — bit-identical to the unfused reference path by construction.
    """
    if use_pallas(backend):
        return _fused.fused_estimate(
            batch, schema_bound, mode=mode, interpret=_interpret()
        )
    return _ref.ref_fused_estimate(batch, schema_bound, mode=mode)


def dict_newton(size, rows, nulls, mean_len, *, backend: Backend = "auto"):
    """Batched Eq-2 dictionary-size inversion (flat float32 arrays)."""
    if backend == "ref":
        return _ref.ref_dict_newton(size, rows, nulls, mean_len)
    return _newton.dict_newton(
        size, rows, nulls, mean_len, interpret=_interpret()
    )


def coupon_newton(m_obs, n_draws, *, backend: Backend = "auto"):
    """Batched Eq-8 coupon-collector inversion (flat float32 arrays)."""
    if backend == "ref":
        return _ref.ref_coupon_newton(m_obs, n_draws)
    return _newton.coupon_newton(m_obs, n_draws, interpret=_interpret())


def minmax_scan(mins, maxs, valid, *, backend: Backend = "auto"):
    """Detector metric reductions over (B, R) row-group statistics."""
    if backend == "ref":
        return _ref.ref_minmax_scan(mins, maxs, valid)
    return _mm.minmax_scan(mins, maxs, valid, interpret=_interpret())


def hll_fold(keys, valid, *, p: int = 8, backend: Backend = "auto"):
    """HLL register fold over (B, R) uint32 keys -> (B, 2^p) registers."""
    if backend == "ref":
        return _ref.ref_hll_fold(keys, valid, p=p)
    return _hll.hll_fold(keys, valid, p=p, interpret=_interpret())


def hll_count(registers):
    """Register banks -> cardinality estimates."""
    return _hll.hll_count(registers)
