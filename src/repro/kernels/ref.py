"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``ref_*`` mirrors its kernel's numerics exactly (same iteration counts,
same clamps) so tests can assert_allclose with tight tolerances across shape
and dtype sweeps.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.ndv import dict_inversion, minmax_diversity
from repro.kernels import hll as hll_kernel


def ref_dict_newton(
    size: jnp.ndarray,
    rows: jnp.ndarray,
    nulls: jnp.ndarray,
    mean_len: jnp.ndarray,
) -> jnp.ndarray:
    """Oracle for newton_ndv.dict_newton (flat arrays)."""
    # backend="ref" pins the pure-jnp solve: with the default "auto" the
    # inversion would route back through the Pallas kernel on TPU and the
    # oracle would compare the kernel against itself.
    return dict_inversion.invert_dict_size(
        size, rows, nulls, mean_len, backend="ref"
    ).ndv


def ref_coupon_newton(m_obs: jnp.ndarray, n_draws: jnp.ndarray) -> jnp.ndarray:
    """Oracle for newton_ndv.coupon_newton (flat arrays)."""
    return minmax_diversity.invert_coupon(m_obs, n_draws, backend="ref").ndv


class RefMinMaxMetrics(NamedTuple):
    overlap_sum: jnp.ndarray
    gmin: jnp.ndarray
    gmax: jnp.ndarray
    sign_changes: jnp.ndarray
    n_valid: jnp.ndarray
    shared_bounds: jnp.ndarray


def ref_minmax_scan(
    mins: jnp.ndarray, maxs: jnp.ndarray, valid: jnp.ndarray
) -> RefMinMaxMetrics:
    """Oracle for minmax_scan.minmax_scan."""
    mins = jnp.asarray(mins, jnp.float32)
    maxs = jnp.asarray(maxs, jnp.float32)
    valid = jnp.asarray(valid, bool)
    big = jnp.float32(3.0e38)
    n = jnp.sum(valid, axis=1).astype(jnp.float32)
    gmin = jnp.min(jnp.where(valid, mins, big), axis=1)
    gmax = jnp.max(jnp.where(valid, maxs, -big), axis=1)
    pv = valid[:, :-1] & valid[:, 1:]
    lo = jnp.maximum(mins[:, :-1], mins[:, 1:])
    hi = jnp.minimum(maxs[:, :-1], maxs[:, 1:])
    overlap = jnp.sum(jnp.where(pv, jnp.maximum(hi - lo, 0.0), 0.0), axis=1)
    mid = (mins + maxs) * 0.5
    d = jnp.where(pv, mid[:, 1:] - mid[:, :-1], 0.0)
    sgn = jnp.sign(d)
    sv = pv[:, :-1] & pv[:, 1:]
    changes = jnp.sum(
        jnp.where(sv & (sgn[:, :-1] * sgn[:, 1:] < 0), 1.0, 0.0), axis=1
    )
    shared = jnp.sum(
        jnp.where(pv & (maxs[:, :-1] == mins[:, 1:]), 1.0, 0.0), axis=1
    )
    return RefMinMaxMetrics(overlap, gmin, gmax, changes, n, shared)


def ref_fused_estimate(batch, schema_bound=None, *, mode: str = "paper"):
    """Oracle for fused_estimate.fused_estimate — the same core, no kernel.

    The megakernel body runs the reference pipeline
    (``estimate_batch_core(..., backend="ref")``) on its tile refs; this
    twin runs the identical call outside any kernel, materializing the
    absent schema bound as +inf the same way the kernel wrapper does. It is
    also the off-TPU serving path for ``fuse="on"`` (see `ops.fused_estimate`),
    which is what makes the fuse knob bit-neutral there by construction.
    """
    # local: estimator imports repro.kernels.ops lazily; importing it at
    # module scope here would close the cycle ops -> ref -> estimator.
    from repro.core.ndv.estimator import estimate_batch_core

    if schema_bound is None:
        schema_bound = jnp.full((batch.batch,), jnp.inf, jnp.float32)
    return estimate_batch_core(
        batch, schema_bound, mode=mode, backend="ref"
    )


def ref_hll_fold(keys: jnp.ndarray, valid: jnp.ndarray, *, p: int = 8) -> jnp.ndarray:
    """Oracle for hll.hll_fold — scatter-max formulation."""
    b, _ = keys.shape
    m = 1 << p
    nbits = 32 - p
    h = hll_kernel._murmur32(keys.astype(jnp.uint32))
    idx = (h >> (32 - p)).astype(jnp.int32)
    rest = (h << p).astype(jnp.uint32)
    rho = jnp.minimum(hll_kernel._clz32(rest) + 1, nbits + 1)
    rho = jnp.where(jnp.asarray(valid, bool), rho, 0)

    def per_col(idx_r, rho_r):
        return jnp.zeros((m,), jnp.float32).at[idx_r].max(rho_r.astype(jnp.float32))

    return jax.vmap(per_col)(idx, rho)
