"""Dry-run cell construction: (arch x shape x mesh) -> lowered jit program.

Everything is ShapeDtypeStruct-based (zero allocation). Each cell returns
the jit-wrapped function plus abstract inputs and shardings, so dryrun.py
can ``.lower().compile()`` and roofline.py can read cost/memory analyses
off the compiled artifact.

Sharding strategy (see DESIGN.md §5):
  * weights: logical rules — FSDP over "data", TP over "model";
  * attention TP: heads-sharded when head counts divide the model axis,
    otherwise Megatron-style SEQUENCE parallelism (q/k/v seq-sharded over
    "model", k/v all-gathered, MLP ff-sharded) — selected per arch;
  * decode: cache time-axis sharded over "model" ("data" too for batch=1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeConfig, cell_supported, get_shape
from repro.launch.mesh import mesh_axis_size
from repro.models import params as MP
from repro.models import registry
from repro.models.config import ModelConfig
from repro.parallel import sharding as SH
from repro.train import optimizer as opt
from repro.train.train_step import TrainState, make_train_step


# ---------------------------------------------------------------------------
# Per-arch rule resolution
# ---------------------------------------------------------------------------


def rules_for(cfg: ModelConfig, mesh, shape: ShapeConfig) -> SH.Rules:
    rules = dict(SH.DEFAULT_RULES)
    msize = mesh_axis_size(mesh, "model")
    dsize = mesh_axis_size(mesh, "data")
    psize = mesh_axis_size(mesh, "pod")

    heads_divide = (
        cfg.num_heads % msize == 0 and cfg.num_kv_heads % msize == 0
    )
    if not heads_divide:
        # Megatron sequence-parallel attention: weights for q/k/v/o stay
        # FSDP-only; activations shard the sequence over "model".
        rules["heads"] = None
        rules["kv_heads"] = None
        rules["seq_model"] = "model"
    else:
        rules["seq_model"] = None

    # Batch sharding: drop axes that do not divide the global batch.
    per_batch_axes = []
    b = shape.global_batch
    if shape.kind == "train":
        b = b // max(shape.microbatches, 1)
    for ax, size in (("pod", psize), ("data", dsize)):
        if ax in mesh.axis_names and size > 1 and b % size == 0:
            per_batch_axes.append(ax)
            b //= size
    rules["batch"] = tuple(per_batch_axes) if per_batch_axes else None

    if shape.kind == "decode":
        # Cache time-axis sharding: prefer axes not already carrying the
        # batch (data) or the heads (model). When heads-TP owns "model",
        # the KV heads stay sharded and time takes "data" if free.
        t_axes = []
        if "data" not in (rules["batch"] or ()) and dsize > 1:
            t_axes.append("data")
        if not heads_divide and msize > 1:
            t_axes.append("model")
        rules["seq_sharded"] = tuple(t_axes) if t_axes else None
        rules["seq_model"] = None
    # MoE dispatch buffers: follow attention seq-parallelism when expert
    # weights are small enough to replicate over "model" (granite); for
    # big-expert models the weights keep ff-TP and the buffers become the
    # TP-gathered operand (mixtral) — see EXPERIMENTS.md §Perf.
    if cfg.moe is not None:
        expert_bytes = 3 * cfg.d_model * cfg.d_ff * cfg.moe.total_experts * 2
        big_experts = expert_bytes > (1 << 30)  # >1 GiB per layer
        rules["moe_seq"] = None if big_experts else rules.get("seq_model")
        if not big_experts:
            # replicate small expert weights over "model" (FSDP over "data"
            # only) — beats 32-wide ff-TP shards, EXPERIMENTS.md §Perf
            rules["ff"] = None
    else:
        rules["moe_seq"] = rules.get("seq_model")
    return rules


# ---------------------------------------------------------------------------
# Abstract inputs per (arch, shape)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        batch: Dict[str, jax.ShapeDtypeStruct] = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
        }
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, s, cfg.encdec.frontend_dim), jnp.bfloat16
            )
        if cfg.family == "vlm":
            batch["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.vlm.num_patches, cfg.vlm.vision_dim), jnp.bfloat16
            )
        return batch
    # decode: one new token against a cache of length s
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "positions": jax.ShapeDtypeStruct((b, 1), i32),
    }


def batch_shardings(batch_abs, mesh, rules) -> Dict[str, NamedSharding]:
    out = {}
    for k, v in batch_abs.items():
        axes: Tuple[Optional[str], ...] = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = SH.checked_sharding(mesh, v.shape, axes, rules)
    return out


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------


class Cell(NamedTuple):
    fn: Callable            # jit-wrapped
    args: Tuple             # abstract args for .lower()
    cfg: ModelConfig
    shape: ShapeConfig
    description: str


def build_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    rule_overrides=None,
    cfg_overrides=None,
    microbatches=None,
) -> Cell:
    cfg = registry.get_config(arch)
    shape = get_shape(shape_name)
    if microbatches is None and cfg.train_microbatches is not None:
        microbatches = cfg.train_microbatches
    if microbatches is not None:
        shape = dataclasses.replace(shape, microbatches=microbatches)
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(reason)
    if cfg_overrides:
        cfg = cfg.scaled(**cfg_overrides)
    cfg = _pad_for_mesh(cfg, mesh)
    model = registry.build_model(cfg)
    rules = rules_for(cfg, mesh, shape)
    if rule_overrides:
        rules.update(rule_overrides)
    specs = model.specs()
    p_shard = SH.spec_shardings(mesh, specs, rules)
    p_abs = MP.abstract_params(specs, dtype=jnp.dtype(cfg.param_dtype))

    if shape.kind == "train":
        o_abs = opt.adamw_abstract_state(p_abs)
        o_shard = opt.AdamWState(
            step=SH.named_sharding(mesh, (), rules),
            mu=p_shard, nu=p_shard, master=p_shard,
        )
        state_abs = TrainState(
            params=p_abs, opt=o_abs,
            rng=jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
        state_shard = TrainState(
            params=p_shard, opt=o_shard,
            rng=SH.named_sharding(mesh, (None,), rules),
        )
        batch_abs = input_specs(cfg, shape)
        b_shard = batch_shardings(batch_abs, mesh, rules)
        step = make_train_step(
            model, cfg, opt.AdamWConfig(),
            schedule=lambda s: jnp.float32(1.0),
            num_microbatches=shape.microbatches,
        )

        def step_with_rules(state, batch):
            with SH.use_rules(rules):
                return step(state, batch)

        fn = jax.jit(
            step_with_rules,
            in_shardings=(state_shard, b_shard),
            out_shardings=(state_shard, None),
            donate_argnums=(0,),
        )
        return Cell(fn, (state_abs, batch_abs), cfg, shape,
                    f"{arch}/{shape_name}: train_step (mb={shape.microbatches})")

    if shape.kind == "prefill":
        batch_abs = input_specs(cfg, shape)
        b_shard = batch_shardings(batch_abs, mesh, rules)

        def prefill(params, batch):
            # serving-prefill contract: only the last position's logits
            with SH.use_rules(rules):
                out = model.forward(params, batch, last_only=True)
            return out.logits

        fn = jax.jit(prefill, in_shardings=(p_shard, b_shard))
        return Cell(fn, (p_abs, batch_abs), cfg, shape,
                    f"{arch}/{shape_name}: prefill forward")

    # decode
    cache_abs, cache_shard = _cache_abstract(model, cfg, shape, mesh, rules)
    toks = input_specs(cfg, shape)
    t_shard = batch_shardings(toks, mesh, rules)

    def serve_step(params, tokens, positions, cache):
        with SH.use_rules(rules):
            out = model.decode_step(params, tokens, positions, cache)
        return jnp.argmax(out.logits[:, -1, :], axis=-1), out.cache

    fn = jax.jit(
        serve_step,
        in_shardings=(p_shard, t_shard["tokens"], t_shard["positions"], cache_shard),
        out_shardings=(None, cache_shard),
        donate_argnums=(3,),
    )
    return Cell(
        fn, (p_abs, toks["tokens"], toks["positions"], cache_abs), cfg, shape,
        f"{arch}/{shape_name}: serve_step (cache={shape.seq_len})",
    )


def _cache_abstract(model, cfg, shape, mesh, rules):
    b = shape.global_batch
    if cfg.family == "encdec":
        sp = model.cache_spec(b, shape.seq_len, enc_len=4096)
    else:
        sp = model.cache_spec(b, shape.seq_len)
    abs_, shard_ = {}, {}
    for k, v in sp.items():
        dt = jnp.int32 if "index" in k else (
            jnp.float32 if k in ("ssm", "wkv") else jnp.dtype(cfg.dtype)
        )
        abs_[k] = jax.ShapeDtypeStruct(v.shape, dt)
        shard_[k] = SH.checked_sharding(mesh, v.shape, v.axes, rules)
    return abs_, shard_


def _pad_for_mesh(cfg: ModelConfig, mesh) -> ModelConfig:
    """Pad vocab to divide the model axis (standard practice; padded rows
    are dead weight, recorded as waste in the roofline's useful-flops ratio)."""
    import math

    msize = mesh_axis_size(mesh, "model")
    mult = math.lcm(128, msize)
    v = cfg.vocab_size
    pad = (-v) % mult
    if pad:
        cfg = cfg.scaled(vocab_size=v + pad)
    return cfg
