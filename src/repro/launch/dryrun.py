import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init). Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out FILE]

Per cell this prints/records:
  * compiled.memory_analysis()  — bytes/device proof-of-fit,
  * compiled.cost_analysis()    — HLO flops/bytes for the roofline,
  * collective operand bytes parsed from the compiled HLO text.
"""
import argparse          # noqa: E402
import gzip              # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs.shapes import SHAPES, cell_supported, get_shape  # noqa: E402
from repro.launch import cells as C  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import registry  # noqa: E402

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f64|s64|u64)\[([0-9,]*)\]")

_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2,
    "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8, "u64": 8,
}


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in an HLO snippet."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective operand bytes from compiled HLO (see ROOFLINE spec)."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        # result-typed op line: "%x = bf16[...] all-gather(...)"
        for op in COLLECTIVE_OPS:
            if f" {op}(" in s or f"= {op}" in s or re.search(rf"\b{op}\b", s.split("(")[0]):
                lhs = s.split("=", 1)
                if len(lhs) == 2 and op in lhs[1].split("(")[0]:
                    # operand bytes: use the RESULT shape (equals operand
                    # volume for AG/AR/RS at the fan-in point)
                    out[op] += _shape_bytes(lhs[0])
                    counts[op] += 1
                break
    out_counts = {f"{k}_count": v for k, v in counts.items()}
    out.update(out_counts)
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


def run_cell(arch: str, shape_name: str, mesh, verbose: bool = True) -> dict:
    t0 = time.time()
    cell = C.build_cell(arch, shape_name, mesh)
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "description": cell.description,
    }
    with mesh:
        lowered = cell.fn.lower(*cell.args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        rec["flops"] = float(cost.get("flops", 0.0))
        rec["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
        for key in ("bytes accessed0{}", "utilization0{}"):
            pass
        try:
            rec["memory"] = {
                "argument_size_in_bytes": mem.argument_size_in_bytes,
                "output_size_in_bytes": mem.output_size_in_bytes,
                "temp_size_in_bytes": mem.temp_size_in_bytes,
                "generated_code_size_in_bytes": mem.generated_code_size_in_bytes,
            }
        except Exception:
            rec["memory"] = str(mem)
        hlo = compiled.as_text()
        # persist HLO so roofline/hillclimb re-analysis never recompiles
        os.makedirs("results/hlo", exist_ok=True)
        hlo_path = f"results/hlo/{arch}_{shape_name}_{rec['mesh']}.txt.gz"
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)
        rec["hlo_path"] = hlo_path
        rec["collectives_flat"] = collective_bytes(hlo)
        rec["hlo_ops"] = len(hlo.splitlines())
        # trip-count-aware per-device analysis (the roofline's real input)
        ana = hlo_analysis.analyze(hlo)
        rec["analysis"] = {
            "flops_per_device": ana.flops,
            "bytes_per_device": ana.bytes,
            "collective_bytes_per_device": ana.collective_bytes,
            "collective_count": ana.collective_count,
            "per_collective": ana.per_collective,
        }
    if verbose:
        a = rec["analysis"]
        print(f"[dryrun] {arch:>24s} x {shape_name:<12s} mesh={rec['mesh']:>9s} "
              f"compile={rec['compile_s']:6.1f}s flops/dev={a['flops_per_device']:.3e} "
              f"coll/dev={a['collective_bytes_per_device']:.3e}B")
        print(f"         memory_analysis: {rec['memory']}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", type=str, default="results/dryrun.json")
    ap.add_argument("--force", action="store_true",
                    help="recompile even if a cached record exists")
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(multi_pod=False),
                  make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = (
        set()
        if args.force
        else {(r["arch"], r["shape"], r["mesh"]) for r in results if "error" not in r}
    )

    archs = registry.ARCHS if (args.all or not args.arch) else [registry.canonical(args.arch)]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) else [args.shape]

    for mesh in meshes:
        mesh_name = "x".join(map(str, mesh.devices.shape))
        for arch in archs:
            cfg = registry.get_config(arch)
            for shape_name in shapes:
                ok, reason = cell_supported(cfg, get_shape(shape_name))
                if not ok:
                    print(f"[skip]   {arch} x {shape_name}: {reason}")
                    continue
                if (arch, shape_name, mesh_name) in done:
                    print(f"[cached] {arch} x {shape_name} x {mesh_name}")
                    continue
                try:
                    rec = run_cell(arch, shape_name, mesh)
                except Exception as e:  # record failures as bugs to fix
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    print(f"[FAIL]   {arch} x {shape_name}: {rec['error'][:200]}")
                results = [
                    r for r in results
                    if (r["arch"], r["shape"], r["mesh"]) != (arch, shape_name, mesh_name)
                ] + [rec]
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if "error" not in r)
    print(f"[dryrun] {n_ok}/{len(results)} cells OK -> {args.out}")


if __name__ == "__main__":
    main()
