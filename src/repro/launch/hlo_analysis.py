"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless
of trip count (verified empirically — a 10-iteration scan of a matmul
reports the flops of a single matmul). Every model here scans over layers /
microbatches / attention blocks, so naive cost numbers undercount by 2-4
orders of magnitude, and collectives inside scanned layers would be missed
entirely by a flat text scan.

This module re-derives the three roofline inputs by walking the HLO module
with loop multipliers:

  flops            — dots: 2 * |result| * |contracted dims|; elementwise: 1
                     per output element; reduces: 1 per input element.
  bytes            — per top-level op: operand + result bytes (fusion
                     internals excluded — they stay in registers/VMEM).
  collective bytes — result bytes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute,
                     multiplied by enclosing trip counts.

Trip counts are recovered from each while-condition's comparison constant
(scan-lowered loops run 0..N-1), falling back to 1.

All numbers are PER DEVICE (the compiled module is the per-device SPMD
program), which is exactly what the roofline terms need.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*?)\)(.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "negate", "abs", "sign", "floor", "ceil", "round",
    "compare", "select", "and", "or", "xor", "not", "clamp", "convert",
    "cosine", "sine", "logistic", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "atan2", "remainder", "cbrt", "erf",
}

FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "copy", "copy-start", "copy-done", "after-all",
    "partition-id", "replica-id", "iota", "broadcast", "reshape",
    "transpose",  # layout ops: bytes counted via consumers
}


def _parse_shape(type_str: str) -> Tuple[int, int]:
    """-> (total elements, total bytes) over all array shapes in the type."""
    elems = 0
    byts = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class OpInfo:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpInfo]
    symbols: Dict[str, str]          # var name -> type string


@dataclasses.dataclass
class CostResult:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: float = 0.0

    def add(self, other: "CostResult", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.collective_count += other.collective_count * mult
        for k, v in other.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v * mult


_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _split_operands(operands: str) -> List[str]:
    """Split an operand list on top-level commas only.

    Shape strings (``f32[256,256]{1,0}``) and nested calls contain commas;
    a naive ``split(",")`` shreds them and breaks the positional mapping
    between fusion parameters and caller operands.
    """
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in operands:
        if ch in "[({":
            depth += 1
        elif ch in "])}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


def _operand_name(fragment: str) -> str:
    """Instruction name from one operand fragment.

    Handles both bare references (``%Arg_1.2`` / ``Arg_1.2``) and typed
    references (``f32[256,256]{1,0} %Arg_1.2``) as newer XLA prints them;
    literal operands (``constant(28)``) pass through as their text.
    """
    m = _OPERAND_NAME_RE.search(fragment)
    if m:
        return m.group(1)
    return fragment.split(" ")[0]


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line or line.startswith("HloModule"):
            continue
        hdr = _COMP_HDR_RE.match(line)
        if hdr and line.endswith("{"):
            cur = Computation(hdr.group(1), [], {})
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry_name = cur.name
            # header params: "%p.1: f32[4,8], %p.2: ..."
            for pm in re.finditer(
                r"%?([\w\.\-]+):\s*([a-z0-9]+\[[0-9,]*\])", hdr.group(2)
            ):
                cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, operands, attrs = m.groups()
        ops = [_operand_name(o) for o in _split_operands(operands)]
        cur.symbols[name] = type_str
        cur.ops.append(OpInfo(name, type_str, opcode, ops, attrs))
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(cond: Computation) -> float:
    """Scan-lowered loops compare the induction var against a constant."""
    consts: Dict[str, float] = {}
    for op in cond.ops:
        if op.opcode == "constant":
            # value sits in the operand slot: %c = s32[] constant(28)
            val = op.operands[0] if op.operands else ""
            try:
                consts[op.name] = float(val)
            except ValueError:
                continue
    # fallback: constants written as operands, e.g. constant(28)
    best = None
    for op in cond.ops:
        if op.opcode == "compare":
            for o in op.operands:
                if o in consts:
                    best = consts[o] if best is None else max(best, consts[o])
    if best is None:
        # try any s32 constant in the body text
        vals = [v for v in consts.values() if v > 0]
        best = max(vals) if vals else 1.0
    return max(best, 1.0)


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    result_elems, _ = _parse_shape(op.type_str)
    lhs = comp.symbols.get(op.operands[0], "") if op.operands else ""
    lhs_dims = _shape_dims(lhs)
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs or "")
    contracted = 1
    if cm and cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contracted *= lhs_dims[i]
    return 2.0 * result_elems * contracted


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._memo: Dict[Tuple[str, bool], CostResult] = {}

    def cost(self) -> CostResult:
        if "__entry__" not in self.comps:
            return CostResult()
        return self._comp_cost(self.comps["__entry__"].name, top=True)

    def _comp_cost(self, name: str, top: bool) -> CostResult:
        key = (name, top)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        out = CostResult()
        if comp is None:
            return out
        self._memo[key] = out  # break cycles defensively
        for op in comp.ops:
            out.add(self._op_cost(op, comp))
        return out

    def _called(self, op: OpInfo, key: str) -> Optional[str]:
        m = re.search(rf"{key}=%?([\w\.\-]+)", op.attrs or "")
        return m.group(1) if m else None

    def _operand_bytes(self, op: OpInfo, comp: Computation) -> float:
        total = 0.0
        for o in op.operands:
            t = comp.symbols.get(o)
            if t:
                total += _parse_shape(t)[1]
        return total

    def _fusion_bytes(self, op: OpInfo, comp: Computation, called: Computation) -> float:
        """HBM traffic of a fusion: slice-aware operand reads + root write.

        A parameter consumed ONLY by slicing ops inside the fusion is read
        at slice granularity (XLA fuses dynamic-slice into consumers — e.g.
        per-layer reads of a stacked KV cache inside a scan). A fusion
        rooted at dynamic-update-slice writes only the updated region
        (in-place loop-carried buffers).
        """
        total = 0.0
        # parameter name -> parameter index
        params = {
            o.name: int(o.operands[0]) if o.operands else -1
            for o in called.ops
            if o.opcode == "parameter"
        }
        for pname, idx in params.items():
            full = 0.0
            if 0 <= idx < len(op.operands):
                t_full = comp.symbols.get(op.operands[idx], "")
                full = _parse_shape(t_full)[1]
            consumers = [o for o in called.ops if pname in o.operands]
            if consumers and all(
                c.opcode in ("dynamic-slice", "slice", "gather")
                for c in consumers
            ):
                total += sum(_parse_shape(c.type_str)[1] for c in consumers)
            elif consumers and all(
                c.opcode == "dynamic-update-slice" and c.operands
                and c.operands[0] == pname
                for c in consumers
            ):
                # in-place carried buffer: DUS writes the region, the rest
                # of the buffer passes through untouched
                total += 0.0
            else:
                total += full
        root = called.ops[-1] if called.ops else None
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = called.symbols.get(root.operands[1], "") if len(root.operands) > 1 else ""
            total += _parse_shape(upd)[1]
        else:
            total += _parse_shape(op.type_str)[1]
        return total

    def _from_bf16_convert(self, op: OpInfo, comp: Computation) -> bool:
        """True if this (f32) collective's data is a convert of bf16 values."""
        if "f32" not in op.type_str:
            return False
        ops_by_name = {o.name: o for o in comp.ops}
        for src_name in op.operands:
            src = ops_by_name.get(src_name)
            if src is None:
                continue
            if src.opcode == "convert" and src.operands:
                orig = comp.symbols.get(src.operands[0], "")
                if "bf16" in orig:
                    return True
            if src.opcode == "fusion":
                called = self._called(src, "calls")
                cc = self.comps.get(called or "")
                if cc and all(
                    o.opcode in ("parameter", "convert") for o in cc.ops
                ) and any("bf16" in t for t in cc.symbols.values()):
                    return True
        return False

    def _op_cost(self, op: OpInfo, comp: Computation) -> CostResult:
        r = CostResult()
        oc = op.opcode
        res_elems, res_bytes = _parse_shape(op.type_str)

        if oc in FREE_OPS:
            return r

        if oc == "while":
            body = self._called(op, "body")
            cond = self._called(op, "condition")
            trips = 1.0
            if cond and cond in self.comps:
                trips = _trip_count(self.comps[cond])
            if body:
                r.add(self._comp_cost(body, top=False), mult=trips)
            return r

        if oc in ("fusion",):
            called = self._called(op, "calls")
            if called:
                inner = self._comp_cost(called, top=False)
                r.flops += inner.flops
                r.collective_bytes += inner.collective_bytes
                r.collective_count += inner.collective_count
                for k, v in inner.per_collective.items():
                    r.per_collective[k] = r.per_collective.get(k, 0.0) + v
                r.bytes += self._fusion_bytes(op, comp, self.comps[called])
            else:
                r.bytes += self._operand_bytes(op, comp) + res_bytes
            return r

        if oc in ("call", "conditional", "async-start"):
            called = self._called(op, "calls") or self._called(op, "to_apply")
            if called:
                r.add(self._comp_cost(called, top=False))
            r.bytes += self._operand_bytes(op, comp) + res_bytes
            return r

        if oc in COLLECTIVES or oc.rstrip("-start").rstrip("-done") in COLLECTIVES:
            base = oc
            for c in COLLECTIVES:
                if oc.startswith(c):
                    base = c
                    break
            if oc.endswith("-done"):
                return r  # counted at -start
            eff_bytes = float(res_bytes)
            # CPU-backend artifact correction: XLA's CPU float-normalization
            # upcasts every bf16 dot operand to f32 BEFORE partitioning, so
            # GSPMD places gathers on the f32 copies. On the TPU target the
            # dot is native bf16 and the collective would carry bf16 — count
            # the TPU-native volume when the operand is a convert-from-bf16.
            if self._from_bf16_convert(op, comp):
                eff_bytes *= 0.5
            r.bytes += self._operand_bytes(op, comp) + res_bytes
            r.collective_bytes += eff_bytes
            r.collective_count += 1
            r.per_collective[base] = r.per_collective.get(base, 0.0) + eff_bytes
            return r

        if oc == "dot":
            r.flops += _dot_flops(op, comp)
            r.bytes += self._operand_bytes(op, comp) + res_bytes
            return r

        if oc in ("convolution",):
            # rough: 2 * result * (kernel elems); kernel = operand 1
            k = comp.symbols.get(op.operands[1], "") if len(op.operands) > 1 else ""
            k_elems, _ = _parse_shape(k)
            r.flops += 2.0 * res_elems * max(k_elems, 1)
            r.bytes += self._operand_bytes(op, comp) + res_bytes
            return r

        if oc in ("reduce", "reduce-window"):
            r.flops += self._operand_bytes(op, comp) / 4.0  # ~1 flop/elem
            r.bytes += self._operand_bytes(op, comp) + res_bytes
            return r

        if oc in ELEMENTWISE:
            r.flops += res_elems
            r.bytes += self._operand_bytes(op, comp) + res_bytes
            return r

        # Sliced access patterns: hardware touches the slice, not the whole
        # operand (counting the operand would charge e.g. a full stacked
        # KV cache to every per-layer dynamic-slice in a scan).
        if oc in ("dynamic-slice", "gather", "slice"):
            r.bytes += 2.0 * res_bytes                    # read slice + write
            return r
        if oc == "dynamic-update-slice":
            # in-place update: read + write the updated region only
            upd = comp.symbols.get(op.operands[1], "") if len(op.operands) > 1 else ""
            r.bytes += 2.0 * _parse_shape(upd)[1]
            return r
        if oc == "scatter":
            upd = comp.symbols.get(op.operands[-1], "") if op.operands else ""
            r.bytes += 3.0 * _parse_shape(upd)[1]
            return r

        # everything else (sort, custom-call, pad, concatenate, rng, ...):
        # traffic only
        r.bytes += self._operand_bytes(op, comp) + res_bytes
        return r


def analyze(text: str) -> CostResult:
    return HloAnalyzer(text).cost()
