"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Only launch/dryrun.py sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` (before any
import); smoke tests and benchmarks see the 1 real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Miniature mesh with the same axis names (tests on 8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.devices.shape[mesh.axis_names.index(name)]
