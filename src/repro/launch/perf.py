import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
).strip()

"""Perf hillclimbing driver (§Perf methodology).

Each invocation compiles ONE cell with a named variant (config / rule /
microbatch overrides), runs the trip-count-aware HLO analysis, and appends
a record to results/perf_log.json:

    PYTHONPATH=src python -m repro.launch.perf --arch granite-moe-3b-a800m \
        --shape train_4k --variant moe_local \
        --cfg '{"moe": {"num_experts": 40, "top_k": 8, "dispatch": "local"}}'

The hypothesis/measurement narrative lives in EXPERIMENTS.md §Perf; this
tool provides the measurements.
"""
import argparse          # noqa: E402
import dataclasses      # noqa: E402
import json              # noqa: E402
import time              # noqa: E402

from repro.launch import cells as C  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS  # noqa: E402

LOG = "results/perf_log.json"


def _decode_cfg_overrides(raw: str):
    if not raw:
        return None
    d = json.loads(raw)
    if "moe" in d and isinstance(d["moe"], dict):
        from repro.models.config import MoEConfig

        d["moe"] = MoEConfig(**d["moe"])
    return d


def measure(arch, shape, variant, cfg_overrides=None, rule_overrides=None,
            microbatches=None, multi_pod=False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = C.build_cell(
        arch, shape, mesh,
        cfg_overrides=cfg_overrides,
        rule_overrides=rule_overrides,
        microbatches=microbatches,
    )
    with mesh:
        compiled = cell.fn.lower(*cell.args).compile()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        import gzip

        os.makedirs("results/hlo", exist_ok=True)
        hp = f"results/hlo/perf_{arch}_{shape}_{variant}.txt.gz"
        with gzip.open(hp, "wt") as f:
            f.write(hlo)
        ana = hlo_analysis.analyze(hlo)
    rec = {
        "arch": arch, "shape": shape, "variant": variant,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "compile_s": round(time.time() - t0, 1),
        "t_compute_s": ana.flops / PEAK_FLOPS,
        "t_memory_s": ana.bytes / HBM_BW,
        "t_collective_s": ana.collective_bytes / ICI_BW,
        "per_collective": ana.per_collective,
        "collective_count": ana.collective_count,
        "temp_bytes_per_dev": mem.temp_size_in_bytes,
        "flops_per_dev": ana.flops,
        "bytes_per_dev": ana.bytes,
    }
    terms = {k: rec[f"t_{k}_s"] for k in ("compute", "memory", "collective")}
    rec["dominant"] = max(terms, key=terms.get)
    rec["bound_s"] = terms[rec["dominant"]]
    rec["roofline_fraction"] = rec["t_compute_s"] / rec["bound_s"] if rec["bound_s"] else 0
    return rec


def log(rec):
    os.makedirs("results", exist_ok=True)
    hist = []
    if os.path.exists(LOG):
        hist = json.load(open(LOG))
    hist = [
        h for h in hist
        if (h["arch"], h["shape"], h["variant"], h.get("mesh"))
        != (rec["arch"], rec["shape"], rec["variant"], rec.get("mesh"))
    ] + [rec]
    json.dump(hist, open(LOG, "w"), indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--cfg", default="")
    ap.add_argument("--rules", default="")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    rec = measure(
        args.arch, args.shape, args.variant,
        cfg_overrides=_decode_cfg_overrides(args.cfg),
        rule_overrides=json.loads(args.rules) if args.rules else None,
        microbatches=args.microbatches,
        multi_pod=args.multi_pod,
    )
    log(rec)
    print(json.dumps({k: v for k, v in rec.items() if k != "per_collective"},
                     indent=1))
    print("per_collective:", {k: f"{v:.3e}" for k, v in rec["per_collective"].items()})


if __name__ == "__main__":
    main()
