"""Roofline analysis over dry-run records (TPU v5e targets).

Per (arch x shape) cell on the single-pod 16x16 mesh:

    T_compute    = flops_per_device    / 197e12      (bf16 MXU peak)
    T_memory     = bytes_per_device    / 819e9       (HBM bandwidth)
    T_collective = coll_bytes_per_dev  / 50e9        (ICI per-link)

All inputs come from the trip-count-aware HLO analysis (per-device SPMD
program — see hlo_analysis.py), so the three terms are directly comparable
per-chip times. The bound is max(terms); the roofline fraction we report
for a cell is T_compute / max(terms) (how close the program is to being
compute-bound, the best achievable state for these workloads).

MODEL_FLOPS uses the 6ND/2ND accounting with the UNPADDED configs
(vocab padding and blockwise-attention masking waste show up as a
useful-flops ratio < 1).
"""
from __future__ import annotations

import argparse
import gzip
import json
import os
from typing import Dict, List, Optional

from repro.configs.shapes import get_shape
from repro.models import registry

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link

CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic MODEL_FLOPS for the WHOLE cell (all chips), unpadded cfg."""
    cfg = registry.get_config(arch)
    shape = get_shape(shape_name)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def attention_flops(arch: str, shape_name: str) -> float:
    """Quadratic-attention flops excluded from 6ND (context for the ratio)."""
    cfg = registry.get_config(arch)
    shape = get_shape(shape_name)
    if cfg.family == "rwkv":
        return 0.0
    s = shape.seq_len
    b = shape.global_batch
    hd = cfg.resolved_head_dim
    h = cfg.num_heads
    layers = cfg.num_layers
    if cfg.family == "hybrid":
        layers = 2  # shared-attention applications
    w = cfg.sliding_window or s
    if shape.kind in ("train", "prefill"):
        per_layer = 2 * 2 * b * s * min(w, s) * h * hd / 2  # causal half
        mult = 3 if shape.kind == "train" else 1            # fwd+bwd
        return mult * layers * per_layer
    return 2 * 2 * b * min(w, s) * h * hd * layers


def reanalyze(records: List[dict]) -> List[dict]:
    """Re-run the HLO analyzer over persisted HLO dumps (no recompiles)."""
    from repro.launch import hlo_analysis

    out = []
    for r in records:
        if "hlo_path" in r and os.path.exists(r["hlo_path"]):
            with gzip.open(r["hlo_path"], "rt") as f:
                ana = hlo_analysis.analyze(f.read())
            r = dict(r)
            r["analysis"] = {
                "flops_per_device": ana.flops,
                "bytes_per_device": ana.bytes,
                "collective_bytes_per_device": ana.collective_bytes,
                "collective_count": ana.collective_count,
                "per_collective": ana.per_collective,
            }
        out.append(r)
    return out


def analyze_records(records: List[dict], mesh_key: str = "16x16") -> List[dict]:
    rows = []
    for r in records:
        if r.get("mesh") != mesh_key or "error" in r or "analysis" not in r:
            continue
        a = r["analysis"]
        t_c = a["flops_per_device"] / PEAK_FLOPS
        t_m = a["bytes_per_device"] / HBM_BW
        t_x = a["collective_bytes_per_device"] / ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        dominant = max(terms, key=terms.get)
        bound = terms[dominant]
        chips = CHIPS.get(mesh_key, 256)
        mf = model_flops(r["arch"], r["shape"])
        hlo_total = a["flops_per_device"] * chips
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "mesh": mesh_key,
            "t_compute_s": t_c,
            "t_memory_s": t_m,
            "t_collective_s": t_x,
            "dominant": dominant,
            "bound_s": bound,
            "roofline_fraction": t_c / bound if bound > 0 else 0.0,
            "model_flops": mf,
            "attn_flops": attention_flops(r["arch"], r["shape"]),
            "hlo_flops_total": hlo_total,
            "useful_ratio": mf / hlo_total if hlo_total else 0.0,
            "tokens_per_s_bound": _tokens_per_s(r, bound),
            "collective_count": a.get("collective_count", 0),
            "per_collective": a.get("per_collective", {}),
        })
    return rows


def _tokens_per_s(r: dict, bound_s: float) -> float:
    shape = get_shape(r["shape"])
    if bound_s <= 0:
        return 0.0
    if shape.kind in ("train", "prefill"):
        return shape.global_batch * shape.seq_len / bound_s
    return shape.global_batch / bound_s


SUGGESTIONS = {
    "compute": "compute-bound: raise MXU efficiency (larger per-chip tiles, "
               "fewer pad/wasted flops) or accept — this is the roofline.",
    "memory": "HBM-bound: fuse elementwise chains, cut remat recompute, "
              "widen microbatch to raise arithmetic intensity.",
    "collective": "ICI-bound: reshard to cut all-gather volume, overlap "
                  "collectives with compute, or move TP axes.",
}


def to_markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | bound | "
           "roofline frac | useful ratio | suggestion |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
            f"{r['useful_ratio']:.2f} | {SUGGESTIONS[r['dominant']][:60]} |\n"
        )
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun.json")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--reanalyze", action="store_true",
                    help="re-run the HLO analyzer over persisted HLO dumps")
    args = ap.parse_args()
    with open(args.dryrun) as f:
        records = json.load(f)
    if args.reanalyze:
        records = reanalyze(records)
        with open(args.dryrun, "w") as f:
            json.dump(records, f, indent=1)
    rows = analyze_records(records, args.mesh)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(to_markdown(rows))
    print(f"{len(rows)} cells -> {args.out}")


if __name__ == "__main__":
    main()
