"""Serving launcher: batched decode over the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import params as MP
from repro.models import registry
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = (
        registry.get_smoke_config(args.arch)
        if args.smoke
        else registry.get_config(args.arch)
    )
    if args.smoke:
        cfg = cfg.scaled(dtype="float32", param_dtype="float32")
    model = registry.build_model(cfg)
    params = MP.init_params(
        model.specs(), jax.random.PRNGKey(0), jnp.dtype(cfg.param_dtype)
    )
    engine = ServeEngine(
        model, cfg, params, slots=args.slots, cache_len=args.cache_len
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(1, cfg.vocab_size, size=8).tolist(),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.generated[:8]}...")


if __name__ == "__main__":
    main()
