"""Fleet launcher: the replicated multi-dataset router over HTTP.

    PYTHONPATH=src python -m repro.launch.serve_fleet \\
        --dataset wh/lineitem=/data/lineitem \\
        --dataset wh/orders=/data/orders \\
        --replicas 3 --port 8090 --refresh-interval 30

    # self-contained smoke (CI): router + 2 replicas x 2 temp datasets,
    # estimate, kill a replica, re-estimate through failover, assert 304
    # revalidation and zero-pack warm start from the shared spill, then a
    # binary POST /batch spanning both datasets (per-tuple 304s asserted
    # through a second mid-batch replica kill, one pooled connection) and
    # a cross-dataset POST /cost (combined ETag stable on the degraded
    # fleet, 304 revalidation, batch-tuple parity)
    PYTHONPATH=src python -m repro.launch.serve_fleet --smoke

A planner then addresses the whole namespace through one endpoint:

    curl -s http://host:8090/datasets
    curl -s 'http://host:8090/wh/lineitem/estimate?mode=improved'
    curl -s -H 'If-None-Match: <etag>' 'http://host:8090/wh/lineitem/estimate?mode=improved'
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time
import urllib.error

from repro.engine import EngineConfig
from repro.fleet import (
    DatasetRegistry,
    Fleet,
    LocalReplica,
    StatsRequest,
    StatsRouter,
    parse_spec,
)
from repro.service import fetch_json
from repro.wire import ConnectionPool, fetch


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dataset", action="append", default=[],
                    metavar="NS/NAME=ROOT",
                    help="serve ROOT as namespace/dataset (repeatable)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8090,
                    help="0 binds an ephemeral port")
    ap.add_argument("--replicas", type=int, default=2,
                    help="replicas per dataset")
    ap.add_argument("--refresh-interval", type=float, default=30.0,
                    help="per-replica ingestion poll seconds; 0 disables")
    ap.add_argument("--probe-interval", type=float, default=5.0,
                    help="replica health-probe seconds; 0 disables")
    ap.add_argument("--strategy", default="auto",
                    help="engine strategy (auto/local/sharded/chunked/composed)")
    ap.add_argument("--backend", default="auto",
                    help="kernel backend (auto/pallas/ref)")
    ap.add_argument("--max-batch", default="auto",
                    help='chunk budget: a power of two, or "auto" to derive '
                         "it from device memory")
    ap.add_argument("--slow-request-ms", type=float, default=None,
                    help="log one structured line per request slower than "
                         "this many milliseconds (default: off)")
    ap.add_argument("--audit", action="store_true",
                    help="per-replica accuracy auditor: sample columns each "
                         "refresh, sketch a reference NDV, record q-error "
                         "into /metrics (ndv_audit_qerror)")
    ap.add_argument("--audit-columns", type=int, default=4,
                    help="columns sampled per audit generation")
    ap.add_argument("--smoke", action="store_true",
                    help="boot 2 replicas x 2 temp datasets on an ephemeral "
                         "port, run the scripted failover client, exit")
    return ap


def _engine_config(args: argparse.Namespace) -> EngineConfig:
    mb = args.max_batch
    return EngineConfig(
        strategy=args.strategy,
        backend=args.backend,
        max_batch=mb if mb == "auto" else int(mb),
    )


def _make_router(args: argparse.Namespace, registry: DatasetRegistry) -> StatsRouter:
    fleet = Fleet(
        registry,
        replicas_per_dataset=args.replicas,
        probe_interval=args.probe_interval or None,
        poll_interval=args.refresh_interval or None,
        audit=args.audit,
        audit_columns=args.audit_columns,
    )
    return StatsRouter(
        fleet,
        host=args.host,
        port=args.port,
        slow_request_ms=args.slow_request_ms,
    )


def _smoke_dataset(root: str, seed: int) -> str:
    import numpy as np

    from repro.columnar.writer import WriterOptions, write_file

    rng = np.random.default_rng(seed)
    for i in range(2):
        write_file(
            os.path.join(root, f"shard_{i:03d}"),
            {
                "tok": rng.integers(0, 100 + 40 * seed, 768).astype(np.int64),
                "val": np.round(rng.uniform(0, 50, 768), 1),
            },
            options=WriterOptions(row_group_size=256),
        )
    return root


def run_smoke(args: argparse.Namespace) -> int:
    args = argparse.Namespace(**{
        **vars(args),
        "port": 0, "replicas": 2,
        "refresh_interval": 0.0, "probe_interval": 0.0,
        "audit": True, "audit_columns": 2,
    })
    base = tempfile.mkdtemp()
    registry = DatasetRegistry()
    cfg = _engine_config(args)
    for name, seed in (("alpha", 1), ("beta", 2)):
        root = _smoke_dataset(os.path.join(base, name), seed)
        registry.add("smoke", name, root, engine_config=cfg)

    with _make_router(args, registry) as router:
        base_url = router.url
        # both datasets serve through one endpoint
        etags = {}
        for name in ("alpha", "beta"):
            url = router.url_for("smoke", name, "estimate") + "?mode=improved"
            status, etag, body = fetch_json(url)
            assert status == 200 and etag and body["estimates"], (status, body)
            etags[name] = (etag, body)
        status, _, listing = fetch_json(base_url + "/datasets")
        assert status == 200 and len(listing["datasets"]) == 2, listing

        # kill the replica that owns alpha's estimate placement mid-run
        fleet = router.fleet
        rset = fleet.sets["smoke/alpha"]
        identity = StatsRequest("estimate", "improved").identity
        victim = rset.rank(identity)[0]
        victim.kill()

        # the request survives (failover retries), body is byte-identical,
        # and the pre-kill ETag still revalidates as 304 on the survivor
        url = router.url_for("smoke", "alpha", "estimate") + "?mode=improved"
        status, etag, body = fetch_json(url)
        assert status == 200, status
        assert etag == etags["alpha"][0], (etag, etags["alpha"][0])
        assert body == etags["alpha"][1], "failover changed the body"
        status, etag304, _ = fetch_json(url, etag=etags["alpha"][0])
        assert status == 304 and etag304 == etags["alpha"][0], (status, etag304)
        assert rset.failovers >= 1 and rset.health[victim.name].healthy is False

        # a freshly started replica warms from the shared spill:
        # first estimate is a cache hit — zero engine packs
        fresh = LocalReplica(
            "smoke/alpha#fresh", registry.get("smoke", "alpha").root,
            engine_config=cfg,
        ).start()
        try:
            resp = fresh.handle(StatsRequest("estimate", "improved"))
            assert resp.status == 200 and resp.etag == etags["alpha"][0]
            packs = fresh.service.catalog.stats.packs
            assert packs == 0, f"fresh replica packed {packs}x despite spill"
        finally:
            fresh.stop()

        # -- batched RPC: one binary /batch frame spanning both datasets --
        pool = ConnectionPool()
        tuples = [
            {"namespace": "smoke", "dataset": "alpha", "mode": "improved"},
            {"namespace": "smoke", "dataset": "beta", "mode": "improved"},
            {"namespace": "smoke", "dataset": "beta"},
            {"namespace": "smoke", "dataset": "ghost"},
        ]
        status, _, env = fetch(base_url + "/batch", pool=pool,
                               method="POST", payload={"tuples": tuples})
        entries = env["responses"]
        assert status == 200, status
        assert [e["status"] for e in entries] == [200, 200, 200, 404], entries
        # tuple bodies/ETags match the singleton routed endpoint exactly
        assert entries[0]["etag"] == etags["alpha"][0], entries[0]
        assert entries[0]["body"] == etags["alpha"][1]
        assert entries[1]["etag"] == etags["beta"][0]

        # kill a second replica mid-batch: the sub-batch requeues whole
        # onto the survivor and every per-tuple 304 stays valid
        beta_set = fleet.sets["smoke/beta"]
        beta_victim = beta_set.rank(
            StatsRequest("estimate", "improved").identity
        )[0]
        beta_victim.kill()
        revalidate = [dict(t) for t in tuples[:3]]
        for t, e in zip(revalidate, entries):
            t["if_none_match"] = e["etag"]
        status, _, env = fetch(base_url + "/batch", pool=pool,
                               method="POST",
                               payload={"tuples": revalidate})
        statuses = [e["status"] for e in env["responses"]]
        assert status == 200 and statuses == [304, 304, 304], statuses
        assert beta_set.failovers >= 1, beta_set.health_view()
        assert pool.stats.snapshot()["opened"] == 1, pool.stats.snapshot()

        status, _, health = fetch_json(base_url + "/health")
        assert status == 200 and health["status"] == "serving", health

        # -- planner tier: cross-dataset /cost through the router ---------
        # Both replica sets have had a kill above, so the combined ETag
        # (a hash of per-dataset /tablestats tags, themselves state-derived)
        # is exercised on the degraded fleet: the tag must not depend on
        # which replica served each tablestats fetch.
        cost_payload = {"graph": {
            "tables": [
                {"name": "a", "namespace": "smoke", "dataset": "alpha"},
                {"name": "b", "namespace": "smoke", "dataset": "beta"},
            ],
            "edges": [{"left": "a", "left_column": "tok",
                       "right": "b", "right_column": "tok"}],
        }}
        status, cost_etag, cost = fetch(
            base_url + "/cost", pool=pool, payload=cost_payload, binary=False
        )
        assert status == 200 and cost_etag, (status, cost)
        assert sorted(cost["best_order"]) == ["a", "b"], cost
        assert set(cost["sources"]) == {"smoke/alpha", "smoke/beta"}, cost
        status, etag2_, _ = fetch(
            base_url + "/cost", pool=pool, payload=cost_payload,
            etag=cost_etag, binary=False,
        )
        assert status == 304 and etag2_ == cost_etag, (status, etag2_)
        # a cost tuple rides /batch with the identical ETag
        status, _, env = fetch(
            base_url + "/batch", pool=pool, method="POST",
            payload={"tuples": [{"cost": cost_payload}]},
        )
        entry = env["responses"][0]
        assert status == 200 and entry["status"] == 200, env
        assert entry["etag"] == cost_etag, (entry["etag"], cost_etag)

        # -- quality observability: explain round-trip + audited q-error --
        url = router.url_for("smoke", "beta", "estimate") \
            + "?mode=improved&explain=1"
        status, etag, explained = fetch_json(url)
        assert status == 200 and etag == etags["beta"][0], (status, etag)
        assert explained["provenance"].keys() \
            == etags["beta"][1]["estimates"].keys()
        assert {k: v for k, v in explained.items() if k != "provenance"} \
            == etags["beta"][1], "explain must not perturb the body"
        # one deterministic audit pass per live replica (the background
        # auditor is commit-driven; the smoke drives it synchronously)
        for rset_ in fleet.sets.values():
            for rep in rset_.replicas:
                if rep.probe():
                    rep.service.run_audit()

        # -- telemetry: /metrics key series + the batch's own trace --
        import json as _json
        import urllib.request as _req

        with _req.urlopen(base_url + "/metrics") as r:
            metrics = r.read().decode()
        for series in ("ndv_http_requests_total", "ndv_service_responses_304",
                       "ndv_service_engine_runs", "ndv_pool_opened",
                       "ndv_fleet_batches", "ndv_engine_dispatches_total",
                       "ndv_route_total", "ndv_audit_qerror"):
            assert series in metrics, f"/metrics missing {series}"
        with _req.urlopen(base_url + "/debug/traces?limit=10") as r:
            traces = _json.load(r)["traces"]
        batch_traces = [t for t in traces if t["name"] == "router.batch"]
        assert batch_traces, [t["name"] for t in traces]

        def _names(node, acc):
            acc.add(node["name"])
            for c in node["children"]:
                _names(c, acc)
            return acc

        span_names = _names(batch_traces[-1], set())
        assert "replica.sub_batch" in span_names, span_names
        print(f"[serve_fleet --smoke] ok: 2 datasets x 2 replicas, "
              f"failover after kill ({rset.failovers} failovers), ETag "
              f"stable across replicas, 304 revalidation on survivor, "
              f"fresh replica warm from spill (0 packs), binary /batch "
              f"across both datasets with per-tuple 304s through a "
              f"mid-batch kill on one keep-alive connection, cross-dataset "
              f"/cost with a combined ETag stable on the degraded fleet "
              f"(304 + batch-tuple parity), ?explain=1 provenance with "
              f"stable ETag, audited q-error in /metrics, /debug/traces "
              f"scraped")
    # context exit shut everything down; a second connect must now fail
    try:
        fetch_json(base_url + "/health")
    except (urllib.error.URLError, ConnectionError):
        print("[serve_fleet --smoke] clean shutdown verified")
        return 0
    print("[serve_fleet --smoke] ERROR: router still answering after stop()",
          file=sys.stderr)
    return 1


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.smoke:
        return run_smoke(args)
    if not args.dataset:
        print("error: at least one --dataset NS/NAME=ROOT is required "
              "(or use --smoke)", file=sys.stderr)
        return 2
    registry = DatasetRegistry()
    cfg = _engine_config(args)
    for spec in args.dataset:
        ns, ds, root = parse_spec(spec)
        registry.add(ns, ds, root, engine_config=cfg)
    with _make_router(args, registry) as router:
        print(f"[serve_fleet] routing {len(registry)} datasets x "
              f"{args.replicas} replicas at {router.url}")
        for key in registry.keys():
            print(f"[serve_fleet]   {router.url}/{key}/estimate")
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            print("\n[serve_fleet] shutting down")
    return 0


if __name__ == "__main__":
    sys.exit(main())
