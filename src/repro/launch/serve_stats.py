"""Stats-serving launcher: the `repro.service` endpoint over one dataset.

    PYTHONPATH=src python -m repro.launch.serve_stats --root /data/ds \
        --port 8080 --refresh-interval 30

    # self-contained smoke (CI): temp dataset, ephemeral port, scripted
    # client asserting estimate / 304 / plan / health, binary-negotiated
    # estimate parity, a per-tuple 200+304 /batch frame, a /cost join
    # order with 304 revalidation, clean shutdown
    PYTHONPATH=src python -m repro.launch.serve_stats --smoke

Query planners then pull estimates without local footer access:

    curl -s 'http://host:8080/estimate?mode=improved'
    curl -s -H 'If-None-Match: <etag>' 'http://host:8080/estimate?mode=improved'
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time
import urllib.error

from repro.engine import EngineConfig, EstimationEngine
from repro.service import StatsServer, StatsService, fetch_json
from repro.wire import ConnectionPool, fetch


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", help="dataset root directory (PQLite files)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080,
                    help="0 binds an ephemeral port")
    ap.add_argument("--refresh-interval", type=float, default=30.0,
                    help="seconds between background refreshes; 0 disables")
    ap.add_argument("--workers", type=int, default=8,
                    help="ingestion scatter-gather thread-pool width")
    ap.add_argument("--strategy", default="auto",
                    help="engine strategy (auto/local/sharded/chunked/composed)")
    ap.add_argument("--backend", default="auto",
                    help="kernel backend (auto/pallas/ref)")
    ap.add_argument("--auto-load-cache", action="store_true",
                    help="restore the dataset's estimate-cache spill on boot")
    ap.add_argument("--save-cache-on-commit", action="store_true",
                    help="spill the compacted estimate cache on each commit")
    ap.add_argument("--slow-request-ms", type=float, default=None,
                    help="log one structured line per request slower than "
                         "this many milliseconds (default: off)")
    ap.add_argument("--audit", action="store_true",
                    help="run the background accuracy auditor: sample columns "
                         "each refresh, sketch a reference NDV, record "
                         "q-error into /metrics (ndv_audit_qerror)")
    ap.add_argument("--audit-columns", type=int, default=4,
                    help="columns sampled per audit generation")
    ap.add_argument("--smoke", action="store_true",
                    help="boot on a temp dataset + ephemeral port, run a "
                         "scripted client, exit (asserts clean shutdown)")
    return ap


def _make_server(args: argparse.Namespace, root: str) -> StatsServer:
    engine = EstimationEngine(
        EngineConfig(strategy=args.strategy, backend=args.backend)
    )
    service = StatsService(
        root,
        engine=engine,
        max_workers=args.workers,
        poll_interval=args.refresh_interval or None,
        auto_load_cache=args.auto_load_cache,
        save_cache_on_commit=args.save_cache_on_commit,
        audit=args.audit,
        audit_columns=args.audit_columns,
    )
    return StatsServer(
        service,
        host=args.host,
        port=args.port,
        slow_request_ms=args.slow_request_ms,
    )


def _smoke_dataset() -> str:
    import numpy as np

    from repro.columnar.writer import WriterOptions, write_file

    root = os.path.join(tempfile.mkdtemp(), "smoke_ds")
    rng = np.random.default_rng(0)
    for i in range(3):
        write_file(
            os.path.join(root, f"shard_{i:03d}"),
            {
                "tok": rng.integers(0, 128, 1024).astype(np.int64),
                "val": np.round(rng.uniform(0, 50, 1024), 1),
            },
            options=WriterOptions(row_group_size=256),
        )
    return root


def run_smoke(args: argparse.Namespace) -> int:
    args = argparse.Namespace(**{**vars(args), "port": 0,
                                 "refresh_interval": 0.0, "audit": True})
    root = args.root or _smoke_dataset()
    with _make_server(args, root) as server:
        base = server.url
        status, etag, body = fetch_json(base + "/estimate?mode=improved")
        assert status == 200 and etag and body["estimates"], (status, body)
        status2, etag2, _ = fetch_json(base + "/estimate?mode=improved", etag=etag)
        assert status2 == 304 and etag2 == etag, (status2, etag2)
        status3, _, plans = fetch_json(base + "/plan?mode=improved")
        assert status3 == 200 and plans["plans"].keys() == body["estimates"].keys()
        status4, _, health = fetch_json(base + "/health")
        assert status4 == 200 and health["status"] == "serving"
        assert health["service"]["responses_304"] == 1, health["service"]
        # binary negotiation decodes bit-identically with the same ETag,
        # and a batched frame answers per-tuple (200 + 304 in one trip)
        pool = ConnectionPool()
        statusb, etagb, bodyb = fetch(
            base + "/estimate?mode=improved", pool=pool, binary=True
        )
        assert (statusb, etagb, bodyb) == (200, etag, body), statusb
        statusb, _, env = fetch(
            base + "/batch", pool=pool, method="POST",
            payload={"tuples": [{"mode": "paper"},
                                {"mode": "improved", "if_none_match": etag}]},
        )
        tuple_statuses = [e["status"] for e in env["responses"]]
        assert statusb == 200 and tuple_statuses == [200, 304], env
        # explain round-trip: provenance attaches without rotating the ETag
        # and the stripped body is byte-identical to the plain response
        # (quality-observability acceptance, ISSUE 9)
        statuse, etage, explained = fetch_json(
            base + "/estimate?mode=improved&explain=1"
        )
        assert statuse == 200 and etage == etag, (statuse, etage)
        assert explained["provenance"].keys() == body["estimates"].keys()
        assert {k: v for k, v in explained.items() if k != "provenance"} \
            == body, "explain must not perturb the response body"
        # planner tier: a self-join /cost over the served dataset answers
        # with a join order, and revalidates 304 on the same state-derived
        # ETag (cacheable POST acceptance, ISSUE 10)
        cost_payload = {"graph": {
            "tables": [{"name": "a"}, {"name": "b"}, {"name": "c"}],
            "edges": [
                {"left": "a", "left_column": "tok",
                 "right": "b", "right_column": "tok"},
                {"left": "b", "left_column": "tok",
                 "right": "c", "right_column": "tok"},
            ],
        }}
        statusc, etagc, cost = fetch(
            base + "/cost", pool=pool, payload=cost_payload, binary=False
        )
        assert statusc == 200 and etagc, (statusc, cost)
        assert sorted(cost["best_order"]) == ["a", "b", "c"], cost
        assert len(cost["joins"]) == 2 and cost["total_cost"] > 0, cost
        statusc2, etagc2, _ = fetch(
            base + "/cost", pool=pool, payload=cost_payload,
            etag=etagc, binary=False,
        )
        assert statusc2 == 304 and etagc2 == etagc, (statusc2, etagc2)
        # one synchronous audit pass (the background thread is event-driven;
        # the smoke drives it deterministically) feeds the q-error series
        server.service.run_audit()
        # /metrics serves the key series and /debug/traces recorded the
        # smoke's own batch (telemetry acceptance, ISSUE 8)
        import json as _json
        import urllib.request as _req

        with _req.urlopen(base + "/metrics") as r:
            metrics = r.read().decode()
        for series in ("ndv_http_requests_total", "ndv_service_responses_304",
                       "ndv_service_engine_runs", "ndv_batch_tuples",
                       "ndv_engine_dispatches_total", "ndv_route_total",
                       "ndv_audit_qerror", "planner_plans_scored_total",
                       "planner_dispatches_total"):
            assert series in metrics, f"/metrics missing {series}"
        with _req.urlopen(base + "/debug/traces?limit=10") as r:
            traces = _json.load(r)["traces"]
        assert any(t["name"] == "service.batch" for t in traces), \
            [t["name"] for t in traces]
        print(f"[serve_stats --smoke] ok: {len(body['estimates'])} columns, "
              f"etag {etag[:10]}..., 304 revalidation, "
              f"{health['ingest']['footers_read']} footers read async, "
              f"binary /estimate bit-identical, /batch per-tuple 200+304, "
              f"/cost join order with 304 revalidation, "
              f"?explain=1 provenance with stable ETag, audited q-error in "
              f"/metrics, /debug/traces scraped")
    # context exit shut the server down; a second connect must now fail
    try:
        fetch_json(base + "/health")
    except (urllib.error.URLError, ConnectionError):
        print("[serve_stats --smoke] clean shutdown verified")
        return 0
    print("[serve_stats --smoke] ERROR: server still answering after stop()",
          file=sys.stderr)
    return 1


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.smoke:
        return run_smoke(args)
    if not args.root:
        print("error: --root is required (or use --smoke)", file=sys.stderr)
        return 2
    with _make_server(args, args.root) as server:
        print(f"[serve_stats] serving {args.root} at {server.url} "
              f"(engine {server.service.engine.cache_token}, "
              f"refresh every {args.refresh_interval or 'never'}s)")
        print(f"[serve_stats] try: curl -s {server.url}/estimate")
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            print("\n[serve_stats] shutting down")
    return 0


if __name__ == "__main__":
    sys.exit(main())
