"""Training launcher (single-host runnable; multi-pod via launch scripts).

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen3-0.6b --smoke --steps 50 --data /tmp/repro_data

On a real cluster each host runs this entrypoint under
``scripts/launch_multipod.sh`` with JAX_COORDINATOR/process env wiring;
here the same code path runs on the local device set.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, TokenPipeline, synthesize_token_dataset
from repro.models import registry
from repro.train import optimizer as opt
from repro.train.train_step import init_train_state
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--data", default="/tmp/repro_data")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = (
        registry.get_smoke_config(args.arch)
        if args.smoke
        else registry.get_config(args.arch)
    )
    cfg = cfg.scaled(dtype="float32", param_dtype="float32") if args.smoke else cfg
    model = registry.build_model(cfg)

    if not os.path.exists(args.data):
        print(f"[train] synthesizing token dataset at {args.data}")
        synthesize_token_dataset(args.data, vocab_size=min(cfg.vocab_size, 4096))

    pipe = TokenPipeline(
        DataConfig(root=args.data, batch_size=args.batch, seq_len=args.seq)
    )
    est = pipe.vocab_estimate()
    if est:
        print(
            f"[train] zero-cost NDV plan: tokens ndv~{est.ndv:.0f} "
            f"layout={est.layout.name} staging={pipe.plan.total_staging_bytes/1e6:.1f}MB"
        )

    state = init_train_state(model, cfg)
    trainer = Trainer(
        model, cfg, opt.AdamWConfig(lr=args.lr),
        schedule=opt.cosine_schedule(10, args.steps),
        trainer_cfg=TrainerConfig(
            total_steps=args.steps, ckpt_dir=args.ckpt,
        ),
        num_microbatches=args.microbatches,
    )
    state, report = trainer.run(
        state, pipe.batches(epochs=100), resume=args.resume
    )
    print(
        f"[train] done: {report.steps_run} steps, final loss "
        f"{report.final_loss:.4f}"
        + (f" (resumed from {report.resumed_from})" if report.resumed_from else "")
    )


if __name__ == "__main__":
    main()
