"""Model configuration for every supported architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # "global": one fleet-wide capacity buffer (scatter into a replicated
    #   (E*C, D) buffer — simple, but the scatter-add forces giant
    #   all-reduces when experts can't shard the mesh's model axis).
    # "local": per-sequence-row dispatch (B, E, C_row, D) — every scatter
    #   stays on the row's own batch shard; no cross-shard reduction.
    dispatch: str = "global"
    # Pad the expert dimension (dead experts, never routed to) so it divides
    # the mesh's model axis and expert-parallelism engages (e.g. 40 -> 48 on
    # a 16-way axis). Padding waste shows up in the useful-flops ratio.
    pad_experts_to: Optional[int] = None
    # "local" dispatch granularity: split each sequence row into this many
    # sub-blocks and dispatch independently per sub-block. Set to the mesh's
    # model-axis size to shard dispatch buffers over "model" via the
    # sequence axis (zero buffer collectives; the capacity is per-sub-block,
    # raising drop variance slightly).
    sub_rows: int = 1

    @property
    def total_experts(self) -> int:
        return self.pad_experts_to or self.num_experts


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD block configuration."""

    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    # chunk=16 keeps the factored per-channel decay exponents fp32-safe
    # (see models/ssm.py rwkv6_time_mix).
    chunk: int = 16


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: Mamba2 backbone + a SHARED attention block applied
    every `attn_every` layers (weights reused at each application)."""

    attn_every: int = 6


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    num_encoder_layers: int = 24
    # audio/vision frontends are stubs: inputs arrive as frame embeddings.
    frontend_dim: int = 1024


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    vision_dim: int = 1024      # stub patch-embedding width
    num_patches: int = 576      # anyres base tile + thumbnails


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config per assigned architecture (src/repro/configs/<id>.py)."""

    name: str
    family: str                   # decoder|encdec|moe|hybrid|rwkv|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    qkv_bias: bool = False                  # qwen2
    qk_norm: bool = False                   # qwen3
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None    # mixtral SWA
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # training-time knobs
    remat_policy: str = "nothing_saveable"  # nothing_saveable|dots_saveable|none
    scan_layers: bool = True
    # per-arch gradient-accumulation override for train shapes (None = the
    # shape default); chosen per §Perf so every train cell fits 16GB HBM
    train_microbatches: Optional[int] = None
    dtype: str = "bfloat16"                 # activations/weights compute dtype
    param_dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "rwkv"

    @property
    def sub_quadratic(self) -> bool:
        """Supports the long_500k cell (SSM / linear / windowed attention)."""
        return self.family in ("hybrid", "rwkv") or self.sliding_window is not None

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced copy for smoke tests (same family, tiny dims)."""
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for 6ND rooflines."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        q = self.num_heads * hd
        kv = self.num_kv_heads * hd
        attn = d * q + 2 * d * kv + q * d
        mlp = 3 * d * f
        if self.family in ("moe",) and self.moe:
            mlp = self.moe.num_experts * 3 * d * f + d * self.moe.num_experts
        per_layer = attn + mlp + 2 * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "rwkv":
            # time-mix: r,k,v,g,o (5 d^2) + decay/bonus; channel-mix ~ 3 d^2+
            per_layer = 5 * d * d + 2 * d + d * int(3.5 * d) * 2
        if self.family == "hybrid" and self.ssm:
            di = self.ssm.expand * d
            nheads = di // self.ssm.head_dim
            per_layer = (
                d * (2 * di + 2 * self.ssm.state_dim + nheads)
                + di * self.ssm.conv_width
                + di * d
                + 2 * d
            )
        n = self.num_layers * per_layer + emb
        if self.family == "hybrid" and self.hybrid:
            n += attn + 3 * d * f  # the shared attention block
        if self.family == "encdec" and self.encdec:
            n += self.encdec.num_encoder_layers * per_layer
            n += self.num_layers * (d * q + 2 * d * kv + q * d + d)  # cross-attn
        if self.family == "vlm" and self.vlm:
            n += self.vlm.vision_dim * d + d * d  # projector
        return int(n)

    def active_param_count(self) -> int:
        """MoE-aware active parameters per token (for 6*N_active*D)."""
        if self.family != "moe" or not self.moe:
            return self.param_count()
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        q = self.num_heads * hd
        kv = self.num_kv_heads * hd
        attn = d * q + 2 * d * kv + q * d
        mlp_active = self.moe.top_k * 3 * d * f + d * self.moe.num_experts
        per_layer = attn + mlp_active + 2 * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        return int(self.num_layers * per_layer + emb)
