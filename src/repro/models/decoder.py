"""Decoder-only LM (dense GQA / MoE / sliding-window variants).

Layers are stacked along a leading "layers" axis and executed with
``lax.scan`` (+ per-layer ``jax.checkpoint`` with a configurable policy), so
HLO size — and 1-core CPU compile time for the 512-device dry-run — is
independent of depth. The same forward serves training and prefill; decode
runs one token against a stacked KV cache.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec
from repro.parallel.sharding import Logical, constrain

F32 = jnp.float32

REMAT_POLICIES = {
    "none": None,
    "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
    "dots_saveable": jax.checkpoint_policies.dots_saveable,
    "dots_with_no_batch_dims_saveable": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


class DecoderOutput(NamedTuple):
    logits: jnp.ndarray
    aux_loss: jnp.ndarray      # MoE load-balance (0 for dense)
    cache: Optional[Any]


class DecoderLM:
    """Dense / MoE decoder with GQA (+SWA, qk-norm, qkv-bias options)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_moe = cfg.family == "moe" and cfg.moe is not None

    # -- parameters ---------------------------------------------------------
    def specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        lyr = {
            "attn": L.attention_specs(cfg, layered=True),
            "ln1": ParamSpec((cfg.num_layers, cfg.d_model), ("layers", None), init="ones"),
            "ln2": ParamSpec((cfg.num_layers, cfg.d_model), ("layers", None), init="ones"),
        }
        if self.is_moe:
            lyr["moe"] = L.moe_specs(cfg, layered=True)
        else:
            lyr["mlp"] = L.mlp_specs(cfg, layered=True)
        return {"embed": L.embed_specs(cfg), "layers": lyr}

    # -- one transformer block (scanned) -------------------------------------
    def _block(self, carry, lp, positions, window, cache_kv=None):
        x, aux = carry
        cfg = self.cfg
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        attn_out, new_cache = L.mha(
            lp["attn"], h, cfg, positions,
            mode="causal", cache=cache_kv, window=window,
        )
        x = x + attn_out
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if self.is_moe:
            mlp_out, moe_aux = L.moe_block(lp["moe"], h, cfg)
            aux = aux + moe_aux.load_balance_loss
        else:
            mlp_out = L.swiglu(lp["mlp"], h)
        x = x + mlp_out
        # Seq-parallel archs keep the residual stream sequence-sharded over
        # "model" (no-op when seq_model rule is None / S==1 decode).
        x = constrain(x, "batch", "seq_model", "embed_no_fsdp")
        return (x, aux), new_cache

    def _scan_layers(self, params, x, positions, cache=None):
        cfg = self.cfg
        window = cfg.sliding_window
        policy = REMAT_POLICIES.get(cfg.remat_policy)

        def body(carry, xs):
            lp, ck = xs

            def inner(c, lp_, ck_):
                return self._block(c, lp_, positions, window, ck_)

            if policy is not None:
                inner = jax.checkpoint(inner, policy=policy)
            new_carry, new_ck = inner(carry, lp, ck)
            return new_carry, new_ck

        aux0 = jnp.zeros((), F32)
        if cfg.scan_layers:
            (x, aux), new_cache = jax.lax.scan(
                body, (x, aux0), (params["layers"], cache)
            )
        else:
            caches = []
            carry = (x, aux0)
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                ck = jax.tree.map(lambda a: a[i], cache) if cache is not None else None
                carry, ck2 = body(carry, (lp, ck))
                caches.append(ck2)
            x, aux = carry
            new_cache = (
                jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
                if cache is not None
                else None
            )
        return x, aux, new_cache

    # -- public API -----------------------------------------------------------
    def forward(
        self, params, batch: Dict[str, jnp.ndarray], last_only: bool = False
    ) -> DecoderOutput:
        """Training / prefill forward. batch: tokens (B,S) [+ positions].

        last_only=True computes logits for the final position only (the
        serving-prefill contract — avoids the (B,S,V) materialization)."""
        cfg = self.cfg
        params = L.cast_params(params, cfg.dtype)
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = batch.get(
            "positions", jnp.broadcast_to(jnp.arange(s), (b, s))
        )
        x = L.embed_tokens(params["embed"], tokens, cfg)
        x = self._prefix_inject(params, x, batch)
        x, aux, _ = self._scan_layers(params, x, positions, cache=None)
        if last_only:
            x = x[:, -1:]
        logits = L.lm_logits(params["embed"], x, cfg)
        return DecoderOutput(logits=logits, aux_loss=aux, cache=None)

    def _prefix_inject(self, params, x, batch):
        return x  # VLM subclass overrides

    # -- decode ----------------------------------------------------------------
    def cache_spec(self, batch: int, cache_len: int) -> Dict[str, Any]:
        """Abstract KV-cache (stacked over layers) + logical axes."""
        cfg = self.cfg
        t = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        hd = cfg.resolved_head_dim
        shape = (cfg.num_layers, batch, t, cfg.num_kv_heads, hd)
        axes = ("layers", "batch", "seq_sharded", "kv_heads", None)
        return {
            "k": ParamSpec(shape, axes, init="zeros"),
            "v": ParamSpec(shape, axes, init="zeros"),
            "index": ParamSpec((cfg.num_layers,), ("layers",), init="zeros"),
        }

    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16):
        sp = self.cache_spec(batch, cache_len)
        return {
            "k": jnp.zeros(sp["k"].shape, dtype),
            "v": jnp.zeros(sp["v"].shape, dtype),
            "index": jnp.zeros(sp["index"].shape, jnp.int32),
        }

    def decode_step(
        self, params, tokens: jnp.ndarray, positions: jnp.ndarray, cache
    ) -> DecoderOutput:
        """One-token decode. tokens: (B,1); cache: stacked KV dict."""
        cfg = self.cfg
        params = L.cast_params(params, cfg.dtype)
        x = L.embed_tokens(params["embed"], tokens, cfg)
        kv = jax.tree.map(lambda a: a, cache)
        cache_tuple = L.KVCache(k=kv["k"], v=kv["v"], index=kv["index"])
        # scan expects per-layer leading axis on cache leaves
        cache_xs = L.KVCache(
            k=cache_tuple.k, v=cache_tuple.v,
            index=cache_tuple.index.astype(jnp.int32),
        )
        x, aux, new_cache = self._scan_layers(
            params, x, positions, cache=cache_xs
        )
        logits = L.lm_logits(params["embed"], x, cfg)
        out_cache = {
            "k": new_cache.k, "v": new_cache.v, "index": new_cache.index
        }
        return DecoderOutput(logits=logits, aux_loss=aux, cache=out_cache)
