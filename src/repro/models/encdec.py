"""Encoder-decoder transformer (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings (B, S_enc, frontend_dim); a learned projector
maps them into d_model. The decoder is a standard causal stack with
cross-attention; at decode time the encoder output (and the cross-attention
K/V) are computed once at prefill and carried in the decode state.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.decoder import REMAT_POLICIES
from repro.models.params import ParamSpec
from repro.parallel.sharding import constrain

F32 = jnp.float32


class EncDecOutput(NamedTuple):
    logits: jnp.ndarray
    aux_loss: jnp.ndarray
    cache: Optional[Any]


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.encdec is not None
        self.cfg = cfg

    def specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        ne = cfg.encdec.num_encoder_layers
        d = cfg.d_model

        def stack(n):
            import dataclasses as dc

            enc_cfg = dc.replace(cfg, num_layers=n)
            return {
                "attn": L.attention_specs(enc_cfg, layered=True),
                "mlp": L.mlp_specs(enc_cfg, layered=True),
                "ln1": ParamSpec((n, d), ("layers", None), init="ones"),
                "ln2": ParamSpec((n, d), ("layers", None), init="ones"),
            }

        dec = stack(cfg.num_layers)
        import dataclasses as dc

        dcfg = dc.replace(cfg, num_layers=cfg.num_layers)
        dec["xattn"] = L.attention_specs(dcfg, layered=True)
        dec["ln_x"] = ParamSpec(
            (cfg.num_layers, d), ("layers", None), init="ones"
        )
        return {
            "embed": L.embed_specs(cfg),
            "frontend_proj": ParamSpec(
                (cfg.encdec.frontend_dim, d), ("embed", None)
            ),
            "enc_final_norm": ParamSpec((d,), (None,), init="ones"),
            "encoder": stack(ne),
            "decoder": dec,
        }

    # -- encoder ---------------------------------------------------------------
    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """frames: (B, S_enc, frontend_dim) from the (stub) audio frontend."""
        cfg = self.cfg
        x = jnp.einsum("bsf,fd->bsd", frames, params["frontend_proj"])
        x = constrain(x, "batch", None, "embed_no_fsdp")
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        policy = REMAT_POLICIES.get(cfg.remat_policy)

        def body(carry, lp):
            def inner(h, lp_):
                a = L.rmsnorm(h, lp_["ln1"], cfg.norm_eps)
                out, _ = L.mha(lp_["attn"], a, cfg, positions, mode="bidirectional")
                h = h + out
                a = L.rmsnorm(h, lp_["ln2"], cfg.norm_eps)
                h = h + L.swiglu(lp_["mlp"], a)
                return constrain(h, "batch", None, "embed_no_fsdp")

            if policy is not None:
                inner = jax.checkpoint(inner, policy=policy)
            return inner(carry, lp), None

        x, _ = jax.lax.scan(body, x, params["encoder"])
        return L.rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)

    # -- decoder ----------------------------------------------------------------
    def _decode_stack(self, params, x, positions, enc_out, cache=None):
        cfg = self.cfg
        policy = REMAT_POLICIES.get(cfg.remat_policy)

        def body(carry, xs):
            lp, ck = xs

            def inner(h, lp_, ck_):
                a = L.rmsnorm(h, lp_["ln1"], cfg.norm_eps)
                out, new_ck = L.mha(
                    lp_["attn"], a, cfg, positions, mode="causal", cache=ck_
                )
                h = h + out
                a = L.rmsnorm(h, lp_["ln_x"], cfg.norm_eps)
                out, _ = L.mha(lp_["xattn"], a, cfg, positions, mode="cross", kv_x=enc_out)
                h = h + out
                a = L.rmsnorm(h, lp_["ln2"], cfg.norm_eps)
                h = h + L.swiglu(lp_["mlp"], a)
                return constrain(h, "batch", None, "embed_no_fsdp"), new_ck

            if policy is not None:
                inner = jax.checkpoint(inner, policy=policy)
            h, new_ck = inner(carry, lp, ck)
            return h, new_ck

        x, new_cache = jax.lax.scan(body, x, (params["decoder"], cache))
        return x, new_cache

    # -- public ------------------------------------------------------------------
    def forward(
        self, params, batch: Dict[str, jnp.ndarray], last_only: bool = False
    ) -> EncDecOutput:
        """batch: frames (B,S_enc,F) + tokens (B,S_dec)."""
        cfg = self.cfg
        params = L.cast_params(params, cfg.dtype)
        enc_out = self.encode(params, batch["frames"].astype(cfg.dtype))
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = batch.get("positions", jnp.broadcast_to(jnp.arange(s), (b, s)))
        x = L.embed_tokens(params["embed"], tokens, cfg)
        x, _ = self._decode_stack(params, x, positions, enc_out, cache=None)
        if last_only:
            x = x[:, -1:]
        logits = L.lm_logits(params["embed"], x, cfg)
        return EncDecOutput(logits=logits, aux_loss=jnp.zeros((), F32), cache=None)

    def cache_spec(self, batch: int, cache_len: int, enc_len: int):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        shape = (cfg.num_layers, batch, cache_len, cfg.num_kv_heads, hd)
        axes = ("layers", "batch", "seq_sharded", "kv_heads", None)
        return {
            "k": ParamSpec(shape, axes, init="zeros"),
            "v": ParamSpec(shape, axes, init="zeros"),
            "index": ParamSpec((cfg.num_layers,), ("layers",), init="zeros"),
            "enc_out": ParamSpec(
                (batch, enc_len, cfg.d_model), ("batch", "seq_sharded", None),
                init="zeros",
            ),
        }

    def decode_step(self, params, tokens, positions, cache) -> EncDecOutput:
        cfg = self.cfg
        params = L.cast_params(params, cfg.dtype)
        x = L.embed_tokens(params["embed"], tokens, cfg)
        kv = L.KVCache(k=cache["k"], v=cache["v"], index=cache["index"].astype(jnp.int32))
        x, new_kv = self._decode_stack(
            params, x, positions, cache["enc_out"], cache=kv
        )
        logits = L.lm_logits(params["embed"], x, cfg)
        out = dict(cache)
        out.update({"k": new_kv.k, "v": new_kv.v, "index": new_kv.index})
        return EncDecOutput(logits=logits, aux_loss=jnp.zeros((), F32), cache=out)
