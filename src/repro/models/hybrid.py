"""Zamba2-style hybrid: Mamba2 backbone + SHARED attention block.

38 Mamba2 layers in three scanned segments; one attention+MLP block with
SHARED weights is applied between segments (two applications — the Zamba
trick: global-context mixing without per-layer attention cost). At decode
the Mamba states update in O(1) and only the shared block maintains KV
caches (one per application site), which is what keeps the long_500k cell
sub-quadratic.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.decoder import REMAT_POLICIES
from repro.models.params import ParamSpec
from repro.parallel.sharding import constrain

F32 = jnp.float32
NUM_SHARED_SITES = 2


class HybridOutput(NamedTuple):
    logits: jnp.ndarray
    aux_loss: jnp.ndarray
    cache: Optional[Any]


def _segments(n_layers: int) -> Tuple[Tuple[int, int], ...]:
    """Split layers into NUM_SHARED_SITES+1 contiguous segments."""
    k = NUM_SHARED_SITES + 1
    base = n_layers // k
    sizes = [base] * k
    for i in range(n_layers - base * k):
        sizes[i] += 1
    out, start = [], 0
    for s in sizes:
        out.append((start, start + s))
        start += s
    return tuple(out)


class HybridLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.ssm is not None and cfg.hybrid is not None
        self.cfg = cfg
        self.segments = _segments(cfg.num_layers)

    def specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        d = cfg.d_model
        shared = {
            "attn": L.attention_specs(cfg, layered=False),
            "mlp": L.mlp_specs(cfg, layered=False),
            "ln1": ParamSpec((d,), (None,), init="ones"),
            "ln2": ParamSpec((d,), (None,), init="ones"),
        }
        return {
            "embed": L.embed_specs(cfg),
            "mamba": {
                **ssm.mamba2_specs(cfg, layered=True),
                "ln": ParamSpec((cfg.num_layers, d), ("layers", None), init="ones"),
            },
            "shared": shared,
        }

    # -- segment scan over mamba layers ----------------------------------------
    def _mamba_segment(self, params, x, lo, hi, states=None):
        cfg = self.cfg
        policy = REMAT_POLICIES.get(cfg.remat_policy)
        seg_params = jax.tree.map(lambda a: a[lo:hi], params["mamba"])
        seg_states = (
            jax.tree.map(lambda a: a[lo:hi], states) if states is not None else None
        )

        def body(carry, xs):
            lp, st = xs

            def inner(h, lp_, st_):
                a = L.rmsnorm(h, lp_["ln"], cfg.norm_eps)
                mp = {k: v for k, v in lp_.items() if k != "ln"}
                if st_ is None:
                    out, new_st = ssm.mamba2_forward(mp, a, cfg)
                else:
                    out, new_st = ssm.mamba2_decode_step(mp, a, st_, cfg)
                return h + out, new_st

            if policy is not None:
                inner = jax.checkpoint(inner, policy=policy)
            h, new_st = inner(carry, lp, st)
            return h, new_st

        x, new_states = jax.lax.scan(body, x, (seg_params, seg_states))
        return x, new_states

    def _shared_block(self, params, x, positions, cache=None):
        cfg = self.cfg
        sp = params["shared"]
        h = L.rmsnorm(x, sp["ln1"], cfg.norm_eps)
        out, new_cache = L.mha(sp["attn"], h, cfg, positions, mode="causal", cache=cache)
        x = x + out
        h = L.rmsnorm(x, sp["ln2"], cfg.norm_eps)
        x = x + L.swiglu(sp["mlp"], h)
        return constrain(x, "batch", None, "embed_no_fsdp"), new_cache

    # -- public -------------------------------------------------------------------
    def forward(
        self, params, batch: Dict[str, jnp.ndarray], last_only: bool = False
    ) -> HybridOutput:
        cfg = self.cfg
        params = L.cast_params(params, cfg.dtype)
        tokens = batch["tokens"]
        b, s = tokens.shape
        pad = (-s) % cfg.ssm.chunk
        positions = batch.get("positions", jnp.broadcast_to(jnp.arange(s), (b, s)))
        x = L.embed_tokens(params["embed"], tokens, cfg)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            positions = jnp.pad(positions, ((0, 0), (0, pad)), mode="edge")
        for i, (lo, hi) in enumerate(self.segments):
            x, _ = self._mamba_segment(params, x, lo, hi)
            if i < NUM_SHARED_SITES:
                x, _ = self._shared_block(params, x, positions)
        if pad:
            x = x[:, :s]
        if last_only:
            x = x[:, -1:]
        logits = L.lm_logits(params["embed"], x, cfg)
        return HybridOutput(logits=logits, aux_loss=jnp.zeros((), F32), cache=None)

    # -- decode ---------------------------------------------------------------------
    def cache_spec(self, batch: int, cache_len: int):
        cfg = self.cfg
        di, nheads, conv_ch = ssm.mamba2_dims(cfg)
        hd = cfg.resolved_head_dim
        nl = cfg.num_layers
        return {
            "ssm": ParamSpec(
                (nl, batch, nheads, cfg.ssm.head_dim, cfg.ssm.state_dim),
                ("layers", "batch", "ff", None, None), init="zeros",
            ),
            "conv": ParamSpec(
                (nl, batch, cfg.ssm.conv_width - 1, conv_ch),
                ("layers", "batch", None, "ff"), init="zeros",
            ),
            "k": ParamSpec(
                (NUM_SHARED_SITES, batch, cache_len, cfg.num_kv_heads, hd),
                (None, "batch", "seq_sharded", "kv_heads", None), init="zeros",
            ),
            "v": ParamSpec(
                (NUM_SHARED_SITES, batch, cache_len, cfg.num_kv_heads, hd),
                (None, "batch", "seq_sharded", "kv_heads", None), init="zeros",
            ),
            "index": ParamSpec((NUM_SHARED_SITES,), (None,), init="zeros"),
        }

    def decode_step(self, params, tokens, positions, cache) -> HybridOutput:
        cfg = self.cfg
        params = L.cast_params(params, cfg.dtype)
        x = L.embed_tokens(params["embed"], tokens, cfg)
        new_ssm, new_conv, new_k, new_v, new_idx = [], [], [], [], []
        for i, (lo, hi) in enumerate(self.segments):
            x, seg_new = self._mamba_segment(
                params, x, lo, hi,
                states=ssm.Mamba2State(ssm=cache["ssm"], conv=cache["conv"]),
            )
            new_ssm.append((lo, hi, seg_new.ssm))
            new_conv.append((lo, hi, seg_new.conv))
            if i < NUM_SHARED_SITES:
                kv = L.KVCache(
                    k=cache["k"][i], v=cache["v"][i],
                    index=cache["index"][i].astype(jnp.int32),
                )
                x, nkv = self._shared_block(params, x, positions, cache=kv)
                new_k.append(nkv.k)
                new_v.append(nkv.v)
                new_idx.append(nkv.index)
        ssm_full = cache["ssm"]
        conv_full = cache["conv"]
        for lo, hi, val in new_ssm:
            ssm_full = jax.lax.dynamic_update_slice_in_dim(ssm_full, val, lo, axis=0)
        for lo, hi, val in new_conv:
            conv_full = jax.lax.dynamic_update_slice_in_dim(
                conv_full, val.astype(conv_full.dtype), lo, axis=0
            )
        logits = L.lm_logits(params["embed"], x, cfg)
        new_cache = {
            "ssm": ssm_full,
            "conv": conv_full,
            "k": jnp.stack(new_k),
            "v": jnp.stack(new_v),
            "index": jnp.stack(new_idx),
        }
        return HybridOutput(logits=logits, aux_loss=jnp.zeros((), F32), cache=new_cache)
