"""Transformer building blocks: norms, RoPE, GQA attention, SwiGLU, MoE.

All blocks are pure functions over (params, activations); params follow the
spec trees declared by each model. Sharding is annotated with logical axes
(`parallel.sharding.constrain`) so the same code runs on 1 CPU device
(constraints no-op) and the 512-chip production mesh (GSPMD partitioning).

Einsum accumulations that feed softmax/losses use
``preferred_element_type=float32`` — bf16 weights, fp32 accumulation, the
standard TPU MXU mixed-precision contract.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamSpec
from repro.parallel.sharding import constrain

F32 = jnp.float32


def cast_params(params, dtype) -> dict:
    """Mixed precision: cast float params to the compute dtype at use-site
    (master copies stay fp32 in the optimizer)."""
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda a: a.astype(dt) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        params,
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(F32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(F32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim)
    )


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) absolute token positions."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(F32) * freqs         # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional bias / qk-norm / sliding window / cross)
# ---------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig, layered: bool = True, cross: bool = False):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    lead = (cfg.num_layers,) if layered else ()
    lax_ = ("layers",) if layered else ()
    sp = {
        "wq": ParamSpec(lead + (d, hq * hd), lax_ + ("embed", "heads")),
        "wk": ParamSpec(lead + (d, hkv * hd), lax_ + ("embed", "kv_heads")),
        "wv": ParamSpec(lead + (d, hkv * hd), lax_ + ("embed", "kv_heads")),
        "wo": ParamSpec(lead + (hq * hd, d), lax_ + ("heads", "embed")),
    }
    if cfg.qkv_bias:
        sp["bq"] = ParamSpec(lead + (hq * hd,), lax_ + ("heads",), init="zeros")
        sp["bk"] = ParamSpec(lead + (hkv * hd,), lax_ + ("kv_heads",), init="zeros")
        sp["bv"] = ParamSpec(lead + (hkv * hd,), lax_ + ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        sp["q_norm"] = ParamSpec(lead + (hd,), lax_ + (None,), init="ones")
        sp["k_norm"] = ParamSpec(lead + (hd,), lax_ + (None,), init="ones")
    return sp


class KVCache(NamedTuple):
    """Decode-time cache. k/v: (B, T, Hkv, hd); index: scalar write pos.

    For sliding-window layers T == window and writes wrap (ring buffer).
    """

    k: jnp.ndarray
    v: jnp.ndarray
    index: jnp.ndarray  # () int32 — next write position (pre-wrap)


def _project_qkv(p, x, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    b, s, _ = x.shape
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _gqa_scores(q, k, cfg: ModelConfig):
    """(B,S,Hq,hd) x (B,T,Hkv,hd) -> (B,Hkv,G,S,T) fp32."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    q = q.reshape(b, s, hkv, g, hd)
    return jnp.einsum(
        "bskgh,btkh->bkgst", q, k, preferred_element_type=F32
    ) / (hd ** 0.5)


# Threshold above which full S x T score materialization is replaced by the
# blockwise online-softmax (flash-style) path. 4k trains fit comfortably;
# 32k prefills do not (scores would be ~GBs/device even sharded).
BLOCKWISE_MIN_SEQ = 8192
Q_CHUNK = 2048
KV_CHUNK = 2048
NEG_INF = -1e30


def _blockwise_attention(
    q, k, v, cfg: ModelConfig, positions, window: Optional[int],
    causal: bool = True,
) -> jnp.ndarray:
    """Causal attention with online softmax over (q-chunk, kv-chunk) tiles.

    TPU adaptation of FlashAttention's tiling: tiles are einsums feeding the
    MXU; the running (max, sum, acc) statistics live in fp32. Double
    ``lax.scan`` keeps HLO size O(1) in sequence length. Fully-masked tiles
    (beyond causal horizon / outside the sliding window) still execute —
    acceptable waste at window==chunk granularity, noted in EXPERIMENTS.
    """
    b, s, hq, hd = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    qc = min(Q_CHUNK, s)
    kc = min(KV_CHUNK, t)
    nq, nk = s // qc, t // kc
    assert s % qc == 0 and t % kc == 0, (s, t)

    qr = q.reshape(b, nq, qc, hkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    pr = positions.reshape(b, nq, qc).transpose(1, 0, 2)
    kr = k.reshape(b, nk, kc, hkv, hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, kc, hkv, hd).transpose(1, 0, 2, 3, 4)
    kpos = jnp.broadcast_to(jnp.arange(t), (b, t)).reshape(b, nk, kc)
    kpos = kpos.transpose(1, 0, 2)
    scale = hd ** -0.5

    def q_step(_, qi):
        q_i, qpos_i = qi                       # (B,qc,K,G,hd), (B,qc)
        q_i = constrain(q_i, "batch", "seq_model", "kv_heads", None, None)

        def kv_step(carry, kj):
            m, l, acc = carry
            k_j, v_j, kpos_j = kj              # (B,kc,K,hd), (B,kc)
            sc = jnp.einsum(
                "bqkgh,bckh->bkgqc", q_i, k_j, preferred_element_type=F32
            ) * scale                           # (B,K,G,qc,kc)
            if causal:
                mask = kpos_j[:, None, :] <= qpos_i[:, :, None]  # (B,qc,kc)
                if window is not None:
                    mask &= kpos_j[:, None, :] > qpos_i[:, :, None] - window
                sc = jnp.where(mask[:, None, None, :, :], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bkgqc,bckh->bkgqh", p.astype(v_j.dtype), v_j,
                preferred_element_type=F32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, qc), NEG_INF, F32)
        l0 = jnp.zeros((b, hkv, g, qc), F32)
        a0 = jnp.zeros((b, hkv, g, qc, hd), F32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kr, vr, kpos))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, qc, hq * hd)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qr, pr))   # (nq,B,qc,H*hd)
    return outs.transpose(1, 0, 2, 3).reshape(b, s, hq * hd)


def mha(
    p,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    *,
    mode: str = "causal",            # causal | bidirectional | cross
    kv_x: Optional[jnp.ndarray] = None,
    cache: Optional[KVCache] = None,
    window: Optional[int] = None,
) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Full multi-head attention with GQA and optional KV cache.

    Train/prefill: cache is None -> attends within x (or kv_x for cross).
    Decode: cache given, x is (B, 1, D); returns updated cache.
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    if mode == "cross":
        q, _, _ = _project_qkv(p, x, cfg)
        _, k, v = _project_qkv(p, kv_x, cfg)
        if s >= BLOCKWISE_MIN_SEQ and k.shape[1] >= BLOCKWISE_MIN_SEQ:
            out = _blockwise_attention(q, k, v, cfg, positions, None, causal=False)
            y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
            return constrain(y, "batch", None, "embed_no_fsdp"), None
    else:
        q, k, v = _project_qkv(p, x, cfg)
        if mode != "bidirectional":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    # Heads-TP when head counts divide the model axis; otherwise the rules
    # route "seq_model" -> "model" (Megatron sequence-parallel attention:
    # queries sharded by sequence block, K/V all-gathered).
    q = constrain(q, "batch", "seq_model", "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)

    new_cache = None
    if cache is not None:
        t_max = cache.k.shape[1]
        write = (
            jnp.mod(cache.index, t_max) if window is not None else cache.index
        )
        k_all = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), write, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), write, axis=1)
        new_cache = KVCache(k=k_all, v=v_all, index=cache.index + s)
        k, v = k_all, v_all
        t = t_max
        # Key absolute positions for masking/rope-consistency: ring or linear.
        slots = jnp.arange(t)
        if window is not None:
            # slot holds absolute position p if p ≡ slot (mod t) and p <= cur.
            cur = cache.index + s - 1
            wraps = (cur - slots) // t_max
            key_pos = cur - jnp.mod(cur - slots, t_max)
            key_pos = jnp.broadcast_to(key_pos, (b, t))
        else:
            key_pos = jnp.broadcast_to(slots, (b, t))
    else:
        t = k.shape[1]
        key_pos = (
            jnp.broadcast_to(jnp.arange(t), (b, t))
            if mode != "cross"
            else None
        )
        # Long-sequence path: blockwise online softmax (causal or bidi).
        if mode in ("causal", "bidirectional") and s >= BLOCKWISE_MIN_SEQ and s == t:
            out = _blockwise_attention(
                q, k, v, cfg, positions, window, causal=(mode == "causal")
            )
            y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
            return constrain(y, "batch", None, "embed_no_fsdp"), None

    scores = _gqa_scores(q, k, cfg)                     # (B,K,G,S,T)

    if mode == "causal" or (mode == "decode"):
        qpos = positions[:, :, None]                    # (B,S,1)
        kpos = key_pos[:, None, :]                      # (B,1,T)
        mask = (kpos <= qpos) & (kpos >= 0)
        if window is not None:
            mask &= kpos > qpos - window
        if cache is not None:
            mask &= kpos[..., :] <= (cache.index + s - 1)[None, None]
            # unwritten slots (pos beyond current) already excluded above
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    elif mode == "bidirectional" and cache is None:
        pass  # full attention over the sequence

    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    out = out.reshape(b, s, cfg.num_heads * hd)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return constrain(y, "batch", None, "embed_no_fsdp"), new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, layered: bool = True, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    lead = (cfg.num_layers,) if layered else ()
    lax_ = ("layers",) if layered else ()
    return {
        "wi": ParamSpec(lead + (d, f), lax_ + ("embed", "ff")),
        "wg": ParamSpec(lead + (d, f), lax_ + ("embed", "ff")),
        "wo": ParamSpec(lead + (f, d), lax_ + ("ff", "embed")),
    }


def swiglu(p, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    g = jnp.einsum("bsd,df->bsf", x, p["wg"])
    h = h * jax.nn.silu(g.astype(F32)).astype(x.dtype)
    h = constrain(h, "batch", None, "ff")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-based dispatch, EP/TP shardable)
# ---------------------------------------------------------------------------


def moe_specs(cfg: ModelConfig, layered: bool = True):
    assert cfg.moe is not None
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.total_experts
    lead = (cfg.num_layers,) if layered else ()
    lax_ = ("layers",) if layered else ()
    return {
        "router": ParamSpec(lead + (d, e), lax_ + ("embed", None)),
        "wi": ParamSpec(lead + (e, d, f), lax_ + ("experts", "embed", "ff")),
        "wg": ParamSpec(lead + (e, d, f), lax_ + ("experts", "embed", "ff")),
        "wo": ParamSpec(lead + (e, f, d), lax_ + ("experts", "ff", "embed")),
    }


class MoEAux(NamedTuple):
    load_balance_loss: jnp.ndarray
    dropped_fraction: jnp.ndarray


def moe_block(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, MoEAux]:
    if cfg.moe.dispatch == "local":
        return moe_block_local(p, x, cfg)
    return moe_block_global(p, x, cfg)


def moe_block_global(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, MoEAux]:
    """Top-k MoE with static capacity (sort-based dispatch, no host ragged).

    Dispatch: flatten tokens, stable-sort (expert, entry) pairs, compute each
    entry's slot within its expert, scatter into an (E, C, D) buffer, run all
    expert FFNs as one batched einsum, gather back weighted by gates.
    """
    assert cfg.moe is not None
    e, k_top = cfg.moe.num_experts, cfg.moe.top_k
    b, s, d = x.shape
    n = b * s
    cap = int(max(1, round(n * k_top * cfg.moe.capacity_factor / e)))

    logits = jnp.einsum("bsd,de->bse", x, p["router"], preferred_element_type=F32)
    top_val, top_idx = jax.lax.top_k(logits, k_top)           # (B,S,K)
    gates = jax.nn.softmax(top_val, axis=-1)                   # renormalized

    flat_e = top_idx.reshape(n * k_top)                        # (NK,)
    flat_tok = jnp.repeat(jnp.arange(n), k_top)                # (NK,)
    flat_gate = gates.reshape(n * k_top)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e))         # (E,)
    slot = jnp.arange(n * k_top) - starts[sorted_e]            # rank in expert
    keep = slot < cap
    flat_slot = jnp.where(keep, sorted_e * cap + slot, e * cap)  # drop bucket

    x_flat = x.reshape(n, d)
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[flat_slot].add(x_flat[flat_tok[order]])
    buf = buf[: e * cap].reshape(e, cap, d)
    buf = constrain(buf, "experts", None, "embed_no_fsdp")

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    h = h * jax.nn.silu(g.astype(F32)).astype(x.dtype)
    h = constrain(h, "experts", None, "ff")
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(e * cap, d)
    y_buf = jnp.concatenate([y_buf, jnp.zeros((1, d), y_buf.dtype)], axis=0)

    contrib = y_buf[flat_slot] * flat_gate[order][:, None].astype(y_buf.dtype)
    y = jnp.zeros((n, d), x.dtype).at[flat_tok[order]].add(contrib)

    # Aux telemetry: Switch-style load-balance loss + drop rate.
    probs = jax.nn.softmax(logits, axis=-1)                    # (B,S,E)
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(top_idx, e).sum(axis=2)).reshape(n, e), axis=0
    )
    frac_probs = jnp.mean(probs.reshape(n, e), axis=0)
    lb_loss = e * jnp.sum(frac_tokens * frac_probs) / k_top
    dropped = 1.0 - jnp.sum(keep) / (n * k_top)
    return y.reshape(b, s, d), MoEAux(lb_loss, dropped)


def moe_block_local(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, MoEAux]:
    """Per-row MoE dispatch: every scatter stays on its own batch shard.

    The global dispatch scatters all tokens into ONE (E*C, D) buffer; when
    the expert count cannot shard the model axis that buffer is replicated
    and XLA must all-reduce it per layer (TBs of ICI on the 16x16 mesh —
    the dominant collective in the MoE baselines). Here each sequence row
    dispatches into its own (E, C_row, D) buffer: buffers are sharded over
    the batch axes exactly like activations, sorting/scattering is row-local,
    and the only collectives left are the FSDP weight gathers. Capacity is
    per-row (C_row = S*k*cf/E), trading slightly higher drop variance for
    locality — the standard per-device-capacity MoE trade.
    """
    assert cfg.moe is not None
    e_real, k_top = cfg.moe.num_experts, cfg.moe.top_k
    e = cfg.moe.total_experts
    b, s, d = x.shape
    sub = cfg.moe.sub_rows
    if sub > 1 and s % sub == 0:
        # Sub-row dispatch: (B, S, D) -> (B, sub, S/sub, D); the sub axis
        # carries "seq_model" so buffers shard over the model axis with no
        # buffer collectives at all.
        xs = x.reshape(b, sub, s // sub, d)
        xs = constrain(xs, "batch", "moe_seq", None, "embed_no_fsdp")
        y4, aux = _moe_local_core(p, xs, cfg)
        return y4.reshape(b, s, d), aux
    y, aux = _moe_local_core(p, x[:, None], cfg)
    return y.reshape(b, s, d), aux


def _moe_local_core(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, MoEAux]:
    """x: (B, U, S_u, D) — dispatch independently per (row, sub-block)."""
    e_real, k_top = cfg.moe.num_experts, cfg.moe.top_k
    e = cfg.moe.total_experts
    b, u, s, d = x.shape
    nk = s * k_top
    cap = int(max(1, round(nk * cfg.moe.capacity_factor / e_real)))

    logits = jnp.einsum("busd,de->buse", x, p["router"], preferred_element_type=F32)
    if e != e_real:  # padded (dead) experts are never routed to
        pad_mask = jnp.arange(e) >= e_real
        logits = jnp.where(pad_mask[None, None, None, :], -1e30, logits)
    top_val, top_idx = jax.lax.top_k(logits, k_top)            # (B,U,S,K)
    gates = jax.nn.softmax(top_val, axis=-1)

    def dispatch_row(xr, er, gr):
        # xr: (S,D); er, gr: (S*K,)
        order = jnp.argsort(er, stable=True)
        se = er[order]
        starts = jnp.searchsorted(se, jnp.arange(e))
        slot = jnp.arange(nk) - starts[se]
        keep = slot < cap
        fs = jnp.where(keep, se * cap + slot, e * cap)
        buf = jnp.zeros((e * cap + 1, d), xr.dtype).at[fs].add(xr[order // k_top])
        return buf[: e * cap].reshape(e, cap, d), order, fs, jnp.sum(keep)

    dispatch = jax.vmap(jax.vmap(dispatch_row))
    buf, order, fs, kept = dispatch(
        x, top_idx.reshape(b, u, nk), gates.reshape(b, u, nk)
    )
    buf = constrain(buf, "batch", "moe_seq", "experts", None, "embed_no_fsdp")

    h = jnp.einsum("buecd,edf->buecf", buf, p["wi"])
    g = jnp.einsum("buecd,edf->buecf", buf, p["wg"])
    h = h * jax.nn.silu(g.astype(F32)).astype(x.dtype)
    h = constrain(h, "batch", "moe_seq", "experts", None, "ff")
    y_buf = jnp.einsum("buecf,efd->buecd", h, p["wo"])
    y_buf = y_buf.reshape(b, u, e * cap, d)
    y_buf = jnp.concatenate(
        [y_buf, jnp.zeros((b, u, 1, d), y_buf.dtype)], axis=2
    )

    def combine_row(ybr, order_r, fs_r, gr):
        contrib = ybr[fs_r] * gr[order_r][:, None].astype(ybr.dtype)
        return jnp.zeros((s, d), ybr.dtype).at[order_r // k_top].add(contrib)

    y = jax.vmap(jax.vmap(combine_row))(
        y_buf, order, fs, gates.reshape(b, u, nk)
    )

    probs = jax.nn.softmax(logits, axis=-1)
    n = b * u * s
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(top_idx, e).sum(axis=3)).reshape(n, e), axis=0
    )
    frac_probs = jnp.mean(probs.reshape(n, e), axis=0)
    lb_loss = e * jnp.sum(frac_tokens * frac_probs) / k_top
    dropped = 1.0 - jnp.sum(kept) / (n * k_top)
    y = constrain(y, "batch", "moe_seq", None, "embed_no_fsdp")
    return y, MoEAux(lb_loss, dropped)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------


def embed_specs(cfg: ModelConfig):
    sp = {
        "embedding": ParamSpec(
            (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=1.0
        ),
        "final_norm": ParamSpec((cfg.d_model,), ("embed_no_fsdp",), init="ones"),
    }
    if not cfg.tie_embeddings:
        sp["lm_head"] = ParamSpec(
            (cfg.d_model, cfg.vocab_size), ("embed", "vocab")
        )
    return sp


def embed_tokens(p, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = p["embedding"][tokens]
    return constrain(x, "batch", None, "embed_no_fsdp")


def lm_logits(p, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    w = p["embedding"].T if cfg.tie_embeddings else p["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=F32)
    return constrain(logits, "batch", None, "vocab")
