"""Parameter specification system.

Every model declares its parameters as a nested dict of ``ParamSpec`` (shape
+ logical axes + initializer). From one spec tree we derive:

  * materialized params (PRNG init) — smoke tests / examples / training;
  * ShapeDtypeStructs — the dry-run path (never allocates);
  * NamedShardings — via the logical->mesh rule table (parallel/sharding).

Layer stacks are declared with a leading "layers" axis so the forward pass
can ``lax.scan`` over stacked weights (bounded HLO size for 62-layer
models, which is what keeps 512-device CPU compiles tractable).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import Logical


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axes, len == len(shape)
    init: str = "normal"              # normal | zeros | ones | scaled
    scale: Optional[float] = None     # stddev; default fan-in scaled

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Params = Dict[str, Any]   # nested dict of jnp arrays
Specs = Dict[str, Any]    # nested dict of ParamSpec


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs: Specs, key: jax.Array, dtype=jnp.float32) -> Params:
    """Materialize parameters with per-leaf PRNG splits."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = spec.scale if spec.scale is not None else fan_in ** -0.5
            out.append(jax.random.normal(k, spec.shape, dtype) * std)
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs: Specs, dtype=jnp.bfloat16) -> Params:
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs, is_leaf=_is_spec
    )


def logical_tree(specs: Specs) -> Any:
    """Tree of Logical annotations (same structure as params)."""
    return jax.tree.map(lambda s: Logical(s.axes), specs, is_leaf=_is_spec)


def param_count(specs: Specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def param_bytes(specs: Specs, bytes_per: int = 2) -> int:
    return param_count(specs) * bytes_per
