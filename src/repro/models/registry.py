"""Model registry: family string -> model class; arch id -> config."""
from __future__ import annotations

import importlib
from typing import Any, Dict

from repro.models.config import ModelConfig
from repro.models.decoder import DecoderLM
from repro.models.encdec import EncDecLM
from repro.models.hybrid import HybridLM
from repro.models.rwkv import RWKVLM
from repro.models.vlm import VLMDecoderLM

FAMILIES = {
    "decoder": DecoderLM,
    "dense": DecoderLM,
    "moe": DecoderLM,
    "hybrid": HybridLM,
    "rwkv": RWKVLM,
    "vlm": VLMDecoderLM,
    "encdec": EncDecLM,
}

ARCHS = (
    "seamless_m4t_large_v2",
    "qwen2_7b",
    "qwen3_0_6b",
    "deepseek_coder_33b",
    "yi_6b",
    "granite_moe_3b_a800m",
    "mixtral_8x22b",
    "zamba2_1_2b",
    "llava_next_mistral_7b",
    "rwkv6_7b",
)

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}


def canonical(arch: str) -> str:
    a = arch.replace("-", "_").replace(".", "_")
    if a in ARCHS:
        return a
    if arch in _ALIAS:
        return _ALIAS[arch]
    raise KeyError(f"unknown arch {arch!r}; known: {', '.join(ARCHS)}")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.SMOKE_CONFIG


def build_model(cfg: ModelConfig):
    return FAMILIES[cfg.family](cfg)


def build(arch: str):
    cfg = get_config(arch)
    return build_model(cfg), cfg
