"""RWKV-6 "Finch" LM: attention-free, per-channel data-dependent decay."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.decoder import REMAT_POLICIES
from repro.models.params import ParamSpec
from repro.parallel.sharding import constrain

F32 = jnp.float32


class RWKVOutput(NamedTuple):
    logits: jnp.ndarray
    aux_loss: jnp.ndarray
    cache: Optional[Any]


class RWKVLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.rwkv is not None
        self.cfg = cfg

    def specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        d = cfg.d_model
        return {
            "embed": L.embed_specs(cfg),
            "layers": {
                **ssm.rwkv6_specs(cfg, layered=True),
                "ln1": ParamSpec((cfg.num_layers, d), ("layers", None), init="ones"),
                "ln2": ParamSpec((cfg.num_layers, d), ("layers", None), init="ones"),
            },
        }

    def _scan_layers(self, params, x, decode_states=None):
        cfg = self.cfg
        policy = REMAT_POLICIES.get(cfg.remat_policy)
        b = x.shape[0]

        def body(carry, xs):
            lp, st = xs

            def inner(h, lp_, st_):
                if st_ is None:
                    st_ = ssm.rwkv6_init_state(cfg, b, h.dtype)
                a = L.rmsnorm(h, lp_["ln1"], cfg.norm_eps)
                if h.shape[1] == 1 and decode_states is not None:
                    tm_out, st_ = ssm.rwkv6_decode_step(lp_, a, st_, cfg)
                else:
                    tm_out, st_ = ssm.rwkv6_time_mix(lp_, a, cfg, st_)
                h = h + tm_out
                a = L.rmsnorm(h, lp_["ln2"], cfg.norm_eps)
                cm_out, st_ = ssm.rwkv6_channel_mix(lp_, a, cfg, st_)
                h = h + cm_out
                return constrain(h, "batch", None, "embed_no_fsdp"), st_

            if policy is not None:
                inner = jax.checkpoint(inner, policy=policy)
            h, new_st = inner(carry, lp, st)
            return h, new_st

        x, new_states = jax.lax.scan(body, x, (params["layers"], decode_states))
        return x, new_states

    def forward(
        self, params, batch: Dict[str, jnp.ndarray], last_only: bool = False
    ) -> RWKVOutput:
        cfg = self.cfg
        params = L.cast_params(params, cfg.dtype)
        tokens = batch["tokens"]
        b, s = tokens.shape
        pad = (-s) % cfg.rwkv.chunk
        x = L.embed_tokens(params["embed"], tokens, cfg)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        x, _ = self._scan_layers(params, x)
        if pad:
            x = x[:, :s]
        if last_only:
            x = x[:, -1:]
        logits = L.lm_logits(params["embed"], x, cfg)
        return RWKVOutput(logits=logits, aux_loss=jnp.zeros((), F32), cache=None)

    # -- decode -----------------------------------------------------------------
    def cache_spec(self, batch: int, cache_len: int):
        """RWKV decode state is O(1) — cache_len is irrelevant (linear attn)."""
        cfg = self.cfg
        nheads, hd = ssm.rwkv6_dims(cfg)
        nl = cfg.num_layers
        return {
            "tm_x": ParamSpec((nl, batch, cfg.d_model), ("layers", "batch", None), init="zeros"),
            "cm_x": ParamSpec((nl, batch, cfg.d_model), ("layers", "batch", None), init="zeros"),
            "wkv": ParamSpec(
                (nl, batch, nheads, hd, hd),
                ("layers", "batch", "heads", None, None), init="zeros",
            ),
        }

    def decode_step(self, params, tokens, positions, cache) -> RWKVOutput:
        cfg = self.cfg
        params = L.cast_params(params, cfg.dtype)
        x = L.embed_tokens(params["embed"], tokens, cfg)
        states = ssm.RWKVState(
            tm_x=cache["tm_x"], cm_x=cache["cm_x"], wkv=cache["wkv"]
        )
        x, new_states = self._scan_layers(params, x, decode_states=states)
        logits = L.lm_logits(params["embed"], x, cfg)
        new_cache = {
            "tm_x": new_states.tm_x, "cm_x": new_states.cm_x, "wkv": new_states.wkv
        }
        return RWKVOutput(logits=logits, aux_loss=jnp.zeros((), F32), cache=new_cache)
