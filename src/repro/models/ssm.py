"""SSM / linear-attention blocks: Mamba2 (SSD) and RWKV-6 (Finch).

Both are implemented in the CHUNKED form (the TPU-native formulation):
within-chunk terms are dense einsums that feed the MXU; cross-chunk terms
carry an O(d_state) recurrent state through a ``lax.scan`` over chunks. This
is the standard hardware adaptation of the papers' CUDA scans — no warp
primitives involved, and compile size stays constant in sequence length.

Decode uses the exact O(1)-per-token recurrences (`*_decode_step`), which is
what makes the ``long_500k`` cell feasible for these families.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamSpec
from repro.parallel.sharding import constrain

F32 = jnp.float32


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================


def mamba2_dims(cfg: ModelConfig):
    ssm = cfg.ssm
    di = ssm.expand * cfg.d_model
    nheads = di // ssm.head_dim
    conv_ch = di + 2 * ssm.state_dim
    return di, nheads, conv_ch


def mamba2_specs(cfg: ModelConfig, layered: bool = True):
    ssm = cfg.ssm
    d = cfg.d_model
    di, nheads, conv_ch = mamba2_dims(cfg)
    lead = (cfg.num_layers,) if layered else ()
    lx = ("layers",) if layered else ()
    return {
        "in_proj": ParamSpec(
            lead + (d, 2 * di + 2 * ssm.state_dim + nheads), lx + ("embed", "ff")
        ),
        "conv_w": ParamSpec(lead + (ssm.conv_width, conv_ch), lx + (None, "ff")),
        "conv_b": ParamSpec(lead + (conv_ch,), lx + ("ff",), init="zeros"),
        "A_log": ParamSpec(lead + (nheads,), lx + ("ff",), init="zeros"),
        "D": ParamSpec(lead + (nheads,), lx + ("ff",), init="ones"),
        "dt_bias": ParamSpec(lead + (nheads,), lx + ("ff",), init="zeros"),
        "norm": ParamSpec(lead + (di,), lx + ("ff",), init="ones"),
        "out_proj": ParamSpec(lead + (di, d), lx + ("ff", "embed")),
    }


class Mamba2State(NamedTuple):
    ssm: jnp.ndarray   # (B, H, head_dim, state)
    conv: jnp.ndarray  # (B, conv_width-1, conv_ch)


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d. xbc: (B,S,C); w: (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :]
        for i in range(width)
    )
    return jax.nn.silu((out + b).astype(F32)).astype(xbc.dtype)


def _split_zxbcdt(p, x, cfg: ModelConfig):
    ssm = cfg.ssm
    di, nheads, _ = mamba2_dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * ssm.state_dim]
    dt = zxbcdt[..., 2 * di + 2 * ssm.state_dim :]
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di]
    Bm = xbc[..., di : di + ssm.state_dim]
    Cm = xbc[..., di + ssm.state_dim :]
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))  # (B,S,H)
    return z, xs, Bm, Cm, dt


def mamba2_forward(
    p,
    x: jnp.ndarray,
    cfg: ModelConfig,
    initial_state: Optional[Mamba2State] = None,
) -> Tuple[jnp.ndarray, Mamba2State]:
    """Chunked SSD scan. x: (B,S,D) with S % chunk == 0 (caller pads)."""
    ssm = cfg.ssm
    di, nheads, conv_ch = mamba2_dims(cfg)
    hd, ns, L = ssm.head_dim, ssm.state_dim, ssm.chunk
    b, s, _ = x.shape
    nc = s // L

    z, xs, Bm, Cm, dt = _split_zxbcdt(p, x, cfg)
    A = -jnp.exp(p["A_log"].astype(F32))                      # (H,) negative
    dA = dt * A                                                # (B,S,H) log-decay

    xh = xs.reshape(b, nc, L, nheads, hd)
    dtc = dt.reshape(b, nc, L, nheads)
    dAc = dA.reshape(b, nc, L, nheads)
    Bc = Bm.reshape(b, nc, L, ns).astype(F32)
    Cc = Cm.reshape(b, nc, L, ns).astype(F32)
    xdt = xh.astype(F32) * dtc[..., None]                      # discretized input

    cum = jnp.cumsum(dAc, axis=2)                              # (B,nc,L,H)
    total = cum[:, :, -1, :]                                   # (B,nc,H)

    # Within-chunk (quadratic in L, masked): G[t,s] = exp(cum_t - cum_s), s<=t
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    G = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    att = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)                # (B,nc,L,L)
    y_intra = jnp.einsum("bclm,bclmh,bcmhp->bclhp", att, G, xdt)

    # Cross-chunk state scan: S' = exp(total) S + sum_s exp(total-cum_s) B_s x_s
    carry_in = jnp.einsum(
        "bclh,bcln,bclhp->bchpn", jnp.exp(total[:, :, None, :] - cum), Bc, xdt
    )                                                           # (B,nc,H,P,N)
    init = (
        initial_state.ssm.astype(F32)
        if initial_state is not None
        else jnp.zeros((b, nheads, hd, ns), F32)
    )

    def step(state, inputs):
        tot_c, inc_c = inputs                                   # (B,H), (B,H,P,N)
        new = state * jnp.exp(tot_c)[:, :, None, None] + inc_c
        return new, state                                       # emit PRE-state

    totals = jnp.moveaxis(total, 1, 0)                          # (nc,B,H)
    incs = jnp.moveaxis(carry_in, 1, 0)                         # (nc,B,H,P,N)
    final_state, prior = jax.lax.scan(step, init, (totals, incs))
    prior = jnp.moveaxis(prior, 0, 1)                           # (B,nc,H,P,N)

    y_inter = jnp.einsum(
        "bcln,bclh,bchpn->bclhp", Cc, jnp.exp(cum), prior
    )
    y = (y_intra + y_inter).reshape(b, s, nheads, hd)
    y = y + p["D"].astype(F32)[None, None, :, None] * xh.reshape(b, s, nheads, hd).astype(F32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(F32)).astype(x.dtype)          # gate
    # grouped rmsnorm over di
    var = jnp.mean(jnp.square(y.astype(F32)), axis=-1, keepdims=True)
    y = (y.astype(F32) * jax.lax.rsqrt(var + 1e-6) * p["norm"].astype(F32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])

    conv_tail_src = jnp.concatenate(
        [
            jnp.zeros((b, cfg.ssm.conv_width - 1, conv_ch), x.dtype),
            _conv_input(p, x, cfg),
        ],
        axis=1,
    )[:, -(cfg.ssm.conv_width - 1) :, :]
    return constrain(out, "batch", None, "embed_no_fsdp"), Mamba2State(
        ssm=final_state, conv=conv_tail_src
    )


def _conv_input(p, x, cfg):
    """Pre-conv xBC stream (needed to seed the decode conv cache)."""
    ssm = cfg.ssm
    di, _, _ = mamba2_dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    return zxbcdt[..., di : 2 * di + 2 * ssm.state_dim]


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Mamba2State:
    di, nheads, conv_ch = mamba2_dims(cfg)
    return Mamba2State(
        ssm=jnp.zeros((batch, nheads, cfg.ssm.head_dim, cfg.ssm.state_dim), F32),
        conv=jnp.zeros((batch, cfg.ssm.conv_width - 1, conv_ch), dtype),
    )


def mamba2_decode_step(
    p, x: jnp.ndarray, state: Mamba2State, cfg: ModelConfig
) -> Tuple[jnp.ndarray, Mamba2State]:
    """O(1) recurrence. x: (B,1,D)."""
    ssm = cfg.ssm
    di, nheads, conv_ch = mamba2_dims(cfg)
    hd, ns = ssm.head_dim, ssm.state_dim
    b = x.shape[0]

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z = zxbcdt[..., :di]
    xbc_new = zxbcdt[:, 0, di : 2 * di + 2 * ns]               # (B,C)
    dt = zxbcdt[..., 2 * di + 2 * ns :]

    conv_buf = jnp.concatenate([state.conv, xbc_new[:, None, :]], axis=1)
    w = p["conv_w"]
    acc = sum(conv_buf[:, i, :] * w[i][None, :] for i in range(w.shape[0]))
    xbc = jax.nn.silu((acc + p["conv_b"]).astype(F32)).astype(x.dtype)

    xs = xbc[:, :di].reshape(b, nheads, hd)
    Bm = xbc[:, di : di + ns].astype(F32)
    Cm = xbc[:, di + ns :].astype(F32)
    dt = jax.nn.softplus(dt[:, 0, :].astype(F32) + p["dt_bias"].astype(F32))
    A = -jnp.exp(p["A_log"].astype(F32))
    decay = jnp.exp(dt * A)                                     # (B,H)

    xdt = xs.astype(F32) * dt[..., None]                        # (B,H,P)
    new_ssm = state.ssm * decay[:, :, None, None] + jnp.einsum(
        "bhp,bn->bhpn", xdt, Bm
    )
    y = jnp.einsum("bhpn,bn->bhp", new_ssm, Cm)
    y = y + p["D"].astype(F32)[None, :, None] * xs.astype(F32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(F32)).astype(x.dtype)
    var = jnp.mean(jnp.square(y.astype(F32)), axis=-1, keepdims=True)
    y = (y.astype(F32) * jax.lax.rsqrt(var + 1e-6) * p["norm"].astype(F32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, Mamba2State(ssm=new_ssm, conv=conv_buf[:, 1:, :])


# ===========================================================================
# RWKV-6 (Finch)
# ===========================================================================


def rwkv6_dims(cfg: ModelConfig):
    hd = cfg.rwkv.head_dim
    nheads = cfg.d_model // hd
    return nheads, hd


def rwkv6_specs(cfg: ModelConfig, layered: bool = True):
    d = cfg.d_model
    nheads, hd = rwkv6_dims(cfg)
    f = cfg.d_ff
    lead = (cfg.num_layers,) if layered else ()
    lx = ("layers",) if layered else ()
    lora = 64
    return {
        # time-mix
        "mu_r": ParamSpec(lead + (d,), lx + (None,), init="ones", scale=0.5),
        "mu_k": ParamSpec(lead + (d,), lx + (None,), init="ones"),
        "mu_v": ParamSpec(lead + (d,), lx + (None,), init="ones"),
        "mu_w": ParamSpec(lead + (d,), lx + (None,), init="ones"),
        "mu_g": ParamSpec(lead + (d,), lx + (None,), init="ones"),
        "wr": ParamSpec(lead + (d, d), lx + ("embed", "heads")),
        "wk": ParamSpec(lead + (d, d), lx + ("embed", "heads")),
        "wv": ParamSpec(lead + (d, d), lx + ("embed", "heads")),
        "wg": ParamSpec(lead + (d, d), lx + ("embed", "heads")),
        "wo": ParamSpec(lead + (d, d), lx + ("heads", "embed")),
        "w_base": ParamSpec(lead + (d,), lx + (None,), init="zeros"),
        "w_lora1": ParamSpec(lead + (d, lora), lx + ("embed", None)),
        "w_lora2": ParamSpec(lead + (lora, d), lx + (None, "heads")),
        "u_bonus": ParamSpec(lead + (nheads, hd), lx + ("heads", None), init="zeros"),
        "ln_x": ParamSpec(lead + (d,), lx + (None,), init="ones"),
        # channel-mix
        "cm_mu_k": ParamSpec(lead + (d,), lx + (None,), init="ones"),
        "cm_mu_r": ParamSpec(lead + (d,), lx + (None,), init="ones"),
        "cm_k": ParamSpec(lead + (d, f), lx + ("embed", "ff")),
        "cm_v": ParamSpec(lead + (f, d), lx + ("ff", "embed")),
        "cm_r": ParamSpec(lead + (d, d), lx + ("embed", "heads")),
    }


class RWKVState(NamedTuple):
    tm_x: jnp.ndarray   # (B, D) last input to time-mix (token shift)
    cm_x: jnp.ndarray   # (B, D) last input to channel-mix
    wkv: jnp.ndarray    # (B, H, hd, hd) linear-attention state


def rwkv6_init_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> RWKVState:
    nheads, hd = rwkv6_dims(cfg)
    return RWKVState(
        tm_x=jnp.zeros((batch, cfg.d_model), dtype),
        cm_x=jnp.zeros((batch, cfg.d_model), dtype),
        wkv=jnp.zeros((batch, nheads, hd, hd), F32),
    )


def _token_shift(x: jnp.ndarray, last: jnp.ndarray) -> jnp.ndarray:
    """prev-token stream: [last, x_0 .. x_{S-2}]."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _lerp(x, prev, mu):
    return x + (prev - x) * mu


def rwkv6_time_mix(
    p, x: jnp.ndarray, cfg: ModelConfig, state: RWKVState
) -> Tuple[jnp.ndarray, RWKVState]:
    """Chunked RWKV-6 WKV with data-dependent per-channel decay."""
    nheads, hd = rwkv6_dims(cfg)
    b, s, d = x.shape
    L = min(cfg.rwkv.chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L

    prev = _token_shift(x, state.tm_x)
    r = jnp.einsum("bsd,dh->bsh", _lerp(x, prev, p["mu_r"]), p["wr"])
    k = jnp.einsum("bsd,dh->bsh", _lerp(x, prev, p["mu_k"]), p["wk"])
    v = jnp.einsum("bsd,dh->bsh", _lerp(x, prev, p["mu_v"]), p["wv"])
    g = jnp.einsum("bsd,dh->bsh", _lerp(x, prev, p["mu_g"]), p["wg"])
    xw = _lerp(x, prev, p["mu_w"])
    w_dd = p["w_base"] + jnp.einsum(
        "bsl,lh->bsh", jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["w_lora1"])),
        p["w_lora2"],
    )
    # Per-channel log decay in (-e, -e^-6). The clamp bounds the factored
    # exp(±cum) within a chunk to e^(chunk * e) — fp32-safe for chunk <= 16
    # (this is why RWKVConfig.chunk defaults to 16; the cross-chunk scan
    # carries exact state so semantics are unaffected across chunks).
    logw = -jnp.exp(jnp.clip(w_dd.astype(F32), -6.0, 1.0))      # (B,S,D)

    rh = r.reshape(b, nc, L, nheads, hd).astype(F32)
    kh = k.reshape(b, nc, L, nheads, hd).astype(F32)
    vh = v.reshape(b, nc, L, nheads, hd).astype(F32)
    lw = logw.reshape(b, nc, L, nheads, hd)

    cum = jnp.cumsum(lw, axis=2)                                 # inclusive
    cum_excl = cum - lw                                          # exclusive
    total = cum[:, :, -1]                                        # (B,nc,H,hd)

    # within-chunk: y_t = r_t . sum_{s<t} exp(cumx_t - cum_s... ) k_s v_s + u.k_t v_t
    # decay from s (exclusive of s) to t (exclusive of t): cum_excl_t - cum_s
    r_dec = rh * jnp.exp(cum_excl)                               # (B,nc,L,H,hd)
    k_dec = kh * jnp.exp(-cum)                                   # 1/prod decay
    scores = jnp.einsum("bclhd,bcmhd->bchlm", r_dec, k_dec)
    tri = jnp.tril(jnp.ones((L, L), bool), k=-1)                 # strictly lower
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    diag = jnp.einsum("bclhd,bclhd->bclh", rh * p["u_bonus"].astype(F32)[None, None], kh)
    y = jnp.einsum("bchlm,bcmhd->bclhd", scores, vh)
    y = y + diag[..., None] * vh

    # cross-chunk
    carry_in = jnp.einsum(
        "bclhd,bclhe->bchde", kh * jnp.exp(total[:, :, None] - cum), vh
    )                                                             # (B,nc,H,hd,hd)

    def step(wkv, inputs):
        tot_c, inc_c = inputs
        new = wkv * jnp.exp(tot_c)[..., None] + inc_c
        return new, wkv

    totals = jnp.moveaxis(total, 1, 0)                            # (nc,B,H,hd)
    incs = jnp.moveaxis(carry_in, 1, 0)
    final_wkv, prior = jax.lax.scan(step, state.wkv, (totals, incs))
    prior = jnp.moveaxis(prior, 0, 1)                             # (B,nc,H,hd,hd)
    y = y + jnp.einsum("bclhd,bchde->bclhe", rh * jnp.exp(cum_excl), prior)

    y = y.reshape(b, s, d)
    # per-head group norm
    yh = y.reshape(b, s, nheads, hd)
    mean = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + 1e-5)
    y = (yh.reshape(b, s, d) * p["ln_x"].astype(F32)).astype(x.dtype)
    y = y * jax.nn.silu(g.astype(F32)).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", y, p["wo"])
    new_state = RWKVState(tm_x=x[:, -1, :], cm_x=state.cm_x, wkv=final_wkv)
    return constrain(out, "batch", None, "embed_no_fsdp"), new_state


def rwkv6_channel_mix(
    p, x: jnp.ndarray, cfg: ModelConfig, state: RWKVState
) -> Tuple[jnp.ndarray, RWKVState]:
    prev = _token_shift(x, state.cm_x)
    xk = _lerp(x, prev, p["cm_mu_k"])
    xr = _lerp(x, prev, p["cm_mu_r"])
    k = jnp.einsum("bsd,df->bsf", xk, p["cm_k"])
    k = jnp.square(jax.nn.relu(k.astype(F32))).astype(x.dtype)
    k = constrain(k, "batch", None, "ff")
    v = jnp.einsum("bsf,fd->bsd", k, p["cm_v"])
    r = jax.nn.sigmoid(
        jnp.einsum("bsd,dh->bsh", xr, p["cm_r"]).astype(F32)
    ).astype(x.dtype)
    out = r * v
    return out, RWKVState(tm_x=state.tm_x, cm_x=x[:, -1, :], wkv=state.wkv)


def rwkv6_decode_step(
    p, x: jnp.ndarray, state: RWKVState, cfg: ModelConfig
) -> Tuple[jnp.ndarray, RWKVState]:
    """Single-token recurrence for BOTH mixes. x: (B,1,D) block input."""
    nheads, hd = rwkv6_dims(cfg)
    b = x.shape[0]
    xt = x[:, 0, :]
    prev = state.tm_x

    def proj(mu, w):
        return jnp.einsum("bd,dh->bh", _lerp(xt, prev, mu), w)

    r = proj(p["mu_r"], p["wr"]).reshape(b, nheads, hd).astype(F32)
    k = proj(p["mu_k"], p["wk"]).reshape(b, nheads, hd).astype(F32)
    v = proj(p["mu_v"], p["wv"]).reshape(b, nheads, hd).astype(F32)
    g = proj(p["mu_g"], p["wg"])
    xw = _lerp(xt, prev, p["mu_w"])
    w_dd = p["w_base"] + jnp.einsum(
        "bl,lh->bh", jnp.tanh(jnp.einsum("bd,dl->bl", xw, p["w_lora1"])),
        p["w_lora2"],
    )
    logw = -jnp.exp(jnp.clip(w_dd.astype(F32), -6.0, 1.0)).reshape(b, nheads, hd)

    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    ru = r * p["u_bonus"].astype(F32)[None]
    y = jnp.einsum("bhd,bhde->bhe", r, state.wkv) + jnp.einsum(
        "bhd,bhde->bhe", ru, kv
    )
    new_wkv = state.wkv * jnp.exp(logw)[..., None] + kv

    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 1e-5)
    y = (y.reshape(b, cfg.d_model) * p["ln_x"].astype(F32)).astype(x.dtype)
    y = y * jax.nn.silu(g.astype(F32)).astype(x.dtype)
    out_tm = jnp.einsum("bh,hd->bd", y, p["wo"])
    return out_tm[:, None, :], RWKVState(tm_x=xt, cm_x=state.cm_x, wkv=new_wkv)
