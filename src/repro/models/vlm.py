"""LLaVA-NeXT-style VLM: Mistral decoder backbone + anyres vision stub.

The vision tower is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, P, vision_dim). A 2-layer MLP projector
maps them into d_model and they replace the first P token positions
(image-prefix convention). Everything else is the dense decoder.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.decoder import DecoderLM
from repro.models.params import ParamSpec
from repro.parallel.sharding import constrain


class VLMDecoderLM(DecoderLM):
    def __init__(self, cfg: ModelConfig):
        assert cfg.vlm is not None
        super().__init__(cfg)

    def specs(self) -> Dict[str, Any]:
        sp = super().specs()
        v, d = self.cfg.vlm.vision_dim, self.cfg.d_model
        sp["projector"] = {
            "w1": ParamSpec((v, d), ("embed", None)),
            "w2": ParamSpec((d, d), ("embed", None)),
        }
        return sp

    def _prefix_inject(self, params, x, batch):
        """Replace the first P positions with projected patch embeddings."""
        patches = batch.get("patches")
        if patches is None:
            return x
        pr = params["projector"]
        h = jnp.einsum("bpv,vd->bpd", patches.astype(x.dtype), pr["w1"])
        h = jnp.einsum("bpd,de->bpe", jnp.tanh(h), pr["w2"])
        h = constrain(h, "batch", None, "embed_no_fsdp")
        p = h.shape[1]
        return jnp.concatenate([h, x[:, p:, :]], axis=1)
