"""repro.obs — unified telemetry: metrics registry + cross-tier tracing.

Stdlib-only (no jax, no other repro imports), so every tier can depend
on it without layering cycles. Two halves behind one kill-switch:

    client ──POST /batch──────────────▶ StatsRouter        (root span)
                                          │  traceparent: header + wire
                                          │                 frame section
                  ┌───────────────────────┴──────────────┐
                  ▼                                      ▼
            replica A  (replica.sub_batch)         replica B
                  │                                      │
            StatsService.batch (service.superpack)       │
                  │                                      │
            EstimationEngine  (engine.pack → engine.dispatch → engine.d2h)
                  │
          spans close bottom-up → each lands in the bounded finished-span
          ring → grouped per trace at GET /debug/traces?limit=N (JSON trees)

    Counters / gauges / histograms land in the process-global
    `MetricsRegistry`; pre-existing stats objects (`ServiceStats`,
    `IngestStats`, `CatalogStats`, `PoolStats`) are registered as
    weakref VIEWS read at scrape time — single source of truth, no
    double counting → GET /metrics (Prometheus text exposition).
    The router re-emits each remote replica's scrape under a
    `replica="<name>"` label next to its own series.

Telemetry is NEUTRAL by contract: nothing here enters `cache_key`,
`cache_token`, or ETag derivation — estimate bytes and ETags are
byte-identical with telemetry on or off (`set_enabled(False)` turns
every increment and span into a no-op; `benchmarks/obs_overhead.py`
holds the warm-path overhead under 5%).
"""
from repro.obs import _state
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    LATENCY_BUCKETS_S,
    MetricsRegistry,
    WIDTH_BUCKETS,
    registry,
)
from repro.obs.trace import (
    Span,
    TRACEPARENT_HEADER,
    TraceCollector,
    collector,
    current_span,
    current_traceparent,
    format_traceparent,
    parse_traceparent,
    root_span,
    span,
    trace_tree,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS_S",
    "MetricsRegistry",
    "Span",
    "TRACEPARENT_HEADER",
    "TraceCollector",
    "WIDTH_BUCKETS",
    "collector",
    "current_span",
    "current_traceparent",
    "enabled",
    "format_traceparent",
    "parse_traceparent",
    "registry",
    "root_span",
    "set_enabled",
    "span",
    "trace_tree",
]


def set_enabled(value: bool) -> None:
    """Flip the process-global telemetry switch (metrics AND spans)."""
    _state.enabled = bool(value)


def enabled() -> bool:
    return _state.enabled
