"""repro.obs — unified telemetry: metrics registry + cross-tier tracing.

Stdlib-only (no jax, no other repro imports), so every tier can depend
on it without layering cycles. Two halves behind one kill-switch:

    client ──POST /batch──────────────▶ StatsRouter        (root span)
                                          │  traceparent: header + wire
                                          │                 frame section
                  ┌───────────────────────┴──────────────┐
                  ▼                                      ▼
            replica A  (replica.sub_batch)         replica B
                  │                                      │
            StatsService.batch (service.superpack)       │
                  │                                      │
            EstimationEngine  (engine.pack → engine.dispatch → engine.d2h)
                  │
          spans close bottom-up → each lands in the bounded finished-span
          ring → grouped per trace at GET /debug/traces?limit=N (JSON trees)

    Counters / gauges / histograms land in the process-global
    `MetricsRegistry`; pre-existing stats objects (`ServiceStats`,
    `IngestStats`, `CatalogStats`, `PoolStats`) are registered as
    weakref VIEWS read at scrape time — single source of truth, no
    double counting → GET /metrics (Prometheus text exposition).
    The router re-emits each remote replica's scrape under a
    `replica="<name>"` label next to its own series.

Telemetry is NEUTRAL by contract: nothing here enters `cache_key`,
`cache_token`, or ETag derivation — estimate bytes and ETags are
byte-identical with telemetry on or off (`set_enabled(False)` turns
every increment and span into a no-op; `benchmarks/obs_overhead.py`
holds the warm-path overhead under 5%).

Estimation-quality observability rides the same registry. Every batch
the estimator runs also emits per-lane PROVENANCE (core/ndv: route
chosen + margin, detector margin, Newton iteration counts/residual,
clamps hit) — extra output lanes of the one shared program, so fused
and unfused twins produce identical diagnostics and nothing enters
cache identity:

    estimate_batch ──▶ BatchEstimates(+route, margins, iters, clamps)
         │ provenance_from_batch (estimator.py)
         ▼
    catalog.provenance_cache_store   ← the ONE funnel that records
         │                             ndv_route_total{route=},
         │                             ndv_newton_iters{solver=},
         │                             ndv_detector_margin
         ├─▶ ?explain=1 on /estimate and per-tuple in /batch
         │     (same ETag — explain never enters identity; wire frames
         │      carry it in a tagged section old peers skip)
         ├─▶ GET /debug/explain      (per-dataset cache dump; the
         │                            router aggregates per replica)
         └─▶ audit loop (service.py, opt-in): samples K columns per
               refresh generation, reference NDV from an HLL sketch
               over one row group (kernels/hll.py), q-error lands in
               ndv_audit_qerror{route=} and rides explain payloads

Metric naming conventions: every series is `ndv_<subsystem>_<noun>`
with unit suffixes per Prometheus style (`_total` counters, `_seconds`/
`_bytes` in the name, `_bucket`/`_sum`/`_count` for histograms). Labels
are low-cardinality enums only (route, solver, tier, status — never
column or dataset names on estimator series; the router adds
`replica="<name>"` when re-emitting remote scrapes).
"""
from repro.obs import _state
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    LATENCY_BUCKETS_S,
    MetricsRegistry,
    WIDTH_BUCKETS,
    registry,
)
from repro.obs.trace import (
    Span,
    TRACEPARENT_HEADER,
    TraceCollector,
    collector,
    current_span,
    current_traceparent,
    format_traceparent,
    parse_traceparent,
    root_span,
    span,
    trace_tree,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS_S",
    "MetricsRegistry",
    "Span",
    "TRACEPARENT_HEADER",
    "TraceCollector",
    "WIDTH_BUCKETS",
    "collector",
    "current_span",
    "current_traceparent",
    "enabled",
    "format_traceparent",
    "parse_traceparent",
    "registry",
    "root_span",
    "set_enabled",
    "span",
    "trace_tree",
]


def set_enabled(value: bool) -> None:
    """Flip the process-global telemetry switch (metrics AND spans)."""
    _state.enabled = bool(value)


def enabled() -> bool:
    return _state.enabled
