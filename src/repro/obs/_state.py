"""The one process-global telemetry switch.

A plain module attribute so the warm-path check (`if not _state.enabled`)
is a single dict lookup — both `metrics` and `trace` read it on every
increment/span. Kept in its own module to avoid an import cycle between
the two halves of the package.
"""

enabled = True
