"""Metrics registry: counters, gauges, histograms, Prometheus exposition.

Stdlib only. Three primitives behind one `MetricsRegistry`:

  `Counter`    monotonically increasing; exposed with the `_total` suffix
               already in its name by convention.
  `Gauge`      set/inc/dec to any value.
  `Histogram`  fixed-bucket; per-cell bucket counts plus sum and count,
               rendered as the cumulative `_bucket`/`_sum`/`_count` series
               Prometheus expects.

Label sets are frozen tuples (`(("k","v"), ...)`, sorted by key) — the
child-cell dict key — and every cell's mutations go through one of the
registry's striped locks (`hash(labels) % N_STRIPES`), so concurrent
increments from the serving tier's handler threads are exact without a
single global hot lock.

Ad-hoc stats objects that predate this registry (`ServiceStats`,
`IngestStats`, `CatalogStats`, `PoolStats`) are re-registered as VIEWS
(`register_stats_view`): the registry holds a weakref and reads the
object's numeric fields at scrape time, so the existing counters stay the
single source of truth and nothing is double-counted. Dead views (object
collected) drop out of the exposition on their own.

`exposition()` renders the Prometheus text format (version 0.0.4) with no
external dependency: `# TYPE`/`# HELP` comments, escaped label values
(`\\`, `\"`, `\n`), `le="+Inf"` terminal buckets.
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
import weakref
from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs import _state

LabelTuple = Tuple[Tuple[str, str], ...]

# Request-latency buckets (seconds): sub-millisecond 304s through
# multi-second cold packs of wide catalogs.
LATENCY_BUCKETS_S = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
)
# Batch-width buckets (tuples per /batch frame): pow2-ish, matching the
# packer's own bucketing instincts.
WIDTH_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 1024.0)

# Estimation-quality buckets (`ndv_*` provenance/audit families; naming
# convention: estimator-quality series are `ndv_<signal>` with `route=` /
# `solver=` labels, never per-column labels — cardinality stays O(1)).
# Newton iteration counts: solvers cap at 32 (§4) / 40 (§5).
ITER_BUCKETS = (1.0, 2.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0, 40.0)
# Detector/route margins live in [0, 1); resolution concentrated near 0
# where routing decisions are fragile.
MARGIN_BUCKETS = (0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.3, 0.5, 0.75)
# Audit q-error = max(est/ref, ref/est) >= 1; log-ish spacing.
QERROR_BUCKETS = (1.05, 1.1, 1.25, 1.5, 2.0, 3.0, 5.0, 10.0, 30.0, 100.0)

_N_STRIPES = 16


def label_tuple(labels: dict) -> LabelTuple:
    """Frozen, key-sorted label identity (the child-cell dict key)."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def escape_label_value(value: str) -> str:
    """Prometheus label-value escaping: backslash, double-quote, newline."""
    return (
        str(value)
        .replace("\\", "\\\\")
        .replace('"', '\\"')
        .replace("\n", "\\n")
    )


def _escape_help(text: str) -> str:
    return text.replace("\\", "\\\\").replace("\n", "\\n")


def format_labels(labels: Iterable[Tuple[str, str]]) -> str:
    """`(("k","v"),)` -> `{k="v"}`; empty -> empty string."""
    items = list(labels)
    if not items:
        return ""
    inner = ",".join(
        f'{k}="{escape_label_value(v)}"' for k, v in items
    )
    return "{" + inner + "}"


def format_value(v: float) -> str:
    """Sample-value rendering: integral floats as ints, else shortest repr."""
    f = float(v)
    if f != f:  # NaN
        return "NaN"
    if f in (float("inf"), float("-inf")):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class _Cell:
    """One (metric, label set) scalar with its striped lock."""

    __slots__ = ("value", "lock")

    def __init__(self, lock: threading.Lock):
        self.value = 0.0
        self.lock = lock


class _HistCell:
    """One (histogram, label set): per-bucket counts + sum + count."""

    __slots__ = ("counts", "sum", "count", "lock")

    def __init__(self, n_buckets: int, lock: threading.Lock):
        self.counts = [0] * n_buckets  # non-cumulative; rendered cumulative
        self.sum = 0.0
        self.count = 0
        self.lock = lock


class _Metric:
    """Shared child-cell bookkeeping for the three primitives."""

    kind = "untyped"

    def __init__(self, name: str, help: str, registry: "MetricsRegistry"):
        self.name = name
        self.help = help
        self._registry = registry
        self._mu = threading.Lock()  # guards the children dict only
        self._children: Dict[LabelTuple, object] = {}
        # Hot-path memo: raw (call-site-ordered, unstringified) kwargs
        # tuple -> cell. Distinct orderings/types of the same labels are
        # extra memo entries, but all alias ONE canonical cell, so counts
        # stay exact and the exposition sees a single series.
        self._fast: Dict[tuple, object] = {}

    def _cell(self, labels: dict):
        fast_key = tuple(labels.items())
        cell = self._fast.get(fast_key)
        if cell is not None:
            return cell
        key = label_tuple(labels)
        with self._mu:
            cell = self._children.get(key)
            if cell is None:
                cell = self._new_cell(self._registry._stripe(key))
                self._children[key] = cell
            self._fast[fast_key] = cell
        return cell

    def _new_cell(self, lock: threading.Lock):
        return _Cell(lock)

    def snapshot(self) -> List[Tuple[LabelTuple, object]]:
        with self._mu:
            return sorted(self._children.items())


class _BoundCounter:
    """A counter pre-resolved to one label set (`Counter.labels(...)`).

    The per-call work is an enabled check, the stripe lock, and the add —
    for call sites hot enough that rebuilding the label identity every
    time shows up (the per-request line in the HTTP tier).
    """

    __slots__ = ("_c",)

    def __init__(self, cell: _Cell):
        self._c = cell

    def inc(self, amount: float = 1) -> None:
        if not _state.enabled:
            return
        cell = self._c
        with cell.lock:
            cell.value += amount


class _BoundHistogram:
    """A histogram pre-resolved to one label set (`Histogram.labels(...)`)."""

    __slots__ = ("_c", "_buckets")

    def __init__(self, cell: _HistCell, buckets: Tuple[float, ...]):
        self._c = cell
        self._buckets = buckets

    def observe(self, value: float) -> None:
        if not _state.enabled:
            return
        cell = self._c
        idx = bisect.bisect_left(self._buckets, value)
        with cell.lock:
            cell.count += 1
            cell.sum += value
            if idx < len(self._buckets):
                cell.counts[idx] += 1


class Counter(_Metric):
    kind = "counter"

    def inc(self, amount: float = 1, **labels) -> None:
        if not _state.enabled:
            return
        cell = self._cell(labels)
        with cell.lock:
            cell.value += amount

    def labels(self, **labels) -> _BoundCounter:
        return _BoundCounter(self._cell(labels))

    def value(self, **labels) -> float:
        return float(self._cell(labels).value)


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        if not _state.enabled:
            return
        cell = self._cell(labels)
        with cell.lock:
            cell.value = float(value)

    def inc(self, amount: float = 1, **labels) -> None:
        if not _state.enabled:
            return
        cell = self._cell(labels)
        with cell.lock:
            cell.value += amount

    def value(self, **labels) -> float:
        return float(self._cell(labels).value)


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help, registry, buckets=LATENCY_BUCKETS_S):
        super().__init__(name, help, registry)
        self.buckets: Tuple[float, ...] = tuple(sorted(float(b) for b in buckets))

    def _new_cell(self, lock: threading.Lock):
        return _HistCell(len(self.buckets), lock)

    def observe(self, value: float, **labels) -> None:
        if not _state.enabled:
            return
        cell = self._cell(labels)
        idx = bisect.bisect_left(self.buckets, value)
        with cell.lock:
            cell.count += 1
            cell.sum += value
            if idx < len(self.buckets):
                cell.counts[idx] += 1

    def labels(self, **labels) -> _BoundHistogram:
        return _BoundHistogram(self._cell(labels), self.buckets)


class _StatsView:
    """Weakref view over an ad-hoc stats object (dataclass or __slots__)."""

    __slots__ = ("prefix", "labels", "ref")

    def __init__(self, prefix: str, labels: LabelTuple, obj: object):
        self.prefix = prefix
        self.labels = labels
        self.ref = weakref.ref(obj)


def _numeric_fields(obj) -> List[Tuple[str, float]]:
    """The scrape-able (name, value) pairs of a stats object."""
    if dataclasses.is_dataclass(obj):
        items = [(f.name, getattr(obj, f.name)) for f in dataclasses.fields(obj)]
    elif hasattr(obj, "__slots__"):
        items = [(s, getattr(obj, s, None)) for s in obj.__slots__]
    else:
        items = list(vars(obj).items())
    out = []
    for name, v in items:
        if name.startswith("_"):
            continue
        if isinstance(v, bool):
            v = int(v)
        if isinstance(v, (int, float)):
            out.append((name, float(v)))
    return out


class MetricsRegistry:
    """Process-global (or test-local) metric namespace."""

    def __init__(self):
        self._mu = threading.Lock()
        self._metrics: "Dict[str, _Metric]" = {}
        self._views: Dict[tuple, _StatsView] = {}
        self._locks = [threading.Lock() for _ in range(_N_STRIPES)]

    def _stripe(self, key: LabelTuple) -> threading.Lock:
        return self._locks[hash(key) % _N_STRIPES]

    def _get(self, name: str, cls, *args):
        with self._mu:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, *args, self) \
                    if cls is not Histogram else cls(name, *args[:1], self, *args[1:])
                return m
            if not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}"
                )
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help)

    def histogram(
        self, name: str, help: str = "", buckets=LATENCY_BUCKETS_S
    ) -> Histogram:
        return self._get(name, Histogram, help, buckets)

    def register_stats_view(
        self, prefix: str, labels: dict, obj: object
    ) -> None:
        """Expose `obj`'s numeric fields as `{prefix}_{field}` gauges.

        Values are read from the live object at scrape time — the existing
        stats dataclasses stay the single source of truth (no double
        counting). Only a weakref is held: when the object is collected,
        the series disappear. Re-registering the same (prefix, labels)
        replaces the previous view (replica restarts).
        """
        view = _StatsView(prefix, label_tuple(labels), obj)
        with self._mu:
            self._views[(prefix, view.labels)] = view

    # -- exposition ----------------------------------------------------------

    def exposition(self) -> str:
        """Prometheus text format v0.0.4 for everything registered."""
        out: List[str] = []
        with self._mu:
            metrics = list(self._metrics.values())
            views = list(self._views.items())
        for m in metrics:
            self._render_metric(out, m)

        # Views: group all (labels, value) samples by derived metric name
        # so each name gets exactly one TYPE header (exposition requires
        # one group per metric).
        grouped: "Dict[str, List[Tuple[LabelTuple, float]]]" = {}
        dead: List[tuple] = []
        for key, view in views:
            obj = view.ref()
            if obj is None:
                dead.append(key)
                continue
            for field, value in _numeric_fields(obj):
                grouped.setdefault(f"{view.prefix}_{field}", []).append(
                    (view.labels, value)
                )
        if dead:
            with self._mu:
                for key in dead:
                    self._views.pop(key, None)
        for name in sorted(grouped):
            out.append(f"# TYPE {name} gauge\n")
            for labels, value in sorted(grouped[name]):
                out.append(
                    f"{name}{format_labels(labels)} {format_value(value)}\n"
                )
        return "".join(out)

    def _render_metric(self, out: List[str], m: _Metric) -> None:
        cells = m.snapshot()
        if not cells:
            return
        if m.help:
            out.append(f"# HELP {m.name} {_escape_help(m.help)}\n")
        out.append(f"# TYPE {m.name} {m.kind}\n")
        if isinstance(m, Histogram):
            for labels, cell in cells:
                with cell.lock:
                    counts = list(cell.counts)
                    total, s = cell.count, cell.sum
                cum = 0
                for b, c in zip(m.buckets, counts):
                    cum += c
                    le = format_labels(labels + (("le", format_value(b)),))
                    out.append(f"{m.name}_bucket{le} {cum}\n")
                le = format_labels(labels + (("le", "+Inf"),))
                out.append(f"{m.name}_bucket{le} {total}\n")
                out.append(
                    f"{m.name}_sum{format_labels(labels)} {format_value(s)}\n"
                )
                out.append(f"{m.name}_count{format_labels(labels)} {total}\n")
        else:
            for labels, cell in cells:
                out.append(
                    f"{m.name}{format_labels(labels)} "
                    f"{format_value(cell.value)}\n"
                )


def add_label_to_exposition(text: str, labels: dict) -> str:
    """Inject labels into every sample line of an exposition blob.

    Used by the fleet router to re-emit a scraped replica's `/metrics`
    under a `replica="<name>"` label. Comment lines are dropped (the
    aggregate is a concatenation; re-announcing TYPEs for names the
    router already emitted would be invalid).
    """
    extra = ",".join(
        f'{k}="{escape_label_value(v)}"' for k, v in sorted(labels.items())
    )
    out: List[str] = []
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        # name{existing} value  |  name value
        head, _, value = line.rpartition(" ")
        if not head:
            continue
        if head.endswith("}"):
            brace = head.index("{")
            inner = head[brace + 1:-1]
            joined = f"{inner},{extra}" if inner else extra
            out.append(f"{head[:brace]}{{{joined}}} {value}\n")
        else:
            out.append(f"{head}{{{extra}}} {value}\n")
    return "".join(out)


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global registry every tier registers into."""
    return _REGISTRY
