"""Request tracing: spans, traceparent propagation, bounded trace ring.

A `Span` is (trace_id, span_id, parent_id, name, monotonic start/stop,
attributes). Root spans are opened only at the HTTP layer (`root_span`);
library code opens children with `span(name)`, which is a NO-OP unless a
current span exists — so engine/catalog calls outside a served request
cost one contextvar read and nothing else.

Propagation follows the W3C traceparent shape
(`00-<32hex trace_id>-<16hex span_id>-01`): carried as an HTTP header on
JSON requests and as an optional tagged section in the wire frame
(`wire.codec._SECTION_TRACE`; unknown-section skip keeps old peers
compatible). The current span rides a `contextvars.ContextVar`, which is
per-thread under `ThreadingHTTPServer` — exactly the granularity we need.

The collector is deliberately flat: finishing a span appends it to one
bounded ring of finished spans and nothing else — no per-trace
registration on the hot path. Grouping spans into traces happens lazily
at `/debug/traces` scrape time, where a full scan of a few thousand
entries is irrelevant. Because parents exit after their children (spans
are context managers), a trace whose root span is in the ring is
complete; a scrape racing an in-flight request may see a rootless
partial trace, which `trace_tree` renders under a synthetic root.

Retention is interest-based: a childless local root (the warm cache-hit
request, which dominates traffic) is NOT retained — its only facts,
latency and status, are already in the request histograms — unless it
errored or was marked with `keep_trace()`. Spans with children, spans
whose parent lives in another process (joined traces), and child spans
always land in the ring.
"""
from __future__ import annotations

import contextvars
import os
import random
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.obs import _state

TRACEPARENT_HEADER = "Traceparent"

# Ring capacity in SPANS (not traces): warm singleton traces are one span
# each, deep /batch traces a few dozen — ample history either way, with
# one fixed memory bound. Kept modest on purpose: every retained span is
# an object the cyclic GC keeps re-scanning.
_MAX_SPANS = 1024
# Trim in chunks so the hot path never pays the O(ring) compaction.
_TRIM_SLACK = 256

# Span/trace ids need uniqueness, not unpredictability: a private PRNG
# seeded from os.urandom once avoids a syscall per id (two per span, on
# every served request).
_id_rng = random.Random(int.from_bytes(os.urandom(16), "big"))
_id_bits = _id_rng.getrandbits  # C-implemented, atomic under the GIL


def _hex_id(nbytes: int) -> str:
    return f"{_id_bits(nbytes * 8):0{nbytes * 2}x}"


def format_traceparent(trace_id: str, span_id: str) -> str:
    return f"00-{trace_id}-{span_id}-01"


def parse_traceparent(value: Optional[str]) -> Optional[Tuple[str, str]]:
    """`00-<32hex>-<16hex>-<2hex>` -> (trace_id, parent_span_id) or None."""
    if not value:
        return None
    parts = value.strip().split("-")
    if len(parts) != 4:
        return None
    version, trace_id, span_id, _flags = parts
    if len(version) != 2 or len(trace_id) != 32 or len(span_id) != 16:
        return None
    try:
        int(trace_id, 16), int(span_id, 16)
    except ValueError:
        return None
    if int(trace_id, 16) == 0 or int(span_id, 16) == 0:
        return None
    return trace_id, span_id


class Span:
    """One timed unit of work inside a trace.

    Also its own context manager (enter publishes it as the current span
    and registers with the collector; exit stamps the end time, restores
    the previous current span, and notifies the collector) — one object
    per span on the request hot path, no separate guard wrapper.
    """

    __slots__ = (
        "trace_id", "span_id", "parent_id", "name",
        "start_s", "end_s", "attributes", "_token", "_has_child", "_keep",
    )

    def __init__(self, trace_id: str, span_id: str,
                 parent_id: Optional[str], name: str,
                 attributes: Optional[Dict[str, object]] = None):
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.start_s = time.monotonic()
        self.end_s: Optional[float] = None
        self.attributes: Dict[str, object] = (
            attributes if attributes is not None else {}
        )
        self._has_child = False
        self._keep = False

    def keep_trace(self) -> None:
        """Force this span into the ring even if it stays childless
        (callers mark error responses and other must-keep requests)."""
        self._keep = True

    def set_attribute(self, key: str, value) -> None:
        self.attributes[key] = value

    @property
    def duration_s(self) -> float:
        end = self.end_s if self.end_s is not None else time.monotonic()
        return end - self.start_s

    @property
    def traceparent(self) -> str:
        return format_traceparent(self.trace_id, self.span_id)

    def to_dict(self) -> dict:
        return {
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "start_s": self.start_s,
            "duration_ms": round(self.duration_s * 1000.0, 3),
            "attributes": dict(self.attributes),
        }

    def __enter__(self) -> "Span":
        self._token = _current.set(self)
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.attributes["error"] = repr(exc)
            self._keep = True
        self.end_s = time.monotonic()
        _current.reset(self._token)
        # Childless LOCAL roots are dropped: a warm cache-hit trace is a
        # single span whose only facts (latency, status) the histograms
        # already carry, and such requests dominate traffic — retaining
        # them would just churn the ring. Anything connected (a child, a
        # parent here or in another process) or marked must-keep lands in
        # the ring. Inlined _COLLECTOR.span_ended: this runs once per
        # served request, where an extra call frame is measurable.
        if self._has_child or self.parent_id is not None or self._keep:
            done = _COLLECTOR._done
            done.append(self)
            if len(done) > _COLLECTOR._cap:
                _COLLECTOR._trim()
        return False


class _NullSpan:
    """Absorbs the Span API when telemetry is off or no trace is active."""

    __slots__ = ()
    trace_id = None
    span_id = None
    parent_id = None
    traceparent = None

    def set_attribute(self, key: str, value) -> None:
        pass

    def keep_trace(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()

_current: "contextvars.ContextVar[Optional[Span]]" = contextvars.ContextVar(
    "repro_obs_current_span", default=None
)


class TraceCollector:
    """Bounded ring of finished spans, grouped into traces at read time.

    `span_ended` is the only hot-path entry point: one lock, one deque
    append. Everything trace-shaped (grouping, ordering, limits) runs at
    `/debug/traces` scrape time over a snapshot.
    """

    def __init__(self, max_spans: int = _MAX_SPANS):
        self._mu = threading.Lock()  # guards trims, not appends
        self._max = max_spans
        self._cap = max_spans + _TRIM_SLACK
        self._done: List[Span] = []

    def span_ended(self, span: Span) -> None:
        # list.append is a single C call — atomic under the GIL, so the
        # per-span hot path takes no lock. Only the (rare, chunked) trim
        # serializes; appends racing a trim land after the slice and
        # survive it. (`Span.__exit__` inlines this body.)
        done = self._done
        done.append(span)
        if len(done) > self._cap:
            self._trim()

    def _trim(self) -> None:
        with self._mu:
            excess = len(self._done) - self._max
            if excess > 0:
                del self._done[:excess]

    def _snapshot(self) -> List[Span]:
        return list(self._done)[-self._max:]

    def traces(self, limit: int = 20) -> List[List[Span]]:
        """Most-recently-finished-first traces (spans in end order).

        A trace's recency is its LAST finished span, so the trace still
        being appended to ranks first. Spans evicted by the ring bound
        simply drop out of their trace (oldest requests first).
        """
        snap = self._snapshot()
        order: List[str] = []
        wanted = set()
        for s in reversed(snap):
            if s.trace_id not in wanted:
                wanted.add(s.trace_id)
                order.append(s.trace_id)
                if len(order) == limit:
                    break
        groups: Dict[str, List[Span]] = {tid: [] for tid in order}
        for s in snap:
            if s.trace_id in wanted:
                groups[s.trace_id].append(s)
        return [groups[tid] for tid in order]

    def find(self, trace_id: str) -> Optional[List[Span]]:
        spans = [s for s in self._snapshot() if s.trace_id == trace_id]
        return spans or None

    def clear(self) -> None:
        with self._mu:
            self._done.clear()


_COLLECTOR = TraceCollector()


def collector() -> TraceCollector:
    return _COLLECTOR


def current_span() -> Optional[Span]:
    return _current.get()


def current_traceparent() -> Optional[str]:
    span = _current.get()
    return span.traceparent if span is not None else None


def root_span(name: str, traceparent: Optional[str] = None, **attributes):
    """Open a trace root (HTTP layer only).

    With a valid incoming `traceparent` the new span joins that trace as
    a child of the remote span; otherwise a fresh trace id is minted.
    """
    if not _state.enabled:
        return _NULL
    parsed = parse_traceparent(traceparent)
    if parsed is not None:
        return Span(parsed[0], _hex_id(8), parsed[1], name, attributes)
    # fresh trace: mint trace id + span id with one RNG draw / one format
    ids = f"{_id_bits(192):048x}"
    return Span(ids[:32], ids[32:], None, name, attributes)


def span(name: str, **attributes):
    """Open a child of the current span; NO-OP without an active trace."""
    if not _state.enabled:
        return _NULL
    parent = _current.get()
    if parent is None:
        return _NULL
    parent._has_child = True  # the parent's trace is now worth retaining
    return Span(parent.trace_id, _hex_id(8), parent.span_id, name, attributes)


def trace_tree(spans: List[Span]) -> dict:
    """Span list -> nested JSON tree (children sorted by start time).

    Spans whose parent is not in the list (e.g. the parent lives in the
    client process) become roots. A single synthetic root wraps multiple
    roots so the result is always one tree.
    """
    by_id = {s.span_id: s.to_dict() for s in spans}
    for node in by_id.values():
        node["children"] = []
    roots = []
    for s in spans:
        node = by_id[s.span_id]
        parent = by_id.get(s.parent_id) if s.parent_id else None
        if parent is not None:
            parent["children"].append(node)
        else:
            roots.append(node)
    for node in by_id.values():
        node["children"].sort(key=lambda c: c["start_s"])
    roots.sort(key=lambda c: c["start_s"])
    if len(roots) == 1:
        return roots[0]
    return {
        "trace_id": spans[0].trace_id if spans else None,
        "name": "(multiple roots)",
        "children": roots,
    }
