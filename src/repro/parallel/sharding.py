"""Logical-axis sharding: one rule table maps every weight/activation axis
onto mesh axes (GSPMD via NamedSharding + with_sharding_constraint).

Conventions (see DESIGN.md §5):

  mesh axes: ("pod", "data", "model")   [single-pod: ("data", "model")]

  logical axes:
    "batch"    -> ("pod", "data")   activations' leading dim
    "seq"      -> None (or "data" for sequence parallelism on long context)
    "embed"    -> "data"            FSDP: parameters' d_model dim
    "heads"    -> "model"           TP: attention heads
    "kv_heads" -> "model"           TP: KV heads (GQA)
    "ff"       -> "model"           TP: MLP hidden
    "vocab"    -> "model"           TP: embedding/vocab rows
    "experts"  -> "model"           EP: MoE experts
    "state"    -> None              SSM state dims stay local
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Any]  # logical axis -> mesh axis | tuple | None

DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",          # FSDP
    "embed_no_fsdp": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "state": None,
    "conv": None,
    "layers": None,
    "seq_sharded": "data",    # KV-cache / long-context time axis
    "seq_model": None,        # Megatron-style attention sequence parallelism
    "moe_seq": None,          # MoE dispatch-buffer sub-row axis (tunable
                              # independently of attention seq-parallelism)
}


@dataclasses.dataclass(frozen=True)
class Logical:
    """A logical sharding annotation attached to a param spec."""

    axes: Tuple[Optional[str], ...]


def resolve_spec(axes: Sequence[Optional[str]], rules: Rules, mesh: Mesh) -> P:
    """Logical axes -> PartitionSpec, dropping mesh axes that don't exist
    and axes whose size doesn't divide the dim (caller validates dims)."""
    names = set(mesh.axis_names)
    out = []
    for ax in axes:
        if ax is None:
            out.append(None)
            continue
        m = rules.get(ax, None)
        if m is None:
            out.append(None)
        elif isinstance(m, tuple):
            kept = tuple(a for a in m if a in names)
            out.append(kept if kept else None)
        else:
            out.append(m if m in names else None)
    # PartitionSpec trailing Nones are fine.
    return P(*out)


def named_sharding(
    mesh: Mesh, axes: Sequence[Optional[str]], rules: Optional[Rules] = None
) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(axes, rules or DEFAULT_RULES, mesh))


def tree_shardings(
    mesh: Mesh,
    logical_tree: Any,
    rules: Optional[Rules] = None,
) -> Any:
    """Map a pytree of Logical specs to a pytree of NamedShardings."""
    rules = rules or DEFAULT_RULES
    return jax.tree.map(
        lambda sp: named_sharding(mesh, sp.axes, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, Logical),
    )


def checked_sharding(
    mesh: Mesh,
    shape: Tuple[int, ...],
    axes: Sequence[Optional[str]],
    rules: Optional[Rules] = None,
) -> NamedSharding:
    """NamedSharding that silently DROPS mesh axes a dim cannot divide.

    This is what makes one rule table serve every architecture: e.g.
    "experts" -> "model" applies to a 16-expert model on a 16-way axis and
    falls back to replication for 8- or 40-expert models.
    """
    rules = rules or DEFAULT_RULES
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    used: set = set()  # a mesh axis may appear at most once per spec (FCFS)
    for dim, ax in zip(shape, axes):
        m = rules.get(ax) if ax is not None else None
        if m is None:
            out.append(None)
            continue
        cand = m if isinstance(m, tuple) else (m,)
        kept = []
        rem = dim
        for a in cand:
            if (
                a in names and a not in used and sizes[a] > 1
                and rem % sizes[a] == 0
            ):
                kept.append(a)
                used.add(a)
                rem //= sizes[a]
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return NamedSharding(mesh, P(*out))


def spec_shardings(mesh: Mesh, specs_tree: Any, rules: Optional[Rules] = None):
    """ParamSpec tree -> divisibility-checked NamedSharding tree."""
    from repro.models.params import ParamSpec  # local import to avoid cycle

    return jax.tree.map(
        lambda sp: checked_sharding(mesh, sp.shape, sp.axes, rules),
        specs_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


_ACTIVE_RULES: Optional[Rules] = None


import contextlib


@contextlib.contextmanager
def use_rules(rules: Rules):
    """Install per-cell rule overrides for the duration of a trace."""
    global _ACTIVE_RULES
    old = _ACTIVE_RULES
    _ACTIVE_RULES = rules
    try:
        yield
    finally:
        _ACTIVE_RULES = old


def active_rules() -> Rules:
    return _ACTIVE_RULES if _ACTIVE_RULES is not None else DEFAULT_RULES


def current_mesh() -> Optional[Mesh]:
    """The Mesh installed via ``with mesh:`` in the calling (trace) context."""
    try:
        from jax._src import mesh as mesh_lib

        phys = mesh_lib.thread_resources.env.physical_mesh
        if phys is not None and phys.axis_names:
            return phys
    except Exception:
        pass
    return None


def constrain(x, *axes: Optional[str], rules: Optional[Rules] = None):
    """with_sharding_constraint using logical axes (no-op outside a mesh).

    Divisibility- and duplicate-axis-checked: axes that cannot legally
    shard this value are dropped rather than erroring, so layer code can
    annotate intent unconditionally.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    sharding = checked_sharding(mesh, x.shape, axes, rules or active_rules())
    return jax.lax.with_sharding_constraint(x, sharding.spec)


def validate_divisibility(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> bool:
    """True if every sharded dim divides evenly on the mesh."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, ax in zip(shape, spec):
        if ax is None:
            continue
        axs = ax if isinstance(ax, tuple) else (ax,)
        total = int(np.prod([sizes[a] for a in axs]))
        if dim % total != 0:
            return False
    return True
