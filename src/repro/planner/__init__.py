"""repro.planner — NDV-driven join ordering: the paper's application.

The paper's headline use of zero-cost NDV estimation is cost-based
query optimization. This package is that consumer: it turns the
catalog's NDV estimates into selectivity and join-cardinality
predictions, and picks the cheapest join order for a client-supplied
join graph — served fleet-wide as `POST /cost`.

    /cost request (JSON or wire frame)
         │ graph.parse_join_graph      — validation → 400s, canonical
         ▼                               identity() → ETag component
    JoinGraph (tables + equi-join edges)
         │ service: catalog rows + estimates     │ router: GET /tablestats
         ▼                                       ▼   per referenced dataset
    {name -> TableStats(rows, {col -> ColumnStats(ndv, conf, route)})}
         │ api.compute_cost
         ├─ enumeration.enumerate_plans   all n! left-deep orders, or a
         │    (planner.enumerate span)    fixed-seed sample — ONE (P, N)
         │                                int32 array, deterministic
         ├─ cost.score_plans              pack (rows, multipliers) lanes,
         │    (planner.score span)        pow2-pad P, fold C_out with one
         │                                jitted lax.scan — 1 dispatch
         │                                for thousands of plans
         └─ best order + per-join cardinalities + total cost
              (?explain=1 adds per-column NDV/route/confidence provenance)

Cost model: C_out (sum of intermediate cardinalities) with the standard
NDV join estimate `|R ⋈ S| ~= |R|·|S| / max(ndv_R(k), ndv_S(k))`;
table pairs with no edge fall back to a cross product (selectivity 1);
NDVs clamp to >= 1. The batched scorer is bit-for-bit identical to the
pure-Python `cost.reference_cost` fold — same parity discipline as the
engine's fused/unfused twins — so serving topology never changes a plan.

Caching: a /cost body is a pure function of (graph identity, dataset
states, mode, max_plans). The service hashes its state token, the
router the per-dataset `/tablestats` ETags, so plans 304 exactly when
every input dataset's stats are unchanged — and ETags match across
replicas. See docs/ARCHITECTURE.md and docs/HTTP_API.md.
"""
from repro.planner.api import ColumnStats, TableStats, compute_cost
from repro.planner.cost import reference_cost, score_plans
from repro.planner.enumeration import enumerate_plans, plan_space_size
from repro.planner.graph import (
    DEFAULT_MAX_PLANS,
    JoinEdge,
    JoinGraph,
    TableRef,
    make_graph,
    parse_join_graph,
    parse_max_plans,
)

__all__ = [
    "ColumnStats",
    "DEFAULT_MAX_PLANS",
    "JoinEdge",
    "JoinGraph",
    "TableRef",
    "TableStats",
    "compute_cost",
    "enumerate_plans",
    "make_graph",
    "parse_join_graph",
    "parse_max_plans",
    "plan_space_size",
    "reference_cost",
    "score_plans",
]
