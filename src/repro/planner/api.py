"""Planner entry point: join graph + per-table stats -> /cost body.

`compute_cost` is the one function both serving tiers call. The
single-dataset `StatsService` feeds it stats it reads from its own
catalog; the fleet `StatsRouter` feeds it stats fetched from each
dataset's replica set via `GET /tablestats`. Either way the body is a
pure function of (graph, stats, mode, max_plans) — replicas holding the
same dataset state produce byte-identical bodies, which is what lets
`/cost` ETags be state-derived and fleet-stable.

Stat resolution per edge endpoint: NDV comes from the named join
column's estimate, clamped to >= 1 (a zero/negative NDV would make the
selectivity 1/max(...) blow up; clamping to 1 degrades the edge to a
pass-through, the conservative choice). Unknown columns raise
`ValueError` -> HTTP 400.
"""
from __future__ import annotations

import time
from typing import Dict, List, NamedTuple, Optional

import numpy as np

from repro.obs import span
from repro.planner.cost import (
    best_plan_index,
    observe_cost_ms,
    reference_cost,
    score_plans,
)
from repro.planner.enumeration import enumerate_plans, plan_space_size
from repro.planner.graph import JoinGraph

__all__ = ["ColumnStats", "TableStats", "compute_cost", "provenance_block"]


class ColumnStats(NamedTuple):
    """One join column's estimate as the planner consumes it."""

    ndv: float
    non_null: int
    confidence: Optional[float] = None
    route: Optional[str] = None


class TableStats(NamedTuple):
    """One table's planner inputs (rows + per-join-column stats)."""

    rows: float
    columns: Dict[str, ColumnStats]


def _clamped_ndv(stats: Dict[str, TableStats], table: str, column: str) -> float:
    ts = stats.get(table)
    if ts is None:
        raise ValueError(f"no stats for table {table!r}")
    cs = ts.columns.get(column)
    if cs is None:
        raise ValueError(f"table {table!r} has no stats for column {column!r}")
    return max(1.0, float(cs.ndv))


def compute_cost(
    graph: JoinGraph,
    stats: Dict[str, TableStats],
    *,
    mode: str,
    max_plans: int,
    explain: bool = False,
) -> dict:
    """Score the plan space and report the cheapest join order.

    `stats` maps each graph table NAME (the alias, not the dataset key)
    to its `TableStats`. Raises `ValueError` for resolvable-to-400
    problems (missing stats for a referenced table/column).
    """
    t0 = time.perf_counter()
    names = graph.names
    n = len(names)
    index = {name: i for i, name in enumerate(names)}

    base_rows = np.empty(n, dtype=np.float32)
    for i, t in enumerate(graph.tables):
        ts = stats.get(t.name)
        if ts is None:
            raise ValueError(f"no stats for table {t.name!r}")
        base_rows[i] = np.float32(
            np.float32(ts.rows) * np.float32(t.filter_selectivity)
        )

    # Per-edge selectivity factor 1 / max(ndv_l, ndv_r), float32 like
    # everything downstream.
    factors = []
    edge_meta = []
    for e in graph.edges:
        ndv_l = _clamped_ndv(stats, e.left, e.left_column)
        ndv_r = _clamped_ndv(stats, e.right, e.right_column)
        factor = float(np.float32(1.0) / np.float32(max(ndv_l, ndv_r)))
        a, b = index[e.left], index[e.right]
        factors.append((a, b, factor))
        edge_meta.append({
            "left": e.left,
            "left_column": e.left_column,
            "right": e.right,
            "right_column": e.right_column,
            "ndv_left": ndv_l,
            "ndv_right": ndv_r,
            "selectivity": factor,
        })

    with span("planner.enumerate", tables=n, max_plans=max_plans):
        plans = enumerate_plans(n, max_plans)
    with span("planner.score", plans=int(plans.shape[0]), tables=n):
        costs, step_cards = score_plans(plans, base_rows, factors)
    best = best_plan_index(plans, costs)
    best_plan = [int(x) for x in plans[best]]
    best_order = [names[i] for i in best_plan]

    # Per-join report for the winning order. The cardinalities come from
    # the batched fold's own output lanes (not recomputed), so the body
    # is exactly what was scored; reference_cost here would match
    # bit-for-bit (the tests pin that), we just avoid the second fold.
    pos = {t: k for k, t in enumerate(best_plan)}
    joins: List[dict] = []
    for k in range(1, n):
        step_edges = [
            edge_meta[j] for j, (a, b, _) in enumerate(factors)
            if max(pos[a], pos[b]) == k
        ]
        joins.append({
            "table": names[best_plan[k]],
            "cardinality": float(step_cards[best][k - 1]),
            "cross_product": not step_edges,
            "edges": step_edges,
        })
    total_cost = float(costs[best]) if n > 1 else 0.0

    body = {
        "mode": mode,
        "tables": [
            {
                "name": t.name,
                **({"namespace": t.namespace, "dataset": t.dataset}
                   if t.dataset_key else {}),
                "rows": float(stats[t.name].rows),
                "filter_selectivity": float(t.filter_selectivity),
                "effective_rows": float(base_rows[index[t.name]]),
            }
            for t in graph.tables
        ],
        "best_order": best_order,
        "joins": joins,
        "total_cost": total_cost,
        "plans_scored": int(plans.shape[0]),
        "plan_space": plan_space_size(n),
        "enumeration": (
            "exhaustive" if plan_space_size(n) <= max_plans else "sampled"
        ),
    }
    if explain:
        body["provenance"] = provenance_block(graph, stats)
    observe_cost_ms((time.perf_counter() - t0) * 1000.0)
    return body


def provenance_block(graph: JoinGraph, stats: Dict[str, TableStats]) -> dict:
    """Which NDV estimates fed each cardinality, with the quality signals.

    The `?explain=1` sidecar for `/cost`: per table, per join column, the
    NDV that entered the selectivity plus its route and confidence (the
    PR 9 signals). Identity-neutral — never hashed into the ETag, exactly
    like `?explain=1` on `/estimate`; both serving tiers attach it to a
    COPY of the cached body.
    """
    needed = graph.columns_by_table()
    return {
        name: {
            col: {
                "ndv": float(stats[name].columns[col].ndv),
                "non_null": int(stats[name].columns[col].non_null),
                "confidence": stats[name].columns[col].confidence,
                "route": stats[name].columns[col].route,
            }
            for col in cols if col in stats[name].columns
        }
        for name, cols in needed.items() if name in stats
    }


def sequential_reference(
    graph: JoinGraph,
    stats: Dict[str, TableStats],
    *,
    max_plans: int,
) -> tuple:
    """Score the same plan space one plan at a time in pure Python.

    The benchmark's sequential baseline and the tests' parity oracle:
    returns `(costs, plans)` where `costs[p]` is `reference_cost` of
    plan p over the identical enumeration.
    """
    names = graph.names
    index = {name: i for i, name in enumerate(names)}
    n = len(names)
    base_rows = np.empty(n, dtype=np.float32)
    for i, t in enumerate(graph.tables):
        base_rows[i] = np.float32(
            np.float32(stats[t.name].rows) * np.float32(t.filter_selectivity)
        )
    factors = []
    for e in graph.edges:
        ndv_l = _clamped_ndv(stats, e.left, e.left_column)
        ndv_r = _clamped_ndv(stats, e.right, e.right_column)
        factors.append((
            index[e.left], index[e.right],
            float(np.float32(1.0) / np.float32(max(ndv_l, ndv_r))),
        ))
    plans = enumerate_plans(n, max_plans)
    costs = np.array(
        [reference_cost([int(x) for x in p], base_rows, factors)[0]
         for p in plans],
        dtype=np.float32,
    )
    return costs, plans
