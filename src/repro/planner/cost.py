"""Batched plan scoring: thousands of join orders, one JAX dispatch.

The cost model is C_out — a plan's cost is the sum of its intermediate
join-result cardinalities — with the NDV-based equi-join estimate

    |R JOIN S on k|  ~=  |R| * |S| / max(ndv_R(k), ndv_S(k))

folded left-deep along each candidate order. Per-edge that is a
multiplicative selectivity `1 / max(ndv_l, ndv_r)` applied at the step
where the edge's later table enters the prefix; a table pair with no
edge contributes no multiplier (cross-product fallback, selectivity 1).

Scoring mirrors how `repro.engine` batches estimation: pack every
candidate plan as a lane of `(P, N)` float32 arrays — per-step row
counts and per-step accumulated edge multipliers — pad P to the next
power of two (bounding retraces, like `catalog.BatchPacker`), and fold
the cost recurrence with one jitted `lax.scan`:

    card_k  = card_{k-1} * rows_k * mult_k
    cost_k  = cost_{k-1} + card_k

Bit-for-bit parity with `reference_cost` (the pure-Python float32 fold
the tests pin) is a contract, same as the engine's fused/unfused twins.
Two things protect it: the edge-multiplier scatter runs HOST-side via
`np.multiply.at` (in-order per edge; XLA scatter order for duplicate
indices is unspecified), and `card_k` has two uses (carry and scan
output) so XLA cannot contract the multiply into an FMA with the cost
add.

Metrics (`repro.obs` registry): `planner_plans_scored_total`,
`planner_dispatches_total`, `planner_cost_ms`; the serving layer wraps
calls in `planner.enumerate` / `planner.score` spans.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.obs import registry

__all__ = [
    "COST_MS_BUCKETS",
    "EdgeFactor",
    "best_plan_index",
    "reference_cost",
    "score_plans",
]

# /cost scoring wall-time (milliseconds — the series is planner_cost_ms):
# sub-ms warm small graphs through cold-trace hundreds of ms.
COST_MS_BUCKETS = (
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 2500.0,
)

_PLANS_SCORED = registry().counter(
    "planner_plans_scored_total",
    "Candidate join orders scored by the batched planner",
)
_DISPATCHES = registry().counter(
    "planner_dispatches_total",
    "Batched plan-scoring dispatches (one per cold /cost computation)",
)
_COST_MS = registry().histogram(
    "planner_cost_ms",
    "End-to-end /cost plan scoring wall time (milliseconds)",
    buckets=COST_MS_BUCKETS,
)

#: (left_table_index, right_table_index, float32 selectivity multiplier).
EdgeFactor = Tuple[int, int, float]


def observe_cost_ms(ms: float) -> None:
    """Record one end-to-end scoring wall time (serving layer calls this)."""
    _COST_MS.observe(float(ms))


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@functools.lru_cache(maxsize=64)
def _scan_fold(n_tables: int, p_pad: int):
    """Jitted cost fold for one (plan length, padded lane count) shape."""

    def fold(rows: jnp.ndarray, mults: jnp.ndarray):
        # rows/mults: (p_pad, n_tables) float32, already gathered per plan.
        card0 = rows[:, 0]
        cost0 = jnp.zeros_like(card0)

        def step(carry, xs):
            card, cost = carry
            rows_k, mult_k = xs
            new_card = card * rows_k * mult_k
            # new_card is BOTH the carry and a scan output — the second
            # use keeps XLA from contracting the multiply chain into an
            # FMA with this add, which would break reference parity.
            new_cost = cost + new_card
            return (new_card, new_cost), new_card

        xs = (rows[:, 1:].T, mults[:, 1:].T)  # (n_tables-1, p_pad)
        (_, cost), cards = jax.lax.scan(step, (card0, cost0), xs)
        return cost, cards

    return jax.jit(fold)


def plan_positions(plans: np.ndarray) -> np.ndarray:
    """Invert plans: `pos[p, t]` = step at which plan p joins table t."""
    p, n = plans.shape
    pos = np.empty((p, n), dtype=np.int64)
    np.put_along_axis(
        pos, plans.astype(np.int64),
        np.broadcast_to(np.arange(n, dtype=np.int64), (p, n)).copy(), axis=1,
    )
    return pos


def pack_step_multipliers(
    plans: np.ndarray, n_tables: int, edges: Sequence[EdgeFactor]
) -> np.ndarray:
    """Per-plan per-step accumulated edge multipliers, host-side.

    Edge e applies at step `max(pos[left], pos[right])` — the moment its
    later table joins the prefix. Accumulation runs edge-by-edge in the
    graph's edge order with `np.multiply.at` (in-order, deterministic),
    which is exactly the order `reference_cost` multiplies in — scatter
    order is part of the bit-parity contract.
    """
    p = plans.shape[0]
    pos = plan_positions(plans)
    mults = np.ones((p, n_tables), dtype=np.float32)
    lanes = np.arange(p)
    for a, b, factor in edges:
        steps = np.maximum(pos[:, a], pos[:, b])
        np.multiply.at(mults, (lanes, steps), np.float32(factor))
    return mults


def score_plans(
    plans: np.ndarray,
    base_rows: np.ndarray,
    edges: Sequence[EdgeFactor],
) -> Tuple[np.ndarray, np.ndarray]:
    """Cost every candidate plan in ONE batched JAX dispatch.

    `plans` is `(P, N)` int32 permutations, `base_rows` the `(N,)`
    float32 filtered table cardinalities, `edges` the precomputed
    selectivity factors. Returns `(costs, step_cards)`:
    `costs[p]` = C_out of plan p (float32), `step_cards[p, k-1]` = the
    intermediate cardinality after step k of plan p (shape `(P, N-1)`).
    """
    p, n = plans.shape
    base_rows = np.asarray(base_rows, dtype=np.float32)
    rows = base_rows[plans]  # (P, N)
    mults = pack_step_multipliers(plans, n, edges)

    p_pad = _pow2_at_least(p)
    if p_pad != p:
        pad = ((0, p_pad - p), (0, 0))
        # Padding lanes fold all-ones — finite, discarded below.
        rows = np.pad(rows, pad, constant_values=1.0)
        mults = np.pad(mults, pad, constant_values=1.0)

    fold = _scan_fold(n, p_pad)
    cost, cards = fold(jnp.asarray(rows), jnp.asarray(mults))
    _DISPATCHES.inc()
    _PLANS_SCORED.inc(p)
    costs = np.asarray(cost)[:p]
    step_cards = np.asarray(cards).T[:p]  # (n-1, p_pad) -> (P, n-1)
    return costs, step_cards


def best_plan_index(plans: np.ndarray, costs: np.ndarray) -> int:
    """Cheapest plan; ties broken by lexicographically smallest order.

    NaN costs (a zero-row table joined under sampled overflow, say) lose
    to any finite cost; an all-NaN field degrades to the lexicographic
    minimum — still deterministic across replicas.
    """
    p = plans.shape[0]
    keys = [(float(costs[i]), tuple(int(x) for x in plans[i]))
            for i in range(p)]
    finite = [k for k in keys if k[0] == k[0]]
    target = min(finite) if finite else min(keys, key=lambda k: k[1])
    return keys.index(target)


def reference_cost(
    plan: Sequence[int],
    base_rows: np.ndarray,
    edges: Sequence[EdgeFactor],
) -> Tuple[float, List[float]]:
    """Pure-Python float32 cost fold — the parity reference for one plan.

    Every operation is an explicit `np.float32` scalar op in the same
    order as the batched fold: per-step multiplier accumulated over
    `edges` in sequence, then `(card * rows_k) * mult_k`, then
    `cost + card`. The batched scorer must match this bit-for-bit.
    """
    n = len(plan)
    pos = {int(t): i for i, t in enumerate(plan)}
    card = np.float32(base_rows[plan[0]])
    cost = np.float32(0.0)
    cards: List[float] = []
    for k in range(1, n):
        mult = np.float32(1.0)
        for a, b, factor in edges:
            if max(pos[a], pos[b]) == k:
                mult = np.float32(mult * np.float32(factor))
        rows_k = np.float32(base_rows[plan[k]])
        card = np.float32(np.float32(card * rows_k) * mult)
        cost = np.float32(cost + card)
        cards.append(float(card))
    return float(cost), cards
