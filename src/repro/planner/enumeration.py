"""Deterministic candidate-plan enumeration (left-deep join orders).

A candidate plan is a permutation of the graph's tables: join the first
two, then fold each subsequent table into the accumulated intermediate —
the classic System-R left-deep space. `enumerate_plans` returns the
candidate set as ONE `(P, N)` int32 array so the scorer can cost every
plan in a single batched dispatch (`repro.planner.cost`).

Determinism is load-bearing: the same graph must enumerate the same
plans in the same order on every replica, or `/cost` bodies (and their
ETags' usefulness) would differ across the fleet. Exhaustive
enumeration uses `itertools.permutations`' lexicographic order; the
sampled regime uses a fixed-seed generator.
"""
from __future__ import annotations

import itertools
import math

import numpy as np

#: Fixed seed for the sampled regime — replicas must agree on the sample.
_SAMPLE_SEED = 0


def plan_space_size(n_tables: int) -> int:
    """Size of the full left-deep space (n!)."""
    return math.factorial(n_tables)


def enumerate_plans(n_tables: int, max_plans: int) -> np.ndarray:
    """All (or a deterministic sample of) table-order permutations.

    Returns a `(P, n_tables)` int32 array, `1 <= P <= max_plans`. When
    `n_tables! <= max_plans` the space is enumerated exhaustively in
    lexicographic order; otherwise `max_plans` permutations are drawn
    from a fixed-seed generator and deduplicated (first occurrence wins,
    so the order — and therefore any cost tie-break — is still
    deterministic).
    """
    if n_tables < 1:
        raise ValueError("need at least one table")
    if max_plans < 1:
        raise ValueError("max_plans must be >= 1")
    total = plan_space_size(n_tables)
    if total <= max_plans:
        plans = np.fromiter(
            itertools.chain.from_iterable(
                itertools.permutations(range(n_tables))
            ),
            dtype=np.int32,
            count=total * n_tables,
        )
        return plans.reshape(total, n_tables)

    rng = np.random.default_rng(_SAMPLE_SEED)
    seen = set()
    out = []
    # Identity first: the sample always contains at least one obvious
    # baseline order, whatever the draw.
    identity = tuple(range(n_tables))
    seen.add(identity)
    out.append(identity)
    while len(out) < max_plans:
        perm = tuple(int(x) for x in rng.permutation(n_tables))
        if perm not in seen:
            seen.add(perm)
            out.append(perm)
    return np.array(out, dtype=np.int32)
