"""Join-graph model: tables, equi-join edges, validation, identity.

A `JoinGraph` is the `/cost` endpoint's unit of work: a set of named
tables (each optionally bound to a registered `namespace/dataset` and
carrying a filter selectivity) and a set of equi-join edges keyed by
column. Everything request-shaped is validated HERE, at construction /
parse time, with `ValueError` — the HTTP layer maps those to 400s, so a
malformed graph can never reach the scoring kernel.

`identity()` is the canonical, order-insensitive tuple the caching tier
hashes into `/cost` ETags: two requests naming the same tables and edges
in any order produce the same identity, so they validate and coalesce
against each other.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

#: Enumeration cap when the request does not set one. 8! = 40320 exceeds
#: it, so graphs of 8+ tables score a deterministic sample (`enumerate`).
DEFAULT_MAX_PLANS = 4096

#: Hard ceiling on the enumeration width a request may ask for — the
#: scored lanes are (P, N) device arrays; an unbounded client-supplied P
#: would be a memory-exhaustion vector on the serving tier.
MAX_PLANS_CEILING = 65536


@dataclasses.dataclass(frozen=True)
class TableRef:
    """One table of a join graph.

    `name` is the graph-local alias edges refer to. `namespace`/`dataset`
    bind the table to a registered dataset on the fleet tier; on the
    single-dataset server they may be omitted (every table reads the
    served dataset — self-join graphs). `filter_selectivity` scales the
    table's base cardinality before any join ((0, 1], default 1.0 — the
    standard independent-filter model).
    """

    name: str
    namespace: Optional[str] = None
    dataset: Optional[str] = None
    filter_selectivity: float = 1.0

    @property
    def dataset_key(self) -> Optional[str]:
        if self.namespace is None or self.dataset is None:
            return None
        return f"{self.namespace}/{self.dataset}"


@dataclasses.dataclass(frozen=True)
class JoinEdge:
    """One equi-join predicate `left.left_column = right.right_column`."""

    left: str
    left_column: str
    right: str
    right_column: str


@dataclasses.dataclass(frozen=True)
class JoinGraph:
    """Validated join graph (construct via `make_graph`/`parse_join_graph`)."""

    tables: Tuple[TableRef, ...]
    edges: Tuple[JoinEdge, ...]

    @property
    def names(self) -> List[str]:
        return [t.name for t in self.tables]

    def table(self, name: str) -> TableRef:
        for t in self.tables:
            if t.name == name:
                return t
        raise KeyError(name)

    def columns_by_table(self) -> Dict[str, List[str]]:
        """Join columns each table contributes (sorted, deduplicated)."""
        cols: Dict[str, set] = {t.name: set() for t in self.tables}
        for e in self.edges:
            cols[e.left].add(e.left_column)
            cols[e.right].add(e.right_column)
        return {name: sorted(c) for name, c in cols.items()}

    def identity(self) -> tuple:
        """Canonical order-insensitive identity (the ETag component)."""
        tables = tuple(sorted(
            (t.name, t.namespace or "", t.dataset or "",
             float(t.filter_selectivity))
            for t in self.tables
        ))
        edges = tuple(sorted(
            # An equi-join is symmetric: (l.a = r.b) == (r.b = l.a).
            tuple(sorted([
                (e.left, e.left_column), (e.right, e.right_column)
            ]))
            for e in self.edges
        ))
        return (tables, edges)


def make_graph(
    tables: List[TableRef], edges: List[JoinEdge]
) -> JoinGraph:
    """Validate and freeze a join graph (ValueError on any request error).

    Checks: at least one table, unique aliases, edges referencing known
    aliases, no self-edges, selectivities in (0, 1], and CONNECTIVITY —
    a disconnected multi-table graph is rejected outright (the caller
    forgot an edge; silently costing the implied cross product of the
    components would hide the mistake). A missing edge on a PAIR inside a
    connected graph is fine: enumeration handles it as a cross-product
    step (`repro.planner.cost`).
    """
    if not tables:
        raise ValueError("join graph needs at least one table")
    names = [t.name for t in tables]
    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"duplicate table names {dupes}")
    for t in tables:
        if not t.name:
            raise ValueError("table names must be non-empty strings")
        if not (0.0 < float(t.filter_selectivity) <= 1.0):
            raise ValueError(
                f"table {t.name!r}: filter_selectivity must be in (0, 1], "
                f"got {t.filter_selectivity}"
            )
        if (t.namespace is None) != (t.dataset is None):
            raise ValueError(
                f"table {t.name!r}: namespace and dataset must be given "
                "together"
            )
    known = set(names)
    for e in edges:
        for side, col in ((e.left, e.left_column), (e.right, e.right_column)):
            if side not in known:
                raise ValueError(f"edge references unknown table {side!r}")
            if not col:
                raise ValueError(
                    f"edge {e.left}~{e.right}: join columns must be "
                    "non-empty strings"
                )
        if e.left == e.right:
            raise ValueError(
                f"self-edge on table {e.left!r}: equi-join edges must "
                "connect two distinct tables"
            )
    _check_connected(names, edges)
    return JoinGraph(tuple(tables), tuple(edges))


def _check_connected(names: List[str], edges: List[JoinEdge]) -> None:
    """Union-find connectivity; ValueError naming the stranded component."""
    parent = {n: n for n in names}

    def find(x: str) -> str:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for e in edges:
        ra, rb = find(e.left), find(e.right)
        if ra != rb:
            parent[ra] = rb
    roots = {find(n) for n in names}
    if len(roots) > 1:
        components = sorted(
            sorted(n for n in names if find(n) == r) for r in roots
        )
        raise ValueError(
            f"disconnected join graph: components {components} share no "
            "edge (add a join edge, or cost the components separately)"
        )


def parse_join_graph(payload, *, require_datasets: bool = False) -> JoinGraph:
    """`/cost` request body -> validated `JoinGraph` (ValueError on junk).

    Shape::

        {"tables": [{"name": "l", "namespace": "wh", "dataset": "lineitem",
                     "filter_selectivity": 0.4}, ...],
         "edges":  [{"left": "l", "left_column": "l_orderkey",
                     "right": "o", "right_column": "o_orderkey"}, ...]}

    `require_datasets=True` (the fleet router) insists every table names a
    registered `namespace`/`dataset`; the single-dataset server accepts
    tables without them (they read the served dataset).
    """
    if not isinstance(payload, dict):
        raise ValueError(
            f"join graph must be an object, got {type(payload).__name__}"
        )
    unknown = set(payload) - {"tables", "edges"}
    if unknown:
        raise ValueError(f"unknown join-graph fields {sorted(unknown)}")
    raw_tables = payload.get("tables")
    if not isinstance(raw_tables, list) or not raw_tables:
        raise ValueError("'tables' must be a non-empty list")
    raw_edges = payload.get("edges", [])
    if not isinstance(raw_edges, list):
        raise ValueError("'edges' must be a list")

    tables: List[TableRef] = []
    for i, t in enumerate(raw_tables):
        if not isinstance(t, dict):
            raise ValueError(f"tables[{i}] must be an object")
        unknown = set(t) - {"name", "namespace", "dataset",
                            "filter_selectivity"}
        if unknown:
            raise ValueError(f"tables[{i}]: unknown fields {sorted(unknown)}")
        name = t.get("name")
        if not isinstance(name, str) or not name:
            raise ValueError(f"tables[{i}]: 'name' must be a non-empty string")
        ns, ds = t.get("namespace"), t.get("dataset")
        for label, v in (("namespace", ns), ("dataset", ds)):
            if v is not None and not isinstance(v, str):
                raise ValueError(f"tables[{i}]: '{label}' must be a string")
        if require_datasets and (ns is None or ds is None):
            raise ValueError(
                f"tables[{i}] ({name!r}): router cost tables need "
                "'namespace' and 'dataset'"
            )
        sel = t.get("filter_selectivity", 1.0)
        if not isinstance(sel, (int, float)) or isinstance(sel, bool):
            raise ValueError(
                f"tables[{i}]: 'filter_selectivity' must be a number"
            )
        tables.append(TableRef(name, ns, ds, float(sel)))

    edges: List[JoinEdge] = []
    for i, e in enumerate(raw_edges):
        if not isinstance(e, dict):
            raise ValueError(f"edges[{i}] must be an object")
        unknown = set(e) - {"left", "left_column", "right", "right_column"}
        if unknown:
            raise ValueError(f"edges[{i}]: unknown fields {sorted(unknown)}")
        parts = {}
        for field in ("left", "left_column", "right", "right_column"):
            v = e.get(field)
            if not isinstance(v, str) or not v:
                raise ValueError(
                    f"edges[{i}]: '{field}' must be a non-empty string"
                )
            parts[field] = v
        edges.append(JoinEdge(**parts))
    return make_graph(tables, edges)


def parse_max_plans(value) -> int:
    """`max_plans` request field -> bounded int (ValueError on junk)."""
    if value is None:
        return DEFAULT_MAX_PLANS
    if isinstance(value, bool) or not isinstance(value, int):
        raise ValueError(f"max_plans must be an integer, got {value!r}")
    if value < 1:
        raise ValueError(f"max_plans must be >= 1, got {value}")
    return min(value, MAX_PLANS_CEILING)
