"""Batched serving engine: continuous-batching decode over a shared cache.

`serve_step` is the jit program the decode_32k / long_500k cells lower:
one new token for every active slot against the persistent cache/state.
The host-side `ServeEngine` does slot management (admit/evict/finished)
around it — the standard continuous-batching split (device step stays
shape-stable; the host mutates slot metadata only).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def make_serve_step(model, cfg: ModelConfig, *, temperature: float = 0.0):
    """Build the jit-able one-token decode step (greedy or sampled)."""

    def serve_step(params, tokens, positions, cache, rng):
        out = model.decode_step(params, tokens, positions, cache)
        logits = out.logits[:, -1, :]                      # (B, V)
        if temperature > 0.0:
            rng, sub = jax.random.split(rng)
            next_tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok.astype(jnp.int32)[:, None], out.cache, rng

    return serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous batching over a fixed number of slots."""

    def __init__(self, model, cfg: ModelConfig, params, *, slots: int = 8,
                 cache_len: int = 1024, temperature: float = 0.0):
        self.model = model
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        sp = model.cache_spec(slots, cache_len)
        self.cache = {
            k: jnp.zeros(
                v.shape, jnp.int32 if "index" in k else jnp.dtype(cfg.dtype)
            )
            for k, v in sp.items()
        }
        self.positions = np.zeros(slots, np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.step_fn = jax.jit(make_serve_step(model, cfg, temperature=temperature))
        self.rng = jax.random.PRNGKey(0)
        self.last_tok = np.zeros((slots, 1), np.int32)

    def _admit(self, queue: List[Request]):
        for i in range(self.slots):
            if self.active[i] is None and queue:
                req = queue.pop(0)
                self.active[i] = req
                # prefill token-by-token (simple; prefill fusion is in
                # launch/serve.py for the batched path)
                for t, tok in enumerate(req.prompt):
                    toks = self.last_tok.copy()
                    toks[i, 0] = tok
                    pos = np.zeros((self.slots, 1), np.int32)
                    pos[i, 0] = t
                    nt, self.cache, self.rng = self.step_fn(
                        self.params, jnp.asarray(toks), jnp.asarray(pos),
                        self.cache, self.rng,
                    )
                self.positions[i] = len(req.prompt)
                self.last_tok[i, 0] = int(np.asarray(nt)[i, 0])

    def run(self, requests: List[Request], eos: int = -1) -> List[Request]:
        queue = list(requests)
        finished: List[Request] = []
        while queue or any(r is not None for r in self.active):
            self._admit(queue)
            pos = self.positions.reshape(-1, 1).astype(np.int32)
            nt, self.cache, self.rng = self.step_fn(
                self.params, jnp.asarray(self.last_tok), jnp.asarray(pos),
                self.cache, self.rng,
            )
            nt = np.asarray(nt)
            for i, req in enumerate(self.active):
                if req is None:
                    continue
                tok = int(nt[i, 0])
                req.generated.append(tok)
                self.positions[i] += 1
                self.last_tok[i, 0] = tok
                if len(req.generated) >= req.max_new_tokens or tok == eos:
                    req.done = True
                    finished.append(req)
                    self.active[i] = None
        return finished
