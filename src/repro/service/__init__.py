"""Stats service: async footer ingestion + a fingerprint-ETag endpoint.

The paper's claim is that NDV is free because the statistics already sit in
file footers; at warehouse scale the consumers of those statistics (query
planners, pipeline schedulers) are *not* colocated with the files. This
package turns the `repro.catalog` library into a service: footers stream in
asynchronously, estimates are served over HTTP, and HTTP caching is driven
by the same fingerprint identity the catalog already uses for its own
caches. Two halves behind one facade:

  `AsyncIngestor`   scatter-gathers `MetadataSource.fingerprint()` /
                    `read_footer()` over a bounded thread pool and commits
                    through `StatsCatalog.apply_footers()` — the last-good
                    merged state serves for the whole duration of a
                    refresh; only the merge-and-swap takes the lock.
  `StatsService`    request side: ETag derivation, If-None-Match short-
                    circuit, single-flight coalescing, counters. The HTTP
                    layer (`StatsServer`, stdlib `ThreadingHTTPServer`,
                    JSON wire format) is a thin translation over it.

ETag / coherence contract
-------------------------

Every cacheable response (`/columns`, `/estimate`, `/plan`) carries a
strong ETag computed as SHA-1 over:

  1. the catalog's fingerprint set — one `file_id@fingerprint` token per
     live file (`StatsCatalog.fingerprint_key()`), so any file addition,
     removal, or rewrite rotates the tag, and *only* dataset changes do;
  2. the engine's `cache_token` — engines that can differ numerically
     (i.e. via the resolved kernel backend) never validate each other's
     responses. Execution shape (strategy, shard count, chunk budget) is
     numerics-neutral by the engine parity contract and deliberately
     absent: a composed server and a local server over one dataset emit
     byte-identical ETags, so a strategy change invalidates no client
     cache;
  3. the request identity — endpoint kind, estimation mode, and schema
     bounds — so a tag validates exactly the response it was issued for.

Clients revalidate with `If-None-Match`. A match is answered `304 Not
Modified` *before any catalog work*: zero footer reads, zero packs, zero
engine executions, no lock (the hit path hashes a state token that is
precomputed at each commit, so revalidation never queues behind an
in-flight cold computation). A miss recomputes under single-flight:
concurrent identical
cold requests share one engine execution, and the response body always
describes the dataset state its ETag names — the tag is re-derived inside
the same critical section that builds the body.

`generation` (monotonic, bumped per committed refresh that changed the
dataset) rides along in every body for observability; the ETag, not the
generation, is the cache key.

Batched RPC: `POST /batch` carries many (columns, mode, bounds,
if_none_match) tuples in one frame. Per-tuple semantics are identical to
`/estimate` — same ETags (an unfiltered tuple shares its tag
byte-for-byte with the plain endpoint), per-tuple 304s and 400s — while
all cold tuples of a batch execute as one cross-(mode, bounds) super-pack
engine call (`repro.catalog.superpack`), with single-flight extended to
per-tuple granularity so concurrent batches and singles coalesce against
each other. Responses negotiate a compact binary encoding
(`Accept: application/x-ndv-wire`, `repro.wire`) that decodes to
bit-identical bodies with the same ETags; JSON stays the default.

Estimation-quality observability: `?explain=1` on `/estimate` (and a
per-tuple `explain` flag in `/batch`) attaches per-column `Provenance`
— route chosen + margin, detector margin, Newton iterations/residual,
clamps, plus the audited q-error when available — WITHOUT touching the
ETag: explain is excluded from request identity, so explained and plain
responses validate each other and differ only by the sidecar (a tagged
wire-frame section old peers skip; explained payloads are memoized per
(etag, wire, audit_version)). `GET /debug/explain` dumps the catalog's
provenance cache + audit samples. The opt-in auditor
(`StatsService(audit=True, audit_columns=K)`) samples K columns per
refresh generation, computes a reference NDV from an HLL sketch over
one row group (`repro.kernels.hll`), and records q-error into
`ndv_audit_qerror{route=}` — see `repro.obs` for the metrics map.

The planner tier rides the same contract: `GET /tablestats` serves the
planner-shaped inputs (total rows + per-column NDV/route/confidence) and
`POST /cost` serves NDV-driven join ordering (`repro.planner`) — a
cacheable POST whose ETag hashes (state token, join-graph identity,
max_plans), so plans 304 exactly while the dataset's stats are
unchanged. Cost tuples ride `/batch` alongside estimate tuples.

Entry points: `repro.launch.serve_stats` (CLI), `serve()` (library),
`examples/profile_dataset.py --serve` (demo). For many datasets behind
one endpoint with N replicas each, see the fleet tier (`repro.fleet`):
it composes this package's `StatsService` into health-checked replica
sets — the state-derived ETag contract above is exactly what makes
replicas interchangeable there. docs/HTTP_API.md is the full endpoint
reference.
"""
from repro.service.http import (  # noqa: F401
    JSONResponseHandler,
    StatsServer,
    batch_envelope,
    fetch_json,
    format_bounds,
    format_columns,
    make_handler,
    parse_batch_queries,
    parse_bounds,
    parse_columns,
    parse_cost_request,
    parse_explain,
    parse_query_tuple,
    serve,
)
from repro.service.ingest import AsyncIngestor, IngestStats  # noqa: F401
from repro.service.service import (  # noqa: F401
    AuditResult,
    CostQuery,
    EstimateQuery,
    Response,
    ServiceStats,
    SingleFlight,
    StatsService,
    etag_matches,
)
