"""Dependency-free HTTP front-end for `StatsService`.

Built on the standard library only (`http.server.ThreadingHTTPServer`,
JSON wire format) so the serving path adds zero dependencies to the repo.
One thread per connection is plenty here: request handling is a dict hit
for warm traffic and an engine call for cold traffic, and the single-flight
layer in `StatsService` collapses concurrent cold bursts anyway.

Routes (all responses are JSON):

  GET  /health                       liveness + counters (never cached)
  GET  /columns                      merged per-column summary      [ETag]
  GET  /estimate?mode=&bounds=       per-column NDV estimates       [ETag]
  GET  /plan?mode=                   per-column memory plans        [ETag]
  POST /refresh                      force one ingestion refresh

`bounds` is `name:value[,name:value...]` (schema-knowledge NDV upper
bounds, Eq 14-15 family). Send `If-None-Match` with a previously returned
ETag to get `304 Not Modified` with an empty body when the dataset state,
engine config, and request identity all still match.
"""
from __future__ import annotations

import json
import threading
import urllib.error
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional, Tuple
from urllib.parse import parse_qs, urlsplit

from repro.service.service import Response, StatsService


def fetch_json(
    url: str,
    *,
    etag: Optional[str] = None,
    method: str = "GET",
    timeout: float = 30.0,
) -> Tuple[int, Optional[str], Optional[dict]]:
    """Minimal stdlib client for the stats endpoint.

    Returns ``(status, etag, body)`` with 304/4xx normalized out of
    urllib's `HTTPError` (a 304 carries no body by design). Shared by the
    launcher smoke test, the latency benchmark, and the e2e tests so the
    wire-level revalidation handling cannot drift between them.
    """
    req = urllib.request.Request(url, method=method)
    if etag is not None:
        req.add_header("If-None-Match", etag)
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, r.headers.get("ETag"), json.load(r)
    except urllib.error.HTTPError as e:
        raw = e.read()
        return e.code, e.headers.get("ETag"), (
            json.loads(raw) if raw else None
        )


def parse_bounds(raw: str) -> Dict[str, float]:
    """`"tok:10,val:2.5"` -> `{"tok": 10.0, "val": 2.5}` (ValueError on junk)."""
    bounds: Dict[str, float] = {}
    for part in raw.split(","):
        part = part.strip()
        if not part:
            continue
        name, sep, value = part.partition(":")
        if not sep or not name:
            raise ValueError(f"bad bounds entry {part!r}; want name:value")
        bounds[name] = float(value)
    return bounds


class JSONResponseHandler(BaseHTTPRequestHandler):
    """Shared wire plumbing for the stats JSON servers.

    One place owns the `Response` -> HTTP translation (ETag header,
    Content-Length, no Content-Type on 304, quiet logging), so the
    per-dataset server here and the fleet router (`repro.fleet.router`)
    cannot drift apart in revalidation behavior.
    """

    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):  # noqa: D102 — quiet by default
        pass

    def _send(self, resp: Response) -> None:
        payload = b""
        if resp.body is not None:
            payload = json.dumps(resp.body).encode()
        self.send_response(resp.status)
        if resp.etag is not None:
            self.send_header("ETag", resp.etag)
        if resp.status != 304:
            self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(payload)))
        self.end_headers()
        if payload:
            self.wfile.write(payload)

    def _error(self, status: int, message: str) -> None:
        self._send(Response(status, {"error": message}, None))


class _Handler(JSONResponseHandler):
    """Routes one request onto the shared `StatsService`."""

    service: StatsService  # injected by make_handler
    server_version = "ndv-stats"

    # -- routes --------------------------------------------------------------

    def do_GET(self) -> None:  # noqa: N802 — http.server API
        url = urlsplit(self.path)
        query = parse_qs(url.query)
        inm = self.headers.get("If-None-Match")
        bounds = None
        if "bounds" in query:
            try:
                bounds = parse_bounds(query["bounds"][0])
            except ValueError as e:  # 400 is for request errors ONLY —
                return self._error(400, str(e))
        try:
            if url.path == "/health":
                self._send(self.service.health())
            elif url.path == "/columns":
                self._send(self.service.columns(if_none_match=inm))
            elif url.path == "/estimate":
                self._send(self.service.estimate(
                    mode=query.get("mode", ["paper"])[0],
                    schema_bounds=bounds,
                    if_none_match=inm,
                ))
            elif url.path == "/plan":
                self._send(self.service.plan(
                    mode=query.get("mode", ["paper"])[0],
                    if_none_match=inm,
                ))
            else:
                self._error(404, f"no such endpoint: {url.path}")
        except Exception as e:
            # — a ValueError from deep inside refresh/merge (e.g. a
            # schema-mismatched file) is a server-side failure: 500.
            self._error(500, f"{type(e).__name__}: {e}")

    def do_POST(self) -> None:  # noqa: N802 — http.server API
        url = urlsplit(self.path)
        try:
            if url.path == "/refresh":
                self._send(self.service.refresh())
            else:
                self._error(404, f"no such endpoint: {url.path}")
        except Exception as e:
            self._error(500, f"{type(e).__name__}: {e}")


def make_handler(service: StatsService):
    return type("BoundStatsHandler", (_Handler,), {"service": service})


class StatsServer:
    """Owns a `ThreadingHTTPServer` serving one `StatsService`.

    Port 0 binds an ephemeral port (read it back from `.port`). `start()`
    runs the accept loop on a daemon thread; `stop()` shuts it down and
    stops the service's ingestion loop. Also usable as a context manager.
    """

    def __init__(
        self, service: StatsService, host: str = "127.0.0.1", port: int = 0
    ):
        self.service = service
        self.httpd = ThreadingHTTPServer((host, port), make_handler(service))
        self.host, self.port = self.httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    @property
    def address(self) -> Tuple[str, int]:
        return (self.host, self.port)

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "StatsServer":
        self.service.start()
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, name="ndv-stats-http", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        # shutdown() blocks on an event only serve_forever() sets — calling
        # it when start() failed before the accept loop ran would hang.
        if self._thread is not None:
            self.httpd.shutdown()
            self._thread.join(timeout=10.0)
            self._thread = None
        self.httpd.server_close()
        self.service.stop()

    def __enter__(self) -> "StatsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def serve(
    source,
    *,
    host: str = "127.0.0.1",
    port: int = 0,
    **service_kwargs,
) -> StatsServer:
    """One-call convenience: build a `StatsService` and start serving it."""
    return StatsServer(
        StatsService(source, **service_kwargs), host=host, port=port
    ).start()
