"""Dependency-free HTTP front-end for `StatsService`.

Built on the standard library only (`http.server.ThreadingHTTPServer`,
JSON wire format) so the serving path adds zero dependencies to the repo.
One thread per connection is plenty here: request handling is a dict hit
for warm traffic and an engine call for cold traffic, and the single-flight
layer in `StatsService` collapses concurrent cold bursts anyway.

Routes (responses are JSON by default):

  GET  /health                       liveness + counters (never cached)
  GET  /columns                      merged per-column summary      [ETag]
  GET  /estimate?mode=&bounds=&explain=  per-column NDV estimates   [ETag]
  GET  /plan?mode=                   per-column memory plans        [ETag]
  GET  /tablestats?mode=&columns=    planner-shaped rows + NDV      [ETag]
  GET  /metrics                      Prometheus text exposition (uncached)
  GET  /debug/traces?limit=N         recent request traces, JSON span trees
  GET  /debug/explain                provenance cache + audit samples
  POST /cost?explain=                cheapest join order for a graph [ETag]
  POST /batch                        many estimate/cost tuples, one frame
  POST /refresh                      force one ingestion refresh

`POST /cost` takes `{"graph": {"tables": [...], "edges": [...]},
"mode"?, "max_plans"?}` (see `repro.planner.graph.parse_join_graph` for
the graph shape) and returns the cheapest join order with per-join
output cardinalities. It is cacheable despite being a POST: the response
carries an ETag over (dataset state, graph identity, max_plans) and an
`If-None-Match` request header earns a 304 — plans revalidate exactly
while the dataset's stats are unchanged. A `/batch` tuple with a "cost"
key (`{"cost": {"graph": ...}, "if_none_match"?, "explain"?}`) carries
the same request inside the batch envelope with identical ETags.

`explain=1` attaches per-column estimation provenance (chosen route and
its margin, detector margin, Newton iteration counts and residual,
clamps hit, plus the latest audit sample) under a "provenance" key. The
flag is identity-neutral: ETags, 304 behavior, and explain-off bodies
are byte-identical to an explain-free server; on the wire encoding the
provenance rides in its own frame section (tag 4) that old peers skip.

`bounds` is `name:value[,name:value...]` (schema-knowledge NDV upper
bounds, Eq 14-15 family); names and values may be percent-escaped, so
column names containing `:` or `,` survive the trip. Send `If-None-Match`
with a previously returned ETag to get `304 Not Modified` with an empty
body when the dataset state, engine config, and request identity all
still match.

Content negotiation: every endpoint answers with the compact binary wire
encoding (`repro.wire`) instead of JSON when the request carries
`Accept: application/x-ndv-wire`. The two encodings decode to
bit-identical bodies and carry the same ETags — the encoding is never
part of a response's identity. `POST /batch` accepts its request body in
either encoding too (by Content-Type); the body is
`{"tuples": [{"columns", "mode", "bounds", "if_none_match"}, ...]}` and
the response `{"responses": [{"status", "etag", "body"}, ...]}` with
per-tuple statuses (304 tuples carry a null body).
"""
from __future__ import annotations

import json
import logging
import threading
import time
import urllib.error
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Tuple
from urllib.parse import parse_qs, quote, unquote, urlsplit

from repro.obs import (
    LATENCY_BUCKETS_S,
    TRACEPARENT_HEADER,
    WIDTH_BUCKETS,
    collector,
    registry,
    root_span,
    trace_tree,
)
from repro.planner import parse_join_graph, parse_max_plans
from repro.service.service import (
    CostQuery,
    EstimateQuery,
    Response,
    StatsService,
)
from repro.wire import (
    JSON_CONTENT_TYPE,
    WIRE_CONTENT_TYPE,
    WireError,
    decode_frame,
    decode_traceparent,
    encode_frame,
)

METRICS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

# One structured line per over-budget request (see `slow_request_ms`).
_slow_log = logging.getLogger("repro.obs.slow")

_REQUESTS = registry().counter(
    "ndv_http_requests_total", "HTTP requests served, by tier/route/status"
)
_LATENCY = registry().histogram(
    "ndv_http_request_seconds",
    "HTTP request wall time in seconds",
    LATENCY_BUCKETS_S,
)
_BATCH_WIDTH = registry().histogram(
    "ndv_batch_tuples",
    "Estimate tuples carried per /batch request",
    WIDTH_BUCKETS,
)

# (tier, route, int status) -> pre-bound (counter, latency histogram).
# The per-request metrics line runs on every exchange; resolving label
# identities (and stringifying the status) once per distinct combination
# keeps it off the profile.
_REQUEST_CELLS: Dict[tuple, tuple] = {}


def _request_cells(tier: str, route: str, status: int) -> tuple:
    # Races store equivalent handles over the same canonical cells.
    pair = _REQUEST_CELLS[(tier, route, status)] = (
        _REQUESTS.labels(tier=tier, route=route, status=str(status)),
        _LATENCY.labels(tier=tier, route=route),
    )
    return pair


def fetch_json(
    url: str,
    *,
    etag: Optional[str] = None,
    method: str = "GET",
    timeout: float = 30.0,
) -> Tuple[int, Optional[str], Optional[dict]]:
    """Minimal stdlib client for the stats endpoint.

    Returns ``(status, etag, body)`` with 304/4xx normalized out of
    urllib's `HTTPError` (a 304 carries no body by design). Shared by the
    launcher smoke test, the latency benchmark, and the e2e tests so the
    wire-level revalidation handling cannot drift between them.
    """
    req = urllib.request.Request(url, method=method)
    if etag is not None:
        req.add_header("If-None-Match", etag)
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, r.headers.get("ETag"), json.load(r)
    except urllib.error.HTTPError as e:
        raw = e.read()
        return e.code, e.headers.get("ETag"), (
            json.loads(raw) if raw else None
        )


def parse_bounds(raw: str) -> Dict[str, float]:
    """`"tok:10,val:2.5"` -> `{"tok": 10.0, "val": 2.5}` (ValueError on junk).

    Each side of a `name:value` pair is percent-unescaped after splitting,
    so serializers (`format_bounds`) can carry column names containing the
    `:` / `,` delimiters themselves. Unescaping is the identity for
    ordinary names — pre-escape clients keep working unchanged.
    """
    bounds: Dict[str, float] = {}
    for part in raw.split(","):
        part = part.strip()
        if not part:
            continue
        name, sep, value = part.partition(":")
        if not sep or not name:
            raise ValueError(f"bad bounds entry {part!r}; want name:value")
        bounds[unquote(name)] = float(unquote(value))
    return bounds


def format_bounds(bounds) -> str:
    """Inverse of `parse_bounds`: mapping (or pair iterable) -> query value.

    Percent-escapes both sides of every pair, so `parse_bounds(
    format_bounds(b)) == b` for EVERY column name — including hostile ones
    containing the `:` / `,` delimiters that an unescaped join corrupts.
    """
    items = bounds.items() if hasattr(bounds, "items") else bounds
    return ",".join(
        f"{quote(str(n), safe='')}:{quote(str(v), safe='')}"
        for n, v in items
    )


def parse_columns(raw: str) -> Tuple[str, ...]:
    """`?columns=` query value -> column-name tuple (ValueError on junk).

    Comma-separated, each name percent-unescaped after the split
    (`format_columns` is the inverse) — same escaping contract as
    `bounds`, so names containing `,` survive the trip.
    """
    cols = tuple(unquote(p) for p in raw.split(",") if p.strip())
    if not cols:
        raise ValueError("columns must name at least one column")
    return cols


def format_columns(columns) -> str:
    """Inverse of `parse_columns`: name iterable -> query value."""
    return ",".join(quote(str(c), safe="") for c in columns)


def parse_cost_request(
    payload, *, require_datasets: bool = False
) -> Tuple[object, str, int]:
    """`/cost` request body -> (graph, mode, max_plans); ValueError -> 400.

    Shared verbatim by the per-dataset server and the fleet router
    (`require_datasets=True` there: every table must name a registered
    namespace/dataset), so the request grammar cannot drift between
    tiers.
    """
    if not isinstance(payload, dict):
        raise ValueError(
            f"cost body must be an object, got {type(payload).__name__}"
        )
    unknown = set(payload) - {"graph", "mode", "max_plans"}
    if unknown:
        raise ValueError(f"unknown cost fields {sorted(unknown)}")
    if "graph" not in payload:
        raise ValueError("cost body needs a 'graph' object")
    graph = parse_join_graph(
        payload["graph"], require_datasets=require_datasets
    )
    mode = payload.get("mode", "paper")
    if not isinstance(mode, str):
        raise ValueError("'mode' must be a string")
    max_plans = parse_max_plans(payload.get("max_plans"))
    return graph, mode, max_plans


def parse_explain(query: Dict[str, List[str]]) -> bool:
    """`?explain=` query value -> bool (ValueError on junk).

    Accepts the usual boolean spellings; anything else is a request error
    (400), never a silent false — a typo'd diagnostics request that
    quietly returns an unexplained body is worse than rejection.
    """
    raw = query.get("explain", ["0"])[0].strip().lower()
    if raw in ("", "0", "false", "no"):
        return False
    if raw in ("1", "true", "yes"):
        return True
    raise ValueError(f"explain must be a boolean flag, got {raw!r}")


def parse_query_tuple(d: dict) -> "EstimateQuery | CostQuery":
    """One `/batch` tuple dict -> `EstimateQuery` (ValueError on junk).

    `bounds` accepts either a `{name: value}` mapping (the native batch
    shape) or the GET query-string format (`parse_bounds` syntax), so a
    client can forward query strings verbatim. `explain` accepts a bool
    or 0/1.

    A tuple carrying a "cost" key is a batched `/cost` request instead:
    its value is the same `{"graph", "mode"?, "max_plans"?}` object the
    standalone endpoint takes, with tuple-level `if_none_match`/`explain`
    riding alongside. Parses to a `CostQuery`.
    """
    if not isinstance(d, dict):
        raise ValueError(f"batch tuple must be an object, got {type(d).__name__}")
    if "cost" in d:
        unknown = set(d) - {"cost", "if_none_match", "explain",
                            "namespace", "dataset"}
        if unknown:
            raise ValueError(
                f"unknown cost tuple fields {sorted(unknown)}"
            )
        graph, mode, max_plans = parse_cost_request(d["cost"])
        inm = d.get("if_none_match")
        if inm is not None and not isinstance(inm, str):
            raise ValueError("'if_none_match' must be a string")
        explain = d.get("explain", False)
        if explain not in (True, False, 0, 1):
            raise ValueError("'explain' must be a boolean or 0/1")
        return CostQuery(
            graph=graph, mode=mode, max_plans=max_plans,
            if_none_match=inm, explain=bool(explain),
        )
    unknown = set(d) - {"columns", "mode", "bounds", "if_none_match",
                        "namespace", "dataset", "explain"}
    if unknown:
        raise ValueError(f"unknown batch tuple fields {sorted(unknown)}")
    cols = d.get("columns")
    if cols is not None:
        if not isinstance(cols, (list, tuple)) or not all(
            isinstance(c, str) for c in cols
        ):
            raise ValueError("'columns' must be a list of column names")
        cols = tuple(cols)
    bounds = d.get("bounds")
    if bounds is not None:
        if isinstance(bounds, str):
            bounds = parse_bounds(bounds)
        elif isinstance(bounds, dict):
            bounds = {str(k): float(v) for k, v in bounds.items()}
        else:
            raise ValueError("'bounds' must be a mapping or name:value string")
    mode = d.get("mode", "paper")
    if not isinstance(mode, str):
        raise ValueError("'mode' must be a string")
    inm = d.get("if_none_match")
    if inm is not None and not isinstance(inm, str):
        raise ValueError("'if_none_match' must be a string")
    explain = d.get("explain", False)
    if explain not in (True, False, 0, 1):
        raise ValueError("'explain' must be a boolean or 0/1")
    return EstimateQuery(
        columns=cols, mode=mode, schema_bounds=bounds, if_none_match=inm,
        explain=bool(explain),
    )


def parse_batch_queries(payload) -> List[EstimateQuery]:
    """`/batch` request body -> query list (ValueError on junk)."""
    if not isinstance(payload, dict) or not isinstance(
        payload.get("tuples"), list
    ):
        raise ValueError("batch body must be an object with a 'tuples' list")
    return [parse_query_tuple(t) for t in payload["tuples"]]


def batch_envelope(results: List[Response]) -> Response:
    """Per-tuple `Response`s -> the one `/batch` HTTP response.

    The envelope itself is uncacheable (no ETag — tuples carry their own);
    per-tuple 304s ride inside it with null bodies.
    """
    return Response(200, {
        "responses": [
            {"status": r.status, "etag": r.etag, "body": r.body}
            for r in results
        ],
    }, None)


class JSONResponseHandler(BaseHTTPRequestHandler):
    """Shared wire plumbing for the stats JSON servers.

    One place owns the `Response` -> HTTP translation (ETag header,
    Content-Length, no Content-Type on 304, content negotiation, quiet
    logging), so the per-dataset server here and the fleet router
    (`repro.fleet.router`) cannot drift apart in revalidation behavior.

    It also owns the telemetry envelope around every request: `do_GET` /
    `do_POST` live HERE — they serve `/metrics` and `/debug/traces`
    directly, and wrap everything else in a root span (joining an
    incoming `Traceparent` header or wire-frame trace section), a
    request counter, and a latency histogram before dispatching to the
    subclass's `handle_get` / `handle_post`. Scrape endpoints create no
    spans, so pollers don't fill the trace ring.
    """

    protocol_version = "HTTP/1.1"
    # Keep-alive exchanges write headers and body as separate small
    # segments; without TCP_NODELAY the second one stalls ~40ms behind the
    # client's delayed ACK (Nagle). The pool client disables it too.
    disable_nagle_algorithm = True

    # Metric label distinguishing the per-dataset server from the fleet
    # router when both live in one process (tests, embedded fleets).
    tier = "service"
    # Log one structured line for requests slower than this (ms); None = off.
    slow_request_ms: Optional[float] = None

    _KNOWN_ROUTES = frozenset(
        {"health", "columns", "estimate", "plan", "tablestats", "cost",
         "refresh", "batch"}
    )

    def log_message(self, fmt, *args):  # noqa: D102 — quiet by default
        pass

    def _route_label(self, path: str) -> str:
        """Collapse the path to a bounded metric label (hostile paths
        must not mint unbounded label values)."""
        name = path.strip("/")
        return name if name in self._KNOWN_ROUTES else "other"

    def handle_get(self, url) -> None:
        raise NotImplementedError

    def handle_post(self, url) -> None:
        raise NotImplementedError

    def do_GET(self) -> None:  # noqa: N802 — http.server API
        self._serve("GET")

    def do_POST(self) -> None:  # noqa: N802 — http.server API
        self._serve("POST")

    def _serve(self, method: str) -> None:
        url = urlsplit(self.path)
        if method == "GET" and url.path == "/metrics":
            return self._serve_metrics()
        if method == "GET" and url.path == "/debug/traces":
            # keep_blank_values so `?limit=` reaches validation and earns a
            # 400 instead of silently vanishing from the parse.
            return self._serve_traces(parse_qs(url.query, keep_blank_values=True))
        if method == "GET" and url.path == "/debug/explain":
            return self._serve_explain(parse_qs(url.query, keep_blank_values=True))

        self._raw_body = b""
        if method == "POST":
            # Pre-read the body so a frame-carried traceparent can seed
            # the root span; `_read_body` re-parses these bytes.
            length = int(self.headers.get("Content-Length") or 0)
            if length:
                self._raw_body = self.rfile.read(length)

        traceparent = self.headers.get(TRACEPARENT_HEADER)
        if not traceparent and self._raw_body[:4] == b"NDVW":
            traceparent = decode_traceparent(self._raw_body)

        route = self._route_label(url.path)
        self._status: Optional[int] = None
        start = time.monotonic()
        with root_span(
            f"{self.tier}.{route}", traceparent, method=method, path=url.path
        ) as span:
            if method == "GET":
                self.handle_get(url)
            else:
                self.handle_post(url)
            span.set_attribute("status", self._status)
            if self._status is not None and self._status >= 400:
                span.keep_trace()  # failed requests always reach the ring
        duration_s = time.monotonic() - start
        status = self._status if self._status is not None else 0
        cells = _REQUEST_CELLS.get((self.tier, route, status)) \
            or _request_cells(self.tier, route, status)
        cells[0].inc()
        cells[1].observe(duration_s)
        if (
            self.slow_request_ms is not None
            and duration_s * 1000.0 >= self.slow_request_ms
        ):
            _slow_log.warning(
                "slow_request tier=%s endpoint=%s status=%s cache=%s "
                "duration_ms=%.1f trace_id=%s",
                self.tier,
                url.path,
                status,
                "revalidated" if self._status == 304 else "full",
                duration_s * 1000.0,
                span.trace_id,
            )

    # -- scrape endpoints (no spans: pollers must not fill the ring) ---------

    def _metrics_text(self) -> str:
        """Exposition body; the router overrides to add replica scrapes."""
        return registry().exposition()

    def _serve_metrics(self) -> None:
        payload = self._metrics_text().encode("utf-8")
        self.send_response(200)
        self.send_header("Content-Type", METRICS_CONTENT_TYPE)
        self.send_header("Content-Length", str(len(payload)))
        self.end_headers()
        self.wfile.write(payload)

    def _serve_traces(self, query: Dict[str, List[str]]) -> None:
        try:
            limit = int(query.get("limit", ["20"])[0])
        except ValueError:
            return self._error(400, "limit must be an integer")
        if limit < 0:
            # A negative limit reaches the ring as a hostile slice index;
            # reject it as the request error it is, not a 500.
            return self._error(400, "limit must be >= 0")
        trees = [trace_tree(spans) for spans in collector().traces(limit)]
        self._send(Response(200, {"traces": trees}, None))

    def _explain_body(self, query: Dict[str, List[str]]) -> Response:
        """`/debug/explain` payload; servers with a provenance source
        override (per-dataset: the service's cache; router: aggregation).
        May raise ValueError for malformed query params -> 400."""
        return Response(
            404, {"error": "this server has no provenance source"}, None
        )

    def _serve_explain(self, query: Dict[str, List[str]]) -> None:
        try:
            resp = self._explain_body(query)
        except ValueError as e:
            return self._error(400, str(e))
        except Exception as e:
            return self._error(500, f"{type(e).__name__}: {e}")
        self._send(resp)

    def _wants_wire(self) -> bool:
        """Whether the request negotiated the binary encoding.

        A substring check is enough for the one non-default media type we
        serve — anything without the exact token (including `*/*`) gets
        JSON, the compatible default.
        """
        return WIRE_CONTENT_TYPE in (self.headers.get("Accept") or "")

    def _encode_payload(self, resp: Response, wire: bool) -> bytes:
        """Serialize a response body (wire frame or JSON bytes).

        Overridable: the service handler memoizes explained payloads here
        (provenance is immutable for a given ETag + audit pass, so its
        serialization need not repeat per request).
        """
        if wire:
            # Top-level provenance (an explained /estimate) rides in
            # the frame's EXPLAIN section, keeping the value section —
            # and so the body an old peer decodes — explain-blind;
            # `repro.wire.client.fetch` re-attaches it.
            body, explain = resp.body, None
            if isinstance(body, dict) and "provenance" in body:
                explain = body["provenance"]
                body = {
                    k: v for k, v in body.items() if k != "provenance"
                }
            return encode_frame(body, explain=explain)
        return json.dumps(resp.body).encode()

    def _send(self, resp: Response) -> None:
        self._status = resp.status
        wire = self._wants_wire()
        payload = b""
        if resp.body is not None:
            payload = self._encode_payload(resp, wire)
        self.send_response(resp.status)
        if resp.etag is not None:
            self.send_header("ETag", resp.etag)
        if resp.status != 304:
            self.send_header(
                "Content-Type", WIRE_CONTENT_TYPE if wire else JSON_CONTENT_TYPE
            )
        self.send_header("Content-Length", str(len(payload)))
        self.end_headers()
        if payload:
            self.wfile.write(payload)

    def _error(self, status: int, message: str) -> None:
        self._send(Response(status, {"error": message}, None))

    def _read_body(self):
        """Decode the request body by its Content-Type (wire or JSON).

        The raw bytes were pre-read by `_serve` (the root span needs any
        frame-carried traceparent before dispatch). Raises ValueError
        (including `WireError`) on malformed payloads — callers answer 400.
        """
        raw = getattr(self, "_raw_body", b"")
        if not raw:
            raise ValueError("empty request body")
        ctype = (self.headers.get("Content-Type") or JSON_CONTENT_TYPE)
        if ctype.split(";")[0].strip() == WIRE_CONTENT_TYPE:
            return decode_frame(raw)
        try:
            return json.loads(raw.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise ValueError(f"bad JSON body: {e}") from None


class _Handler(JSONResponseHandler):
    """Routes one request onto the shared `StatsService`."""

    service: StatsService  # injected by make_handler
    server_version = "ndv-stats"

    def _explain_body(self, query) -> Response:
        return self.service.debug_explain()

    def _encode_payload(self, resp: Response, wire: bool) -> bytes:
        # Explained responses re-serialize the same provenance on every
        # request; memoize the bytes on the service. The ETag names the
        # estimate state and the audit version names the q-error sidecar —
        # together they pin everything an explained payload contains.
        if (
            resp.etag is not None
            and isinstance(resp.body, dict)
            and "provenance" in resp.body
        ):
            key = (resp.etag, wire, self.service.audit_version)
            cached = self.service.explain_payload_peek(key)
            if cached is None:
                cached = super()._encode_payload(resp, wire)
                self.service.explain_payload_store(key, cached)
            return cached
        return super()._encode_payload(resp, wire)

    # -- routes --------------------------------------------------------------

    def handle_get(self, url) -> None:
        query = parse_qs(url.query)
        inm = self.headers.get("If-None-Match")
        bounds = None
        if "bounds" in query:
            try:
                bounds = parse_bounds(query["bounds"][0])
            except ValueError as e:  # 400 is for request errors ONLY —
                return self._error(400, str(e))
        try:
            if url.path == "/health":
                self._send(self.service.health())
            elif url.path == "/columns":
                self._send(self.service.columns(if_none_match=inm))
            elif url.path == "/estimate":
                try:
                    explain = parse_explain(query)
                except ValueError as e:
                    return self._error(400, str(e))
                self._send(self.service.estimate(
                    mode=query.get("mode", ["paper"])[0],
                    schema_bounds=bounds,
                    if_none_match=inm,
                    explain=explain,
                ))
            elif url.path == "/plan":
                self._send(self.service.plan(
                    mode=query.get("mode", ["paper"])[0],
                    if_none_match=inm,
                ))
            elif url.path == "/tablestats":
                columns = None
                if "columns" in query:
                    try:
                        columns = parse_columns(query["columns"][0])
                    except ValueError as e:
                        return self._error(400, str(e))
                self._send(self.service.table_stats(
                    mode=query.get("mode", ["paper"])[0],
                    columns=columns,
                    if_none_match=inm,
                ))
            else:
                self._error(404, f"no such endpoint: {url.path}")
        except Exception as e:
            # — a ValueError from deep inside refresh/merge (e.g. a
            # schema-mismatched file) is a server-side failure: 500.
            self._error(500, f"{type(e).__name__}: {e}")

    def handle_post(self, url) -> None:
        try:
            if url.path == "/refresh":
                self._send(self.service.refresh())
            elif url.path == "/cost":
                try:
                    explain = parse_explain(
                        parse_qs(url.query, keep_blank_values=True)
                    )
                    graph, mode, max_plans = parse_cost_request(
                        self._read_body()
                    )
                except ValueError as e:
                    return self._error(400, str(e))
                self._send(self.service.cost(
                    graph=graph, mode=mode, max_plans=max_plans,
                    if_none_match=self.headers.get("If-None-Match"),
                    explain=explain,
                ))
            elif url.path == "/batch":
                try:
                    queries = parse_batch_queries(self._read_body())
                except ValueError as e:
                    return self._error(400, str(e))
                _BATCH_WIDTH.observe(len(queries), tier=self.tier)
                self._send(batch_envelope(self.service.batch(queries)))
            else:
                self._error(404, f"no such endpoint: {url.path}")
        except Exception as e:
            self._error(500, f"{type(e).__name__}: {e}")


def make_handler(service: StatsService, *, slow_request_ms: Optional[float] = None):
    return type(
        "BoundStatsHandler",
        (_Handler,),
        {"service": service, "slow_request_ms": slow_request_ms},
    )


class StatsServer:
    """Owns a `ThreadingHTTPServer` serving one `StatsService`.

    Port 0 binds an ephemeral port (read it back from `.port`). `start()`
    runs the accept loop on a daemon thread; `stop()` shuts it down and
    stops the service's ingestion loop. Also usable as a context manager.
    """

    def __init__(
        self,
        service: StatsService,
        host: str = "127.0.0.1",
        port: int = 0,
        slow_request_ms: Optional[float] = None,
    ):
        self.service = service
        self.httpd = ThreadingHTTPServer(
            (host, port),
            make_handler(service, slow_request_ms=slow_request_ms),
        )
        self.host, self.port = self.httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    @property
    def address(self) -> Tuple[str, int]:
        return (self.host, self.port)

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "StatsServer":
        self.service.start()
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, name="ndv-stats-http", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        # shutdown() blocks on an event only serve_forever() sets — calling
        # it when start() failed before the accept loop ran would hang.
        if self._thread is not None:
            self.httpd.shutdown()
            self._thread.join(timeout=10.0)
            self._thread = None
        self.httpd.server_close()
        self.service.stop()

    def __enter__(self) -> "StatsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def serve(
    source,
    *,
    host: str = "127.0.0.1",
    port: int = 0,
    **service_kwargs,
) -> StatsServer:
    """One-call convenience: build a `StatsService` and start serving it."""
    return StatsServer(
        StatsService(source, **service_kwargs), host=host, port=port
    ).start()
