"""Async footer ingestion: scatter-gather over a `MetadataSource`.

Footer I/O is the one non-free step in zero-cost NDV estimation (the paper
reads *metadata*, but the metadata still lives at the end of remote files).
`AsyncIngestor` overlaps that I/O over a bounded thread pool and commits
results through `StatsCatalog.apply_footers()`:

  scatter   fingerprint every listed file concurrently (stat-cheap), diff
            against the catalog's committed fingerprints, then read only
            the new/changed footers — again concurrently.
  gather    hand the parsed `FileEntry`s plus the authoritative live-id
            list to `apply_footers()`, which merges and swaps atomically.

The commit (and only the commit) runs under the shared service lock, so
the *last-good merged state keeps serving* for the entire duration of the
slow half: a refresh against an object store with hundred-millisecond
footer reads never blocks an `estimate()` call.

A file that vanishes between listing and reading is treated as removed
(never added) — the same semantics `StatsCatalog.update()` applies — so a
compaction job racing the ingestor produces a consistent, monotonic view.

`generation` increments on every committed refresh that changed the
dataset; the serving layer folds it into responses so clients can observe
state progression without comparing fingerprint sets.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import threading
import time
from typing import Callable, List, Optional, Tuple

from repro.catalog import FileEntry, StatsCatalog, UpdateSummary
from repro.obs import span


@dataclasses.dataclass
class IngestStats:
    """Observability counters for the ingestion half (see `/health`)."""

    refreshes: int = 0            # refresh() calls that ran to completion
    commits: int = 0              # refreshes that changed the dataset
    fingerprints: int = 0         # fingerprint() calls issued
    footers_read: int = 0         # read_footer() calls that succeeded
    vanished: int = 0             # files lost between listing and reading
    errors: int = 0               # refreshes that raised (state untouched)
    last_error: Optional[str] = None
    last_refresh_s: float = 0.0   # wall time of the most recent refresh


class AsyncIngestor:
    """Non-blocking ingestion loop feeding one `StatsCatalog`.

    Args:
      catalog: the catalog to feed. The ingestor assumes it is the only
        writer; route manual rescans through `refresh()`, not
        `catalog.update()`.
      max_workers: thread-pool width for the scatter phases.
      poll_interval: seconds between automatic refreshes once `start()` is
        called; None means manual `refresh()` only.
      lock: the lock guarding catalog state, shared with the serving layer
        (reads and the commit both take it; footer I/O never does).
      on_commit: callback invoked (under the lock) after each committed
        refresh that changed the dataset — the service hooks cache
        compaction and optional cache spilling here.
    """

    def __init__(
        self,
        catalog: StatsCatalog,
        *,
        max_workers: int = 8,
        poll_interval: Optional[float] = None,
        lock: Optional[threading.RLock] = None,
        on_commit: Optional[Callable[[UpdateSummary], None]] = None,
    ):
        if max_workers < 1:
            raise ValueError("max_workers must be >= 1")
        self.catalog = catalog
        self.max_workers = max_workers
        self.poll_interval = poll_interval
        self.lock = lock if lock is not None else threading.RLock()
        self.on_commit = on_commit
        self.stats = IngestStats()
        self.generation = 0
        self._refresh_mutex = threading.Lock()  # serialize refreshes
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._pool: Optional[concurrent.futures.ThreadPoolExecutor] = None

    # -- one refresh ---------------------------------------------------------

    def refresh(self) -> UpdateSummary:
        """Scatter-gather one full re-scan and commit it.

        Thread-safe and serialized: concurrent callers queue up rather than
        racing the snapshot/commit pair. Raises whatever the merge raises
        (e.g. a schema-mismatched file) — the previous state keeps serving
        and the error is recorded in `stats.last_error`.
        """
        with self._refresh_mutex, span("ingest.refresh") as sp:
            t0 = time.perf_counter()
            try:
                fresh, live_ids = self._scatter_gather()
                sp.set_attribute("footers", len(fresh))
                # ONE critical section for commit + generation + on_commit:
                # a reader must never observe the new merged state paired
                # with a pre-commit generation/ETag (the serving layer
                # rotates its state token inside on_commit).
                with self.lock:
                    summary = self.catalog.apply_footers(
                        fresh, live_ids=live_ids
                    )
                    if summary.changed:
                        self.generation += 1
                        self.stats.commits += 1
                        if self.on_commit is not None:
                            self.on_commit(summary)
            except Exception as e:
                self.stats.errors += 1
                self.stats.last_error = f"{type(e).__name__}: {e}"
                raise
            finally:
                self.stats.last_refresh_s = time.perf_counter() - t0
            self.stats.refreshes += 1
            return summary

    def _scatter_gather(self) -> Tuple[List[FileEntry], List[str]]:
        """The slow, lock-free half: fingerprint sweep + footer reads."""
        source = self.catalog.source
        ids = source.list_files()
        with self.lock:
            prev = self.catalog.entry_fingerprints()

        def fingerprint(fid: str) -> Tuple[str, Optional[str]]:
            try:
                return fid, source.fingerprint(fid)
            except FileNotFoundError:
                return fid, None

        def read(fid_fp: Tuple[str, str]) -> Optional[FileEntry]:
            fid, fp = fid_fp
            try:
                return FileEntry(fid, fp, source.read_footer(fid))
            except FileNotFoundError:
                return None

        pool = self._get_pool()
        fps = list(pool.map(fingerprint, ids))
        self.stats.fingerprints += len(fps)
        live = [(fid, fp) for fid, fp in fps if fp is not None]
        changed = [(fid, fp) for fid, fp in live if prev.get(fid) != fp]
        fresh: List[FileEntry] = [
            e for e in pool.map(read, changed) if e is not None
        ]
        self.stats.footers_read += len(fresh)
        # A file can vanish between fingerprint and footer read: drop it
        # from the live set too, or apply_footers would demand its footer.
        lost = {fid for fid, _ in changed} - {e.file_id for e in fresh}
        self.stats.vanished += (len(fps) - len(live)) + len(lost)
        live_ids = [fid for fid, _ in live if fid not in lost]
        return fresh, live_ids

    def _get_pool(self) -> concurrent.futures.ThreadPoolExecutor:
        # One executor for the ingestor's lifetime (recreated after stop()):
        # a short poll_interval must not churn max_workers OS threads per
        # sweep. Only refresh() uses it, and refreshes are serialized.
        if self._pool is None:
            self._pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=self.max_workers, thread_name_prefix="ndv-ingest"
            )
        return self._pool

    # -- polling loop --------------------------------------------------------

    def start(self) -> None:
        """Start the background polling loop (requires `poll_interval`)."""
        if self._thread is not None:
            return
        if not self.poll_interval:
            raise ValueError("start() requires a poll_interval")
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._poll_loop, name="ndv-ingest-poll", daemon=True
        )
        self._thread.start()

    def _poll_loop(self) -> None:
        while not self._stop.wait(self.poll_interval):
            try:
                self.refresh()
            except Exception:
                # recorded in stats.last_error; last-good state keeps serving
                pass

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()
