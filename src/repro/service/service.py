"""`StatsService`: the facade joining async ingestion and stat serving.

One object owns a `StatsCatalog`, its `AsyncIngestor`, the shared lock, and
the request-side machinery (ETags, single-flight). The HTTP layer
(`repro.service.http`) is a thin translation onto this class — every
endpoint method here is synchronous, HTTP-agnostic, and returns a
`Response(status, body, etag)`, which keeps the whole serving contract
testable without sockets.

Coherence model (see the package docstring for the client-facing contract):

  * Every cacheable response carries an ETag = SHA-1 over the catalog's
    fingerprint set, the engine's `cache_token` (the resolved backend —
    the only numerics-bearing knob; execution strategy is neutral, so
    tags survive strategy changes), and the request identity (endpoint
    kind, mode, schema bounds). Any file add/remove/rewrite changes the
    fingerprint set and therefore rotates every ETag; an unchanged
    dataset validates forever.
  * An `If-None-Match` hit is answered before any catalog work: zero packs,
    zero engine executions, zero merges, and no lock — the fingerprint-set
    digest is precomputed at each commit (`_state_token`), so revalidation
    traffic never queues behind an in-flight cold computation.
  * Concurrent identical cold requests are coalesced (single-flight): one
    leader computes, everyone else waits on its result. With the catalog's
    own estimate cache this bounds work to one engine execution per
    (dataset state, engine config, mode, bounds) no matter the fan-in.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from collections import OrderedDict
from typing import (
    Callable,
    Dict,
    List,
    NamedTuple,
    Optional,
    Sequence,
    Tuple,
    Union,
)

from repro.catalog import (
    StatsCatalog,
    SuperpackJob,
    estimate_to_json,
    superpack_estimate,
)
from repro.catalog.source import MetadataSource
from repro.core.ndv.estimator import provenance_to_json
from repro.obs import registry, span
from repro.obs.metrics import QERROR_BUCKETS
from repro.planner import (
    ColumnStats,
    DEFAULT_MAX_PLANS,
    JoinGraph,
    TableStats,
    compute_cost,
)
from repro.planner.api import provenance_block
from repro.service.ingest import AsyncIngestor

MODES = ("paper", "improved")


class EstimateQuery(NamedTuple):
    """One tuple of a batched estimate request (`StatsService.batch`).

    `columns=None` means every column (identical identity — and therefore
    ETag — to a plain `/estimate` call, so 304 caches are shared between
    the batched and unbatched paths); a tuple of names restricts the body
    to those columns and extends the ETag identity accordingly.
    """

    columns: Optional[Tuple[str, ...]] = None
    mode: str = "paper"
    schema_bounds: Optional[Dict[str, float]] = None
    if_none_match: Optional[str] = None
    # Diagnostics-only: excluded from the ETag identity and the
    # single-flight key, so explain-on and explain-off tuples coalesce and
    # revalidate against each other; provenance attaches to a COPY of the
    # published body, never to the shared single-flight result.
    explain: bool = False


class CostQuery(NamedTuple):
    """One `/cost` tuple of a batched request (`StatsService.batch`).

    Same identity rules as the standalone endpoint: `if_none_match` and
    `explain` are excluded from the ETag identity, so batched cost tuples
    revalidate against standalone `/cost` responses byte-for-byte.
    """

    graph: JoinGraph
    mode: str = "paper"
    max_plans: int = DEFAULT_MAX_PLANS
    if_none_match: Optional[str] = None
    explain: bool = False


class AuditResult(NamedTuple):
    """One sketch-audited column: dataset estimate vs a sampled reference.

    The reference is a HyperLogLog count (`repro.kernels.hll`) over ONE
    row group per file — a zero-ish-cost sample, not a full scan — so the
    q-error is a drift signal (route misfires, systematic bias), not a
    full-accuracy statement. `row_group` is the sampled index.
    """

    column: str
    route: str
    estimate: float
    reference: float
    qerror: float
    generation: int
    row_group: int


class Response(NamedTuple):
    """Transport-agnostic endpoint result."""

    status: int                 # 200 | 304 | 400
    body: Optional[dict]        # JSON-ready payload; None for 304
    etag: Optional[str]         # quoted ETag; None where caching is invalid


@dataclasses.dataclass
class ServiceStats:
    """Request-side counters (ingestion counters live on the ingestor)."""

    requests: int = 0
    responses_200: int = 0
    responses_304: int = 0
    engine_runs: int = 0            # estimate-cache misses served (executions)
    single_flight_leaders: int = 0  # cold computations actually performed
    coalesced_waits: int = 0        # requests that rode a leader's result
    spill_reloads: int = 0          # shared-spill rechecks that found entries


class _Call:
    __slots__ = ("event", "result", "error")

    def __init__(self):
        self.event = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None


class SingleFlight:
    """Duplicate-call suppression: one in-flight computation per key.

    Two APIs over one mechanism: `do()` is the classic run-once wrapper;
    `claim()` / `finish()` / `wait()` expose the leadership handshake so a
    BATCH of keys can be claimed up front, computed jointly (one super-pack
    engine call), and published per key — the per-tuple granularity the
    `/batch` endpoint needs. Keys are shared with the single-request path,
    so a concurrent `/estimate` coalesces onto a batch's leader and vice
    versa.
    """

    def __init__(self):
        self._mu = threading.Lock()
        self._calls: Dict[tuple, _Call] = {}

    def claim(self, key: tuple) -> Tuple[_Call, bool]:
        """Claim leadership of `key`; returns (call, is_leader).

        A leader MUST eventually `finish()` the call (success or error),
        or every follower blocks forever. A follower `wait()`s on it.
        """
        with self._mu:
            call = self._calls.get(key)
            leader = call is None
            if leader:
                call = _Call()
                self._calls[key] = call
        return call, leader

    def finish(
        self,
        key: tuple,
        call: _Call,
        *,
        result: object = None,
        error: Optional[BaseException] = None,
    ) -> None:
        """Publish a claimed call's outcome and release the key."""
        call.result = result
        call.error = error
        with self._mu:
            self._calls.pop(key, None)
        call.event.set()

    @staticmethod
    def wait(call: _Call) -> object:
        """Block on a follower's call; re-raises the leader's exception."""
        call.event.wait()
        if call.error is not None:
            raise call.error
        return call.result

    def do(self, key: tuple, fn: Callable[[], object]) -> Tuple[object, bool]:
        """Run `fn` once per concurrent burst of `key`; returns (result,
        was_leader). Followers re-raise the leader's exception."""
        call, leader = self.claim(key)
        if leader:
            result, error = None, None
            try:
                result = fn()
            except BaseException as e:
                error = e
            self.finish(key, call, result=result, error=error)
            if error is not None:
                raise error
            return result, True
        return self.wait(call), False


def etag_matches(if_none_match: str, etag: str) -> bool:
    """RFC 7232 weak comparison of an If-None-Match header against one ETag."""
    for candidate in if_none_match.split(","):
        candidate = candidate.strip()
        if candidate == "*":
            return True
        if candidate.startswith("W/"):
            candidate = candidate[2:]
        if candidate == etag:
            return True
    return False


class StatsService:
    """Async-ingesting, ETag-serving stats facade over one catalog.

    Args:
      source: a `StatsCatalog`, a `MetadataSource`, or a dataset root path.
      engine: optional injected `EstimationEngine` (used only when `source`
        is not already a catalog; a catalog brings its own).
      max_workers: ingestion scatter width.
      poll_interval: seconds between background refreshes under `start()`;
        None serves whatever `refresh()` is called manually.
      auto_load_cache: thread the catalog's mtime-guarded cache auto-load.
      save_cache_on_commit: keep the on-disk estimate-cache spill current —
        rewritten (compacted) after each committed refresh that changed the
        dataset, and again whenever a cold request populates a new entry,
        so a restarted server serves the newest state warm.
      shared_spill: run this service as one replica of a set sharing the
        dataset's on-disk estimate-cache spill. Implies `auto_load_cache`
        and `save_cache_on_commit`, and additionally re-checks the spill
        (mtime-guarded, one stat when nothing changed) before every cold
        computation — so a request this replica never computed is served
        from a sibling replica's spill instead of re-running the engine.
        Spill writes are merge-not-clobber and atomic under concurrent
        replicas (see `StatsCatalog.save_cache`).
      health_hook: optional callable polled by `probe()`; returning False
        marks this replica unhealthy to replica managers (the fleet tier's
        ejection signal) without affecting direct request serving.
      name: telemetry label for this service's stats views in `/metrics`
        (`{service="<name>"}`) — distinguishes replicas sharing a process.
      audit: opt-in background accuracy auditor. After every committed
        refresh (and once at start) a daemon thread samples
        `audit_columns` columns — a rotating, generation-keyed window over
        the sorted column list — computes a reference NDV with the HLL
        sketch kernel over one row group per file, and records
        `max(est/ref, ref/est)` into the `ndv_audit_qerror{route=}`
        histogram. Results surface per column in `?explain=1` bodies and
        `/debug/explain`. Requires a filesystem-backed source (the sketch
        reads raw values); columns whose data cannot be read are skipped.
      audit_columns: sample width K per audit pass.
    """

    def __init__(
        self,
        source: Union[StatsCatalog, MetadataSource, str],
        *,
        engine=None,
        max_workers: int = 8,
        poll_interval: Optional[float] = None,
        auto_load_cache: bool = False,
        save_cache_on_commit: bool = False,
        shared_spill: bool = False,
        health_hook: Optional[Callable[[], bool]] = None,
        name: str = "stats",
        audit: bool = False,
        audit_columns: int = 4,
    ):
        if shared_spill:
            auto_load_cache = True
            save_cache_on_commit = True
        if isinstance(source, StatsCatalog):
            self.catalog = source
        else:
            self.catalog = StatsCatalog(
                source, engine=engine, auto_load_cache=auto_load_cache
            )
        self.engine = self.catalog.engine
        self.lock = threading.RLock()
        self.save_cache_on_commit = save_cache_on_commit
        self.shared_spill = shared_spill
        self.health_hook = health_hook
        self.closed = False
        self.ingestor = AsyncIngestor(
            self.catalog,
            max_workers=max_workers,
            poll_interval=poll_interval,
            lock=self.lock,
            on_commit=self._on_commit,
        )
        self.stats = ServiceStats()
        self._flight = SingleFlight()
        self._state_token: Optional[str] = None
        self._started_at = time.monotonic()
        self.audit_enabled = audit
        self.audit_columns = audit_columns
        self._audit_results: Dict[str, AuditResult] = {}
        self._audit_wake = threading.Event()
        self._audit_thread: Optional[threading.Thread] = None
        # Serialized explained payloads (wire frames / JSON bytes), keyed
        # by (etag, wire, audit_version) — see `_Handler._encode_payload`.
        # `audit_version` bumps whenever the audit sidecar changes, so a
        # new audit pass orphans stale entries instead of serving them.
        self.audit_version = 0
        self._explain_payloads: "OrderedDict[tuple, bytes]" = OrderedDict()
        # The pre-existing stats objects stay the single source of truth;
        # /metrics reads them live through weakref views (repro.obs).
        self.name = name
        labels = {"service": name}
        reg = registry()
        reg.register_stats_view("ndv_service", labels, self.stats)
        reg.register_stats_view("ndv_ingest", labels, self.ingestor.stats)
        reg.register_stats_view("ndv_catalog", labels, self.catalog.stats)

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        """Initial synchronous refresh, then the polling loop (if any)."""
        self.closed = False
        self.refresh()
        if self.ingestor.poll_interval:
            self.ingestor.start()
        if self.audit_enabled and self._audit_thread is None:
            self._audit_wake.set()  # audit the initial state too
            self._audit_thread = threading.Thread(
                target=self._audit_loop, name="ndv-audit", daemon=True
            )
            self._audit_thread.start()

    def stop(self) -> None:
        self.ingestor.stop()
        self.closed = True
        if self._audit_thread is not None:
            self._audit_wake.set()  # wake the loop so it observes `closed`
            self._audit_thread.join(timeout=10.0)
            self._audit_thread = None

    def probe(self) -> bool:
        """Replica-manager liveness probe (the fleet tier's health signal).

        True while the service can serve: not stopped, and the optional
        `health_hook` (fault injection, external circuit breakers) agrees.
        Deliberately cheap — no catalog work, no lock — so a prober can
        hammer it.
        """
        if self.closed:
            return False
        if self.health_hook is not None and not self.health_hook():
            return False
        return True

    def __enter__(self) -> "StatsService":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def _on_commit(self, summary) -> None:
        # Runs under self.lock, after a committed refresh changed the state:
        # stale-fingerprint cache lines can never be requested again, and
        # the precomputed state token must rotate with the fingerprint set.
        self.catalog.compact_caches()
        self._state_token = self._compute_state_token()
        if self.save_cache_on_commit:
            self.catalog.save_cache()
        if self.audit_enabled:
            self._audit_wake.set()  # new generation: schedule an audit pass

    def _ensure_ready(self) -> None:
        if not self.catalog.scanned:
            self.ingestor.refresh()

    # -- ETags ---------------------------------------------------------------

    def _compute_state_token(self) -> str:
        """Digest of (fingerprint set, engine config). Call under the lock."""
        h = hashlib.sha1()
        for part in sorted(self.catalog.fingerprint_key()):
            h.update(part.encode())
            h.update(b"\x00")
        h.update(self.engine.cache_token.encode())
        return h.hexdigest()

    def _current_state_token(self) -> str:
        # Reading the attribute is atomic and the token only changes inside
        # a commit, so the hot path (every 304) takes no lock at all.
        token = self._state_token
        if token is None:
            with self.lock:
                token = self._state_token = self._compute_state_token()
        return token

    def _etag(
        self,
        kind: str,
        mode: str = "",
        bounds_key: tuple = (),
        columns: Optional[Tuple[str, ...]] = None,
    ) -> str:
        h = hashlib.sha1(self._current_state_token().encode())
        h.update(f"|{kind}|{mode}|{bounds_key!r}".encode())
        if columns is not None:
            # Appended ONLY when a filter is present, so unfiltered batch
            # tuples share tags byte-for-byte with plain /estimate calls.
            h.update(f"|cols={columns!r}".encode())
        return f'"{h.hexdigest()}"'

    # -- endpoints -----------------------------------------------------------

    def health(self) -> Response:
        """Liveness + counters. Never cached (no ETag, never 304)."""
        with self.lock:
            scanned = self.catalog.scanned
            body = {
                "status": "serving" if scanned else "starting",
                "generation": self.ingestor.generation,
                "files": len(self.catalog.entry_fingerprints()),
                "columns": len(self.catalog.column_names) if scanned else 0,
                "engine": self.engine.cache_token,
                "ingestor_running": self.ingestor.running,
                "uptime_s": time.monotonic() - self._started_at,
                "service": dataclasses.asdict(self.stats),
                "ingest": dataclasses.asdict(self.ingestor.stats),
                "catalog": dataclasses.asdict(self.catalog.stats),
            }
        return Response(200, body, None)

    def refresh(self) -> Response:
        """Force one scatter-gather refresh; returns the update summary."""
        summary = self.ingestor.refresh()
        return Response(200, {
            "generation": self.ingestor.generation,
            "added": summary.added,
            "updated": summary.updated,
            "removed": summary.removed,
            "total": summary.total,
            "changed": summary.changed,
        }, None)

    def columns(self, *, if_none_match: Optional[str] = None) -> Response:
        """Merged per-column summary of the dataset view."""
        self.stats.requests += 1
        self._ensure_ready()
        with self.lock:
            etag = self._etag("columns")
            if if_none_match is not None and etag_matches(if_none_match, etag):
                self.stats.responses_304 += 1
                return Response(304, None, etag)
            merged = self.catalog.merged_metadata()
            body = {
                "etag": etag,
                "generation": self.ingestor.generation,
                "files": self.catalog.num_files,
                "columns": {
                    name: {
                        "non_null": m.non_null,
                        "num_row_groups": m.num_row_groups,
                        "physical_type": int(m.physical_type),
                    }
                    for name, m in merged.items()
                },
            }
        self.stats.responses_200 += 1
        return Response(200, body, etag)

    def estimate(
        self,
        *,
        mode: str = "paper",
        schema_bounds: Optional[Dict[str, float]] = None,
        if_none_match: Optional[str] = None,
        explain: bool = False,
    ) -> Response:
        """Dataset-level NDV estimates, bit-identical to
        `StatsCatalog.estimate()` under the same engine config.

        `explain=True` attaches per-column provenance (route, margins,
        Newton diagnostics, clamps — plus the latest audit sample when the
        auditor has one) under a "provenance" key, on a COPY of the body:
        the ETag, the single-flight result, and every explain-off response
        stay byte-identical to the explain-free server.
        """
        resp = self._cached_endpoint(
            "estimate", mode, schema_bounds, if_none_match,
            lambda etag, gen: {
                "etag": etag,
                "generation": gen,
                "mode": mode,
                "schema_bounds": schema_bounds,
                "estimates": {
                    name: estimate_to_json(e)
                    for name, e in self.catalog.estimate(
                        mode=mode, schema_bounds=schema_bounds
                    ).items()
                },
            },
        )
        if explain:
            resp = self._attach_provenance(resp, mode, schema_bounds)
        return resp

    def plan(
        self,
        *,
        mode: str = "paper",
        if_none_match: Optional[str] = None,
    ) -> Response:
        """Per-column memory plans via the default `NDVPlanner`.

        Deliberately no planner override: the ETag/single-flight key has no
        planner component, so differently-configured planners would
        validate and coalesce against each other. Custom planners belong on
        the library path (`catalog.plan(planner)`), not the cached one.
        """
        return self._cached_endpoint(
            "plan", mode, None, if_none_match,
            lambda etag, gen: {
                "etag": etag,
                "generation": gen,
                "mode": mode,
                "plans": {
                    name: dataclasses.asdict(p)
                    for name, p in self.catalog.plan(mode=mode).items()
                },
            },
        )

    def table_stats(
        self,
        *,
        mode: str = "paper",
        columns: Optional[Tuple[str, ...]] = None,
        if_none_match: Optional[str] = None,
    ) -> Response:
        """Planner-shaped table statistics: row count + per-column NDV.

        The fleet router's `/cost` input: one small cacheable body per
        dataset carrying everything the join-cardinality formula needs —
        total rows (footer sums), per-column NDV, non-null count, and the
        PR 9 quality signals (route, confidence). `columns=None` serves
        every column; a filter restricts the body AND extends the ETag
        identity (same rule as filtered batch tuples). Unknown columns
        are a request error (400).
        """
        if columns is not None:
            self._ensure_ready()
            unknown = [
                c for c in columns if c not in set(self.catalog.column_names)
            ]
            if unknown:
                self.stats.requests += 1
                return Response(
                    400, {"error": f"unknown columns {unknown}"}, None
                )

        def build(etag: str, gen: int) -> dict:
            ests = self.catalog.estimate(mode=mode)
            provs = self.catalog.provenance(mode=mode, engine=self.engine)
            merged = self.catalog.merged_metadata()
            names = columns if columns is not None else sorted(ests)
            return {
                "etag": etag,
                "generation": gen,
                "mode": mode,
                "rows": self.catalog.total_rows(),
                "columns": {
                    name: {
                        "ndv": float(ests[name].ndv),
                        "non_null": int(merged[name].non_null),
                        "confidence": float(ests[name].confidence),
                        "route": (
                            provs[name].route if name in provs else None
                        ),
                    }
                    for name in names
                },
            }

        return self._cached_response(
            "tablestats", mode, (), if_none_match, build, columns
        )

    def cost(
        self,
        *,
        graph: JoinGraph,
        mode: str = "paper",
        max_plans: int = DEFAULT_MAX_PLANS,
        if_none_match: Optional[str] = None,
        explain: bool = False,
    ) -> Response:
        """Cheapest join order + per-join cardinalities for a join graph.

        Tables read THIS service's dataset (aliases make self-join graphs;
        cross-dataset graphs are the fleet router's `/cost`). The ETag
        hashes (state token, graph identity, max_plans): a plan 304s
        exactly while the dataset's stats are unchanged, and rotates with
        any file add/remove/rewrite. `explain=True` attaches the
        per-column NDV/route/confidence provenance that fed each
        cardinality, on a copy — identity-neutral like `/estimate`'s.
        """
        ident_key = (repr(graph.identity()), int(max_plans))

        def build(etag: str, gen: int) -> dict:
            stats_map = self._planner_stats(graph, mode)
            body = compute_cost(
                graph, stats_map, mode=mode, max_plans=max_plans
            )
            return {"etag": etag, "generation": gen, **body}

        try:
            resp = self._cached_response(
                "cost", mode, ident_key, if_none_match, build
            )
        except ValueError as e:
            # Graph references a column this dataset doesn't have.
            return Response(400, {"error": str(e)}, None)
        if explain and resp.status == 200 and resp.body is not None:
            with self.lock:
                stats_map = self._planner_stats(graph, mode)
            body = dict(resp.body)
            body["provenance"] = provenance_block(graph, stats_map)
            resp = Response(resp.status, body, resp.etag)
        return resp

    def _planner_stats(self, graph: JoinGraph, mode: str):
        """Per-table `TableStats` for `compute_cost`, from this catalog.

        Every graph alias reads the served dataset, so tables share the
        row count and column estimates. Call under the lock (the cost
        build does). Raises ValueError for unknown join columns -> 400.
        """
        ests = self.catalog.estimate(mode=mode)
        provs = self.catalog.provenance(mode=mode, engine=self.engine)
        merged = self.catalog.merged_metadata()
        rows = float(self.catalog.total_rows())
        needed = graph.columns_by_table()
        unknown = sorted(
            {c for cols in needed.values() for c in cols} - set(ests)
        )
        if unknown:
            raise ValueError(f"unknown join columns {unknown}")
        stats_map: Dict[str, TableStats] = {}
        for name, cols in needed.items():
            stats_map[name] = TableStats(
                rows=rows,
                columns={
                    c: ColumnStats(
                        ndv=float(ests[c].ndv),
                        non_null=int(merged[c].non_null),
                        confidence=float(ests[c].confidence),
                        route=provs[c].route if c in provs else None,
                    )
                    for c in cols
                },
            )
        return stats_map

    def batch(
        self, queries: Sequence[Union[EstimateQuery, "CostQuery"]]
    ) -> List[Response]:
        """Many estimate tuples, one engine dispatch per cold mode group.

        Per-tuple semantics are exactly `estimate()`'s: the same ETags
        (unfiltered tuples share tags byte-for-byte with `/estimate`),
        per-tuple 304s, per-tuple 400s for bad modes or unknown columns,
        and bodies bit-identical to the sequential path (the super-pack
        exactness contract, `repro.catalog.superpack`).

        Cold tuples extend single-flight to per-tuple granularity: each
        cold tuple's ("estimate", etag) key is claimed up front — keys
        already in flight (a concurrent `/estimate`, another batch, or a
        duplicate within this one) ride that leader — and all claimed
        tuples execute as ONE `superpack_estimate` call under the lock,
        publishing each tuple's body to its own followers.

        `CostQuery` tuples ride the same envelope: each runs the standalone
        `cost()` path (its own single-flight key and 304 semantics — a
        cost tuple's ETag matches the standalone endpoint's byte-for-byte).
        The batched plan scorer is already one dispatch per graph, so cost
        tuples don't super-pack across graphs the way estimate tuples do.
        """
        n = len(queries)
        responses: List[Optional[Response]] = [None] * n
        if n == 0:
            return []
        for i, q in enumerate(queries):
            if isinstance(q, CostQuery):
                try:
                    responses[i] = self.cost(
                        graph=q.graph, mode=q.mode, max_plans=q.max_plans,
                        if_none_match=q.if_none_match, explain=q.explain,
                    )
                except Exception as e:
                    responses[i] = Response(
                        500, {"error": f"{type(e).__name__}: {e}"}, None
                    )
        est_count = sum(
            1 for q in queries if not isinstance(q, CostQuery)
        )
        self.stats.requests += est_count
        if est_count == 0:
            return responses
        self._ensure_ready()
        known = set(self.catalog.column_names)

        claimed: List[tuple] = []   # (index, query, key, call)
        in_batch: List[Tuple[int, int]] = []   # (follower idx, leader idx)
        waiting: List[tuple] = []   # (index, call) — led by another thread
        leader_for: Dict[tuple, int] = {}
        for i, q in enumerate(queries):
            if isinstance(q, CostQuery):
                continue
            if q.mode not in MODES:
                responses[i] = Response(
                    400, {"error": f"mode {q.mode!r} not in {list(MODES)}"},
                    None,
                )
                continue
            if q.columns is not None:
                unknown = [c for c in q.columns if c not in known]
                if unknown:
                    responses[i] = Response(
                        400, {"error": f"unknown columns {unknown}"}, None
                    )
                    continue
            bounds_key = (
                tuple(sorted(q.schema_bounds.items()))
                if q.schema_bounds else ()
            )
            etag = self._etag("estimate", q.mode, bounds_key, q.columns)
            if q.if_none_match is not None and etag_matches(
                q.if_none_match, etag
            ):
                self.stats.responses_304 += 1
                responses[i] = Response(304, None, etag)
                continue
            key = ("estimate", etag)
            if key in leader_for:
                in_batch.append((i, leader_for[key]))
                continue
            call, is_leader = self._flight.claim(key)
            if is_leader:
                leader_for[key] = i
                claimed.append((i, q, key, call))
            else:
                waiting.append((i, call))

        if claimed:
            self._batch_compute(claimed, responses)
        for i, leader_idx in in_batch:
            self.stats.coalesced_waits += 1
            r = responses[leader_idx]
            if r.status == 200:
                self.stats.responses_200 += 1
            responses[i] = r
        for i, call in waiting:
            self.stats.coalesced_waits += 1
            try:
                body = SingleFlight.wait(call)
            except Exception as e:
                responses[i] = Response(
                    500, {"error": f"{type(e).__name__}: {e}"}, None
                )
                continue
            self.stats.responses_200 += 1
            responses[i] = Response(200, body, body["etag"])
        for i, q in enumerate(queries):
            # After publication: provenance attaches to per-tuple COPIES,
            # so coalesced tuples sharing a leader's body are unaffected.
            # (Cost tuples handled their own explain above.)
            if isinstance(q, CostQuery):
                continue
            if q.explain and responses[i] is not None \
                    and responses[i].status == 200:
                responses[i] = self._attach_provenance(
                    responses[i], q.mode, q.schema_bounds, q.columns
                )
        return responses

    def _batch_compute(self, claimed: List[tuple], responses: list) -> None:
        """Execute all claimed tuples jointly and publish each call.

        Every claimed call is finished no matter what — on failure with
        the error (followers re-raise it), so nobody blocks forever.
        """
        try:
            with self.lock:
                if self.shared_spill:
                    self.stats.spill_reloads += bool(
                        self.catalog.maybe_load_cache()
                    )
                jobs: List[SuperpackJob] = []
                job_index: Dict[tuple, int] = {}
                slots: List[int] = []
                for _, q, _, _ in claimed:
                    jkey = (
                        q.mode,
                        tuple(sorted(q.schema_bounds.items()))
                        if q.schema_bounds else None,
                    )
                    idx = job_index.get(jkey)
                    if idx is None:
                        idx = job_index[jkey] = len(jobs)
                        jobs.append(SuperpackJob(
                            self.catalog, q.mode, q.schema_bounds
                        ))
                    slots.append(idx)
                with span(
                    "service.superpack",
                    tuples=len(claimed), groups=len(jobs), service=self.name,
                ) as sp:
                    result = superpack_estimate(jobs, engine=self.engine)
                    sp.set_attribute("engine_calls", result.engine_calls)
                self.stats.engine_runs += result.engine_calls
                if result.engine_calls and self.save_cache_on_commit:
                    self.catalog.save_cache()
                gen = self.ingestor.generation
                bodies = []
                for (i, q, key, call), idx in zip(claimed, slots):
                    est_map = result.estimates[idx]
                    names = q.columns if q.columns is not None else est_map
                    bounds_key = (
                        tuple(sorted(q.schema_bounds.items()))
                        if q.schema_bounds else ()
                    )
                    # Recomputed inside the lock: the body must describe
                    # the state its ETag names, even across a mid-flight
                    # refresh commit (same rule as `_cached_endpoint`).
                    body = {
                        "etag": self._etag(
                            "estimate", q.mode, bounds_key, q.columns
                        ),
                        "generation": gen,
                        "mode": q.mode,
                        "schema_bounds": q.schema_bounds,
                        "estimates": {
                            name: estimate_to_json(est_map[name])
                            for name in names
                        },
                    }
                    if q.columns is not None:
                        body["columns"] = list(q.columns)
                    bodies.append(body)
        except BaseException as e:
            for i, q, key, call in claimed:
                self._flight.finish(key, call, error=e)
                responses[i] = Response(
                    500, {"error": f"{type(e).__name__}: {e}"}, None
                )
            if not isinstance(e, Exception):
                raise  # KeyboardInterrupt and friends: release, then bubble
            return
        for (i, q, key, call), body in zip(claimed, bodies):
            self._flight.finish(key, call, result=body)
            self.stats.single_flight_leaders += 1
            self.stats.responses_200 += 1
            responses[i] = Response(200, body, body["etag"])

    # -- provenance + audit --------------------------------------------------

    _EXPLAIN_PAYLOADS_MAX = 32

    def explain_payload_peek(self, key: tuple) -> Optional[bytes]:
        """Memoized serialized explained payload, or None.

        Keys carry (etag, wire-format flag, audit_version): the ETag pins
        the estimate state and request identity, the audit version the
        q-error sidecar — nothing else can change an explained payload's
        bytes. Filled by the HTTP handler (`_Handler._encode_payload`).
        """
        with self.lock:
            payload = self._explain_payloads.get(key)
            if payload is not None:
                self._explain_payloads.move_to_end(key)
            return payload

    def explain_payload_store(self, key: tuple, payload: bytes) -> None:
        with self.lock:
            self._explain_payloads[key] = payload
            self._explain_payloads.move_to_end(key)
            while len(self._explain_payloads) > self._EXPLAIN_PAYLOADS_MAX:
                self._explain_payloads.popitem(last=False)

    def _attach_provenance(
        self,
        resp: Response,
        mode: str,
        schema_bounds: Optional[Dict[str, float]],
        columns: Optional[Tuple[str, ...]] = None,
    ) -> Response:
        """Explained twin of a 200 response: same ETag, body copy + provenance.

        Usually a provenance-cache hit (filled alongside every engine run);
        a spill-warmed estimate recomputes once through the catalog. Audit
        samples ride along per column when the auditor has visited it.
        """
        if resp.status != 200 or resp.body is None:
            return resp
        with self.lock:
            provs = self.catalog.provenance(
                mode=mode, schema_bounds=schema_bounds, engine=self.engine
            )
            audits = dict(self._audit_results)
        names = (
            columns if columns is not None
            else list(resp.body.get("estimates", {}))
        )
        prov_json: Dict[str, dict] = {}
        for name in names:
            p = provs.get(name)
            if p is None:
                continue
            d = provenance_to_json(p)
            a = audits.get(name)
            if a is not None:
                d["audit"] = {
                    "qerror": a.qerror,
                    "reference_ndv": a.reference,
                    "estimate_ndv": a.estimate,
                    "generation": a.generation,
                    "row_group": a.row_group,
                }
            prov_json[name] = d
        body = dict(resp.body)
        body["provenance"] = prov_json
        return Response(resp.status, body, resp.etag)

    def debug_explain(self) -> Response:
        """The catalog's provenance cache + audit samples, JSON-shaped.

        Never cached (no ETag): it describes the server's *cache contents*,
        not a deterministic function of dataset state.
        """
        with self.lock:
            entries = self.catalog.provenance_entries()
            audits = dict(self._audit_results)
            gen = self.ingestor.generation
        return Response(200, {
            "service": self.name,
            "generation": gen,
            "entries": [
                {
                    "mode": key[1],
                    "schema_bounds": (
                        {n: v for n, v in key[2]} if key[2] else None
                    ),
                    "files": len(key[0]),
                    "columns": {
                        name: provenance_to_json(p)
                        for name, p in sorted(provs.items())
                    },
                }
                for key, provs in entries
            ],
            "audits": {
                name: a._asdict() for name, a in sorted(audits.items())
            },
        }, None)

    def _audit_loop(self) -> None:
        while True:
            self._audit_wake.wait()
            self._audit_wake.clear()
            if self.closed:
                return
            try:
                self.run_audit()
            except Exception:
                # The auditor is a diagnostic sidecar: it must never take
                # the serving loop down. Failures show as missing samples.
                pass

    def run_audit(self) -> List[AuditResult]:
        """One audit pass: sample K columns, sketch a reference, record q-error.

        Public and synchronous so tests and smoke flows can drive it
        deterministically; the background thread calls exactly this.
        """
        with self.lock:
            if not self.catalog.scanned:
                return []
            gen = self.ingestor.generation
            names = sorted(self.catalog.column_names)
            files = list(self.catalog.files)
            ests = self.catalog.estimate(mode="paper")
            provs = self.catalog.provenance(mode="paper")
        if not names or not files:
            return []
        k = min(self.audit_columns, len(names))
        start = (gen * k) % len(names)
        sample = [names[(start + i) % len(names)] for i in range(k)]
        hist = registry().histogram(
            "ndv_audit_qerror",
            "Audit q-error max(est/ref, ref/est): metadata estimate vs a "
            "one-row-group-per-file HLL reference, by chosen route",
            QERROR_BUCKETS,
        )
        results: List[AuditResult] = []
        for col in sample:
            if col not in ests or col not in provs:
                continue
            ref = self._audit_reference(col, files, gen)
            if ref is None or ref <= 0.0:
                continue
            est = float(ests[col].ndv)
            q = max(est / ref, ref / est) if est > 0 else float("inf")
            route = provs[col].route
            hist.observe(q, route=route)
            results.append(AuditResult(
                column=col, route=route, estimate=est, reference=ref,
                qerror=q, generation=gen, row_group=gen,
            ))
        with self.lock:
            if results:
                for r in results:
                    self._audit_results[r.column] = r
                # New q-error sidecar: orphan memoized explained payloads
                # (they embed the audit results current at build time).
                self.audit_version += 1
                self._explain_payloads.clear()
        return results

    def _audit_reference(
        self, col: str, files: List[str], gen: int
    ) -> Optional[float]:
        """HLL reference NDV for one column: one row group per file.

        Registers merge by element-max across files, so the count covers
        the union of the sampled row groups. Values hash through their
        string form — distinctness, not representation, is what the sketch
        needs. Unreadable files (metadata-only sources) yield None.
        """
        import zlib

        import numpy as np

        from repro.columnar.reader import DataReader
        from repro.kernels import ops as kernel_ops

        regs = None
        for fid in files:
            try:
                reader = DataReader(fid)
                if col not in reader.npz.files:
                    continue
                n_rg = reader.footer.num_row_groups
                if not n_rg:
                    continue
                idx = gen % n_rg  # rotate the sampled row group per pass
                lo = sum(
                    rg.num_rows for rg in reader.footer.row_groups[:idx]
                )
                hi = lo + reader.footer.row_groups[idx].num_rows
                vals = reader.npz[col][lo:hi]
                mask = reader.null_mask(col)
                valid = (
                    ~mask[lo:hi] if mask is not None
                    else np.ones(len(vals), bool)
                )
            except Exception:
                continue
            if not len(vals):
                continue
            keys = np.fromiter(
                (zlib.crc32(str(v).encode()) for v in vals),
                np.uint32, len(vals),
            )
            bank = np.asarray(kernel_ops.hll_fold(
                keys[None, :], valid[None, :].astype(np.float32)
            ))
            regs = bank if regs is None else np.maximum(regs, bank)
        if regs is None:
            return None
        return float(np.asarray(kernel_ops.hll_count(regs))[0])

    def _cached_endpoint(
        self,
        kind: str,
        mode: str,
        schema_bounds: Optional[Dict[str, float]],
        if_none_match: Optional[str],
        build: Callable[[str, int], dict],
    ) -> Response:
        bounds_key = (
            tuple(sorted(schema_bounds.items())) if schema_bounds else ()
        )
        return self._cached_response(
            kind, mode, bounds_key, if_none_match, build
        )

    def _cached_response(
        self,
        kind: str,
        mode: str,
        ident_key: tuple,
        if_none_match: Optional[str],
        build: Callable[[str, int], dict],
        columns: Optional[Tuple[str, ...]] = None,
    ) -> Response:
        """The shared cacheable-endpoint skeleton (ETag precheck,
        single-flight, lock discipline). `ident_key` is whatever request
        identity the endpoint hashes besides kind/mode — schema bounds for
        the estimate family, (graph identity, max_plans) for `/cost`."""
        self.stats.requests += 1
        if mode not in MODES:
            return Response(
                400, {"error": f"mode {mode!r} not in {list(MODES)}"}, None
            )
        self._ensure_ready()
        etag = self._etag(kind, mode, ident_key, columns)
        if if_none_match is not None and etag_matches(if_none_match, etag):
            # The entire hit path: one lock-free digest. No pack, no engine.
            self.stats.responses_304 += 1
            return Response(304, None, etag)

        def compute() -> dict:
            with self.lock:
                # Recompute the tag inside the lock: a refresh may have
                # committed since the cheap pre-check, and the body must
                # describe the state its ETag names.
                etag_now = self._etag(kind, mode, ident_key, columns)
                if self.shared_spill:
                    # A sibling replica may have computed (and spilled)
                    # this entry already: one stat when nothing changed,
                    # and a cache line instead of an engine run when it did.
                    self.stats.spill_reloads += bool(
                        self.catalog.maybe_load_cache()
                    )
                misses = self.catalog.stats.estimate_cache_misses
                with span(
                    "service.compute",
                    kind=kind, mode=mode, service=self.name,
                ):
                    body = build(etag_now, self.ingestor.generation)
                new_runs = (
                    self.catalog.stats.estimate_cache_misses - misses
                )
                self.stats.engine_runs += new_runs
                if new_runs and self.save_cache_on_commit:
                    # the spill must include what was just computed, or a
                    # restart between now and the next commit starts cold
                    self.catalog.save_cache()
                return body

        body, leader = self._flight.do((kind, etag), compute)
        if leader:
            self.stats.single_flight_leaders += 1
        else:
            self.stats.coalesced_waits += 1
        self.stats.responses_200 += 1
        return Response(200, body, body["etag"])
