"""Optimizers: AdamW (fp32 master + moments) and Adafactor-lite.

Implemented from scratch (no optax in this container). The optimizer state
lives in fp32 regardless of the bf16 compute params — the standard mixed
precision recipe — and every state leaf inherits the parameter's logical
sharding, so FSDP shards optimizer state for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray       # () int32
    mu: Any                 # fp32 first moment, like params
    nu: Any                 # fp32 second moment
    master: Any             # fp32 master params


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32))) for x in leaves))


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    master = jax.tree.map(lambda p: p.astype(F32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros), master=master)


def adamw_abstract_state(param_structs) -> AdamWState:
    """ShapeDtypeStruct mirror for the dry-run path."""
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, F32)  # noqa: E731
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(f32, param_structs),
        nu=jax.tree.map(f32, param_structs),
        master=jax.tree.map(f32, param_structs),
    )


def adamw_update(
    grads, state: AdamWState, cfg: AdamWConfig, lr_scale: jnp.ndarray
) -> Tuple[Any, AdamWState]:
    """One AdamW step. Returns (new bf16-castable params, new state).

    grads are in params dtype (bf16-safe): they are upcast here once.
    """
    step = state.step + 1
    g32 = jax.tree.map(lambda g: g.astype(F32), grads)
    if cfg.grad_clip_norm is not None:
        norm = global_norm(g32)
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / (norm + 1e-9))
        g32 = jax.tree.map(lambda g: g * scale, g32)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
    bc1 = 1 - b1 ** step.astype(F32)
    bc2 = 1 - b2 ** step.astype(F32)
    lr = cfg.lr * lr_scale

    def upd(master, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return master - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master
        )

    master = jax.tree.map(upd, state.master, mu, nu)
    return master, AdamWState(step=step, mu=mu, nu=nu, master=master)


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------


def cosine_schedule(
    warmup_steps: int, total_steps: int, min_ratio: float = 0.1
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def fn(step):
        step = step.astype(F32)
        warm = jnp.minimum(step / max(warmup_steps, 1), 1.0)
        frac = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return warm * cos

    return fn
