"""The jit-compiled training step: microbatched grad accumulation + AdamW.

Structure (all inside ONE jit program so XLA can overlap the backward's
gradient reduce-scatter with compute):

  scan over microbatches:
      value_and_grad(loss(params_bf16, microbatch))   [remat inside layers]
      accumulate fp32 grads
  psum over ("pod","data") is implicit — GSPMD inserts the hierarchical
  all-reduce from the batch sharding; grads of FSDP-sharded params become
  reduce-scatters fused with the accumulation.
  AdamW update on fp32 master; emit bf16 params for the next step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel.sharding import constrain
from repro.train import optimizer as opt

F32 = jnp.float32


class TrainState(NamedTuple):
    params: Any            # compute-dtype params (bf16)
    opt: opt.AdamWState    # fp32 moments + master
    rng: jnp.ndarray


class StepMetrics(NamedTuple):
    loss: jnp.ndarray
    aux_loss: jnp.ndarray
    grad_norm: jnp.ndarray
    tokens: jnp.ndarray


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: Optional[jnp.ndarray]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean masked token cross-entropy in fp32. logits: (B,S,V).

    The gold-logit gather is written as a one-hot masked reduction so it
    stays partitioned when the vocab axis is TP-sharded (a take_along_axis
    would force an all-gather of the full logits).
    """
    logits = logits.astype(F32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, len(logits.shape) - 1)
    onehot = vocab_iota == labels[..., None]
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(labels, F32)
    mask = mask.astype(F32)
    total = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / total, total


def make_loss_fn(model, cfg: ModelConfig, aux_weight: float = 0.01):
    def loss_fn(params, batch):
        out = model.forward(params, batch)
        labels = batch.get("labels", batch["tokens"])
        # next-token shift: predict t+1 from <=t
        logits = out.logits[:, :-1]
        tgt = labels[:, 1:]
        mask = batch.get("loss_mask")
        mask = mask[:, 1:] if mask is not None else None
        ce, ntok = cross_entropy(logits, tgt, mask)
        loss = ce + aux_weight * out.aux_loss
        return loss, (ce, out.aux_loss, ntok)

    return loss_fn


def make_train_step(
    model,
    cfg: ModelConfig,
    opt_cfg: opt.AdamWConfig,
    schedule: Callable,
    num_microbatches: int = 1,
) -> Callable[[TrainState, Dict[str, jnp.ndarray]], Tuple[TrainState, StepMetrics]]:
    """Build the jit-able train step (microbatched over the batch dim)."""
    loss_fn = make_loss_fn(model, cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def split_mb(x):
        b = x.shape[0]
        assert b % num_microbatches == 0, (b, num_microbatches)
        return x.reshape(num_microbatches, b // num_microbatches, *x.shape[1:])

    def train_step(state: TrainState, batch) -> Tuple[TrainState, StepMetrics]:
        params = state.params

        if num_microbatches == 1:
            (loss, (ce, aux, ntok)), grads = grad_fn(params, batch)
        else:
            mbs = jax.tree.map(split_mb, batch)

            def body(carry, mb):
                g_acc, l_acc, a_acc, n_acc = carry
                mb = jax.tree.map(
                    lambda x: constrain(x, "batch"), mb
                )
                (l, (ce_i, a, n)), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda acc, gi: acc + gi.astype(F32), g_acc, g
                )
                return (g_acc, l_acc + ce_i, a_acc + a, n_acc + n), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            (grads, ce_sum, aux_sum, ntok), _ = jax.lax.scan(
                body,
                (g0, jnp.zeros((), F32), jnp.zeros((), F32), jnp.zeros((), F32)),
                mbs,
            )
            inv = 1.0 / num_microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            ce, aux = ce_sum * inv, aux_sum * inv
            loss = ce

        gnorm = opt.global_norm(grads)
        lr_scale = schedule(state.opt.step)
        master, new_opt = opt.adamw_update(grads, state.opt, opt_cfg, lr_scale)
        new_params = jax.tree.map(
            lambda m, p: m.astype(p.dtype), master, params
        )
        new_state = TrainState(params=new_params, opt=new_opt, rng=state.rng)
        return new_state, StepMetrics(
            loss=loss, aux_loss=aux, grad_norm=gnorm,
            tokens=jnp.asarray(ntok, F32),
        )

    return train_step


def init_train_state(model, cfg: ModelConfig, seed: int = 0) -> TrainState:
    from repro.models import params as P

    key = jax.random.PRNGKey(seed)
    params = P.init_params(model.specs(), key, jnp.dtype(cfg.param_dtype))
    return TrainState(
        params=params, opt=opt.adamw_init(params), rng=jax.random.PRNGKey(seed + 1)
    )
