"""The training loop: checkpoint/restart, heartbeats, straggler eviction.

Single-host container, but the control flow is the multi-pod one:

    loop:
      maybe restore (LATEST checkpoint + deterministic data skip)
      for step in range(start, total):
          batch  = pipeline.next()
          state  = train_step(state, batch)        # jit, overlapped comms
          coordinator.heartbeat(step_time)
          fault plan / heartbeat scan -> membership change?
             -> save + elastic restart (smaller/larger DP degree)
          every ckpt_interval: async checkpoint
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.ft.coordinator import Coordinator, FaultPlan, elastic_batch_split
from repro.train import optimizer as opt
from repro.train.train_step import TrainState, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_interval: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_async: bool = True
    log_interval: int = 10
    num_workers: int = 1          # simulated fleet size for FT bookkeeping
    lr_rescale_on_shrink: bool = True


@dataclasses.dataclass
class TrainReport:
    steps_run: int
    final_loss: float
    losses: List[float]
    restarts: int
    evictions: List[str]
    resumed_from: Optional[int]


class Trainer:
    def __init__(
        self,
        model,
        cfg,
        opt_cfg: opt.AdamWConfig,
        schedule: Callable,
        trainer_cfg: TrainerConfig,
        num_microbatches: int = 1,
    ):
        self.model = model
        self.cfg = cfg
        self.tc = trainer_cfg
        self.step_fn = jax.jit(
            make_train_step(model, cfg, opt_cfg, schedule, num_microbatches)
        )
        self.ckpt = CheckpointManager(trainer_cfg.ckpt_dir)
        self.coord = Coordinator(trainer_cfg.num_workers)

    def run(
        self,
        state: TrainState,
        batches: Iterator[Dict[str, np.ndarray]],
        *,
        fault_plan: Optional[FaultPlan] = None,
        resume: bool = True,
    ) -> tuple[TrainState, TrainReport]:
        tc = self.tc
        start = 0
        resumed_from = None
        if resume and self.ckpt.latest_step() is not None:
            start, state = self.ckpt.restore(state)
            resumed_from = start
        losses: List[float] = []
        restarts = 0
        step = start
        it = iter(batches)
        # Deterministic skip: consume batches already trained on.
        for _ in range(start):
            next(it, None)
        while step < tc.total_steps:
            try:
                batch = next(it)
            except StopIteration:
                break
            t0 = time.monotonic()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics.loss)
            dt = time.monotonic() - t0
            losses.append(loss)
            step += 1

            # Single-host container simulates the fleet: every alive worker
            # reports the measured step time (on a real deployment each host
            # heartbeats for itself).
            for w in self.coord.alive_workers():
                self.coord.heartbeat(w, dt)
            if fault_plan is not None and self.coord.apply_plan(fault_plan, step):
                # membership changed: checkpoint, then elastic continue
                self.ckpt.save(step, state, blocking=True)
                restarts += 1
                alive = len(self.coord.alive_workers())
                if tc.lr_rescale_on_shrink and alive:
                    pass  # lr scale folded into schedule by caller if desired
            if step % tc.ckpt_interval == 0 or step == tc.total_steps:
                self.ckpt.save(step, state, blocking=not tc.ckpt_async)
            if step % tc.log_interval == 0:
                print(
                    f"step {step:5d} loss {loss:.4f} "
                    f"gnorm {float(metrics.grad_norm):.3f} {dt*1e3:.0f}ms"
                )
        self.ckpt.wait()
        return state, TrainReport(
            steps_run=step - start,
            final_loss=losses[-1] if losses else float("nan"),
            losses=losses,
            restarts=restarts,
            evictions=list(self.coord.log),
            resumed_from=resumed_from,
        )
