"""Binary wire format + persistent-connection client for the stats tier.

The service and fleet tiers speak stdlib HTTP/JSON by default. A planner
fleet polling thousands of datasets pays for that convenience three times
per request: a fresh TCP connection, JSON text encoding, and one HTTP
round trip per (dataset, mode, bounds) tuple. This package removes all
three without adding a dependency:

  `codec`    a compact length-prefixed binary encoding of the same
             response dicts the JSON endpoints serve. Negotiated per
             request (`Accept: application/x-ndv-wire`); JSON stays the
             default and the two encodings decode to bit-identical
             bodies carrying byte-identical ETags, so a client may switch
             encodings mid-session without invalidating a single cached
             tag.
  `client`   a keep-alive `http.client.HTTPConnection` pool with safe
             reconnect-on-stale, shared by the router->replica hop and
             the benchmark client — one TCP connection serves thousands
             of requests instead of one each.

Batched RPC rides on both: `POST /batch` (service and router tiers)
carries many estimate tuples in one frame, and the router forwards one
binary sub-batch per rendezvous-chosen replica over a pooled connection.

Frame byte layout (version 1)
-----------------------------

    frame    := magic "NDVW" | version u8 (=1) | nsections varint
                | section*
    section  := tag varint | length varint | payload[length]

Unknown section tags are skipped (forward compatibility). Version 1
frames carry two required sections plus one optional one:

    tag 1  STRINGS  varint count, then per string: varint byte length +
                    UTF-8 bytes. Every string in the value tree — dict
                    keys, column names, ETags — is interned here once and
                    referenced by index, so a 10,000-column response
                    names each column exactly once.
    tag 2  VALUE    one tagged value tree (the response body):

        0x00 null        0x01 false            0x02 true
        0x03 int         zigzag varint
        0x04 float       8-byte IEEE-754 little-endian
        0x05 string      varint string-table index
        0x06 list        varint n, then n values
        0x07 dict        varint n, then n x (varint key index, value)
        0x08 f64 list    varint n, then n x 8-byte LE (all-float lists)
        0x09 str list    varint n, then n string-table indices
        0x0A table       dict-of-dicts with one shared key set (the
                         /estimate `estimates` and /plan `plans` maps):
                         varint rows, varint cols, col-key indices,
                         row-name indices, then per column one packed
                         array: 'F' f64 LE | 'I' zigzag varints |
                         'B' bool bytes | 'S' string indices | 'V'
                         tagged values (mixed-type fallback)
    tag 3  TRACE    optional: a UTF-8 traceparent string
                    (`00-<trace>-<span>-01`) carrying request-trace
                    context out-of-band. Never part of the decoded
                    value, so ETags over frame bodies stay trace-blind;
                    pre-trace peers skip it via the unknown-section rule.
    tag 4  EXPLAIN  optional: a second tagged value tree (per-column
                    estimation provenance, attached when the request
                    asked `explain=1`) sharing the frame's string table.
                    Like TRACE it lives outside the value section, so the
                    body bytes and their ETag are explain-blind;
                    `decode_explain` reads it best-effort and
                    `client.fetch` re-attaches it as the body's
                    "provenance" key so wire and JSON clients observe
                    identical explained bodies. Pre-provenance peers skip
                    the tag.

All varints are unsigned LEB128; signed integers are zigzag-mapped
first. Integers of any magnitude survive (no 64-bit clamp), floats are
bit-exact (the same exactness JSON's shortest-round-trip reprs give),
and decode order preserves encode order — `decode(encode(body))` equals
`json.loads(json.dumps(body))` for every JSON-representable body, which
is the negotiation contract the HTTP layer tests enforce.

Truncated, foreign, or future-versioned frames raise `WireError` with a
message naming the failure; nothing in here can raise a bare struct or
index error on hostile input.
"""
from repro.wire.client import (  # noqa: F401
    ConnectionPool,
    fetch,
)
from repro.wire.codec import (  # noqa: F401
    JSON_CONTENT_TYPE,
    WIRE_CONTENT_TYPE,
    WireError,
    decode_explain,
    decode_frame,
    decode_frame_and_explain,
    decode_traceparent,
    encode_frame,
)
