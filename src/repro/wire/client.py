"""Keep-alive HTTP client: a connection pool + a binary-aware `fetch`.

`urllib.request.urlopen` opens a fresh TCP connection per request, which
dominates warm-request latency once bodies are 304-sized. The service
tier already speaks HTTP/1.1 with Content-Length (keep-alive capable);
this module supplies the client half:

  `ConnectionPool`   thread-safe pool of `http.client.HTTPConnection`s
                     keyed by (host, port). A connection is checked out
                     for exactly one request and returned on success. A
                     *reused* connection that fails mid-request (server
                     idle-timeout, replica kill) is discarded and the
                     request retried once on a fresh connection — a
                     fresh connection's failure propagates, so real
                     outages still look like `FAILOVER_ERRORS`.
  `fetch`            pooled, content-negotiating replacement for
                     `repro.service.http.fetch_json`: same
                     (status, etag, body) contract, plus binary framing
                     (`Accept: application/x-ndv-wire`) and POST bodies.

Stdlib only; http:// URLs only (the stats tier is plaintext-intra-DC).
"""
from __future__ import annotations

import http.client
import socket
import threading
from typing import Any, Dict, List, Optional, Tuple
from urllib.parse import urlsplit

from repro.obs import TRACEPARENT_HEADER, current_traceparent, registry
from repro.wire.codec import (
    JSON_CONTENT_TYPE,
    WIRE_CONTENT_TYPE,
    decode_frame_and_explain,
    encode_frame,
)
import json

_HostKey = Tuple[str, int]

# Errors that mean "this pooled connection went stale underneath us",
# worth one retry on a fresh connection. http.client.RemoteDisconnected
# is a ConnectionResetError; BadStatusLine covers half-closed sockets.
_STALE_ERRORS = (ConnectionError, BrokenPipeError, http.client.HTTPException, TimeoutError, OSError)


class _KeepAliveConnection(http.client.HTTPConnection):
    """`HTTPConnection` with Nagle disabled.

    A kept-alive socket carrying small request/response pairs hits the
    Nagle + delayed-ACK interaction: the second small segment of every
    exchange (headers, then body, written separately by both http.client
    and http.server) stalls ~40ms waiting for the peer's delayed ACK.
    TCP_NODELAY removes the stall; applied in `connect()` so it survives
    http.client's auto-reconnect of a closed connection.
    """

    def connect(self) -> None:
        super().connect()
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)


class PoolStats:
    """Counters for tests and the connection-reuse benchmark."""

    # __weakref__ lets the metrics registry hold this object as a scrape-
    # time view (`repro.obs`) without keeping it alive.
    __slots__ = ("opened", "reused", "retried_stale", "__weakref__")

    def __init__(self):
        self.opened = 0
        self.reused = 0
        self.retried_stale = 0

    def snapshot(self) -> Dict[str, int]:
        return {
            f: getattr(self, f) for f in self.__slots__ if f != "__weakref__"
        }


class ConnectionPool:
    """Thread-safe keep-alive pool of plain HTTP connections."""

    def __init__(
        self,
        *,
        max_per_host: int = 8,
        timeout: float = 30.0,
        name: str = "default",
    ):
        self.max_per_host = max_per_host
        self.timeout = timeout
        self.name = name
        self.stats = PoolStats()
        self._lock = threading.Lock()
        self._idle: Dict[_HostKey, List[http.client.HTTPConnection]] = {}
        self._closed = False
        registry().register_stats_view("ndv_pool", {"pool": name}, self.stats)

    # -- checkout / checkin --

    def _checkout(self, key: _HostKey) -> Tuple[http.client.HTTPConnection, bool]:
        """Return (connection, was_pooled)."""
        with self._lock:
            bucket = self._idle.get(key)
            if bucket:
                self.stats.reused += 1
                return bucket.pop(), True
            self.stats.opened += 1
        conn = _KeepAliveConnection(key[0], key[1], timeout=self.timeout)
        return conn, False

    def _checkin(self, key: _HostKey, conn: http.client.HTTPConnection) -> None:
        with self._lock:
            if not self._closed:
                bucket = self._idle.setdefault(key, [])
                if len(bucket) < self.max_per_host:
                    bucket.append(conn)
                    return
        conn.close()

    def close(self) -> None:
        with self._lock:
            self._closed = True
            idle, self._idle = self._idle, {}
        for bucket in idle.values():
            for conn in bucket:
                conn.close()

    # -- one request --

    def request(
        self,
        url: str,
        *,
        method: str = "GET",
        headers: Optional[Dict[str, str]] = None,
        body: Optional[bytes] = None,
    ) -> Tuple[int, Dict[str, str], bytes]:
        """One HTTP exchange; returns (status, lowercased headers, body).

        Retries exactly once, and only when the failed connection came
        from the pool (a stale keep-alive socket, not a dead server).
        """
        parts = urlsplit(url)
        if parts.scheme != "http":
            raise ValueError(f"ConnectionPool only speaks http://, got {url!r}")
        key = (parts.hostname or "localhost", parts.port or 80)
        path = parts.path or "/"
        if parts.query:
            path = f"{path}?{parts.query}"

        last_stale: Optional[BaseException] = None
        for _ in range(2):
            conn, was_pooled = self._checkout(key)
            try:
                conn.request(method, path, body=body, headers=headers or {})
                resp = conn.getresponse()
                payload = resp.read()
            except _STALE_ERRORS as e:
                conn.close()
                if was_pooled:
                    # Stale keep-alive socket: retry once on a fresh one.
                    self.stats.retried_stale += 1
                    last_stale = e
                    continue
                raise
            resp_headers = {k.lower(): v for k, v in resp.getheaders()}
            if resp.will_close:
                conn.close()
            else:
                self._checkin(key, conn)
            return resp.status, resp_headers, payload
        raise last_stale  # both attempts stale — surface the transport error


_default_pool: Optional[ConnectionPool] = None
_default_pool_lock = threading.Lock()


def default_pool() -> ConnectionPool:
    """Process-wide pool shared by callers that don't manage their own."""
    global _default_pool
    with _default_pool_lock:
        if _default_pool is None or _default_pool._closed:
            _default_pool = ConnectionPool()
        return _default_pool


def fetch(
    url: str,
    *,
    pool: Optional[ConnectionPool] = None,
    etag: Optional[str] = None,
    method: str = "GET",
    payload: Any = None,
    binary: bool = True,
    extra_headers: Optional[Dict[str, str]] = None,
) -> Tuple[int, Optional[str], Any]:
    """Pooled, encoding-negotiated request; returns (status, etag, body).

    Mirrors `repro.service.http.fetch_json`: 304 yields body None, any
    JSON/wire error body is decoded and returned with its status.
    `binary=True` sends `Accept: application/x-ndv-wire`; the body is
    decoded by the *response's* Content-Type, so a JSON-only server
    degrades transparently. `payload` (when not None) is sent as the
    request body in the same encoding that is being accepted.
    """
    pool = pool or default_pool()
    headers: Dict[str, str] = {
        "Accept": WIRE_CONTENT_TYPE if binary else JSON_CONTENT_TYPE,
    }
    if etag:
        headers["If-None-Match"] = etag
    # Propagate the active trace (if any) downstream: always as a header,
    # and inside the wire frame for binary bodies so frame-only relays
    # keep the context too.
    traceparent = current_traceparent()
    if traceparent:
        headers[TRACEPARENT_HEADER] = traceparent
    if extra_headers:
        headers.update(extra_headers)

    body_bytes: Optional[bytes] = None
    if payload is not None:
        if binary:
            body_bytes = encode_frame(payload, traceparent=traceparent)
            headers["Content-Type"] = WIRE_CONTENT_TYPE
        else:
            body_bytes = json.dumps(payload).encode("utf-8")
            headers["Content-Type"] = JSON_CONTENT_TYPE
        if method == "GET":
            method = "POST"

    status, resp_headers, raw = pool.request(
        url, method=method, headers=headers, body=body_bytes
    )
    resp_etag = resp_headers.get("etag")
    if status == 304 or not raw:
        return status, resp_etag, None
    ctype = resp_headers.get("content-type", JSON_CONTENT_TYPE)
    if ctype.split(";")[0].strip() == WIRE_CONTENT_TYPE:
        # Wire responses carry provenance out-of-band (section 4) so the
        # value section — and its ETag — stays explain-blind. Re-attach it
        # here so wire and JSON clients observe identical bodies (one
        # combined pass: the string table decodes once for both sections).
        body, explain = decode_frame_and_explain(raw)
        if explain is not None and isinstance(body, dict):
            body = dict(body)
            body["provenance"] = explain
    else:
        body = json.loads(raw.decode("utf-8"))
    return status, resp_etag, body
