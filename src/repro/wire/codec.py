"""Length-prefixed binary codec for stats-tier response bodies.

See the package docstring (`repro.wire`) for the full byte layout. The
contract implemented here: for every JSON-representable value ``x``,

    decode_frame(encode_frame(x)) == json.loads(json.dumps(x))

— same float bits (both paths are exact), same int/float distinction,
same key order, tuples normalized to lists, non-string dict keys coerced
exactly as ``json.dumps`` coerces them. That equivalence is what lets the
HTTP layer negotiate encodings per request while ETags keep naming one
response, not one (response, encoding) pair.

Stdlib only. Hostile input (truncation, bad magic, future versions,
out-of-range string indices) raises `WireError`, never a bare struct or
index error.
"""
from __future__ import annotations

import struct
from typing import Any, Dict, List, Tuple

MAGIC = b"NDVW"
VERSION = 1

WIRE_CONTENT_TYPE = "application/x-ndv-wire"
JSON_CONTENT_TYPE = "application/json"

_SECTION_STRINGS = 1
_SECTION_VALUE = 2
# Optional out-of-band trace context (UTF-8 traceparent string). Peers
# that predate it skip it via the unknown-section rule below.
_SECTION_TRACE = 3
# Optional per-estimate provenance (a second encoded value tree sharing
# the frame's string table). Carried outside the value section so the
# response body — and therefore its ETag — is byte-identical whether or
# not a peer asked to explain; pre-provenance peers skip the tag.
_SECTION_EXPLAIN = 4

_T_NULL = 0x00
_T_FALSE = 0x01
_T_TRUE = 0x02
_T_INT = 0x03
_T_FLOAT = 0x04
_T_STR = 0x05
_T_LIST = 0x06
_T_DICT = 0x07
_T_F64_LIST = 0x08
_T_STR_LIST = 0x09
_T_TABLE = 0x0A

# Table column type codes (packed little-endian arrays per column).
_COL_FLOAT = ord("F")
_COL_INT = ord("I")
_COL_BOOL = ord("B")
_COL_STR = ord("S")
_COL_ANY = ord("V")

# Varint size ceiling: 128 continuation bytes = ints up to ~2^896. Far
# beyond any real payload, small enough that a hostile all-0x80 stream
# cannot grow an unbounded bignum.
_MAX_VARINT_BYTES = 128

_F64 = struct.Struct("<d")


class WireError(ValueError):
    """Malformed, truncated, or future-versioned wire frame."""


# -- varints ------------------------------------------------------------------


def _write_uvarint(out: bytearray, n: int) -> None:
    while True:
        b = n & 0x7F
        n >>= 7
        if n:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def _zigzag(n: int) -> int:
    return n * 2 if n >= 0 else -n * 2 - 1


def _unzigzag(z: int) -> int:
    return z // 2 if z % 2 == 0 else -(z + 1) // 2


class _Reader:
    """Bounds-checked byte reader: every underrun is a WireError."""

    __slots__ = ("data", "pos", "end")

    def __init__(self, data: bytes, start: int = 0, end: int = -1):
        self.data = data
        self.pos = start
        self.end = len(data) if end < 0 else end

    def take(self, n: int) -> bytes:
        if self.pos + n > self.end:
            raise WireError(
                f"truncated frame: wanted {n} bytes at offset {self.pos}, "
                f"have {self.end - self.pos}"
            )
        out = self.data[self.pos:self.pos + n]
        self.pos += n
        return out

    def byte(self) -> int:
        if self.pos >= self.end:
            raise WireError(f"truncated frame at offset {self.pos}")
        b = self.data[self.pos]
        self.pos += 1
        return b

    def uvarint(self) -> int:
        shift = 0
        value = 0
        for i in range(_MAX_VARINT_BYTES):
            b = self.byte()
            value |= (b & 0x7F) << shift
            if not b & 0x80:
                return value
            shift += 7
        raise WireError("varint exceeds the size ceiling")

    @property
    def exhausted(self) -> bool:
        return self.pos >= self.end


# -- encode -------------------------------------------------------------------


def _json_key(key: Any) -> str:
    """Dict-key coercion, exactly as ``json.dumps`` performs it."""
    if type(key) is str:
        return key
    if key is True:
        return "true"
    if key is False:
        return "false"
    if key is None:
        return "null"
    if type(key) is int or type(key) is float:
        return repr(key) if type(key) is float else str(key)
    raise WireError(f"dict key of type {type(key).__name__} is not encodable")


class _Encoder:
    def __init__(self):
        self.strings: List[str] = []
        self._index: Dict[str, int] = {}
        self.body = bytearray()

    def intern(self, s: str) -> int:
        idx = self._index.get(s)
        if idx is None:
            idx = self._index[s] = len(self.strings)
            self.strings.append(s)
        return idx

    def value(self, v: Any) -> None:
        out = self.body
        t = type(v)
        if v is None:
            out.append(_T_NULL)
        elif v is True:
            out.append(_T_TRUE)
        elif v is False:
            out.append(_T_FALSE)
        elif t is int:
            out.append(_T_INT)
            _write_uvarint(out, _zigzag(v))
        elif t is float:
            out.append(_T_FLOAT)
            out += _F64.pack(v)
        elif t is str:
            out.append(_T_STR)
            _write_uvarint(out, self.intern(v))
        elif t is list or t is tuple:
            self._list(list(v))
        elif t is dict:
            self._dict(v)
        else:
            raise WireError(
                f"value of type {t.__name__} is not wire-encodable"
            )

    def _list(self, v: list) -> None:
        out = self.body
        if v and all(type(e) is float for e in v):
            out.append(_T_F64_LIST)
            _write_uvarint(out, len(v))
            for e in v:
                out += _F64.pack(e)
            return
        if v and all(type(e) is str for e in v):
            out.append(_T_STR_LIST)
            _write_uvarint(out, len(v))
            for e in v:
                _write_uvarint(out, self.intern(e))
            return
        out.append(_T_LIST)
        _write_uvarint(out, len(v))
        for e in v:
            self.value(e)

    def _dict(self, v: dict) -> None:
        out = self.body
        keys = [_json_key(k) for k in v]
        if len(set(keys)) != len(keys):
            # json.dumps would silently collapse coerced-key collisions;
            # refuse instead — the stats tier never produces them.
            raise WireError("dict keys collide after JSON key coercion")
        values = list(v.values())
        cols = self._table_columns(values)
        if cols is not None:
            out.append(_T_TABLE)
            _write_uvarint(out, len(values))           # rows
            _write_uvarint(out, len(cols))             # cols
            for ck in cols:
                _write_uvarint(out, self.intern(ck))
            for rk in keys:
                _write_uvarint(out, self.intern(rk))
            for ci, ck in enumerate(cols):
                self._table_column([row[ck] for row in values])
            return
        out.append(_T_DICT)
        _write_uvarint(out, len(values))
        for k, e in zip(keys, values):
            _write_uvarint(out, self.intern(k))
            self.value(e)

    @staticmethod
    def _table_columns(values: list):
        """Shared column-key tuple if this is a packable table, else None.

        A table is a dict of >= 2 rows whose values are all dicts sharing
        one key sequence (same keys, same order) with plain-string keys —
        the /estimate and /plan response maps.
        """
        if len(values) < 2 or not all(type(r) is dict for r in values):
            return None
        first = list(values[0])
        if not first or not all(type(k) is str for k in first):
            return None
        for row in values[1:]:
            if list(row) != first:
                return None
        return first

    def _table_column(self, cells: list) -> None:
        out = self.body
        if all(type(c) is float for c in cells):
            out.append(_COL_FLOAT)
            for c in cells:
                out += _F64.pack(c)
        elif all(type(c) is bool for c in cells):
            out.append(_COL_BOOL)
            out += bytes(int(c) for c in cells)
        elif all(type(c) is int for c in cells):
            out.append(_COL_INT)
            for c in cells:
                _write_uvarint(out, _zigzag(c))
        elif all(type(c) is str for c in cells):
            out.append(_COL_STR)
            for c in cells:
                _write_uvarint(out, self.intern(c))
        else:
            out.append(_COL_ANY)
            for c in cells:
                self.value(c)


def encode_frame(
    obj: Any, *, traceparent: str = None, explain: Any = None
) -> bytes:
    """Encode one JSON-representable value as a v1 wire frame.

    `traceparent` rides in its own section, outside the value — it never
    changes what `decode_frame` returns, so ETags over frame bodies stay
    trace-blind. `explain` (when not None) is a second value tree encoded
    into its own section with the same guarantee: the value section's
    bytes do not change, and peers that predate the tag skip it.
    """
    enc = _Encoder()
    enc.value(obj)
    value_body = bytes(enc.body)
    explain_body = None
    if explain is not None:
        # Same encoder: explain strings are appended to the shared table
        # AFTER the value's, so the value body stays byte-stable.
        enc.body = bytearray()
        enc.value(explain)
        explain_body = bytes(enc.body)

    strings = bytearray()
    _write_uvarint(strings, len(enc.strings))
    for s in enc.strings:
        raw = s.encode("utf-8")
        _write_uvarint(strings, len(raw))
        strings += raw

    sections = [(_SECTION_STRINGS, strings), (_SECTION_VALUE, value_body)]
    if explain_body is not None:
        sections.append((_SECTION_EXPLAIN, explain_body))
    if traceparent:
        sections.append((_SECTION_TRACE, traceparent.encode("utf-8")))

    frame = bytearray(MAGIC)
    frame.append(VERSION)
    _write_uvarint(frame, len(sections))
    for tag, payload in sections:
        _write_uvarint(frame, tag)
        _write_uvarint(frame, len(payload))
        frame += payload
    return bytes(frame)


# -- decode -------------------------------------------------------------------


class _Decoder:
    def __init__(self, strings: List[str], reader: _Reader):
        self.strings = strings
        self.r = reader

    def string(self) -> str:
        idx = self.r.uvarint()
        try:
            return self.strings[idx]
        except IndexError:
            raise WireError(
                f"string index {idx} out of range "
                f"(table has {len(self.strings)})"
            ) from None

    def value(self) -> Any:
        tag = self.r.byte()
        if tag == _T_NULL:
            return None
        if tag == _T_FALSE:
            return False
        if tag == _T_TRUE:
            return True
        if tag == _T_INT:
            return _unzigzag(self.r.uvarint())
        if tag == _T_FLOAT:
            return _F64.unpack(self.r.take(8))[0]
        if tag == _T_STR:
            return self.string()
        if tag == _T_LIST:
            return [self.value() for _ in range(self.r.uvarint())]
        if tag == _T_DICT:
            return {
                self.string(): self.value()
                for _ in range(self.r.uvarint())
            }
        if tag == _T_F64_LIST:
            n = self.r.uvarint()
            return [_F64.unpack(self.r.take(8))[0] for _ in range(n)]
        if tag == _T_STR_LIST:
            return [self.string() for _ in range(self.r.uvarint())]
        if tag == _T_TABLE:
            return self._table()
        raise WireError(f"unknown value tag 0x{tag:02x}")

    def _table(self) -> dict:
        rows = self.r.uvarint()
        cols = self.r.uvarint()
        col_keys = [self.string() for _ in range(cols)]
        row_keys = [self.string() for _ in range(rows)]
        columns = [self._table_column(rows) for _ in range(cols)]
        return {
            rk: {ck: columns[ci][ri] for ci, ck in enumerate(col_keys)}
            for ri, rk in enumerate(row_keys)
        }

    def _table_column(self, rows: int) -> list:
        kind = self.r.byte()
        if kind == _COL_FLOAT:
            return [_F64.unpack(self.r.take(8))[0] for _ in range(rows)]
        if kind == _COL_BOOL:
            return [bool(b) for b in self.r.take(rows)]
        if kind == _COL_INT:
            return [_unzigzag(self.r.uvarint()) for _ in range(rows)]
        if kind == _COL_STR:
            return [self.string() for _ in range(rows)]
        if kind == _COL_ANY:
            return [self.value() for _ in range(rows)]
        raise WireError(f"unknown table column type 0x{kind:02x}")


def _scan_sections(data: bytes) -> Tuple[bytes, Dict[int, Tuple[int, int]]]:
    """Validate the frame header and map section tag -> (start, end)."""
    if not isinstance(data, (bytes, bytearray, memoryview)):
        raise WireError(f"frame must be bytes, got {type(data).__name__}")
    data = bytes(data)
    if len(data) < len(MAGIC) + 1:
        raise WireError(f"frame too short ({len(data)} bytes)")
    if data[:len(MAGIC)] != MAGIC:
        raise WireError(f"bad magic {data[:len(MAGIC)]!r}; want {MAGIC!r}")
    version = data[len(MAGIC)]
    if version != VERSION:
        raise WireError(f"unsupported wire version {version}; want {VERSION}")
    r = _Reader(data, start=len(MAGIC) + 1)
    sections: Dict[int, Tuple[int, int]] = {}
    for _ in range(r.uvarint()):
        tag = r.uvarint()
        length = r.uvarint()
        start = r.pos
        r.take(length)  # bounds check + skip
        sections.setdefault(tag, (start, start + length))
    return data, sections


def decode_traceparent(data: bytes) -> "str | None":
    """The frame's trace section as a string, or None if absent/invalid.

    Never raises on a well-framed payload without (or with a garbled)
    trace section — tracing is best-effort and must not fail a request.
    """
    try:
        data, sections = _scan_sections(data)
    except WireError:
        return None
    bounds = sections.get(_SECTION_TRACE)
    if bounds is None:
        return None
    try:
        return data[bounds[0]:bounds[1]].decode("utf-8")
    except UnicodeDecodeError:
        return None


def _decode_strings(data: bytes, sections: Dict[int, Tuple[int, int]]) -> List[str]:
    if _SECTION_STRINGS not in sections:
        raise WireError(f"frame is missing section {_SECTION_STRINGS}")
    s0, s1 = sections[_SECTION_STRINGS]
    sr = _Reader(data, start=s0, end=s1)
    strings = []
    for _ in range(sr.uvarint()):
        raw = sr.take(sr.uvarint())
        try:
            strings.append(raw.decode("utf-8"))
        except UnicodeDecodeError as e:
            raise WireError(f"invalid UTF-8 in string table: {e}") from None
    return strings


def _decode_section_value(
    data: bytes, strings: List[str], bounds: Tuple[int, int], what: str
) -> Any:
    vr = _Reader(data, start=bounds[0], end=bounds[1])
    value = _Decoder(strings, vr).value()
    if not vr.exhausted:
        raise WireError(
            f"{vr.end - vr.pos} trailing bytes after the {what} section"
        )
    return value


def decode_frame(data: bytes) -> Any:
    """Decode a v1 wire frame back to the value it encoded."""
    data, sections = _scan_sections(data)
    strings = _decode_strings(data, sections)
    if _SECTION_VALUE not in sections:
        raise WireError(f"frame is missing section {_SECTION_VALUE}")
    return _decode_section_value(
        data, strings, sections[_SECTION_VALUE], "value"
    )


def decode_explain(data: bytes) -> Any:
    """The frame's provenance section as a value, or None if absent.

    Best-effort, like `decode_traceparent`: a well-framed payload without
    (or with a garbled) explain section yields None rather than an error —
    diagnostics must never fail the request that carried them.
    """
    try:
        data, sections = _scan_sections(data)
        bounds = sections.get(_SECTION_EXPLAIN)
        if bounds is None:
            return None
        strings = _decode_strings(data, sections)
        return _decode_section_value(data, strings, bounds, "explain")
    except WireError:
        return None


def decode_frame_and_explain(data: bytes) -> Tuple[Any, Any]:
    """`(decode_frame(data), decode_explain(data))` in one pass.

    The string table dominates decode time, and a caller interested in
    both sections (`repro.wire.client.fetch`) would otherwise decode it
    twice. Error semantics are preserved per section: the value decode
    raises `WireError` exactly as `decode_frame` does, the explain decode
    stays best-effort (None on a garbled or absent section).
    """
    data, sections = _scan_sections(data)
    strings = _decode_strings(data, sections)
    if _SECTION_VALUE not in sections:
        raise WireError(f"frame is missing section {_SECTION_VALUE}")
    value = _decode_section_value(
        data, strings, sections[_SECTION_VALUE], "value"
    )
    explain = None
    bounds = sections.get(_SECTION_EXPLAIN)
    if bounds is not None:
        try:
            explain = _decode_section_value(data, strings, bounds, "explain")
        except WireError:
            explain = None
    return value, explain
