import os
import sys

# Tests run on the single real CPU device (the dry-run sets its own flags
# in a separate process). Keep threads bounded for the 1-core container.
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
