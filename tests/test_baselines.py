"""Data-access baseline estimators (HLL / CVM / sampling) sanity tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.baselines import (
    cvm_ndv,
    exact_ndv,
    hll_estimate,
    hll_merge,
    hll_ndv,
    hll_registers,
    sampling_chao,
    sampling_gee,
    splitmix64,
)


def test_hll_accuracy_bands():
    rng = np.random.default_rng(0)
    for true in (100, 10_000, 200_000):
        vals = rng.integers(0, true, true * 3).astype(np.int64)
        t = exact_ndv(vals)
        est = hll_ndv(vals, p=12)
        assert abs(est - t) / t < 0.05, (true, est, t)  # sigma ~1.6% at p=12


def test_hll_merge_is_union():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 1000, 5000).astype(np.uint64)
    b = rng.integers(500, 1500, 5000).astype(np.uint64)
    import jax.numpy as jnp

    ha = (splitmix64(a) >> np.uint64(32)).astype(np.uint32)
    hb = (splitmix64(b) >> np.uint64(32)).astype(np.uint32)
    ra = hll_registers(jnp.asarray(ha), 10)
    rb = hll_registers(jnp.asarray(hb), 10)
    merged = float(hll_estimate(hll_merge(ra, rb)))
    true_union = exact_ndv(np.concatenate([a, b]))
    assert abs(merged - true_union) / true_union < 0.12


def test_cvm_reasonable():
    rng = np.random.default_rng(2)
    vals = rng.integers(0, 5000, 20000)
    t = exact_ndv(vals)
    est = cvm_ndv(vals, buffer_size=2048, seed=3)
    assert abs(est - t) / t < 0.15


@given(st.integers(10, 2000), st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_gee_at_full_sample_is_exactish(ndv, seed):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, ndv, ndv * 4)
    t = exact_ndv(vals)
    # full sample: GEE = f1*1 + rest = number of distincts
    assert sampling_gee(vals, vals.size) == pytest.approx(t)
    assert sampling_chao(vals, vals.size) >= t - 1e-6


def test_splitmix_deterministic_and_spread():
    x = np.arange(1 << 12, dtype=np.uint64)
    h1, h2 = splitmix64(x), splitmix64(x)
    assert np.array_equal(h1, h2)
    # top bytes roughly uniform
    tops = (h1 >> np.uint64(56)).astype(np.int64)
    counts = np.bincount(tops, minlength=256)
    assert counts.std() / counts.mean() < 0.3
