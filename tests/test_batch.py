"""Batched estimate RPC: super-pack execution, per-tuple caching, routing.

Pins the acceptance criteria of the batched tier:
  * `superpack_estimate` answers bit-identically to the per-catalog
    sequential path, with exactly one engine dispatch per cold
    (engine, mode, width) group, and writes back through the same
    per-catalog estimate caches
  * `StatsService.batch` keeps per-tuple `/estimate` semantics — shared
    ETags (byte-for-byte on unfiltered tuples), per-tuple 304s/400s,
    bodies equal to the sequential endpoint — while all cold tuples of a
    batch run as ONE engine call
  * the fleet's `POST /batch` spans datasets, answers per-tuple errors in
    place, and keeps 304s valid across a replica kill mid-stream
  * `RemoteReplica` carries schema bounds for hostile column names
    (containing the `:` / `,` delimiters) without corruption
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.catalog import StatsCatalog, SuperpackJob, superpack_estimate
from repro.columnar.writer import WriterOptions, write_file
from repro.fleet import (
    DatasetRegistry,
    Fleet,
    LocalReplica,
    RemoteReplica,
    ReplicaSet,
    StatsRequest,
    StatsRouter,
)
from repro.service import (
    EstimateQuery,
    StatsServer,
    StatsService,
    format_bounds,
    parse_bounds,
)
from repro.wire import ConnectionPool, fetch


def _write(root, name, seed, vocab=64, columns=("tok", "val")):
    rng = np.random.default_rng(seed)
    data = {}
    for col in columns:
        if col.startswith("tok") or ":" in col or "," in col:
            data[col] = rng.integers(0, vocab, 512).astype(np.int64)
        else:
            data[col] = np.round(rng.uniform(0, 100, 512), 1)
    return write_file(
        os.path.join(root, name), data,
        options=WriterOptions(row_group_size=128),
    )


@pytest.fixture()
def roots(tmp_path):
    out = {}
    for name, seed in (("a", 1), ("b", 2)):
        root = str(tmp_path / name)
        for i in range(2):
            _write(root, f"shard_{i:03d}", seed=seed * 10 + i)
        out[name] = root
    return out


# -- superpack seam -----------------------------------------------------------


def test_superpack_matches_sequential_and_counts_dispatches(roots):
    cat_a = StatsCatalog(roots["a"])
    cat_b = StatsCatalog(roots["b"])
    for c in (cat_a, cat_b):
        c.update()
    jobs = [
        SuperpackJob(cat_a),
        SuperpackJob(cat_b, mode="improved"),
        SuperpackJob(cat_a, schema_bounds={"tok": 10.0}),
        SuperpackJob(cat_b),
    ]
    result = superpack_estimate(jobs)
    # two mode groups (paper, improved) over identical widths -> exactly
    # two engine dispatches for four cold jobs
    assert result.cold_jobs == 4
    assert result.engine_calls == 2
    # bit-identical to the sequential path (fresh catalogs, so the
    # reference estimates below are their own cold computations)
    ref_a = StatsCatalog(roots["a"])
    ref_b = StatsCatalog(roots["b"])
    for ref in (ref_a, ref_b):
        ref.update()
    assert result.estimates[0] == ref_a.estimate()
    assert result.estimates[1] == ref_b.estimate(mode="improved")
    assert result.estimates[2] == ref_a.estimate(schema_bounds={"tok": 10.0})
    assert result.estimates[3] == ref_b.estimate()


def test_superpack_warm_rerun_and_cache_writeback(roots):
    cat = StatsCatalog(roots["a"])
    cat.update()
    jobs = [SuperpackJob(cat), SuperpackJob(cat, mode="improved")]
    first = superpack_estimate(jobs)
    # paper and improved are distinct dispatch groups
    assert first.engine_calls == 2
    assert first.cold_jobs == 2

    second = superpack_estimate(jobs)
    assert second.engine_calls == 0
    assert second.cold_jobs == 0
    assert second.estimates == first.estimates

    # write-back: the catalog's own sequential path is now a cache hit
    misses = cat.stats.estimate_cache_misses
    assert cat.estimate(mode="improved") == first.estimates[1]
    assert cat.stats.estimate_cache_misses == misses


# -- service batch ------------------------------------------------------------


def test_service_batch_per_tuple_semantics(roots):
    with StatsService(roots["a"]) as svc:
        queries = [
            EstimateQuery(),
            EstimateQuery(mode="improved"),
            EstimateQuery(columns=("tok",)),
            EstimateQuery(schema_bounds={"tok": 8.0}),
            EstimateQuery(mode="nope"),
            EstimateQuery(columns=("missing",)),
        ]
        out = svc.batch(queries)
        assert [r.status for r in out] == [200, 200, 200, 200, 400, 400]

        # unfiltered tuple == the sequential endpoint, byte-for-byte etag
        seq = svc.estimate()
        assert out[0].etag == seq.etag
        assert out[0].body == seq.body

        # filtered tuple: narrowed body, distinct etag, columns echoed
        assert set(out[2].body["estimates"]) == {"tok"}
        assert out[2].body["columns"] == ["tok"]
        assert out[2].etag != out[0].etag

        # per-tuple 304s on re-send
        revalidate = [
            q._replace(if_none_match=r.etag)
            for q, r in zip(queries[:4], out[:4])
        ]
        again = svc.batch(revalidate)
        assert [r.status for r in again] == [304] * 4
        assert [r.etag for r in again] == [r.etag for r in out[:4]]
        assert all(r.body is None for r in again)


def test_service_batch_cold_tuples_share_one_engine_call(roots):
    with StatsService(roots["a"]) as svc:
        assert svc.stats.engine_runs == 0
        out = svc.batch([
            EstimateQuery(),
            EstimateQuery(schema_bounds={"tok": 16.0}),
            EstimateQuery(schema_bounds={"val": 50.0}),
        ])
        assert [r.status for r in out] == [200, 200, 200]
        # three cold tuples, one mode, one width -> ONE engine dispatch
        assert svc.stats.engine_runs == 1
        assert svc.stats.single_flight_leaders == 3


def test_service_batch_duplicates_coalesce_in_batch(roots):
    with StatsService(roots["a"]) as svc:
        out = svc.batch([EstimateQuery(), EstimateQuery()])
        assert [r.status for r in out] == [200, 200]
        assert out[0].body == out[1].body
        assert svc.stats.coalesced_waits == 1
        assert svc.stats.single_flight_leaders == 1
        assert svc.stats.engine_runs == 1


def test_http_batch_envelope_json_binary_identical(roots):
    with StatsServer(StatsService(roots["a"])) as srv:
        pool = ConnectionPool()
        payload = {"tuples": [
            {},
            {"mode": "improved"},
            {"columns": ["tok"], "bounds": {"tok": 8.0}},
        ]}
        sj, _, envj = fetch(srv.url + "/batch", pool=pool,
                            method="POST", payload=payload, binary=False)
        sw, _, envw = fetch(srv.url + "/batch", pool=pool,
                            method="POST", payload=payload, binary=True)
        assert (sj, sw) == (200, 200)
        assert envj == envw
        assert [e["status"] for e in envj["responses"]] == [200, 200, 200]
        # bounds accepted in query-string syntax too
        s2, _, env2 = fetch(
            srv.url + "/batch", pool=pool, method="POST",
            payload={"tuples": [{"columns": ["tok"], "bounds": "tok:8"}]},
        )
        assert env2["responses"][0] == envj["responses"][2]


def test_http_batch_rejects_junk(roots):
    with StatsServer(StatsService(roots["a"])) as srv:
        pool = ConnectionPool()
        for payload in (
            {"tuples": "nope"},
            {"tuples": [{"unknown_field": 1}]},
            {"tuples": [{"bounds": 7}]},
        ):
            status, _, body = fetch(srv.url + "/batch", pool=pool,
                                    method="POST", payload=payload)
            assert status == 400 and "error" in body


# -- fleet batch --------------------------------------------------------------


def test_router_batch_spans_datasets_with_per_tuple_errors(roots):
    reg = DatasetRegistry()
    reg.add("wh", "a", roots["a"])
    reg.add("wh", "b", roots["b"])
    fleet = Fleet(reg, replicas_per_dataset=2)
    with StatsRouter(fleet) as router:
        pool = ConnectionPool()
        tuples = [
            {"namespace": "wh", "dataset": "a"},
            {"namespace": "wh", "dataset": "b", "mode": "improved"},
            {"namespace": "wh", "dataset": "a", "columns": ["tok"]},
            {"namespace": "wh", "dataset": "ghost"},
        ]
        status, _, env = fetch(router.url + "/batch", pool=pool,
                               method="POST", payload={"tuples": tuples})
        assert status == 200
        statuses = [e["status"] for e in env["responses"]]
        assert statuses == [200, 200, 200, 404]

        # unfiltered tuple validates against the routed singleton endpoint
        s1, etag1, body1 = fetch(
            router.url + "/wh/a/estimate", pool=pool, binary=False
        )
        assert (s1, etag1) == (200, env["responses"][0]["etag"])
        assert body1 == env["responses"][0]["body"]

        # per-tuple 304s, surviving a replica kill mid-stream
        revalidate = [dict(t) for t in tuples[:3]]
        for t, e in zip(revalidate, env["responses"]):
            t["if_none_match"] = e["etag"]
        fleet.sets["wh/a"].replicas[0].kill()
        status, _, env2 = fetch(router.url + "/batch", pool=pool,
                                method="POST",
                                payload={"tuples": revalidate})
        assert status == 200
        assert [e["status"] for e in env2["responses"]] == [304, 304, 304]
        assert [e["etag"] for e in env2["responses"]] == [
            e["etag"] for e in env["responses"][:3]
        ]
        assert fleet.stats.batches == 2
        assert fleet.stats.batch_tuples == 7


def test_call_batch_all_replicas_down_answers_503_in_place(roots):
    replicas = [
        LocalReplica(f"r{i}", roots["a"]).start() for i in range(2)
    ]
    rset = ReplicaSet("wh/a", replicas)
    try:
        for r in replicas:
            r.kill()
        out, _ = rset.call_batch([
            StatsRequest("estimate"),
            StatsRequest("estimate", mode="improved"),
        ])
        assert [r.status for r in out] == [503, 503]
        assert all("failed" in r.body["error"] for r in out)
    finally:
        for r in replicas:
            r.stop()


def test_request_identity_stable_without_columns():
    # pre-existing rendezvous placements must not move: the identity tuple
    # only grows when a columns filter is actually present
    plain = StatsRequest("estimate", mode="improved")
    assert plain.identity == ("estimate", "improved", ())
    filtered = StatsRequest("estimate", columns=("tok",))
    assert filtered.identity == ("estimate", "paper", (), ("tok",))


# -- hostile-name bounds serialization (regression) ---------------------------

HOSTILE = "w:eird,col"


def test_format_parse_bounds_roundtrip_hostile_names():
    bounds = {HOSTILE: 3.0, "a,b": 2.0, "c:d": 1.5, "plain": 9.0}
    assert parse_bounds(format_bounds(bounds)) == bounds
    # plain names keep the readable unescaped form
    assert format_bounds({"plain": 9.0}) == "plain:9.0"


def test_remote_replica_carries_hostile_bounds(tmp_path):
    root = str(tmp_path / "hostile")
    _write(root, "s0", seed=3, columns=("tok", HOSTILE))
    with StatsServer(StatsService(root)) as srv:
        replica = RemoteReplica("r0", srv.url)
        try:
            resp = replica.handle(StatsRequest(
                "estimate", schema_bounds=((HOSTILE, 3.0),)
            ))
            assert resp.status == 200
            # the bound arrived intact and applied to the right column
            assert resp.body["schema_bounds"] == {HOSTILE: 3.0}
            assert resp.body["estimates"][HOSTILE]["ndv"] <= 3.0
            # and the unbounded estimate differs (the bound did something)
            free = replica.handle(StatsRequest("estimate"))
            assert free.body["estimates"][HOSTILE]["ndv"] > 3.0
        finally:
            replica.stop()


def test_remote_replica_batch_roundtrip(roots):
    with StatsServer(StatsService(roots["a"])) as srv:
        replica = RemoteReplica("r0", srv.url)
        try:
            reqs = [
                StatsRequest("estimate"),
                StatsRequest("estimate", mode="improved",
                             schema_bounds=(("tok", 8.0),)),
                StatsRequest("estimate", columns=("val",)),
            ]
            out = replica.handle_batch(reqs)
            assert [r.status for r in out] == [200, 200, 200]
            assert set(out[2].body["estimates"]) == {"val"}
            # one keep-alive socket carried the whole exchange
            assert replica.pool.stats.snapshot()["opened"] == 1
            again = replica.handle_batch([
                dataclasses.replace(r, if_none_match=o.etag)
                for r, o in zip(reqs, out)
            ])
            assert [r.status for r in again] == [304, 304, 304]
        finally:
            replica.stop()
