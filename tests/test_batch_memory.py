"""Batch memory prediction (paper §8) — unit + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.ndv.batch_memory import expected_batch_dictionary, predict_batch_memory
from repro.core.ndv.types import Layout


def test_eq16_against_simulation():
    rng = np.random.default_rng(0)
    ndv, mean_len = 1000, 8.0
    batch_bytes = 16384
    rows_per_batch = int(batch_bytes / mean_len)
    sims = []
    for _ in range(200):
        draw = rng.integers(0, ndv, rows_per_batch)
        sims.append(np.unique(draw).size * mean_len)
    pred = float(expected_batch_dictionary(
        jnp.float32(batch_bytes), jnp.float32(ndv * mean_len)
    ))
    assert abs(np.mean(sims) - pred) / pred < 0.02


@given(
    ndv=st.floats(1, 1e7),
    mean_len=st.floats(1, 128),
    rows=st.floats(1e3, 1e9),
    batch_mb=st.floats(0.1, 512),
)
@settings(max_examples=60, deadline=None)
def test_properties(ndv, mean_len, rows, batch_mb):
    batch = batch_mb * 1e6
    out = predict_batch_memory(
        jnp.asarray([ndv], jnp.float32),
        jnp.asarray([mean_len], jnp.float32),
        jnp.asarray([rows], jnp.float32),
        float(batch),
    )
    d_global = float(out.d_global[0])
    d_batch = float(out.d_batch[0])
    # 0 <= D_batch <= min(D_global, B)
    assert -1e-3 <= d_batch <= min(d_global, batch) * (1 + 1e-4) + 1e-3
    # totals: n_batches * d_batch
    assert abs(float(out.d_total[0]) - float(out.n_batches[0]) * d_batch) < 1e-2 * max(float(out.d_total[0]), 1)


def test_sorted_uses_conservative_bound():
    out = predict_batch_memory(
        jnp.asarray([1e6], jnp.float32),
        jnp.asarray([8.0], jnp.float32),
        jnp.asarray([1e8], jnp.float32),
        1e6,
        layout=jnp.asarray([int(Layout.SORTED)], jnp.int32),
    )
    # conservative: min(D_global, B) = 1e6 (B), not the Eq16 expectation
    assert abs(float(out.d_batch[0]) - 1e6) < 1.0


def test_batch_monotone_in_batch_size():
    sizes = [1e4, 1e5, 1e6, 1e7]
    preds = [
        float(expected_batch_dictionary(jnp.float32(b), jnp.float32(8e6)))
        for b in sizes
    ]
    assert all(b > a for a, b in zip(preds, preds[1:]))
