"""benchmarks/compare.py + the BENCH artifact schema from benchmarks/run.py."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import compare, run  # noqa: E402


def _bench(path, rows, quick=False):
    payload = {
        "quick": quick,
        "git_sha": "cafe" * 10,
        "generated_at": "2026-08-08T00:00:00+00:00",
        "rows": [
            {"name": n, "us_per_call": us, "derived": ""} for n, us in rows
        ],
        "errors": [],
    }
    path.write_text(json.dumps(payload))
    return str(path)


def test_regression_over_threshold_exits_nonzero(tmp_path, capsys):
    base = _bench(tmp_path / "a.json", [("k/x", 100.0), ("k/y", 50.0)])
    new = _bench(tmp_path / "b.json", [("k/x", 130.0), ("k/y", 50.0)])
    assert compare.main([base, new]) == 1
    out = capsys.readouterr().out
    assert "REGRESSION k/x" in out


def test_within_threshold_exits_zero(tmp_path):
    base = _bench(tmp_path / "a.json", [("k/x", 100.0), ("k/y", 50.0)])
    new = _bench(tmp_path / "b.json", [("k/x", 115.0), ("k/y", 41.0)])
    assert compare.main([base, new]) == 0


def test_custom_threshold(tmp_path):
    base = _bench(tmp_path / "a.json", [("k/x", 100.0)])
    new = _bench(tmp_path / "b.json", [("k/x", 115.0)])
    assert compare.main(["--threshold", "0.1", base, new]) == 1
    assert compare.main(["--threshold", "0.5", base, new]) == 0


def test_unmatched_rows_never_fail(tmp_path, capsys):
    base = _bench(tmp_path / "a.json", [("k/old", 100.0), ("k/x", 10.0)])
    new = _bench(tmp_path / "b.json", [("k/new", 9999.0), ("k/x", 10.0)])
    assert compare.main([base, new]) == 0
    out = capsys.readouterr().out
    assert "k/old" in out and "k/new" in out


def test_sub_microsecond_rows_are_skipped(tmp_path):
    base = _bench(tmp_path / "a.json", [("k/tiny", 0.2)])
    new = _bench(tmp_path / "b.json", [("k/tiny", 0.9)])  # 4.5x, all jitter
    assert compare.main([base, new]) == 0


def test_quick_vs_full_is_refused(tmp_path):
    base = _bench(tmp_path / "a.json", [("k/x", 100.0)], quick=True)
    new = _bench(tmp_path / "b.json", [("k/x", 100.0)], quick=False)
    assert compare.main([base, new]) == 2


def test_bad_usage_exits_2(tmp_path):
    assert compare.main([]) == 2
    base = _bench(tmp_path / "a.json", [("k/x", 1.0)])
    assert compare.main(["--threshold", "nope", base, base]) == 2


def test_run_payload_carries_sha_and_timestamp():
    payload = run.build_payload(
        [{"name": "k/x", "us_per_call": 1.0, "derived": ""}], []
    )
    assert payload["rows"] and payload["errors"] == []
    # In this repo checkout the SHA is a real 40-hex commit.
    sha = payload["git_sha"]
    assert sha == "unknown" or (len(sha) == 40 and int(sha, 16) >= 0)
    # ISO-8601 with explicit UTC offset.
    assert "T" in payload["generated_at"]
    assert payload["generated_at"].endswith("+00:00")


def test_compare_round_trips_run_schema(tmp_path):
    payload = run.build_payload(
        [{"name": "k/x", "us_per_call": 10.0, "derived": "d=1"}], []
    )
    p = tmp_path / "r.json"
    p.write_text(json.dumps(payload))
    rows, quick = compare.load_rows(str(p))
    assert rows == {"k/x": 10.0}
    assert quick is False


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
