"""StatsCatalog subsystem: packing, caching, incremental ingestion, parity.

Covers the acceptance criteria of the catalog refactor:
  * catalog estimates == estimate_columns on the merged metadata (exact)
  * warm calls perform no re-packing and hit the estimate cache
  * update() ingests only new/changed footers and merges incrementally
  * shape bucketing keeps jit traces shared across nearby shapes
  * the vectorized packer reproduces the legacy per-column loop bit-exactly
  * estimate_file threads mode through to the estimator
"""
import dataclasses

import numpy as np
import pytest

from repro.catalog import (
    BatchPacker,
    InMemoryMetadataSource,
    StatsCatalog,
    bucket_size,
)
from repro.columnar import read_footer, write_file
from repro.columnar.writer import WriterOptions
from repro.core import estimate_columns, estimate_file
from repro.core.ndv.estimator import estimate_batch
from repro.core.ndv.types import ColumnBatch, ColumnMetadata, PhysicalType


def _shard(seed, rows=512, vocab=64):
    rng = np.random.default_rng(seed)
    return {
        "tok": rng.integers(0, vocab, rows).astype(np.int64),
        "val": np.round(rng.uniform(0, 100, rows), 1),
        "tag": rng.choice(np.array(["red", "green", "blue", "cyan"]), rows),
    }


@pytest.fixture()
def dataset(tmp_path):
    for i in range(3):
        write_file(
            str(tmp_path / f"shard_{i:03d}"), _shard(i),
            options=WriterOptions(row_group_size=128),
        )
    return str(tmp_path)


def test_estimate_matches_estimate_columns_exactly(dataset):
    catalog = StatsCatalog(dataset)
    merged = catalog.merged_metadata()
    cols = [merged[n] for n in catalog.column_names]
    for mode in ("paper", "improved"):
        got = catalog.estimate(mode=mode)
        ref = {e.column_name: e for e in estimate_columns(cols, mode=mode)}
        assert got.keys() == ref.keys()
        for name in got:
            assert got[name] == ref[name], name


def test_warm_cache_no_repack_no_rescan(dataset):
    catalog = StatsCatalog(dataset)
    first = catalog.estimate(mode="improved")
    assert catalog.stats.packs == 1
    assert catalog.stats.estimate_cache_misses == 1
    second = catalog.estimate(mode="improved")
    assert second == first
    assert catalog.stats.packs == 1               # no re-pack
    assert catalog.stats.estimate_cache_hits == 1
    # a different mode re-estimates but still reuses the packed batch
    catalog.estimate(mode="paper")
    assert catalog.stats.packs == 1
    assert catalog.stats.estimate_cache_misses == 2


def test_incremental_update_reads_only_new_footers(dataset, tmp_path):
    catalog = StatsCatalog(dataset)
    catalog.estimate()
    reads = catalog.stats.footers_read
    assert reads == 3
    key_before = catalog.fingerprint_key()

    write_file(
        str(tmp_path / "shard_099"), _shard(99),
        options=WriterOptions(row_group_size=128),
    )
    summary = catalog.update()
    assert summary.added == 1 and summary.updated == 0 and summary.removed == 0
    assert catalog.stats.footers_read == reads + 1   # only the new footer
    assert catalog.fingerprint_key() != key_before
    assert catalog.num_files == 4

    # merged view covers the new chunks; estimates recompute (cache miss)
    misses = catalog.stats.estimate_cache_misses
    ests = catalog.estimate()
    assert catalog.stats.estimate_cache_misses == misses + 1
    merged = catalog.merged_metadata()
    assert merged["tok"].num_row_groups == 16  # 4 files x 4 row groups
    cols = [merged[n] for n in catalog.column_names]
    ref = {e.column_name: e for e in estimate_columns(cols)}
    for name in ests:
        assert ests[name] == ref[name]


def test_update_detects_rewrites_via_fingerprint():
    f0 = write_file_footer(_shard(0))
    f1 = write_file_footer(_shard(1))
    src = InMemoryMetadataSource({"a": f0, "b": f1})
    catalog = StatsCatalog(src)
    before = catalog.estimate()
    src.add("a", write_file_footer(_shard(7)))  # rewrite file "a"
    summary = catalog.update()
    assert summary.updated == 1 and summary.added == 0
    after = catalog.estimate()
    assert catalog.stats.estimate_cache_misses == 2
    assert set(after) == set(before)


def test_failed_update_preserves_consistent_state(dataset, tmp_path):
    catalog = StatsCatalog(dataset)
    before = catalog.estimate()
    files_before = catalog.num_files
    # a schema-mismatched file arrives: update() must fail...
    write_file(
        str(tmp_path / "shard_bad"), {"other": np.arange(64)},
        options=WriterOptions(row_group_size=32),
    )
    with pytest.raises(ValueError, match="schema"):
        catalog.update()
    # ...and every subsequent retry must fail the same way (the bad file's
    # fingerprint must not be committed as 'seen'),
    with pytest.raises(ValueError, match="schema"):
        catalog.update()
    # ...while the previous consistent view keeps serving.
    assert catalog.num_files == files_before
    assert catalog.estimate() == before


def test_schema_mismatch_raises_regardless_of_order(tmp_path):
    write_file(str(tmp_path / "a"), {"x": np.arange(50), "y": np.arange(50)})
    write_file(str(tmp_path / "b"), {"x": np.arange(50)})
    with pytest.raises(ValueError, match="missing columns \\['y'\\]"):
        StatsCatalog(str(tmp_path)).estimate()
    # reversed listing order: the extra-column direction must also raise,
    # not silently drop column y from the dataset view
    f_a = read_footer(str(tmp_path / "a"))
    f_b = read_footer(str(tmp_path / "b"))
    with pytest.raises(ValueError, match="unexpected columns \\['y'\\]"):
        StatsCatalog(InMemoryMetadataSource({"1b": f_b, "2a": f_a})).estimate()


def test_update_add_remove_rewrite_in_one_refresh():
    """One refresh covering all three change kinds reports them all —
    and matches the async ingestion path's semantics (see test_service)."""
    src = InMemoryMetadataSource({
        "a": write_file_footer(_shard(1)),
        "b": write_file_footer(_shard(2)),
        "c": write_file_footer(_shard(3)),
    })
    catalog = StatsCatalog(src)
    assert catalog.update() == (3, 0, 0, 3)
    src.add("d", write_file_footer(_shard(4)))   # add
    src.remove("b")                              # remove
    src.add("c", write_file_footer(_shard(33)))  # rewrite
    summary = catalog.update()
    assert summary == (1, 1, 1, 3)
    assert summary.changed
    assert set(catalog.files) == {"a", "c", "d"}
    assert catalog.estimate() == StatsCatalog(src).estimate()
    # steady state afterwards: nothing to report
    assert catalog.update() == (0, 0, 0, 3)


class _VanishingSource(InMemoryMetadataSource):
    """Lists a file whose fingerprint/footer read then fails: the race of a
    deletion landing between the listing and the stat."""

    def __init__(self, footers, vanished=()):
        super().__init__(footers)
        self.vanished = set(vanished)

    def list_files(self):
        return sorted(set(super().list_files()) | self.vanished)

    def fingerprint(self, file_id):
        if file_id in self.vanished:
            raise FileNotFoundError(file_id)
        return super().fingerprint(file_id)


def test_update_reports_vanished_files_as_removed():
    src = _VanishingSource({
        "a": write_file_footer(_shard(1)),
        "b": write_file_footer(_shard(2)),
    })
    catalog = StatsCatalog(src)
    assert catalog.update() == (2, 0, 0, 2)
    # "b" is deleted but still shows up in the listing
    footer_b = src.read_footer("b")
    src.remove("b")
    src.vanished.add("b")
    summary = catalog.update()
    assert summary == (0, 0, 1, 1)
    assert catalog.files == ["a"]
    # a vanished file that was never ingested is not reported as anything
    src.vanished.add("ghost")
    assert catalog.update() == (0, 0, 0, 1)
    # and reappearing is an ordinary addition
    src.vanished.remove("b")
    src.add("b", footer_b)
    assert catalog.update() == (1, 0, 0, 2)


def test_apply_footers_rejects_unknown_live_id():
    src = InMemoryMetadataSource({"a": write_file_footer(_shard(1))})
    catalog = StatsCatalog(src)
    catalog.update()
    with pytest.raises(ValueError, match="neither a previous"):
        catalog.apply_footers([], live_ids=["a", "mystery"])


# -- persistent-cache hygiene ------------------------------------------------


def test_save_cache_compacts_stale_fingerprint_sets(dataset, tmp_path):
    import json

    catalog = StatsCatalog(dataset)
    catalog.estimate(mode="paper")
    write_file(
        str(tmp_path / "shard_000"), _shard(42),   # rewrite one file
        options=WriterOptions(row_group_size=128),
    )
    catalog.update()
    catalog.estimate(mode="paper")
    catalog.estimate(mode="improved")
    assert len(catalog._estimate_cache) == 3       # 1 stale + 2 live
    path = catalog.save_cache()
    with open(path) as f:
        entries = json.load(f)["entries"]
    live = sorted(catalog.fingerprint_key())
    assert len(entries) == 2                       # stale entry dropped
    assert all(e["key"]["files"] == live for e in entries)
    # opting out persists the LRU verbatim
    catalog.save_cache(compact=False)
    with open(path) as f:
        assert len(json.load(f)["entries"]) == 3

    # in-memory hook drops the same stale entries (plus the stale batch
    # and the stale provenance sidecar, which is keyed like the estimates)
    assert catalog.compact_caches() == 3     # 1 estimate + 1 batch + 1 prov
    assert len(catalog._estimate_cache) == 2
    assert len(catalog._provenance_cache) == 2


def test_auto_load_cache_serves_warm_and_is_mtime_guarded(dataset):
    import os

    first = StatsCatalog(dataset)
    expected = first.estimate(mode="improved")
    path = first.save_cache()

    warm = StatsCatalog(dataset, auto_load_cache=True)
    got = warm.estimate(mode="improved")
    assert got == expected
    assert warm.stats.packs == 0                   # served from the spill
    assert warm.stats.estimate_cache_hits == 1
    # unchanged file -> guarded no-op; touched file -> reloaded
    assert warm.maybe_load_cache() == 0
    os.utime(path, ns=(os.stat(path).st_atime_ns, os.stat(path).st_mtime_ns + 1))
    assert warm.maybe_load_cache() == 1
    # missing file is a quiet cold start
    os.remove(path)
    assert StatsCatalog(dataset, auto_load_cache=True).maybe_load_cache() == 0


def write_file_footer(cols, rg=128):
    import tempfile

    d = tempfile.mkdtemp()
    return write_file(d, cols, options=WriterOptions(row_group_size=rg))


# -- packer ------------------------------------------------------------------


def _legacy_pack(cols):
    """The historical per-column Python loop, kept as a reference oracle."""
    import jax.numpy as jnp

    b = len(cols)
    r = max(max((c.num_row_groups for c in cols), default=1), 1)
    f = lambda: np.zeros((b,), np.float32)  # noqa: E731
    g = lambda: np.zeros((b, r), np.float32)  # noqa: E731
    chunk_S, chunk_rows, chunk_nulls = g(), g(), g()
    chunk_dict = np.zeros((b, r), bool)
    N, nulls, m_min, m_max, mean_len = f(), f(), f(), f(), f()
    n_groups = np.zeros((b,), np.int32)
    len_sample = np.zeros((b,), np.int32)
    mins, maxs = g(), g()
    valid = np.zeros((b, r), bool)
    fixed_width = np.zeros((b,), bool)
    int_like = np.zeros((b,), bool)
    single_byte = np.zeros((b,), bool)
    for i, c in enumerate(cols):
        n = c.num_row_groups
        chunk_S[i, :n] = np.asarray(c.chunk_sizes, np.float32)
        chunk_rows[i, :n] = np.asarray(c.chunk_rows, np.float32)
        chunk_nulls[i, :n] = np.asarray(c.chunk_nulls, np.float32)
        chunk_dict[i, :n] = np.asarray(c.chunk_dict_encoded, bool)
        N[i] = c.num_values
        nulls[i] = c.null_count
        n_groups[i] = n
        mins[i, :n] = np.asarray(c.mins, np.float32)[:n]
        maxs[i, :n] = np.asarray(c.maxs, np.float32)[:n]
        valid[i, :n] = True
        m_min[i] = c.distinct_min_count
        m_max[i] = c.distinct_max_count
        w = c.physical_type.fixed_width
        if w is not None:
            mean_len[i] = float(w)
            len_sample[i] = n * 2
            fixed_width[i] = True
        elif n == 1:
            mean_len[i] = float(
                (float(c.min_lengths[0]) + float(c.max_lengths[0])) / 2.0
            )
            len_sample[i] = 2
        else:
            lens = np.concatenate([
                np.asarray(c.min_lengths, np.float64)[:n],
                np.asarray(c.max_lengths, np.float64)[:n],
            ])
            mean_len[i] = float(lens.mean()) if lens.size else 1.0
            len_sample[i] = int(c.distinct_min_count + c.distinct_max_count)
        int_like[i] = c.physical_type.is_integer_like
        single_byte[i] = (
            c.physical_type == PhysicalType.BYTE_ARRAY
            and float(np.max(np.asarray(c.max_lengths)[:n], initial=0.0)) <= 1.0
        )
    J = jnp.asarray
    return ColumnBatch(
        chunk_S=J(chunk_S), chunk_rows=J(chunk_rows),
        chunk_nulls=J(chunk_nulls), chunk_dict_encoded=J(chunk_dict),
        N=J(N), nulls=J(nulls), n_groups=J(n_groups),
        mins=J(mins), maxs=J(maxs), valid=J(valid),
        m_min=J(m_min), m_max=J(m_max), mean_len=J(mean_len),
        len_sample=J(len_sample), fixed_width=J(fixed_width),
        int_like=J(int_like), single_byte=J(single_byte),
    )


def _mixed_columns(dataset):
    catalog = StatsCatalog(dataset)
    merged = catalog.merged_metadata()
    cols = [merged[n] for n in catalog.column_names]
    # add a ragged single-group column and an all-null-length corner
    rng = np.random.default_rng(3)
    cols.append(ColumnMetadata(
        chunk_sizes=np.array([512.0]),
        chunk_rows=np.array([100.0]),
        chunk_nulls=np.array([4.0]),
        chunk_dict_encoded=np.array([True]),
        mins=np.array([3.0]),
        maxs=np.array([9.0]),
        min_lengths=np.array([2.0]),
        max_lengths=np.array([6.0]),
        distinct_min_count=1.0,
        distinct_max_count=1.0,
        physical_type=PhysicalType.BYTE_ARRAY,
        column_name="ragged",
    ))
    return cols


def test_vectorized_packer_matches_legacy_loop(dataset):
    cols = _mixed_columns(dataset)
    got = BatchPacker(bucket_rows=False, bucket_cols=False).pack(cols)
    ref = _legacy_pack(cols)
    for field in dataclasses.fields(ColumnBatch):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field.name)),
            np.asarray(getattr(ref, field.name)),
            err_msg=field.name,
        )
    # from_columns is the same unbucketed path
    fc = ColumnBatch.from_columns(cols)
    np.testing.assert_array_equal(np.asarray(fc.chunk_S), np.asarray(ref.chunk_S))


def test_bucketed_pack_is_masked_superset(dataset):
    cols = _mixed_columns(dataset)
    plain = BatchPacker(bucket_rows=False, bucket_cols=False).pack(cols)
    bucketed = BatchPacker().pack(cols)
    b, r = plain.batch, plain.max_groups
    assert bucketed.batch == bucket_size(b)
    assert bucketed.max_groups == bucket_size(r, 8)
    for field in dataclasses.fields(ColumnBatch):
        got = np.asarray(getattr(bucketed, field.name))
        ref = np.asarray(getattr(plain, field.name))
        sliced = got[:b, :r] if got.ndim == 2 else got[:b]
        np.testing.assert_array_equal(sliced, ref, err_msg=field.name)
    # padding lanes are fully masked
    assert not np.asarray(bucketed.valid)[b:].any()
    assert not np.asarray(bucketed.valid)[:, r:].any()
    assert (np.asarray(bucketed.n_groups)[b:] == 0).all()


def test_bucketing_shares_jit_traces(dataset):
    cols = _mixed_columns(dataset)
    base = cols[0]
    packer = BatchPacker()
    shapes = set()
    before = estimate_batch._cache_size()
    for r in (9, 11, 13, 16):
        trimmed = dataclasses.replace(
            base,
            chunk_sizes=np.resize(np.asarray(base.chunk_sizes), r),
            chunk_rows=np.resize(np.asarray(base.chunk_rows), r),
            chunk_nulls=np.resize(np.asarray(base.chunk_nulls), r),
            chunk_dict_encoded=np.resize(np.asarray(base.chunk_dict_encoded), r),
            mins=np.resize(np.asarray(base.mins), r),
            maxs=np.resize(np.asarray(base.maxs), r),
            min_lengths=np.resize(np.asarray(base.min_lengths), r),
            max_lengths=np.resize(np.asarray(base.max_lengths), r),
            min_reprs=None,
            max_reprs=None,
        )
        batch = packer.pack([trimmed])
        shapes.add((batch.batch, batch.max_groups))
        estimate_batch(batch, mode="paper")
    assert shapes == {(1, 16)}  # 9..16 row groups share one bucketed shape
    assert estimate_batch._cache_size() - before <= 1


def test_estimate_file_threads_mode(dataset):
    from repro.columnar.reader import column_metadata_from_footer, list_files

    footer = read_footer(list_files(dataset)[0])
    cols = [
        column_metadata_from_footer(footer, n) for n in footer.column_names
    ]
    for mode in ("paper", "improved"):
        got = estimate_file(footer, mode=mode)
        ref = estimate_columns(cols, mode=mode)
        assert got == ref


def test_schema_bounds_via_catalog(dataset):
    catalog = StatsCatalog(dataset)
    unbounded = catalog.estimate()
    bounded = catalog.estimate(schema_bounds={"tok": 10.0})
    assert bounded["tok"].ndv <= 10.0 < unbounded["tok"].ndv
    # other columns unaffected by someone else's bound
    assert bounded["val"].ndv == unbounded["val"].ndv


def test_pipeline_plans_through_catalog(dataset):
    from repro.data.pipeline import DataConfig, TokenPipeline

    pipe = TokenPipeline(DataConfig(root=dataset, token_column="tok"))
    ests = pipe.catalog.estimate(mode=pipe.cfg.mode)
    assert pipe.plan.estimates == ests
    assert set(pipe.plan.memory) == set(ests)
    assert pipe.plan.total_staging_bytes > 0
    assert pipe.vocab_estimate() is ests["tok"] or (
        pipe.vocab_estimate() == ests["tok"]
    )


def test_concurrent_save_cache_merges_not_clobbers(dataset):
    # Two catalogs (standing in for two replica processes) spill different
    # entries to the shared file: the union must survive, whichever order
    # the writes land in.
    import json

    a = StatsCatalog(dataset)
    b = StatsCatalog(dataset)
    a.estimate(mode="paper")
    b.estimate(mode="improved")
    path = a.save_cache()
    assert b.save_cache() == path
    with open(path) as f:
        entries = json.load(f)["entries"]
    assert sorted(e["key"]["mode"] for e in entries) == ["improved", "paper"]

    # a third cold catalog warms from the merged spill: both modes, no packs
    c = StatsCatalog(dataset, auto_load_cache=True)
    assert c.estimate(mode="paper") == a.estimate(mode="paper")
    assert c.estimate(mode="improved") == b.estimate(mode="improved")
    assert c.stats.packs == 0


def test_save_cache_skips_when_disk_is_newer_and_complete(dataset):
    import os

    a = StatsCatalog(dataset)
    b = StatsCatalog(dataset)
    a.estimate(mode="paper")
    b.estimate(mode="paper")
    b.estimate(mode="improved")
    path = b.save_cache()                  # b's spill is a superset of a's
    mtime = os.stat(path).st_mtime_ns
    a.save_cache()                         # nothing to add -> skipped
    assert os.stat(path).st_mtime_ns == mtime
    # with something new to contribute the write happens (and merges)
    a.estimate(mode="paper", schema_bounds={"tok": 8.0})
    a.save_cache()
    assert os.stat(path).st_mtime_ns != mtime
    fresh = StatsCatalog(dataset, auto_load_cache=True)
    assert fresh.estimate(mode="improved") == b.estimate(mode="improved")
    assert fresh.stats.packs == 0


def test_save_cache_survives_concurrent_thread_writers(dataset):
    # Hammer one spill path from many threads; every write must stay
    # atomic and the final file must contain every writer's entry.
    import json
    import os
    import threading

    catalogs = []
    bounds = [{"tok": float(2 ** i)} for i in range(6)]
    for sb in bounds:
        c = StatsCatalog(dataset)
        c.estimate(mode="paper", schema_bounds=sb)
        catalogs.append(c)
    threads = [
        threading.Thread(target=c.save_cache) for c in catalogs for _ in range(3)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    path = catalogs[0]._default_cache_path()
    with open(path) as f:
        entries = json.load(f)["entries"]   # parses: no torn writes
    got = {tuple(e["key"]["schema_bounds"][0]) for e in entries}
    assert got == {("tok", b["tok"]) for b in bounds}
    # no temp-file litter left next to the dataset
    litter = [f for f in os.listdir(dataset) if f.endswith(".tmp")]
    assert litter == []


def test_spill_with_foreign_shape_is_treated_as_absent(dataset):
    # Valid JSON, right version, wrong shape: loads as a cold start and
    # save_cache overwrites it rather than crashing replica boot.
    import json

    catalog = StatsCatalog(dataset)
    catalog.estimate(mode="paper")
    path = catalog._default_cache_path()
    for junk in ('{"version": 1}', '{"version": 1, "entries": [{}]}', "[1]"):
        with open(path, "w") as f:
            f.write(junk)
        assert StatsCatalog(dataset, auto_load_cache=True).load_cache() == 0
        assert catalog.save_cache() == path
        with open(path) as f:
            assert len(json.load(f)["entries"]) == 1
