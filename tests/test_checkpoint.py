"""Checkpoint manager: atomicity, async, GC, elastic restore."""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "c": jnp.ones((3,), jnp.bfloat16)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(7, t)
    step, got = mgr.restore(_tree(seed=1))
    assert step == 7
    for (p1, l1), (p2, l2) in zip(
        jax.tree_util.tree_flatten_with_path(t)[0],
        jax.tree_util.tree_flatten_with_path(got)[0],
    ):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_latest_pointer_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]


def test_async_save_durable(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_crash_invisible_staging(tmp_path):
    """A checkpoint is visible iff complete: a staging dir is ignored."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp0"))
    assert mgr.latest_step() == 1


def test_restore_casts_dtype(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((4,), jnp.float32)})
    _, got = mgr.restore({"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)})
    assert got["w"].dtype == jnp.bfloat16


def test_missing_leaf_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((4,))})
    with pytest.raises(KeyError):
        mgr.restore({"w": jnp.ones((4,)), "extra": jnp.ones((2,))})
