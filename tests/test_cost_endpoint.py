"""`/tablestats` + `/cost` end-to-end: both serving tiers, both encodings.

Covers the planner-tier serving acceptance criteria:
  * POST /cost on the single-dataset server returns the cheapest join
    order + per-join cardinalities for a >=6-table graph, scoring >=1000
    candidate plans in ONE batched JAX dispatch (asserted via the
    planner_* obs counters through the HTTP path)
  * /cost is a cacheable POST: strong state-derived ETag, If-None-Match
    304, tag rotation on dataset rewrite, explain identity-neutrality,
    byte-identical JSON and wire bodies, /batch carriage parity
  * the router's /cost combines per-dataset /tablestats ETags: 304s
    survive replica kills (tags are state-derived, replica-independent)
    and unknown datasets answer 404
"""
import json
import os

import numpy as np
import pytest

from repro.columnar.writer import WriterOptions, write_file
from repro.fleet import DatasetRegistry, Fleet, StatsRouter
from repro.service import StatsServer, StatsService, fetch_json
from repro.wire import ConnectionPool, fetch


def _write(root, name, seed, rows=256, vocab=64):
    rng = np.random.default_rng(seed)
    return write_file(
        os.path.join(root, name),
        {
            "tok": rng.integers(0, vocab, rows).astype(np.int64),
            "val": np.round(rng.uniform(0, 100, rows), 1),
        },
        options=WriterOptions(row_group_size=128),
    )


@pytest.fixture()
def dataset(tmp_path):
    root = str(tmp_path / "ds")
    for i in range(2):
        _write(root, f"shard_{i:03d}", seed=i)
    return root


@pytest.fixture()
def served(dataset):
    server = StatsServer(StatsService(dataset)).start()
    yield server
    server.stop()


@pytest.fixture()
def pool():
    p = ConnectionPool()
    yield p
    p.close()


def _post_json(url, payload, etag=None):
    return fetch(url, payload=payload, etag=etag, binary=False)


def _chain_graph(aliases, column="tok"):
    """Self-join chain over the served dataset: a0 - a1 - ... on `column`."""
    return {
        "tables": [{"name": a} for a in aliases],
        "edges": [
            {"left": aliases[i], "left_column": column,
             "right": aliases[i + 1], "right_column": column}
            for i in range(len(aliases) - 1)
        ],
    }


# -- single-dataset server ---------------------------------------------------


def test_cost_body_shape_and_etag(served):
    graph = _chain_graph(["a", "b", "c"])
    status, etag, body = _post_json(served.url + "/cost", {"graph": graph})
    assert status == 200 and etag and body["etag"] == etag
    assert sorted(body["best_order"]) == ["a", "b", "c"]
    assert len(body["joins"]) == 2
    for join in body["joins"]:
        assert join["cardinality"] > 0
        assert not join["cross_product"] and join["edges"]
        for e in join["edges"]:
            assert e["selectivity"] == pytest.approx(
                1.0 / max(e["ndv_left"], e["ndv_right"])
            )
    assert body["total_cost"] == pytest.approx(
        sum(j["cardinality"] for j in body["joins"])
    )
    assert body["plans_scored"] == 6 and body["enumeration"] == "exhaustive"
    # identity is listing-order-insensitive: same tag for a shuffled graph
    shuffled = {
        "tables": list(reversed(graph["tables"])),
        "edges": list(reversed(graph["edges"])),
    }
    status2, etag2, _ = _post_json(served.url + "/cost", {"graph": shuffled})
    assert status2 == 200 and etag2 == etag


def test_cost_revalidates_and_rotates_on_rewrite(served, dataset):
    graph = _chain_graph(["r", "s"])
    status, etag, _ = _post_json(served.url + "/cost", {"graph": graph})
    assert status == 200
    status2, etag2, body2 = _post_json(
        served.url + "/cost", {"graph": graph}, etag=etag
    )
    assert (status2, body2) == (304, None) and etag2 == etag
    # rewrite one shard -> refresh -> the old tag stops validating
    _write(dataset, "shard_000", seed=77)
    assert fetch_json(served.url + "/refresh", method="POST")[0] == 200
    status3, etag3, body3 = _post_json(
        served.url + "/cost", {"graph": graph}, etag=etag
    )
    assert status3 == 200 and etag3 != etag and body3["etag"] == etag3


def test_cost_wire_and_json_bodies_identical(served, pool):
    graph = _chain_graph(["x", "y", "z"])
    sj, ej, bj = fetch(served.url + "/cost", payload={"graph": graph},
                       binary=False, pool=pool)
    sw, ew, bw = fetch(served.url + "/cost", payload={"graph": graph},
                       binary=True, pool=pool)
    assert sj == sw == 200 and ej == ew
    assert json.dumps(bj, sort_keys=True) == json.dumps(bw, sort_keys=True)
    # wire-negotiated revalidation honors the JSON-minted tag
    s304, e304, _ = fetch(served.url + "/cost", payload={"graph": graph},
                          binary=True, etag=ej, pool=pool)
    assert s304 == 304 and e304 == ej


def test_cost_batch_carriage_matches_standalone(served, pool):
    graph = _chain_graph(["p", "q"])
    status, etag, body = _post_json(served.url + "/cost", {"graph": graph})
    assert status == 200
    sb, _, envelope = fetch(
        served.url + "/batch",
        payload={"tuples": [
            {"cost": {"graph": graph}},
            {"mode": "paper"},                      # estimate tuple
            {"cost": {"graph": graph}, "if_none_match": etag},
        ]},
        binary=False, pool=pool,
    )
    assert sb == 200
    r_cost, r_est, r_reval = envelope["responses"]
    assert r_cost["status"] == 200 and r_cost["body"]["etag"] == etag
    assert json.dumps(r_cost["body"], sort_keys=True) == json.dumps(
        body, sort_keys=True
    )
    assert r_est["status"] == 200 and "estimates" in r_est["body"]
    assert r_reval["status"] == 304


def test_cost_explain_is_identity_neutral(served):
    graph = _chain_graph(["m", "n"])
    status, etag, plain = _post_json(served.url + "/cost", {"graph": graph})
    status2, etag2, explained = _post_json(
        served.url + "/cost?explain=1", {"graph": graph}
    )
    assert status == status2 == 200
    assert etag2 == etag  # explain never touches identity
    assert "provenance" not in plain
    prov = explained["provenance"]
    for alias in ("m", "n"):
        assert prov[alias]["tok"]["route"] in ("dict", "minmax")
        assert prov[alias]["tok"]["ndv"] > 0
    without = {k: v for k, v in explained.items() if k != "provenance"}
    assert json.dumps(without, sort_keys=True) == json.dumps(
        plain, sort_keys=True
    )


def test_cost_request_errors(served):
    url = served.url + "/cost"
    # disconnected graph
    status, _, body = _post_json(url, {"graph": {
        "tables": [{"name": "a"}, {"name": "b"}], "edges": []}})
    assert status == 400 and "disconnected" in body["error"]
    # unknown join column
    status, _, body = _post_json(url, {"graph": _chain_graph(
        ["a", "b"], column="no_such_col")})
    assert status == 400 and "no_such_col" in body["error"]
    # junk fields at body / graph level
    assert _post_json(url, {"graph": _chain_graph(["a", "b"]),
                            "surprise": 1})[0] == 400
    assert _post_json(url, {"graph": {**_chain_graph(["a", "b"]),
                                      "hints": []}})[0] == 400
    # bad mode, bad max_plans
    assert _post_json(url, {"graph": _chain_graph(["a", "b"]),
                            "mode": "psychic"})[0] == 400
    assert _post_json(url, {"graph": _chain_graph(["a", "b"]),
                            "max_plans": 0})[0] == 400
    # single-table graph is fine and free
    status, etag, body = _post_json(
        url, {"graph": {"tables": [{"name": "solo"}], "edges": []}}
    )
    assert status == 200 and etag
    assert body["total_cost"] == 0.0 and body["joins"] == []


def test_cost_acceptance_one_dispatch_thousands_of_plans(served):
    # The headline acceptance criterion: a 7-table graph's 4096-plan
    # sample scores as ONE batched dispatch, observed through the obs
    # counters across the HTTP path.
    from repro.planner.cost import _DISPATCHES, _PLANS_SCORED

    graph = _chain_graph([f"acc{i}" for i in range(7)])
    d0, p0 = _DISPATCHES.value(), _PLANS_SCORED.value()
    status, etag, body = _post_json(served.url + "/cost", {"graph": graph})
    assert status == 200 and etag
    assert body["plans_scored"] == 4096 >= 1000
    assert body["plan_space"] == 5040 and body["enumeration"] == "sampled"
    assert len(body["best_order"]) == 7 and len(body["joins"]) == 6
    assert _DISPATCHES.value() - d0 == 1.0
    assert _PLANS_SCORED.value() - p0 == 4096.0
    # warm revalidation scores nothing at all
    d1, p1 = _DISPATCHES.value(), _PLANS_SCORED.value()
    assert _post_json(served.url + "/cost", {"graph": graph},
                      etag=etag)[0] == 304
    assert (_DISPATCHES.value(), _PLANS_SCORED.value()) == (d1, p1)


def test_tablestats_endpoint(served):
    status, etag, body = fetch_json(served.url + "/tablestats")
    assert status == 200 and etag and body["etag"] == etag
    assert body["rows"] == 512  # 2 shards x 256 rows, footer sums
    assert sorted(body["columns"]) == ["tok", "val"]
    for col in body["columns"].values():
        assert col["ndv"] > 0 and col["route"] in ("dict", "minmax")
    assert fetch_json(served.url + "/tablestats", etag=etag)[0] == 304
    # column filter narrows the body and mints a distinct tag
    s2, e2, b2 = fetch_json(served.url + "/tablestats?columns=tok")
    assert s2 == 200 and e2 != etag and sorted(b2["columns"]) == ["tok"]
    assert fetch_json(served.url + "/tablestats?columns=nope")[0] == 400


# -- fleet router -------------------------------------------------------------


@pytest.fixture()
def registry(tmp_path):
    reg = DatasetRegistry()
    for name, seed in (("orders", 10), ("lines", 20)):
        root = str(tmp_path / name)
        for i in range(2):
            _write(root, f"shard_{i:03d}", seed=seed + i, vocab=48)
        reg.add("wh", name, root)
    return reg


@pytest.fixture()
def routed(registry):
    router = StatsRouter(Fleet(registry, replicas_per_dataset=2)).start()
    yield router
    router.stop()


def _fleet_graph():
    return {
        "tables": [
            {"name": "o", "namespace": "wh", "dataset": "orders"},
            {"name": "l", "namespace": "wh", "dataset": "lines",
             "filter_selectivity": 0.5},
        ],
        "edges": [{"left": "o", "left_column": "tok",
                   "right": "l", "right_column": "tok"}],
    }


def test_router_cost_etag_survives_replica_kill(routed):
    graph = _fleet_graph()
    status, etag, body = _post_json(routed.url + "/cost", {"graph": graph})
    assert status == 200 and etag and body["etag"] == etag
    assert sorted(body["sources"]) == ["wh/lines", "wh/orders"]
    assert body["best_order"] and len(body["joins"]) == 1
    assert _post_json(routed.url + "/cost", {"graph": graph},
                      etag=etag)[0] == 304
    # kill one replica per set: failover must not rotate the tag
    for rset in routed.fleet.sets.values():
        rset.replicas[0].kill()
    s2, e2, _ = _post_json(routed.url + "/cost", {"graph": graph},
                           etag=etag)
    assert s2 == 304 and e2 == etag
    s3, e3, b3 = _post_json(routed.url + "/cost", {"graph": graph})
    assert s3 == 200 and e3 == etag
    assert json.dumps(b3, sort_keys=True) == json.dumps(
        body, sort_keys=True
    )


def test_router_cost_explain_reports_routes(routed):
    graph = _fleet_graph()
    status, etag, plain = _post_json(routed.url + "/cost", {"graph": graph})
    s2, e2, body = _post_json(routed.url + "/cost?explain=1",
                              {"graph": graph})
    assert status == s2 == 200 and e2 == etag
    assert body["provenance"]["o"]["tok"]["route"] in ("dict", "minmax")
    assert "provenance" not in plain


def test_router_cost_dataset_errors(routed):
    # unknown dataset -> 404
    status, _, body = _post_json(routed.url + "/cost", {"graph": {
        "tables": [{"name": "x", "namespace": "wh", "dataset": "nope"}],
        "edges": [],
    }})
    assert status == 404 and "not registered" in body["error"]
    # a table without namespace/dataset is a parse-time 400 on the router
    status, _, body = _post_json(routed.url + "/cost", {"graph": {
        "tables": [{"name": "x"}], "edges": [],
    }})
    assert status == 400 and "namespace" in body["error"]


def test_router_batch_carries_cost_tuples(routed, pool):
    graph = _fleet_graph()
    status, etag, body = _post_json(routed.url + "/cost", {"graph": graph})
    assert status == 200
    sb, _, envelope = fetch(
        routed.url + "/batch",
        payload={"tuples": [
            {"cost": {"graph": graph}},
            {"namespace": "wh", "dataset": "orders", "mode": "paper"},
            {"cost": {"graph": graph}, "if_none_match": etag},
        ]},
        binary=False, pool=pool,
    )
    assert sb == 200
    r_cost, r_est, r_reval = envelope["responses"]
    assert r_cost["status"] == 200 and r_cost["body"]["etag"] == etag
    assert json.dumps(r_cost["body"], sort_keys=True) == json.dumps(
        body, sort_keys=True
    )
    assert r_est["status"] == 200
    assert r_reval["status"] == 304


def test_router_tablestats_passthrough(routed):
    url = routed.url + "/wh/orders/tablestats?columns=tok"
    status, etag, body = fetch_json(url)
    assert status == 200 and etag and sorted(body["columns"]) == ["tok"]
    assert fetch_json(url, etag=etag)[0] == 304
    assert fetch_json(routed.url + "/wh/orders/tablestats?columns=bad")[0] \
        == 400
