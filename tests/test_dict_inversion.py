"""Unit tests for dictionary size inversion (paper §4)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.ndv import dict_inversion as di


def forward_size(ndv, rows, nulls, mean_len):
    bits = max(np.ceil(np.log2(max(ndv, 1)) - 1e-9), 1)
    return ndv * mean_len + (rows - nulls) * bits / 8.0


def test_exact_recovery_simple():
    ndv, rows, nulls, ln = 1000.0, 100000.0, 0.0, 8.0
    s = forward_size(ndv, rows, nulls, ln)
    res = di.invert_dict_size(
        jnp.array([s]), jnp.array([rows]), jnp.array([nulls]), jnp.array([ln])
    )
    assert abs(float(res.ndv[0]) - ndv) / ndv < 1e-3
    assert not bool(res.likely_fallback[0])


def test_convergence_iterations_reasonable():
    """Paper: 5-10 iterations to 1e-6 typically."""
    rng = np.random.default_rng(0)
    ndv = rng.integers(2, 1_000_000, 256).astype(np.float64)
    rows = ndv * rng.uniform(2, 50, 256)
    ln = rng.uniform(1, 64, 256)
    s = np.array([forward_size(n, r, 0, l) for n, r, l in zip(ndv, rows, ln)])
    res = di.invert_dict_size(
        jnp.asarray(s, jnp.float32), jnp.asarray(rows, jnp.float32),
        jnp.zeros(256, jnp.float32), jnp.asarray(ln, jnp.float32),
    )
    med_iters = float(np.median(np.asarray(res.iterations)))
    assert med_iters <= 12, med_iters
    err = np.abs(np.asarray(res.ndv) - ndv) / ndv
    assert np.median(err) < 0.01


@given(
    ndv=st.integers(2, 10**7),
    mult=st.floats(1.5, 1000.0),
    mean_len=st.floats(1.0, 256.0),
    null_frac=st.floats(0.0, 0.5),
)
@settings(max_examples=60, deadline=None)
def test_inversion_property(ndv, mult, mean_len, null_frac):
    """Round-trip: forward Eq 1 then invert recovers ndv within a few %."""
    rows = float(np.ceil(ndv * mult))
    # realistic metadata: can't have fewer non-null rows than distincts
    nulls = min(float(np.floor(rows * null_frac)), rows - float(ndv))
    s = forward_size(ndv, rows, nulls, mean_len)
    res = di.invert_dict_size(
        jnp.array([s], jnp.float32), jnp.array([rows], jnp.float32),
        jnp.array([nulls], jnp.float32), jnp.array([mean_len], jnp.float32),
    )
    got = float(res.ndv[0])
    assert got >= 1.0
    assert abs(got - ndv) / ndv < 0.05


def test_fallback_detection():
    """Plain-encoded chunk: S ~ rows*len -> Eq 5 fires."""
    rows, ln = 100000.0, 8.0
    s = rows * ln
    res = di.invert_dict_size(
        jnp.array([s]), jnp.array([rows]), jnp.array([0.0]), jnp.array([ln])
    )
    assert bool(res.likely_fallback[0])


def test_no_false_fallback_low_ndv():
    s = forward_size(100, 100000, 0, 8.0)
    res = di.invert_dict_size(
        jnp.array([s]), jnp.array([100000.0]), jnp.array([0.0]), jnp.array([8.0])
    )
    assert not bool(res.likely_fallback[0])


def test_monotonic_in_size():
    """Bigger S (same rows/len) must never decrease estimated ndv."""
    rows, ln = 50000.0, 10.0
    sizes = [forward_size(n, rows, 0, ln) for n in (10, 100, 1000, 10000)]
    res = di.invert_dict_size(
        jnp.asarray(sizes, jnp.float32), jnp.full(4, rows, jnp.float32),
        jnp.zeros(4, jnp.float32), jnp.full(4, ln, jnp.float32),
    )
    vals = np.asarray(res.ndv)
    assert np.all(np.diff(vals) > 0)
