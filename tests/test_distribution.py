"""Distribution detector tests (paper §6)."""
import jax.numpy as jnp
import numpy as np

from repro.core.ndv import distribution as dd
from repro.core.ndv.types import Layout


def _metrics(mins, maxs):
    mins = jnp.asarray([mins], jnp.float32)
    maxs = jnp.asarray([maxs], jnp.float32)
    valid = jnp.ones_like(mins, bool)
    return dd.detect_distribution(mins, maxs, valid)


def test_sorted_layout():
    mins = np.arange(0, 100, 10.0)
    maxs = mins + 9.0
    m = _metrics(mins, maxs)
    assert Layout(int(m.layout[0])) == Layout.SORTED
    assert float(m.overlap_ratio[0]) < 0.1
    assert float(m.monotonicity[0]) > 0.9


def test_well_spread_layout():
    mins = np.full(10, 0.0) + np.random.default_rng(0).uniform(0, 1, 10)
    maxs = np.full(10, 100.0) - np.random.default_rng(1).uniform(0, 1, 10)
    m = _metrics(mins, maxs)
    assert Layout(int(m.layout[0])) == Layout.WELL_SPREAD
    assert float(m.overlap_ratio[0]) > 0.7


def test_pseudo_sorted_layout():
    # drifting ranges with moderate overlap
    mins = np.arange(0, 100, 10.0)
    maxs = mins + 12.0
    m = _metrics(mins, maxs)
    assert Layout(int(m.layout[0])) in (Layout.PSEUDO_SORTED, Layout.SORTED)


def test_mixed_layout():
    rng = np.random.default_rng(2)
    mins = rng.uniform(0, 50, 12)
    maxs = mins + rng.uniform(5, 15, 12)
    m = _metrics(mins, maxs)
    # shuffled medium ranges: not sorted, not fully overlapping
    assert Layout(int(m.layout[0])) in (Layout.MIXED, Layout.PSEUDO_SORTED)


def test_single_group_defaults_well_spread():
    m = _metrics([5.0], [10.0])
    assert Layout(int(m.layout[0])) == Layout.WELL_SPREAD


def test_constant_column():
    m = _metrics([7.0] * 8, [7.0] * 8)
    assert Layout(int(m.layout[0])) == Layout.WELL_SPREAD


def test_masking_ignores_padding():
    mins = jnp.asarray([[0, 10, 20, 99, 99]], jnp.float32)
    maxs = jnp.asarray([[9, 19, 29, 0, 0]], jnp.float32)
    valid = jnp.asarray([[True, True, True, False, False]])
    m = dd.detect_distribution(mins, maxs, valid)
    assert Layout(int(m.layout[0])) == Layout.SORTED
