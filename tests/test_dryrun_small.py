"""Dry-run machinery on a miniature mesh (subprocess: needs >1 host device).

Full-size cells are exercised by `python -m repro.launch.dryrun` (results in
results/dryrun.json); here we prove the machinery end to end in CI-size.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.launch import cells as C
from repro.launch import hlo_analysis
from repro.launch.mesh import make_debug_mesh

out = []
for arch, shape in [("qwen3_0_6b", "train_4k"), ("rwkv6_7b", "decode_32k"),
                    ("granite_moe_3b_a800m", "train_4k")]:
    mesh = make_debug_mesh()
    cell = C.build_cell(arch, shape, mesh)
    with mesh:
        compiled = cell.fn.lower(*cell.args).compile()
        ana = hlo_analysis.analyze(compiled.as_text())
        mem = compiled.memory_analysis()
    out.append({
        "arch": arch, "shape": shape,
        "flops": ana.flops, "bytes": ana.bytes,
        "coll": ana.collective_bytes,
        "temp": mem.temp_size_in_bytes,
    })
print(json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_cells_compile_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=540,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    rows = json.loads(res.stdout.strip().splitlines()[-1])
    assert len(rows) == 3
    for r in rows:
        assert r["flops"] > 0, r
        assert r["bytes"] > 0, r
        # multi-device mesh must produce collectives for a sharded model
        assert r["coll"] > 0, r


def test_dryrun_results_exist_and_green():
    """The committed full-scale dry-run results: 66/66 cells, no errors."""
    path = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.json")
    if not os.path.exists(path):
        pytest.skip("full dry-run not run in this checkout")
    rows = json.load(open(path))
    errors = [r for r in rows if "error" in r]
    assert not errors, errors[:2]
    meshes = {r["mesh"] for r in rows}
    assert {"16x16", "2x16x16"} <= meshes
    assert len(rows) == 66
