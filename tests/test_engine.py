"""EstimationEngine: strategy parity, shard-aware packing, cache keying,
and estimate-cache persistence.

The engine's contract is bit-for-bit parity across execution strategies for
real (non-padding) lanes. `test_strategy_parity_matrix` is the CI parity
matrix selector: the workflow runs it once per (strategy, device count)
cell via ``-k "parity_matrix and <strategy>"`` under
``XLA_FLAGS=--xla_force_host_platform_device_count={1,4}``, so a parity
break names the exact strategy/topology that diverged. Sharded parity on
>= 4 devices additionally runs in a subprocess (XLA device count is fixed
at process start); when the host process itself has >= 4 simulated devices
the in-process variants run too.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.catalog import BatchPacker, StatsCatalog
from repro.core import estimate_columns
from repro.core.ndv.types import ColumnMetadata, PhysicalType
from repro.engine import EngineConfig, EstimationEngine, default_engine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _column(seed: int, r: int, name: str = "c") -> ColumnMetadata:
    rng = np.random.default_rng(seed)
    mins = np.sort(rng.uniform(0, 1e5, r))
    return ColumnMetadata(
        chunk_sizes=rng.uniform(2_000.0, 90_000.0, r),
        chunk_rows=np.full(r, 4096.0),
        chunk_nulls=rng.integers(0, 64, r).astype(np.float64),
        chunk_dict_encoded=rng.uniform(size=r) > 0.2,
        mins=mins,
        maxs=mins + rng.uniform(10.0, 1e4, r),
        min_lengths=np.full(r, 8.0),
        max_lengths=np.full(r, 8.0),
        distinct_min_count=float(max(r - 1, 1)),
        distinct_max_count=float(r),
        physical_type=PhysicalType.INT64,
        column_name=f"{name}{seed}",
    )


def _columns(width: int):
    # Ragged row-group counts: exercises padding in both axes.
    return [_column(i, r=1 + (i % 7)) for i in range(width)]


# -- strategy×device parity matrix (the CI selector) --------------------------


@pytest.mark.parametrize("strategy", ["local", "sharded", "chunked", "composed"])
def test_strategy_parity_matrix(strategy):
    """One cell of the CI parity matrix: `strategy` vs local, bit for bit.

    Runs at whatever device count the process was started with (the CI
    matrix forces 1 and 4 via XLA_FLAGS) — every strategy must hold parity
    on every topology, including the degenerate single-device mesh.
    """
    ref_engine = EstimationEngine(EngineConfig(strategy="local"))
    eng = EstimationEngine(EngineConfig(strategy=strategy, max_batch=8))
    # Widths straddling the mesh-wide budget, plus one below the shard count.
    for width in (3, 13, 64):
        cols = _columns(width)
        bounds = [np.inf] * width
        bounds[width // 2] = 5.0
        for mode in ("paper", "improved"):
            ref, ref_prov = ref_engine.estimate_columns_explained(
                cols, bounds, mode=mode
            )
            got, got_prov = eng.estimate_columns_explained(
                cols, bounds, mode=mode
            )
            assert got == ref, (strategy, width, mode)
            # Provenance rides the same lanes through the same execution
            # plans: diagnostics must hold the parity contract too, or an
            # explained response would change with the serving topology.
            assert got_prov == ref_prov, (strategy, width, mode)


@pytest.mark.parametrize(
    "strategy", ["local", "composed"], ids=["fused_local", "fused_composed"]
)
def test_fused_parity_matrix(strategy):
    """Fused cells of the CI parity matrix: fuse=on vs fuse=off, bit for bit.

    The fuse knob stays out of engine cache identity, so it must be
    numerics-invisible on every strategy/topology the matrix runs
    (`-k "parity_matrix and fused_<strategy>"` under 1 and 4 simulated
    devices). Local and composed bracket the strategy space: single jit
    call vs mesh-split + per-shard chunk streaming.
    """
    on = EstimationEngine(EngineConfig(strategy=strategy, max_batch=8, fuse="on"))
    off = EstimationEngine(EngineConfig(strategy=strategy, max_batch=8, fuse="off"))
    assert on.cache_key == off.cache_key
    assert on.cache_token == off.cache_token
    for width in (3, 13, 64):
        cols = _columns(width)
        bounds = [np.inf] * width
        bounds[width // 2] = 5.0
        for mode in ("paper", "improved"):
            ref, ref_prov = off.estimate_columns_explained(
                cols, bounds, mode=mode
            )
            got, got_prov = on.estimate_columns_explained(
                cols, bounds, mode=mode
            )
            assert got == ref, (strategy, width, mode)
            assert got_prov == ref_prov, (strategy, width, mode)


# -- chunked parity (any device count) ---------------------------------------


@pytest.mark.parametrize("mode", ["paper", "improved"])
@pytest.mark.parametrize("width", [5, 13, 64])
def test_chunked_matches_local_bit_for_bit(mode, width):
    cols = _columns(width)
    local = EstimationEngine(EngineConfig(strategy="local"))
    chunked = EstimationEngine(EngineConfig(strategy="chunked", max_batch=8))
    ref = local.estimate_columns(cols, mode=mode)
    got = chunked.estimate_columns(cols, mode=mode)
    assert got == ref  # NDVEstimate equality is exact float equality


def test_chunked_with_schema_bounds_matches_local():
    cols = _columns(20)
    bounds = [np.inf] * 20
    bounds[3] = 7.0
    bounds[17] = 2.0
    local = EstimationEngine(EngineConfig(strategy="local"))
    chunked = EstimationEngine(EngineConfig(strategy="chunked", max_batch=8))
    ref = local.estimate_columns(cols, bounds)
    got = chunked.estimate_columns(cols, bounds)
    assert got == ref
    assert got[3].ndv <= 7.0 and got[17].ndv <= 2.0


# -- sharded parity -----------------------------------------------------------

_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 "
    "--xla_cpu_multi_thread_eigen=false"
)
import json
import numpy as np
import jax

from tests.test_engine import _columns
from repro.engine import EngineConfig, EstimationEngine

assert jax.device_count() >= 4, jax.device_count()
out = {"devices": jax.device_count(), "ok": True, "fail": []}
for width in (3, 13, 64):          # 3 < shards: pure padding lanes on 3 shards
    cols = _columns(width)
    for mode in ("paper", "improved"):
        local = EstimationEngine(EngineConfig(strategy="local"))
        sharded = EstimationEngine(EngineConfig(strategy="sharded"))
        chunked = EstimationEngine(EngineConfig(strategy="chunked", max_batch=8))
        composed = EstimationEngine(EngineConfig(strategy="composed", max_batch=4))
        ref = local.estimate_columns(cols, mode=mode)
        for name, eng in (
            ("sharded", sharded), ("chunked", chunked), ("composed", composed)
        ):
            got = eng.estimate_columns(cols, mode=mode)
            if got != ref:
                out["ok"] = False
                out["fail"].append([name, mode, width])

# auto resolves to composed when both multi-device and over-budget hold,
# and the composed result still matches local bit for bit.
auto = EstimationEngine(EngineConfig(strategy="auto", max_batch=4))
cols = _columns(64)
batch = auto.make_packer().pack(cols)
resolved = auto.resolve_strategy(batch.batch)
if resolved != "composed":
    out["ok"] = False
    out["fail"].append(["auto-resolution", resolved, batch.batch])
ref = EstimationEngine(EngineConfig(strategy="local")).estimate_columns(cols)
if auto.estimate_columns(cols) != ref:
    out["ok"] = False
    out["fail"].append(["auto-composed-parity", "paper", 64])
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_parity_on_simulated_devices():
    """Bit-equality of sharded/chunked vs local on 4 simulated CPU devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        SRC + os.pathsep + os.path.join(os.path.dirname(__file__), "..")
    )
    res = subprocess.run(
        [sys.executable, "-c", _PARITY_SCRIPT],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["devices"] >= 4
    assert out["ok"], out["fail"]


@pytest.mark.skipif(
    jax.device_count() < 4, reason="needs >= 4 devices (CI parity step)"
)
@pytest.mark.parametrize("mode", ["paper", "improved"])
def test_sharded_matches_local_in_process(mode):
    cols = _columns(13)
    ref = EstimationEngine(EngineConfig(strategy="local")).estimate_columns(
        cols, mode=mode
    )
    got = EstimationEngine(EngineConfig(strategy="sharded")).estimate_columns(
        cols, mode=mode
    )
    assert got == ref


# -- composed strategy ---------------------------------------------------------


def test_composed_plan_shapes():
    from repro.engine import composed_plan

    # wider than one super-chunk: whole super-chunks, equal spans
    padded, spans = composed_plan(100, 3, 4)
    assert padded == 108 and padded % (3 * 4) == 0
    assert spans == [(lo, lo + 12) for lo in range(0, 108, 12)]
    # fits one dispatch: pad only to the shard count, not a full super-chunk
    assert composed_plan(5, 3, 4) == (6, [(0, 6)])
    assert composed_plan(8, 4, 1024) == (8, [(0, 8)])
    with pytest.raises(ValueError, match="positive"):
        composed_plan(0, 1, 1)


def test_composed_matches_local_any_device_count():
    # Parity must hold even on the degenerate 1-device mesh (CPU default):
    # composed then reduces to pure chunk streaming.
    cols = _columns(37)
    local = EstimationEngine(EngineConfig(strategy="local"))
    comp = EstimationEngine(EngineConfig(strategy="composed", max_batch=8))
    for mode in ("paper", "improved"):
        assert comp.estimate_columns(cols, mode=mode) == local.estimate_columns(
            cols, mode=mode
        )


def test_auto_resolves_composed_when_multi_device_and_over_budget(monkeypatch):
    eng = EstimationEngine(EngineConfig(strategy="auto", max_batch=8))
    monkeypatch.setattr(
        EstimationEngine, "shard_count", property(lambda self: 4)
    )
    # over the mesh-wide budget (4 shards x 8) -> composed
    assert eng.resolve_strategy(64) == "composed"
    # at or under it -> plain sharded
    assert eng.resolve_strategy(32) == "sharded"
    assert eng.resolve_strategy(4) == "sharded"


@pytest.mark.skipif(
    jax.device_count() < 4, reason="needs >= 4 devices (CI parity step)"
)
def test_auto_resolves_composed_in_process():
    eng = EstimationEngine(EngineConfig(strategy="auto", max_batch=4))
    batch = eng.make_packer().pack(_columns(64))
    assert eng.resolve_strategy(batch.batch) == "composed"
    ref = EstimationEngine(EngineConfig(strategy="local")).estimate_columns(
        _columns(64)
    )
    assert eng.estimate_columns(_columns(64)) == ref


def test_composed_packer_coordinates_shards_and_chunks(monkeypatch):
    monkeypatch.setattr(
        EstimationEngine, "shard_count", property(lambda self: 3)
    )
    eng = EstimationEngine(EngineConfig(strategy="composed", max_batch=4))
    packer = eng.make_packer()
    assert packer.col_multiple == 3 and packer.col_chunk == 4
    # bucket(37) = 64 > one super-chunk (12) -> whole super-chunks
    assert packer.shape_for(37, 4)[0] == 72
    # narrow batch: multiple of shards only, NOT padded to a super-chunk
    assert packer.shape_for(5, 4)[0] == 9  # bucket 8 -> next multiple of 3


def test_shard_clamp_is_surfaced_once(caplog):
    n_dev = jax.device_count()
    eng = EstimationEngine(
        EngineConfig(strategy="sharded", num_shards=n_dev + 60)
    )
    with caplog.at_level("WARNING", logger="repro.engine.engine"):
        assert eng.shard_count == n_dev
        assert eng.shard_count == n_dev  # second read: no duplicate log
    clamps = [r for r in caplog.records if "clamping" in r.message]
    assert len(clamps) == 1
    assert str(n_dev + 60) in clamps[0].getMessage()
    # a satisfiable config never logs
    caplog.clear()
    with caplog.at_level("WARNING", logger="repro.engine.engine"):
        assert EstimationEngine(
            EngineConfig(strategy="sharded", num_shards=n_dev)
        ).shard_count == n_dev
    assert not [r for r in caplog.records if "clamping" in r.message]


# -- packer shard-awareness ----------------------------------------------------


def test_packer_col_multiple_rounds_up_evenly():
    packer = BatchPacker(col_multiple=3)
    cols = _columns(4)
    batch = packer.pack(cols)
    assert batch.batch % 3 == 0
    assert batch.batch == 6  # bucket(4) = 4 -> next multiple of 3
    # padding lanes fully masked
    assert not np.asarray(batch.valid)[4:].any()
    assert (np.asarray(batch.n_groups)[4:] == 0).all()


def test_engine_packer_matches_shard_count():
    eng = EstimationEngine(EngineConfig(strategy="sharded"))
    packer = eng.make_packer()
    assert packer.col_multiple == eng.shard_count
    batch = packer.pack(_columns(5))
    assert batch.batch % eng.shard_count == 0


def test_backend_values_agree_on_clean_data():
    # pallas (interpret) vs ref run different iteration counts; on
    # well-conditioned synthetic columns all backends converge to the
    # same estimates within float tolerance.
    cols = _columns(4)
    ref = EstimationEngine(EngineConfig(backend="ref")).estimate_columns(cols)
    auto = EstimationEngine(EngineConfig(backend="auto")).estimate_columns(cols)
    assert auto == ref  # off-TPU, auto IS the reference path
    pallas = EstimationEngine(
        EngineConfig(backend="pallas")
    ).estimate_columns(cols)
    for a, b in zip(pallas, ref):
        assert a.ndv == pytest.approx(b.ndv, rel=1e-3)
        assert a.layout == b.layout


def test_estimate_columns_uses_shared_default_packer():
    from repro.engine import default_packer

    ests = estimate_columns(_columns(3))
    assert len(ests) == 3
    assert default_packer() is default_packer()  # one instance per process
    assert default_engine() is default_engine()


def test_engine_config_validation():
    with pytest.raises(ValueError, match="strategy"):
        EngineConfig(strategy="turbo")
    with pytest.raises(ValueError, match="power of two"):
        EngineConfig(max_batch=3)
    with pytest.raises(ValueError, match="backend"):
        EngineConfig(backend="cuda")


# -- catalog integration -------------------------------------------------------


def _dataset(tmp_path, n_files=2):
    from repro.columnar import write_file
    from repro.columnar.writer import WriterOptions

    rng = np.random.default_rng(0)
    for i in range(n_files):
        write_file(
            str(tmp_path / f"shard_{i:03d}"),
            {
                "tok": rng.integers(0, 64, 512).astype(np.int64),
                "val": np.round(rng.uniform(0, 100, 512), 1),
            },
            options=WriterOptions(row_group_size=128),
        )
    return str(tmp_path)


def test_catalog_cache_shared_across_strategies_split_by_backend(tmp_path):
    # The neutrality rules: strategy / shard count / chunk budget are
    # numerics-neutral (parity contract), so engines differing only in them
    # SHARE a cache line — a strategy change invalidates nothing. Only the
    # backend can change numerics, so it still splits entries.
    root = _dataset(tmp_path)
    catalog = StatsCatalog(root)
    e_local = EstimationEngine(EngineConfig(strategy="local"))
    e_chunked = EstimationEngine(EngineConfig(strategy="chunked", max_batch=2))
    e_composed = EstimationEngine(
        EngineConfig(strategy="composed", max_batch=2, num_shards=1)
    )

    first = catalog.estimate(engine=e_local)
    assert catalog.stats.estimate_cache_misses == 1
    # same config, different engine instance -> cache hit (config is the key)
    again = catalog.estimate(engine=EstimationEngine(EngineConfig(strategy="local")))
    assert catalog.stats.estimate_cache_hits == 1
    assert again == first
    # different execution shape, same numerics -> same entry stays warm
    assert catalog.estimate(engine=e_chunked) == first
    assert catalog.estimate(engine=e_composed) == first
    assert catalog.stats.estimate_cache_hits == 3
    assert catalog.stats.estimate_cache_misses == 1
    # a different backend is a different numeric identity -> separate entry
    catalog.estimate(engine=EstimationEngine(EngineConfig(backend="ref")))
    assert catalog.stats.estimate_cache_misses == 2


def test_catalog_estimates_match_direct_engine_call(tmp_path):
    root = _dataset(tmp_path)
    engine = EstimationEngine(EngineConfig(strategy="chunked", max_batch=2))
    catalog = StatsCatalog(root, engine=engine)
    got = catalog.estimate(mode="improved")
    merged = catalog.merged_metadata()
    cols = [merged[n] for n in catalog.column_names]
    ref = {
        e.column_name: e
        for e in engine.estimate_columns(cols, mode="improved")
    }
    assert got == ref


def test_save_load_cache_round_trip(tmp_path):
    root = _dataset(tmp_path)
    catalog = StatsCatalog(root)
    warm = catalog.estimate(mode="improved")
    catalog.estimate(mode="paper")
    path = catalog.save_cache()
    assert os.path.exists(path)

    # fresh catalog (a restart): loads the spilled entries, serves without
    # re-estimating
    restarted = StatsCatalog(root)
    assert restarted.load_cache() == 2
    got = restarted.estimate(mode="improved")
    assert restarted.stats.estimate_cache_hits == 1
    assert restarted.stats.estimate_cache_misses == 0
    assert restarted.stats.packs == 0
    assert got == warm  # bit-identical through the JSON round trip


def test_load_cache_misses_on_changed_dataset(tmp_path):
    from repro.columnar import write_file
    from repro.columnar.writer import WriterOptions

    root = _dataset(tmp_path)
    catalog = StatsCatalog(root)
    catalog.estimate()
    catalog.save_cache()

    rng = np.random.default_rng(9)
    write_file(
        str(tmp_path / "shard_099"),
        {
            "tok": rng.integers(0, 64, 512).astype(np.int64),
            "val": np.round(rng.uniform(0, 100, 512), 1),
        },
        options=WriterOptions(row_group_size=128),
    )
    restarted = StatsCatalog(root)
    assert restarted.load_cache() == 1
    restarted.estimate()  # new fingerprint set -> stale entry unreachable
    assert restarted.stats.estimate_cache_misses == 1


def test_load_cache_missing_file_is_cold_start(tmp_path):
    root = _dataset(tmp_path)
    catalog = StatsCatalog(root)
    assert catalog.load_cache() == 0


def test_save_cache_requires_root_for_memory_sources():
    from repro.catalog import InMemoryMetadataSource

    catalog = StatsCatalog(InMemoryMetadataSource({}))
    with pytest.raises(ValueError, match="root"):
        catalog.save_cache()


def test_pipeline_engine_config_threads_through(tmp_path):
    from repro.data.pipeline import DataConfig, TokenPipeline, synthesize_token_dataset

    root = str(tmp_path / "ds")
    synthesize_token_dataset(root, num_shards=1, rows_per_shard=1 << 12)
    cfg = DataConfig(
        root=root,
        engine=EngineConfig(strategy="chunked", max_batch=2),
    )
    pipe = TokenPipeline(cfg)
    assert pipe.catalog.engine.config.strategy == "chunked"
    assert pipe.plan.estimates  # planned through the chunked engine


# -- "auto" chunk budget -------------------------------------------------------


def test_auto_chunk_budget_math():
    from repro.engine import DEFAULT_MAX_BATCH, auto_chunk_budget
    from repro.engine.engine import (
        AUTO_MAX_BATCH,
        AUTO_MEM_FRACTION,
        AUTO_MIN_BATCH,
        NOMINAL_LANE_BYTES,
    )

    # no memory report -> historical constant
    assert auto_chunk_budget(None) == DEFAULT_MAX_BATCH
    assert auto_chunk_budget(0) == DEFAULT_MAX_BATCH
    # 16 GiB at the documented fraction and lane footprint, floor-pow2
    want = int(16 * 2**30 * AUTO_MEM_FRACTION / NOMINAL_LANE_BYTES)
    got = auto_chunk_budget(16 * 2**30)
    assert got == 1 << (want.bit_length() - 1) == 65536
    # clamps on both ends, always a power of two
    assert auto_chunk_budget(1) == AUTO_MIN_BATCH
    assert auto_chunk_budget(1 << 60) == AUTO_MAX_BATCH
    for mem in (2**28, 2**31, 7 * 10**9):
        b = auto_chunk_budget(mem)
        assert b & (b - 1) == 0 and AUTO_MIN_BATCH <= b <= AUTO_MAX_BATCH


def test_auto_budget_shrinks_per_shard(monkeypatch):
    # The composed per-shard budget divides the memory report across the
    # mesh (simulated host devices all report the one shared pool), so the
    # budget shrinks as the mesh grows — and the report is read only once
    # per engine no matter how many shard counts are resolved.
    from repro.engine import engine as engine_mod

    calls = []

    def fake_detect():
        calls.append(1)
        return 16 * 2**30

    monkeypatch.setattr(engine_mod, "detect_device_memory", fake_detect)
    eng = EstimationEngine(EngineConfig(strategy="composed", max_batch="auto"))
    assert eng.resolve_max_batch() == 65536
    assert eng.resolve_max_batch(shards=4) == 65536 // 4
    assert eng.resolve_max_batch(shards=3) == 16384  # pow2 floor of /3
    assert len(calls) == 1


def test_engine_config_auto_max_batch_validation():
    assert EngineConfig(max_batch="auto").max_batch == "auto"
    with pytest.raises(ValueError, match="auto"):
        EngineConfig(max_batch="turbo")


def test_resolve_max_batch_auto_detects_once(monkeypatch):
    from repro.engine import engine as engine_mod

    calls = []

    def fake_detect():
        calls.append(1)
        return 16 * 2**30

    monkeypatch.setattr(engine_mod, "detect_device_memory", fake_detect)
    eng = EstimationEngine(EngineConfig(strategy="chunked", max_batch="auto"))
    assert eng.resolve_max_batch() == 65536
    assert eng.resolve_max_batch() == 65536
    assert len(calls) == 1  # detection is cached per engine
    # a fixed budget never consults the device
    calls.clear()
    fixed = EstimationEngine(EngineConfig(max_batch=128))
    assert fixed.resolve_max_batch() == 128 and not calls


def test_engine_identity_is_backend_only():
    # The execution shape (strategy, shards, chunk budget — resolved or
    # not) is numerics-neutral, so none of it may leak into cache keys or
    # ETag material: a spill written on a big-memory host under "local"
    # stays warm on a small sharded mesh, and a client cache survives a
    # server-side strategy change.
    for cfg in (
        EngineConfig(strategy="chunked", max_batch="auto"),
        EngineConfig(strategy="composed", max_batch=128, num_shards=8),
        EngineConfig(strategy="local"),
    ):
        eng = EstimationEngine(cfg)
        assert eng.cache_key == ("auto",)
        assert eng.cache_token == "k.ref"  # resolved backend, nothing else
    assert EstimationEngine(EngineConfig(backend="ref")).cache_key == ("ref",)


def test_auto_budget_chunked_parity_with_local():
    local = EstimationEngine(EngineConfig(strategy="local"))
    auto = EstimationEngine(EngineConfig(strategy="chunked", max_batch="auto"))
    auto._auto_budgets = {1: 2}  # force real chunking at test width
    cols = _columns(7)
    packer = BatchPacker()
    batch = packer.pack(cols)
    for mode in ("paper", "improved"):
        ref = local.estimate(batch, mode=mode)
        got = auto.estimate(batch, mode=mode)
        for f_ref, f_got in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(f_ref), np.asarray(f_got))
