"""Property tests for the composed execution plan (hypothesis-gated).

The composed strategy's whole correctness burden sits on `composed_plan`:
if every column lands in exactly one span, every span is the same width,
and every shard's slice of every span is a whole number of equal chunks,
then the executor is just the (already parity-proven) sharded dispatch
looped over spans. So the shape math gets the exhaustive treatment.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.engine import composed_plan

pow2 = st.integers(0, 12).map(lambda e: 1 << e)


@settings(max_examples=300, deadline=None)
@given(width=pow2, chunk=pow2, shards=pow2)
def test_composed_plan_covers_each_column_once_no_ragged_tail(
    width, chunk, shards
):
    padded, spans = composed_plan(width, shards, chunk)

    # every real column is covered, and padding stays bounded: less than
    # one extra stride (or shard group, on the single-dispatch path)
    assert padded >= width
    stride = shards * chunk
    assert padded - width < (stride if width > stride else shards)

    # spans tile [0, padded) exactly once, in order, equal widths
    assert spans[0][0] == 0 and spans[-1][1] == padded
    widths = {hi - lo for lo, hi in spans}
    assert len(widths) == 1  # one jit trace shape
    for (_, hi), (lo2, _) in zip(spans, spans[1:]):
        assert hi == lo2  # no gap, no overlap

    # every shard's slice of every span is equal-width with no ragged
    # tail, and multi-span plans never exceed the per-shard chunk budget
    (span_width,) = widths
    assert span_width % shards == 0
    per_shard = span_width // shards
    assert per_shard <= chunk
    if len(spans) > 1:
        assert per_shard == chunk  # full chunks only — one trace shape


@settings(max_examples=100, deadline=None)
@given(
    width=st.integers(1, 5000),
    chunk=pow2,
    shards=st.integers(1, 9),
)
def test_composed_plan_holds_for_non_pow2_widths_and_shards(
    width, chunk, shards
):
    # The packer buckets B to a power of two, but the plan must stay sound
    # for any width/shard count (e.g. a 3-device mesh, an unbucketed pack).
    padded, spans = composed_plan(width, shards, chunk)
    assert padded >= width and padded % shards == 0
    covered = 0
    for lo, hi in spans:
        assert lo == covered and (hi - lo) % shards == 0
        assert (hi - lo) // shards <= chunk
        covered = hi
    assert covered == padded
