"""End-to-end estimator tests against ground truth (paper §10 claims)."""
import os

import numpy as np
import pytest

from repro.columnar import (
    column_metadata_from_footer,
    read_footer,
    write_file,
)
from repro.columnar.generator import (
    int_domain,
    partitioned_column,
    sorted_column,
    string_domain,
    uniform_column,
    zipf_column,
)
from repro.columnar.writer import WriterOptions
from repro.core import Layout, estimate_columns

ROWS = 1 << 16
RG = 4096


def _estimate(tmp_path, cols, mode="paper"):
    write_file(str(tmp_path / "f"), cols, options=WriterOptions(row_group_size=RG))
    footer = read_footer(str(tmp_path / "f"))
    metas = [column_metadata_from_footer(footer, n) for n in footer.column_names]
    return {e.column_name: e for e in estimate_columns(metas, mode=mode)}


def test_well_spread_under_10pct(tmp_path):
    """Paper §10.1: errors typically below 10% for well-spread columns.

    Paper mode needs rows-per-group >> ndv (chunk dictionaries then cover
    the domain: the regime the paper's production data was in). The
    coverage-limited regime is characterized in benchmarks/accuracy.py,
    where the improved mode repairs it.
    """
    dom = int_domain(1000, seed=1)
    vals, truth = uniform_column(dom, ROWS, seed=2)
    # uniform-length strings: representative extrema lengths (Eq 4's
    # assumption; the heavy-tailed case is characterized in benchmarks)
    sdom = string_domain(500, seed=3, dist="uniform")
    svals, struth = zipf_column(sdom, ROWS, seed=4)
    for mode in ("paper", "improved"):
        est = _estimate(tmp_path, {"u": vals, "z": svals}, mode=mode)
        assert abs(est["u"].ndv - truth) / truth < 0.10, (mode, est["u"])
        assert abs(est["z"].ndv - struth) / struth < 0.10, (mode, est["z"])
    # the improved coverage correction is accurate even at ratio ~2
    dom2 = int_domain(2000, seed=5)
    vals2, truth2 = uniform_column(dom2, ROWS, seed=6)
    est2 = _estimate(tmp_path, {"u": vals2}, mode="improved")["u"]
    assert abs(est2.ndv - truth2) / truth2 < 0.05, est2


def test_sorted_underestimation_and_repair(tmp_path):
    """Paper Table 1: dict inversion underestimates sorted data; the
    improved layout-aware aggregation repairs it."""
    dom = int_domain(3000, seed=5)
    vals, truth = sorted_column(dom, ROWS, seed=6)
    paper = _estimate(tmp_path, {"s": vals}, mode="paper")["s"]
    improved = _estimate(tmp_path, {"s": vals}, mode="improved")["s"]
    assert paper.layout == Layout.SORTED
    # dictionary inversion alone underestimates on sorted layouts
    assert paper.ndv_dict < 0.5 * truth
    # improved disjoint-sum aggregation is tight
    assert abs(improved.ndv - truth) / truth < 0.05, improved


def test_partitioned_improved(tmp_path):
    dom = int_domain(3000, seed=7)
    vals, truth = partitioned_column(dom, ROWS, partitions=16, seed=8)
    improved = _estimate(tmp_path, {"p": vals}, mode="improved")["p"]
    assert abs(improved.ndv - truth) / truth < 0.15, improved


def test_final_never_exceeds_rows(tmp_path):
    dom = int_domain(50, seed=9)
    vals, truth = uniform_column(dom, 256, seed=10)
    est = _estimate(tmp_path, {"t": vals})["t"]
    assert est.ndv <= 256


def test_unique_column_flags_lower_bound(tmp_path):
    """All-distinct int64 column: dictionary page overflows the 1MiB limit
    -> plain fallback -> estimate marked as a lower bound (Eq 5)."""
    vals = (np.random.default_rng(0).permutation(1 << 18) * 3 + 7).astype(np.int64)
    write_file(
        str(tmp_path / "u"), {"ids": vals},
        options=WriterOptions(row_group_size=1 << 18),
    )
    footer = read_footer(str(tmp_path / "u"))
    meta = column_metadata_from_footer(footer, "ids")
    est = estimate_columns([meta])[0]
    assert est.is_lower_bound


def test_range_bound_integer(tmp_path):
    """Eq 14: dense integer range caps the estimate."""
    vals = np.random.default_rng(1).integers(0, 100, ROWS).astype(np.int64)
    est = _estimate(tmp_path, {"r": vals})["r"]
    assert est.ndv <= 100.0 + 1


def test_nulls_respected(tmp_path):
    dom = int_domain(500, seed=11)
    vals, truth = uniform_column(dom, ROWS, seed=12)
    mask = np.random.default_rng(2).uniform(size=ROWS) < 0.3
    write_file(
        str(tmp_path / "n"), {"c": vals}, null_masks={"c": mask},
        options=WriterOptions(row_group_size=RG),
    )
    footer = read_footer(str(tmp_path / "n"))
    meta = column_metadata_from_footer(footer, "c")
    assert meta.null_count == int(mask.sum())
    est = estimate_columns([meta])[0]
    assert abs(est.ndv - truth) / truth < 0.15
