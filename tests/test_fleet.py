"""Fleet tier: registry, rendezvous placement, failover, ETag stability.

Covers the serving acceptance criteria of the fleet subsystem:
  * two independently-constructed `StatsService`s over one dataset emit
    byte-identical ETags and bodies for identical requests — the property
    every router failover and client revalidation relies on
  * the router serves >=2 datasets x >=2 replicas; killing a replica
    mid-burst loses no requests (failover retries succeed) and the old
    ETag still revalidates 304 on the survivor
  * a freshly started replica serves its first estimate from the shared
    on-disk spill with zero engine packs
  * rendezvous hashing is deterministic, spreads distinct identities, and
    moves only the ejected replica's keys
"""
import concurrent.futures
import os
import threading

import numpy as np
import pytest

from repro.columnar.writer import WriterOptions, write_file
from repro.engine import EngineConfig
from repro.fleet import (
    DatasetRegistry,
    DatasetSpec,
    Fleet,
    LocalReplica,
    NoReplicaAvailable,
    RemoteReplica,
    ReplicaSet,
    StatsRequest,
    StatsRouter,
    parse_spec,
)
from repro.service import StatsServer, StatsService, fetch_json


def _write(root, name, seed, vocab=64):
    rng = np.random.default_rng(seed)
    return write_file(
        os.path.join(root, name),
        {
            "tok": rng.integers(0, vocab, 512).astype(np.int64),
            "val": np.round(rng.uniform(0, 100, 512), 1),
        },
        options=WriterOptions(row_group_size=128),
    )


@pytest.fixture()
def dataset(tmp_path):
    root = str(tmp_path / "ds")
    for i in range(3):
        _write(root, f"shard_{i:03d}", seed=i)
    return root


@pytest.fixture()
def registry(tmp_path):
    reg = DatasetRegistry()
    for name, seed in (("alpha", 10), ("beta", 20)):
        root = str(tmp_path / name)
        for i in range(2):
            _write(root, f"shard_{i:03d}", seed=seed + i, vocab=32 * (seed + 1))
        reg.add("wh", name, root)
    return reg


@pytest.fixture()
def routed(registry):
    router = StatsRouter(Fleet(registry, replicas_per_dataset=2)).start()
    yield router
    router.stop()


# -- ETag stability across replicas (the failover invariant) -----------------


def test_etags_byte_identical_across_independent_services(dataset):
    # Two services, two engines, two ingestion passes — zero shared state
    # beyond the dataset directory. Identical requests must produce
    # byte-identical ETags AND bodies, or router failover would invalidate
    # every client cache.
    a = StatsService(dataset)
    b = StatsService(dataset)
    a.start(), b.start()
    try:
        for kind, kwargs in (
            ("columns", {}),
            ("estimate", {"mode": "paper"}),
            ("estimate", {"mode": "improved"}),
            ("estimate", {"mode": "paper", "schema_bounds": {"tok": 9.0}}),
            ("plan", {"mode": "improved"}),
        ):
            ra = getattr(a, kind)(**kwargs)
            rb = getattr(b, kind)(**kwargs)
            assert ra.etag == rb.etag and ra.etag, (kind, kwargs)
            assert ra.body == rb.body, (kind, kwargs)
            # a tag minted by a validates on b (and vice versa): 304
            assert getattr(b, kind)(
                **kwargs, if_none_match=ra.etag
            ).status == 304
            assert getattr(a, kind)(
                **kwargs, if_none_match=rb.etag
            ).status == 304
    finally:
        a.stop(), b.stop()


def test_etags_byte_identical_across_engine_strategies(dataset):
    # The parity contract extended to the wire: a composed replica and a
    # local replica of one dataset are interchangeable — byte-identical
    # ETags and bodies, cross-validating 304s — so migrating a dataset's
    # EngineConfig between strategies invalidates no client cache.
    from repro.engine import EngineConfig, EstimationEngine

    a = StatsService(dataset)  # default engine: strategy "auto"
    b = StatsService(
        dataset,
        engine=EstimationEngine(
            EngineConfig(strategy="composed", max_batch=2)
        ),
    )
    a.start(), b.start()
    try:
        for kind, kwargs in (
            ("estimate", {"mode": "paper"}),
            ("estimate", {"mode": "improved"}),
            ("plan", {"mode": "paper"}),
        ):
            ra = getattr(a, kind)(**kwargs)
            rb = getattr(b, kind)(**kwargs)
            assert ra.etag == rb.etag and ra.etag, (kind, kwargs)
            assert ra.body == rb.body, (kind, kwargs)
            assert getattr(b, kind)(
                **kwargs, if_none_match=ra.etag
            ).status == 304
    finally:
        a.stop(), b.stop()


# -- registry ----------------------------------------------------------------


def test_registry_validation_and_parse_spec(tmp_path):
    reg = DatasetRegistry()
    spec = reg.add("wh", "lineitem", str(tmp_path))
    assert spec.key == "wh/lineitem" and "wh/lineitem" in reg
    assert reg.get("wh", "lineitem") is spec
    with pytest.raises(ValueError, match="already registered"):
        reg.add("wh", "lineitem", str(tmp_path))
    with pytest.raises(KeyError, match="not registered"):
        reg.get("wh", "nope")
    with pytest.raises(ValueError, match="path segment"):
        DatasetSpec("bad/ns", "x", str(tmp_path))
    with pytest.raises(ValueError, match="path segment"):
        DatasetSpec("wh", "", str(tmp_path))

    assert parse_spec("wh/li=/data/x") == ("wh", "li", "/data/x")
    for bad in ("wh/li", "noslash=/x", "wh/li=", "a/b c=/x"):
        with pytest.raises(ValueError):
            parse_spec(bad)


# -- rendezvous placement ----------------------------------------------------


class _StubReplica:
    def __init__(self, name, fail=False):
        self.name = name
        self.fail = fail
        self.calls = 0

    def start(self):
        return self

    def stop(self):
        pass

    def probe(self):
        return not self.fail

    def handle(self, req):
        self.calls += 1
        if self.fail:
            raise ConnectionError(f"{self.name} down")
        from repro.service import Response

        return Response(200, {"replica": self.name}, '"tag"')


def test_rendezvous_placement_deterministic_spreads_and_moves_minimally():
    names = [f"r{i}" for i in range(4)]
    rset = ReplicaSet("wh/a", [_StubReplica(n) for n in names])
    identities = [("estimate", m, b) for m in ("paper", "improved")
                  for b in [(), (("tok", 2.0),), (("val", 8.0),)]]
    placement = {i: rset.rank(i)[0].name for i in identities}
    # deterministic: an independently-built set places identically
    rset2 = ReplicaSet("wh/a", [_StubReplica(n) for n in names])
    assert placement == {i: rset2.rank(i)[0].name for i in identities}
    # spreads: more than one replica owns something across identities
    assert len(set(placement.values())) > 1
    # minimal movement: ejecting one replica only moves its own keys
    victim = placement[identities[0]]
    survivors = [r for r in rset.replicas if r.name != victim]
    rset3 = ReplicaSet("wh/a", survivors)
    for ident, owner in placement.items():
        if owner != victim:
            assert rset3.rank(ident)[0].name == owner
    # a different dataset key reshuffles placement independently
    other = ReplicaSet("wh/b", [_StubReplica(n) for n in names])
    assert any(
        other.rank(i)[0].name != placement[i] for i in identities
    )


def test_replica_set_failover_ejection_and_rejoin():
    good, bad = _StubReplica("good"), _StubReplica("bad", fail=True)
    rset = ReplicaSet("wh/a", [bad, good])
    req = StatsRequest("estimate", "paper")
    for _ in range(4):
        resp, name, _ = rset.call(req)
        assert resp.status == 200 and name == "good"
    # the failing replica was ejected after the first attempt: exactly one
    # failed call ever reached it
    assert bad.calls <= 1 and rset.failovers >= 1
    assert rset.health["bad"].healthy is False
    # probe_all rejoins it once it recovers
    bad.fail = False
    assert rset.probe_all() == {"bad": True, "good": True}
    assert rset.health["bad"].healthy is True
    # all-down set raises with every replica's error
    good.fail = bad.fail = True
    with pytest.raises(NoReplicaAvailable, match="all 2 replicas"):
        rset.call(req)


# -- router HTTP e2e ---------------------------------------------------------


def test_router_serves_datasets_and_survives_replica_kill(routed):
    # both datasets serve through one endpoint with distinct estimates
    bodies = {}
    for name in ("alpha", "beta"):
        url = routed.url_for("wh", name, "estimate") + "?mode=improved"
        status, etag, body = fetch_json(url)
        assert status == 200 and etag and body["estimates"]
        bodies[name] = (etag, body)
    assert bodies["alpha"][1] != bodies["beta"][1]

    status, _, listing = fetch_json(routed.url + "/datasets")
    assert status == 200
    assert [d["key"] for d in listing["datasets"]] == ["wh/alpha", "wh/beta"]
    assert all(d["healthy"] == 2 for d in listing["datasets"])

    # kill the replica that owns alpha's placement, then hammer the route
    # concurrently: every request must succeed via failover
    rset = routed.fleet.sets["wh/alpha"]
    victim = rset.rank(StatsRequest("estimate", "improved").identity)[0]
    victim.kill()
    url = routed.url_for("wh", "alpha", "estimate") + "?mode=improved"
    with concurrent.futures.ThreadPoolExecutor(8) as pool:
        results = list(pool.map(lambda _: fetch_json(url), range(16)))
    assert all(status == 200 for status, _, _ in results)
    assert all(etag == bodies["alpha"][0] for _, etag, _ in results)
    assert all(body == bodies["alpha"][1] for _, _, body in results)
    assert rset.failovers >= 1
    assert rset.health[victim.name].healthy is False

    # the pre-kill ETag revalidates 304 on the survivor — client caches
    # survive the failover byte-for-byte
    status, etag, _ = fetch_json(url, etag=bodies["alpha"][0])
    assert status == 304 and etag == bodies["alpha"][0]

    # beta never noticed
    status, etag, body = fetch_json(
        routed.url_for("wh", "beta", "estimate") + "?mode=improved",
        etag=bodies["beta"][0],
    )
    assert status == 304

    # /health reports the degraded set but keeps serving
    status, _, health = fetch_json(routed.url + "/health")
    assert status == 200 and health["status"] == "serving"
    assert health["datasets"]["wh/alpha"]["healthy"] == 1
    assert health["router"]["retried"] >= 1

    # revived replica rejoins on probe and serves the same tags
    victim.revive()
    routed.fleet.probe_all()
    assert rset.health[victim.name].healthy is True
    assert victim.handle(
        StatsRequest("estimate", "improved", if_none_match=bodies["alpha"][0])
    ).status == 304


def test_router_refresh_broadcast_keeps_replica_etags_aligned(routed, registry):
    url = routed.url_for("wh", "alpha", "estimate") + "?mode=paper"
    _, etag, _ = fetch_json(url)
    # dataset change: the old tag must rotate on EVERY replica, or a later
    # failover would serve a stale 304
    _write(registry.get("wh", "alpha").root, "shard_new", seed=99)
    status, _, body = fetch_json(
        routed.url + "/wh/alpha/refresh", method="POST"
    )
    assert status == 200
    summaries = body["refreshed"]["wh/alpha"]
    assert len(summaries) == 2
    assert all(s["added"] == 1 for s in summaries.values())
    for replica in routed.fleet.sets["wh/alpha"].replicas:
        resp = replica.handle(
            StatsRequest("estimate", "paper", if_none_match=etag)
        )
        assert resp.status == 200 and resp.etag != etag
    # global refresh touches every dataset
    status, _, body = fetch_json(routed.url + "/refresh", method="POST")
    assert status == 200 and set(body["refreshed"]) == {"wh/alpha", "wh/beta"}


def test_router_error_paths(routed):
    status, _, body = fetch_json(routed.url + "/wh/nope/estimate")
    assert status == 404 and "not registered" in body["error"]
    status, _, _ = fetch_json(routed.url + "/no/such/route/at/all")
    assert status == 404
    status, _, body = fetch_json(
        routed.url + "/wh/alpha/estimate?bounds=junk"
    )
    assert status == 400 and "bounds" in body["error"]
    status, _, body = fetch_json(
        routed.url + "/wh/alpha/estimate?mode=bogus"
    )
    assert status == 400
    # all replicas of one dataset down -> 503 for it, degraded /health,
    # but the sibling dataset keeps serving
    for replica in routed.fleet.sets["wh/alpha"].replicas:
        replica.kill()
    status, _, body = fetch_json(routed.url + "/wh/alpha/estimate")
    assert status == 503 and "all 2 replicas" in body["error"]
    status, _, health = fetch_json(routed.url + "/health")
    assert health["status"] == "degraded"
    status, _, _ = fetch_json(routed.url_for("wh", "beta", "estimate"))
    assert status == 200


# -- shared-spill warm start -------------------------------------------------


def test_fresh_replica_first_estimate_zero_packs(routed, registry):
    url = routed.url_for("wh", "alpha", "estimate") + "?mode=improved"
    _, etag, body = fetch_json(url)
    fresh = LocalReplica(
        "wh/alpha#fresh", registry.get("wh", "alpha").root
    ).start()
    try:
        resp = fresh.handle(StatsRequest("estimate", "improved"))
        assert resp.status == 200
        assert resp.etag == etag and resp.body["estimates"] == body["estimates"]
        assert fresh.service.catalog.stats.packs == 0
        assert fresh.service.catalog.stats.estimate_cache_hits == 1
    finally:
        fresh.stop()


def test_running_replica_picks_up_sibling_spill_without_engine_run(dataset):
    # Replica A boots first (nothing spilled yet), THEN replica B computes
    # and spills: A's cold path must re-check the shared spill and serve
    # B's entry without an engine run of its own.
    a = LocalReplica("ds#a", dataset).start()
    b = LocalReplica("ds#b", dataset).start()
    try:
        b.handle(StatsRequest("estimate", "improved"))
        resp = a.handle(StatsRequest("estimate", "improved"))
        assert resp.status == 200
        assert a.service.catalog.stats.packs == 0
        assert a.service.stats.engine_runs == 0
        assert a.service.stats.spill_reloads == 1
    finally:
        a.stop(), b.stop()


# -- RemoteReplica proxying --------------------------------------------------


def test_remote_replica_proxies_and_fails_over(dataset):
    with StatsServer(StatsService(dataset)) as upstream:
        remote = RemoteReplica("up", upstream.url)
        dead = RemoteReplica("dead", "http://127.0.0.1:9")  # discard port
        assert remote.probe() is True and dead.probe() is False
        rset = ReplicaSet("wh/a", [dead, remote])
        req = StatsRequest(
            "estimate", "improved", schema_bounds=(("tok", 7.0),)
        )
        resp, name, _ = rset.call(req)
        assert resp.status == 200 and name == "up"
        assert resp.body["schema_bounds"] == {"tok": 7.0}
        # If-None-Match forwards through the proxy
        resp2, _, _ = rset.call(StatsRequest(
            "estimate", "improved", schema_bounds=(("tok", 7.0),),
            if_none_match=resp.etag,
        ))
        assert resp2.status == 304 and resp2.etag == resp.etag
        assert rset.health["dead"].healthy is False


def test_request_scoped_errors_propagate_without_ejection():
    # A deterministic per-request failure (every replica would fail it
    # identically) must NOT eject anyone — one poison request must not
    # degrade the set. Transport failures still do.
    class _Poisoned(_StubReplica):
        def handle(self, req):
            self.calls += 1
            raise ValueError("dataset schema mismatch")

    rset = ReplicaSet("wh/a", [_Poisoned("r0"), _Poisoned("r1")])
    with pytest.raises(ValueError, match="schema mismatch"):
        rset.call(StatsRequest("estimate"))
    assert rset.failovers == 0
    assert all(h.healthy for h in rset.health.values())
    # exactly one replica was attempted: no retry cascade either
    assert sum(r.calls for r in rset.replicas) == 1


def test_remote_replica_percent_encodes_bounds(dataset):
    # bounds values with URL metacharacters must round-trip through the
    # proxy intact (and must not raise mid-URL-construction).
    with StatsServer(StatsService(dataset)) as upstream:
        remote = RemoteReplica("up", upstream.url)
        bounds = (("tok", 7.5),)
        resp = remote.handle(StatsRequest(
            "estimate", "improved",
            schema_bounds=(("a&b=c d", 3.0),) + bounds,
        ))
        assert resp.status == 200
        assert resp.body["schema_bounds"] == {"a&b=c d": 3.0, "tok": 7.5}


def test_remote_replica_passes_5xx_through_without_ejection():
    # An upstream 500 is an application/dataset error (every replica would
    # produce it identically) — it must relay as-is, not eject the set.
    import json as _json
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class _AlwaysFailing(BaseHTTPRequestHandler):
        def log_message(self, *a):
            pass

        def do_GET(self):
            payload = _json.dumps({"error": "ValueError: schema"}).encode()
            self.send_response(500)
            self.send_header("Content-Length", str(len(payload)))
            self.end_headers()
            self.wfile.write(payload)

    httpd = ThreadingHTTPServer(("127.0.0.1", 0), _AlwaysFailing)
    t = threading.Thread(target=httpd.serve_forever, daemon=True)
    t.start()
    try:
        url = f"http://127.0.0.1:{httpd.server_address[1]}"
        rset = ReplicaSet("wh/a", [RemoteReplica("sick", url)])
        resp, name, attempts = rset.call(StatsRequest("estimate"))
        assert resp.status == 500 and "schema" in resp.body["error"]
        assert rset.failovers == 0
        assert rset.health["sick"].healthy is True
    finally:
        httpd.shutdown()
        httpd.server_close()


# -- estimation-quality observability across the fleet (ISSUE 9) --------------


@pytest.fixture()
def audited_router(registry):
    router = StatsRouter(
        Fleet(registry, replicas_per_dataset=2, audit=True, audit_columns=4)
    ).start()
    yield router
    router.stop()


def _run_audits(fleet):
    for rset in fleet.sets.values():
        for rep in rset.replicas:
            rep.service.run_audit()


def test_routed_explain_same_etag_and_stripped_body(routed):
    url = routed.url_for("wh", "alpha", "estimate") + "?mode=improved"
    status, etag, plain = fetch_json(url)
    assert status == 200
    status, etag_e, explained = fetch_json(url + "&explain=1")
    assert status == 200 and etag_e == etag
    assert explained["provenance"].keys() == plain["estimates"].keys()
    assert {k: v for k, v in explained.items() if k != "provenance"} == plain
    status, _, body = fetch_json(url + "&explain=junk")
    assert status == 400 and "error" in body


def test_batch_per_tuple_explain(routed):
    from repro.wire import ConnectionPool, fetch

    pool = ConnectionPool()
    try:
        status, _, env = fetch(
            routed.url + "/batch", pool=pool, method="POST",
            payload={"tuples": [
                {"namespace": "wh", "dataset": "alpha", "mode": "paper",
                 "explain": True},
                {"namespace": "wh", "dataset": "alpha", "mode": "paper"},
                {"namespace": "wh", "dataset": "beta", "mode": "paper",
                 "columns": ["tok"], "explain": True},
            ]},
        )
        assert status == 200
        bodies = [e["body"] for e in env["responses"]]
        assert "provenance" in bodies[0]
        assert "provenance" not in bodies[1]
        assert set(bodies[2]["provenance"]) == {"tok"}
        # the unexplained tuple's body+etag match the explained one stripped
        stripped = {k: v for k, v in bodies[0].items() if k != "provenance"}
        assert stripped == bodies[1]
        assert env["responses"][0]["etag"] == env["responses"][1]["etag"]
    finally:
        pool.close()


def test_router_debug_explain_aggregates_replicas(audited_router):
    fleet = audited_router.fleet
    for key in ("wh/alpha", "wh/beta"):
        ns, name = key.split("/")
        fetch_json(audited_router.url_for(ns, name, "estimate"))
    _run_audits(fleet)
    status, _, body = fetch_json(audited_router.url + "/debug/explain")
    assert status == 200
    assert set(body["datasets"]) == {"wh/alpha", "wh/beta"}
    for key, per_replica in body["datasets"].items():
        assert len(per_replica) == 2, (key, list(per_replica))
        for payload in per_replica.values():
            assert "entries" in payload and "audits" in payload
            assert payload["audits"], "audit samples missing from aggregation"

    # namespace+dataset narrowing
    status, _, body = fetch_json(
        audited_router.url + "/debug/explain?namespace=wh&dataset=beta"
    )
    assert status == 200 and set(body["datasets"]) == {"wh/beta"}


def test_router_debug_endpoints_hardened(routed):
    for q in ("limit=-1", "limit=abc", "limit="):
        status, _, body = fetch_json(routed.url + f"/debug/traces?{q}")
        assert status == 400 and "error" in body, q
    status, _, body = fetch_json(routed.url + "/debug/explain?dataset=nope")
    assert status == 404
    for q in ("dataset=", "namespace=", "namespace=wh"):
        status, _, body = fetch_json(routed.url + f"/debug/explain?{q}")
        assert status == 400 and "error" in body, q


def test_fleet_batch_explain_feeds_router_metrics(audited_router):
    """E2E: /batch with explain + audits show up in the router's /metrics."""
    import urllib.request

    from repro.wire import ConnectionPool, fetch

    _run_audits(audited_router.fleet)
    pool = ConnectionPool()
    try:
        status, _, env = fetch(
            audited_router.url + "/batch", pool=pool, method="POST",
            payload={"tuples": [
                {"namespace": "wh", "dataset": "alpha", "mode": "paper",
                 "explain": True},
                {"namespace": "wh", "dataset": "beta", "mode": "improved",
                 "explain": True},
            ]},
        )
        assert status == 200
        assert all(e["status"] == 200 for e in env["responses"])
        assert all("provenance" in e["body"] for e in env["responses"])
    finally:
        pool.close()
    with urllib.request.urlopen(audited_router.url + "/metrics") as r:
        text = r.read().decode()
    assert "ndv_route_total" in text and 'route="' in text
    assert "ndv_newton_iters" in text
    assert "ndv_detector_margin" in text
    assert "ndv_audit_qerror" in text
