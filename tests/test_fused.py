"""Fused estimation megakernel + device-resident catalog batches.

Covers the acceptance criteria of the fusion PR:
  * fuse=on vs fuse=off is bit-identical through the real jitted entry
    (`estimate_batch`) and through engines — `test_fused_parity_matrix` in
    test_engine.py runs the strategy-level cells under the CI matrix.
  * the interpret-mode megakernel agrees with its pure-XLA twin
    (`ref_fused_estimate`) exactly on discrete fields and last-ulp-tight on
    floats — the same kernel-vs-oracle contract every kernel here carries.
  * `fuse` never enters engine cache identity (`cache_key`/`cache_token`).
  * the catalog's device-resident batch tier: one `jax.device_put` per
    fingerprint generation, zero host-to-device transfers on the warm
    estimate path (asserted under `jax.transfer_guard_host_to_device`),
    residency dropped when a commit changes the dataset.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.catalog import BatchPacker, StatsCatalog
from repro.columnar import write_file
from repro.columnar.writer import WriterOptions
from repro.core.ndv.estimator import estimate_batch
from repro.core.ndv.types import ColumnMetadata, PhysicalType
from repro.engine import EngineConfig, EstimationEngine
from repro.kernels import ops


def _column(seed: int, r: int) -> ColumnMetadata:
    rng = np.random.default_rng(seed)
    mins = np.sort(rng.uniform(0, 1e5, r))
    return ColumnMetadata(
        chunk_sizes=rng.uniform(2_000.0, 90_000.0, r),
        chunk_rows=np.full(r, 4096.0),
        chunk_nulls=rng.integers(0, 64, r).astype(np.float64),
        chunk_dict_encoded=rng.uniform(size=r) > 0.2,
        mins=mins,
        maxs=mins + rng.uniform(10.0, 1e4, r),
        min_lengths=np.full(r, 8.0),
        max_lengths=np.full(r, 8.0),
        distinct_min_count=float(max(r - 1, 1)),
        distinct_max_count=float(r),
        physical_type=PhysicalType.INT64,
        column_name=f"c{seed}",
    )


def _batch(width: int):
    cols = [_column(i, r=1 + (i % 7)) for i in range(width)]
    return BatchPacker(bucket_cols=False, bucket_rows=False).pack(cols)


# -- fuse knob: bit-neutrality through the real entry point -------------------


@pytest.mark.parametrize("mode", ["paper", "improved"])
@pytest.mark.parametrize("width", [3, 13, 64])
def test_fuse_on_off_bitwise_identical(mode, width):
    """fuse=on must be indistinguishable from fuse=off, field by field."""
    batch = _batch(width)
    on = estimate_batch(batch, None, mode=mode, fuse="on")
    off = estimate_batch(batch, None, mode=mode, fuse="off")
    for field in on._fields:
        a = np.asarray(getattr(on, field))
        b = np.asarray(getattr(off, field))
        assert np.array_equal(a, b), (mode, width, field)


def test_fuse_on_off_bitwise_identical_with_schema_bounds():
    batch = _batch(9)
    sb = jnp.asarray(
        np.where(np.arange(9) % 3 == 0, 5.0, np.inf).astype(np.float32)
    )
    on = estimate_batch(batch, sb, fuse="on")
    off = estimate_batch(batch, sb, fuse="off")
    assert np.array_equal(np.asarray(on.ndv), np.asarray(off.ndv))


def test_use_fused_rejects_unknown_modes():
    with pytest.raises(ValueError, match="fuse"):
        ops.use_fused("sometimes")
    assert ops.use_fused("on") is True
    assert ops.use_fused("off") is False


def test_fuse_absent_from_engine_identity():
    """A fuse flip must not cool any cache line or client ETag."""
    base = EstimationEngine(EngineConfig(fuse="auto"))
    for fuse in ("on", "off"):
        other = EstimationEngine(EngineConfig(fuse=fuse))
        assert other.cache_key == base.cache_key
        assert other.cache_token == base.cache_token


# -- megakernel vs twin (kernel-vs-oracle contract) ---------------------------


_EXACT_FIELDS = (
    "layout", "is_lower_bound", "dict_iterations",
    # provenance lanes (ISSUE 9): discrete diagnostics must agree exactly
    "route", "coupon_iterations", "clamp_flags",
)
_FLOAT_FIELDS = (
    "ndv", "ndv_dict", "ndv_minmax", "confidence",
    "overlap_ratio", "monotonicity", "mean_len",
    # provenance lanes (ISSUE 9): margins/residuals to kernel tightness
    "route_margin", "detector_margin", "dict_residual",
)


@pytest.mark.parametrize("mode", ["paper", "improved"])
@pytest.mark.parametrize("width", [5, 13, 64])
def test_fused_kernel_matches_twin(mode, width):
    """Interpret-mode megakernel vs `ref_fused_estimate`, whole pipeline.

    Discrete outputs must agree exactly; float outputs to the usual
    kernel-vs-oracle tightness (the pallas_call wrapping shifts codegen
    context, which can move transcendental tails by an ulp).
    """
    batch = _batch(width)
    kern = ops.fused_estimate(batch, None, mode=mode, backend="pallas")
    twin = ops.fused_estimate(batch, None, mode=mode, backend="ref")
    for field in _EXACT_FIELDS:
        a = np.asarray(getattr(kern, field))
        b = np.asarray(getattr(twin, field))
        assert np.array_equal(a, b), (mode, width, field)
    for field in _FLOAT_FIELDS:
        np.testing.assert_allclose(
            np.asarray(getattr(kern, field)),
            np.asarray(getattr(twin, field)),
            rtol=1e-5, atol=1e-6, err_msg=f"{mode}/{width}/{field}",
        )


def test_fused_twin_is_the_unfused_reference_path():
    """Off-TPU serving contract: the fused route IS the reference program."""
    batch = _batch(11)
    twin = ops.fused_estimate(batch, None, mode="paper", backend="auto")
    unfused = estimate_batch(batch, None, mode="paper", fuse="off")
    for field in twin._fields:
        assert np.array_equal(
            np.asarray(getattr(twin, field)),
            np.asarray(getattr(unfused, field)),
        ), field


# -- device-resident catalog batches ------------------------------------------


def _shard(seed, rows=512, vocab=64):
    rng = np.random.default_rng(seed)
    return {
        "tok": rng.integers(0, vocab, rows).astype(np.int64),
        "val": np.round(rng.uniform(0, 100, rows), 1),
    }


@pytest.fixture()
def dataset(tmp_path):
    for i in range(3):
        write_file(
            str(tmp_path / f"shard_{i:03d}"), _shard(i),
            options=WriterOptions(row_group_size=128),
        )
    return str(tmp_path)


def test_warm_estimate_has_zero_host_to_device_transfers(dataset):
    catalog = StatsCatalog(dataset)
    first = catalog.estimate()
    assert catalog.stats.device_puts == 1
    assert catalog.num_resident_batches == 1
    # Force the full estimation path (not just the estimate-cache dict hit):
    # the resident tier must carry it without a single H2D transfer.
    catalog._estimate_cache.clear()
    with jax.transfer_guard_host_to_device("disallow"):
        second = catalog.estimate()
    assert second == first
    assert catalog.stats.device_puts == 1   # no re-transfer
    assert catalog.stats.resident_hits >= 1


def test_residency_dropped_when_commit_changes_fingerprint(dataset, tmp_path):
    catalog = StatsCatalog(dataset)
    catalog.estimate()
    assert catalog.num_resident_batches == 1
    # Grow the dataset: the commit changes the fingerprint set, so the
    # resident device arrays for the old generation must be released.
    write_file(
        str(tmp_path / "shard_new"), _shard(99),
        options=WriterOptions(row_group_size=128),
    )
    summary = catalog.update()
    assert summary.changed
    assert catalog.num_resident_batches == 0
    catalog.estimate()
    assert catalog.stats.device_puts == 2
    assert catalog.num_resident_batches == 1


def test_unchanged_commit_keeps_residency(dataset):
    catalog = StatsCatalog(dataset)
    catalog.estimate()
    summary = catalog.update()   # nothing changed on disk
    assert not summary.changed
    assert catalog.num_resident_batches == 1


def test_compact_caches_drops_stale_resident_entries(dataset, tmp_path):
    catalog = StatsCatalog(dataset)
    catalog.estimate()
    stale = catalog.fingerprint_key()
    # Simulate a foreign key surviving in the resident tier (e.g. loaded
    # under compact=False semantics): compaction must evict it.
    catalog._resident_cache[frozenset({"ghost@deadbeef"})] = (
        catalog._resident_cache[stale]
    )
    assert catalog.num_resident_batches == 2
    assert catalog.compact_caches() >= 1
    assert catalog.num_resident_batches == 1
