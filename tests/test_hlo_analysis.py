"""Unit tests for the trip-count-aware HLO cost analyzer (roofline input)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze

D = 256


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    w = jax.ShapeDtypeStruct((D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    c = analyze(_compile_text(lambda w, x: x @ w, w, x))
    assert c.flops == pytest.approx(2 * D**3, rel=1e-6)


def test_scan_trip_multiplier():
    w = jax.ShapeDtypeStruct((D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def scanned(w, x):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y

    c1 = analyze(_compile_text(lambda w, x: x @ w, w, x))
    c2 = analyze(_compile_text(scanned, w, x))
    assert c2.flops / c1.flops == pytest.approx(12.0, rel=0.05)


def test_nested_scan_multiplies():
    w = jax.ShapeDtypeStruct((D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def nested(w, x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            y, _ = jax.lax.scan(inner, c, None, length=5)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    c1 = analyze(_compile_text(lambda w, x: x @ w, w, x))
    c = analyze(_compile_text(nested, w, x))
    assert c.flops / c1.flops == pytest.approx(20.0, rel=0.05)


def test_sliced_cache_reads_slice_not_buffer():
    """A scan reading per-step slices of a big stacked buffer must charge
    slice-sized reads, not the whole buffer per step."""
    big = jax.ShapeDtypeStruct((64, 1024, 16), jnp.float32)  # 4 MB
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def f(big, x):
        def body(c, sl):                      # sl: (1024, 16) slice
            return c + sl[:16, :], None
        y, _ = jax.lax.scan(body, x, big)
        return y

    c = analyze(_compile_text(f, big, x))
    total_buffer = 64 * 1024 * 16 * 4
    # each step should read ~a slice (64 KiB), so total ~= one full pass,
    # NOT 64 x full buffer
    assert c.bytes < 12 * total_buffer, (c.bytes, total_buffer)


def test_elementwise_counted_once_per_element():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = analyze(_compile_text(lambda x: x + 1.0, x))
    assert c.flops == pytest.approx(1024 * 1024, rel=0.2)


def test_no_collectives_on_single_device():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = analyze(_compile_text(lambda x: x * 2, x))
    assert c.collective_bytes == 0
    assert c.collective_count == 0
