"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("m", [1, 7, 128, 1000, 8192, 10000])
@pytest.mark.parametrize("len_scale", [1.0, 32.0])
def test_dict_newton_sweep(m, len_scale):
    ndv = RNG.integers(1, 1_000_000, m).astype(np.float64)
    rows = ndv * RNG.uniform(1.5, 80, m)
    nulls = (rows * RNG.uniform(0, 0.2, m))
    mean_len = RNG.uniform(1, 8, m) * len_scale
    bits = np.maximum(np.ceil(np.log2(np.maximum(ndv, 1)) - 1e-9), 1)
    S = ndv * mean_len + (rows - nulls) * bits / 8

    args = [jnp.asarray(a, jnp.float32) for a in (S, rows, nulls, mean_len)]
    k = np.asarray(ops.dict_newton(*args))
    r = np.asarray(ops.dict_newton(*args, backend="ref"))
    np.testing.assert_allclose(k, r, rtol=1e-4)
    rel = np.abs(k - ndv) / ndv
    assert np.quantile(rel, 0.99) < 0.02


@pytest.mark.parametrize("m", [1, 65, 4096])
def test_coupon_newton_sweep(m):
    n = RNG.integers(2, 4096, m).astype(np.float32)
    D = RNG.uniform(1, 1e6, m).astype(np.float32)
    obs = D * (1 - np.exp(-n / D))
    k = np.asarray(ops.coupon_newton(jnp.asarray(obs), jnp.asarray(n)))
    r = np.asarray(ops.coupon_newton(jnp.asarray(obs), jnp.asarray(n), backend="ref"))
    np.testing.assert_allclose(k, r, rtol=1e-3)


@pytest.mark.parametrize("b,r", [(1, 2), (3, 17), (32, 250), (65, 513)])
def test_minmax_scan_sweep(b, r):
    mins = RNG.normal(size=(b, r)).astype(np.float32)
    maxs = mins + np.abs(RNG.normal(size=(b, r))).astype(np.float32)
    valid = RNG.uniform(size=(b, r)) < 0.85
    k = ops.minmax_scan(jnp.asarray(mins), jnp.asarray(maxs), jnp.asarray(valid))
    o = ops.minmax_scan(
        jnp.asarray(mins), jnp.asarray(maxs), jnp.asarray(valid), backend="ref"
    )
    for f in ("overlap_sum", "gmin", "gmax", "sign_changes", "n_valid", "shared_bounds"):
        np.testing.assert_allclose(
            np.asarray(getattr(k, f)), np.asarray(getattr(o, f)),
            rtol=1e-5, atol=1e-5, err_msg=f,
        )


@pytest.mark.parametrize("b,r,p", [(2, 64, 6), (8, 128, 8), (17, 300, 8), (4, 1024, 10)])
def test_hll_fold_sweep(b, r, p):
    keys = RNG.integers(0, 2**32, size=(b, r), dtype=np.uint32)
    valid = RNG.uniform(size=(b, r)) < 0.9
    k = np.asarray(ops.hll_fold(jnp.asarray(keys), jnp.asarray(valid), p=p))
    o = np.asarray(ops.hll_fold(jnp.asarray(keys), jnp.asarray(valid), p=p, backend="ref"))
    assert np.array_equal(k, o)


def test_hll_count_accuracy():
    b, r = 16, 2048
    keys = RNG.integers(0, 2**32, size=(b, r), dtype=np.uint32)
    valid = np.ones((b, r), bool)
    regs = ops.hll_fold(jnp.asarray(keys), jnp.asarray(valid), p=10)
    est = np.asarray(ops.hll_count(regs))
    true = np.array([len(np.unique(keys[i])) for i in range(b)])
    rel = np.abs(est - true) / true
    # sigma ~ 1.04/sqrt(1024) ~ 3.3%; allow 4 sigma
    assert np.max(rel) < 0.14, rel


def test_tile_geometry_memoized_per_shape():
    """Pad geometry is computed once per flat length, not once per call."""
    from repro.kernels import newton_ndv as nk

    nk._tile_geometry.cache_clear()
    for _ in range(5):
        padded, tile_rows = nk._tile_geometry(777)
    assert padded % (nk.BLOCK_M * nk.LANES) == 0
    assert tile_rows == padded // nk.LANES
    info = nk._tile_geometry.cache_info()
    assert info.misses == 1
    assert info.hits == 4


def test_repeated_same_shape_newton_calls_do_not_retrace(monkeypatch):
    """`_pad_to_tiles` runs only at trace time, so its call count counts
    traces: a second same-shape `dict_newton` call must add zero."""
    from repro.kernels import newton_ndv as nk

    calls = []
    orig = nk._pad_to_tiles

    def counting(x, fill):
        calls.append(x.shape)
        return orig(x, fill)

    monkeypatch.setattr(nk, "_pad_to_tiles", counting)
    m = 731  # unlikely to be warm in this process's jit cache
    args = [jnp.asarray(RNG.uniform(1, 100, m), jnp.float32) for _ in range(4)]
    first = np.asarray(nk.dict_newton(*args))
    traces_after_first = len(calls)
    second = np.asarray(nk.dict_newton(*args))
    assert len(calls) == traces_after_first
    assert np.array_equal(first, second)


def test_estimator_matches_kernel_path():
    """core dict inversion == kernel dict_newton on the same metadata."""
    from repro.core.ndv import dict_inversion

    ndv = RNG.integers(2, 100000, 512).astype(np.float64)
    rows = ndv * RNG.uniform(2, 40, 512)
    ln = RNG.uniform(2, 30, 512)
    bits = np.maximum(np.ceil(np.log2(ndv) - 1e-9), 1)
    S = ndv * ln + rows * bits / 8
    a = [jnp.asarray(x, jnp.float32) for x in (S, rows, np.zeros(512), ln)]
    core = np.asarray(dict_inversion.invert_dict_size(*a).ndv)
    kern = np.asarray(ops.dict_newton(*a))
    np.testing.assert_allclose(core, kern, rtol=5e-3)
